"""Table III: succinct-trie comparison — bST vs LOUDS-trie vs FST-style,
search time per query (τ = 1..5) and index space.

Paper's claims reproduced *relatively*: bST is faster (up to ~6x vs
LOUDS, ~4x vs FST on Review/CP) and smaller (~2.6x vs LOUDS, ~1.9x vs
FST).  Here all three run the same level-synchronous traversal; the
encodings differ exactly as in the paper, so time differences isolate
encoding overhead (select0-based LOUDS child ranges vs rank/select
TABLE/LIST vs zero-cost dense + collapsed tail) and space reflects the
per-level bit costs."""

from __future__ import annotations

import numpy as np

from repro.core.bst import build_bst, build_fst_style, build_louds
from repro.core.search import make_batch_searcher
from repro.core.trie_builder import build_trie_levels

from .common import Csv, make_dataset, timeit


def run(csv: Csv, datasets=("review", "cp")) -> None:
    for name in datasets:
        cfg, db, queries = make_dataset(name)
        trie = build_trie_levels(db, cfg.b)
        variants = {
            "bST": build_bst(db, cfg.b, trie=trie),
            "LOUDS": build_louds(db, cfg.b, trie=trie),
            "FST": build_fst_style(db, cfg.b, trie=trie),
        }
        space = {}
        for vname, index in variants.items():
            mib = index.model_bits() / 8 / 2**20
            space[vname] = mib
            csv.add(f"table3/{name}/space/{vname}", 0.0, f"MiB={mib:.2f}")
            for tau in (1, 3, 5):
                searcher = make_batch_searcher(index, tau)
                t = timeit(searcher, queries)
                per_q_ms = t / queries.shape[0] * 1e3
                csv.add(f"table3/{name}/tau{tau}/{vname}",
                        per_q_ms * 1e3, f"ms_per_query={per_q_ms:.3f}")
        # paper claim: bST smallest
        assert space["bST"] < space["FST"] < space["LOUDS"] * 1.2, space
        csv.add(f"table3/{name}/ratio", 0.0,
                f"louds_over_bst={space['LOUDS'] / space['bST']:.2f}x;"
                f"fst_over_bst={space['FST'] / space['bST']:.2f}x")


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
