"""Streaming-ingest benchmark for the dynamic segmented index
(DESIGN.md §4): inserts/sec while the LSM-style stack seals and merges
segments, the cost of a forced merge, and query latency mid-stream vs
post-merge.

Rows:
  * ``ingest/<ds>/insert``        — amortized µs per inserted sketch over
                                    the whole stream (incl. flush/merge),
                                    derived inserts/sec
  * ``ingest/<ds>/delete``        — µs per tombstoned id
  * ``ingest/<ds>/merge``         — one forced two-segment merge
  * ``ingest/<ds>/query_mid``     — batched topk with a live delta buffer
  * ``ingest/<ds>/query_postmerge`` — batched topk after merge+compact
  * ``ingest/<ds>/sweep_seg{1,4,16}`` — fixed-corpus segment-count sweep:
                                    batched topk µs/query and device
                                    dispatches per query at 1/4/16 sealed
                                    segments — the fused arena path must
                                    keep both flat (DESIGN.md §6); the
                                    non-smoke run asserts it
  * ``ingest/<ds>/capacity_{suffix,full}`` — fixed-corpus device/host
                                    bytes per sealed row, tiered suffix
                                    store vs full-length arena; asserts
                                    the suffix layout at least halves
                                    device column bytes (DESIGN.md §7)
  * ``ingest/<ds>/tier_{hot,half,cold}`` — hot-budget sweep: column
                                    bytes migrate to the host tier at an
                                    unchanged fused dispatch count
  * ``ingest/<ds>/wal_{off,on}``  — the same insert stream ephemeral vs
                                    journaled (delta WAL + segment
                                    snapshots, fsync-batched); the
                                    non-smoke run asserts the durable
                                    path keeps > half the ephemeral
                                    inserts/sec (DESIGN.md §8)
  * ``ingest/<ds>/wal_overhead``  — the ratio ips_off / ips_on plus the
                                    journal/snapshot bytes it bought

Correctness ride-along (every mode, incl. --smoke): the post-merge top-k
must be bit-identical to a fresh static build over the survivors."""

from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from repro.core import (SegmentedIndex, build_bst, dispatch_stats,
                        reset_dispatch_stats, topk_batch)
from repro.store import CollectionStore

from . import common
from .common import Csv, cap_n, make_dataset, timeit


def run(csv: Csv, datasets=("review",), k: int = 10) -> None:
    for name in datasets:
        cfg, db, queries = make_dataset(name, n=cap_n(1 << 15))
        n = len(db)
        chunk = max(64, n // 64)
        idx = SegmentedIndex(cfg.L, cfg.b, delta_cap=max(256, n // 8))

        t0 = time.perf_counter()
        ids = np.zeros((0,), np.int64)
        for lo in range(0, n, chunk):
            ids = np.concatenate([ids, idx.insert(db[lo:lo + chunk])])
        dt = time.perf_counter() - t0
        csv.add(f"ingest/{name}/insert", dt * 1e6 / n,
                f"ips={n / dt:.0f};segments={len(idx.segments)}")

        rng = np.random.default_rng(2)
        victims = ids[rng.choice(n, n // 10, replace=False)]
        t0 = time.perf_counter()
        removed = idx.delete(victims)
        dt = time.perf_counter() - t0
        csv.add(f"ingest/{name}/delete", dt * 1e6 / max(removed, 1),
                f"removed={removed}")

        # mid-stream query latency: delta buffer + segments answer together
        qs = queries[: min(8, len(queries))]
        nn_mid = idx.topk_batch(qs, k)   # warm + capture tau once
        t_mid = timeit(lambda: idx.topk_batch(qs, k))
        csv.add(f"ingest/{name}/query_mid", t_mid * 1e6 / len(qs),
                f"tau={nn_mid.tau}")

        # a controlled two-segment merge (auto-merge may have collapsed
        # the streaming stack already, so measure on a fresh two-half
        # stack: one n/2 + n/2 -> n rebuild via build_trie_levels)
        idx2 = SegmentedIndex(cfg.L, cfg.b, delta_cap=n + 1,
                              auto_merge=False)
        idx2.insert(db[: n // 2])
        idx2.flush()
        idx2.insert(db[n // 2:])
        idx2.flush()
        t0 = time.perf_counter()
        assert idx2.merge()
        dt = time.perf_counter() - t0
        csv.add(f"ingest/{name}/merge", dt * 1e6,
                f"rows={n};rows_per_s={n / dt:.0f}")

        idx.flush()
        idx.maybe_merge()
        idx.compact(min_dead_frac=0.0)

        t_post = timeit(lambda: idx.topk_batch(qs, k))
        csv.add(f"ingest/{name}/query_postmerge", t_post * 1e6 / len(qs),
                f"segments={len(idx.segments)};"
                f"space_KiB={idx.space_bits() / 8 / 1024:.1f}")

        # correctness ride-along: post-merge == fresh static build
        surv = np.ones(n, bool)
        surv[victims] = False
        surv_ids = np.flatnonzero(surv)
        static = topk_batch(build_bst(db[surv], cfg.b), qs, k)
        mapped = np.where(np.asarray(static.ids) >= 0,
                          surv_ids[np.maximum(np.asarray(static.ids), 0)], -1)
        dyn = idx.topk_batch(qs, k)
        np.testing.assert_array_equal(np.asarray(dyn.dists),
                                      np.asarray(static.dists))
        np.testing.assert_array_equal(np.asarray(dyn.ids), mapped)

        # segment-count sweep (fixed corpus): the fused arena must keep
        # query latency AND dispatch count flat in n_segments
        n_sweep = min(n, cap_n(1 << 12))
        sweep_t = {}
        for n_seg in (1, 4, 16):
            sw = SegmentedIndex(cfg.L, cfg.b, delta_cap=n_sweep + 1,
                                auto_merge=False)
            chunk = n_sweep // n_seg
            for lo in range(0, n_seg * chunk, chunk):
                sw.insert(db[lo:lo + chunk])
                sw.flush()
            assert len(sw.segments) == n_seg
            nn = sw.topk_batch(qs, k)         # warm (arena + compiles)
            reset_dispatch_stats()
            sw.topk_batch(qs, k)
            disp = dispatch_stats()["total"]
            t_q = timeit(lambda: sw.topk_batch(qs, k))
            sweep_t[n_seg] = t_q
            csv.add(f"ingest/{name}/sweep_seg{n_seg}",
                    t_q * 1e6 / len(qs),
                    f"segments={n_seg};dispatches={disp};tau={nn.tau};"
                    f"rows={n_sweep}")
        if not common.SMOKE:
            # flat, not linear: 16 segments may not cost 16x one segment
            assert sweep_t[16] < 6 * sweep_t[1], sweep_t

        # capacity: tiered suffix column store vs the full-length arena
        # on the same fixed corpus — device/host bytes per sealed row
        # (DESIGN.md §7); the packed suffix must at least halve the
        # device column bytes on every geometry with b*(L - l_s) <= 32
        n_cap = min(n, cap_n(1 << 12))
        cap_chunk = max(16, n_cap // 4)           # 4 sealed segments
        cap_kw = dict(delta_cap=n_cap + 1, auto_merge=False)
        col_bytes = {}
        for layout in ("suffix", "full"):
            ci = SegmentedIndex(cfg.L, cfg.b, layout=layout, **cap_kw)
            for lo in range(0, n_cap, cap_chunk):
                ci.insert(db[lo:lo + cap_chunk])
                ci.flush()
            ci.topk_batch(qs, k)              # builds the store + warms
            st = ci.stats()
            rows = sum(seg.n for seg in ci.segments)
            store = ci._arena
            col_bytes[layout] = store.col_bytes()
            t_q = timeit(lambda: ci.topk_batch(qs, k))
            csv.add(f"ingest/{name}/capacity_{layout}",
                    t_q * 1e6 / len(qs),
                    f"rows={rows};"
                    f"bytes_per_row_device={store.col_bytes('hot') / rows:.2f};"
                    f"bytes_per_row_host={store.host_bytes() / rows:.2f};"
                    f"device_KiB={st['device_bytes'] / 1024:.1f};"
                    f"host_KiB={st['host_bytes'] / 1024:.1f}")
        assert col_bytes["full"] >= 2 * col_bytes["suffix"], col_bytes

        # cold-tier sweep: shrink the hot budget full -> half -> zero;
        # column bytes migrate to host while the query path must stay at
        # the same fused dispatch count (staging is a transfer, not a
        # launch)
        disp_by_tag = {}
        for frac, tag in ((1.0, "hot"), (0.5, "half"), (0.0, "cold")):
            ti = SegmentedIndex(cfg.L, cfg.b, layout="suffix",
                                hot_bytes=int(col_bytes["suffix"] * frac),
                                **cap_kw)
            for lo in range(0, n_cap, cap_chunk):
                ti.insert(db[lo:lo + cap_chunk])
                ti.flush()
            ti.topk_batch(qs, k)              # warm (stage + compiles)
            reset_dispatch_stats()
            ti.topk_batch(qs, k)
            disp = dispatch_stats()
            disp_by_tag[tag] = disp["total"]
            assert disp["fanout"] == 0, disp
            rows = sum(seg.n for seg in ti.segments)
            store = ti._arena
            t_q = timeit(lambda: ti.topk_batch(qs, k))
            csv.add(f"ingest/{name}/tier_{tag}", t_q * 1e6 / len(qs),
                    f"hot_bytes={int(col_bytes['suffix'] * frac)};"
                    f"dispatches={disp['total']};"
                    f"bytes_per_row_device="
                    f"{store.col_bytes('hot') / rows:.2f};"
                    f"bytes_per_row_host={store.host_bytes() / rows:.2f}")
        assert disp_by_tag["cold"] == disp_by_tag["hot"], disp_by_tag

        # durability overhead: identical insert stream, ephemeral vs
        # journaled (delta WAL + segment snapshots, default fsync batch).
        # Acceptance (DESIGN.md §8): the durable path keeps more than
        # half the ephemeral inserts/sec — fsync batching amortizes the
        # syscall cost across delta_cap-sized flush windows.
        n_wal = min(n, cap_n(1 << 13))
        wal_chunk = max(64, n_wal // 64)
        ips = {}
        for tag in ("wal_off", "wal_on"):
            wi = SegmentedIndex(cfg.L, cfg.b,
                                delta_cap=max(256, n_wal // 8))
            tmpd = store_d = None
            if tag == "wal_on":
                tmpd = tempfile.mkdtemp(prefix="bench_wal_")
                store_d = CollectionStore(tmpd)
                store_d.attach(wi)
            t0 = time.perf_counter()
            for lo in range(0, n_wal, wal_chunk):
                wi.insert(db[lo:lo + wal_chunk])
            if store_d is not None:
                store_d.wal.sync()        # durable path pays its fsync
            dt = time.perf_counter() - t0
            ips[tag] = n_wal / dt
            extra = f"ips={ips[tag]:.0f};rows={n_wal}"
            if store_d is not None:
                sst = store_d.stats()
                extra += (f";wal_KiB={sst['wal_bytes'] / 1024:.1f}"
                          f";snap_KiB={sst['snapshot_bytes'] / 1024:.1f}"
                          f";truncations={sst['wal_truncations']}")
                store_d.close()
                shutil.rmtree(tmpd, ignore_errors=True)
            csv.add(f"ingest/{name}/{tag}", dt * 1e6 / n_wal, extra)
        csv.add(f"ingest/{name}/wal_overhead",
                ips["wal_off"] / ips["wal_on"],
                f"ips_off={ips['wal_off']:.0f};ips_on={ips['wal_on']:.0f}")
        if not common.SMOKE:
            assert 2 * ips["wal_on"] > ips["wal_off"], ips
