"""Table II: average number of solutions per τ — validates that the
synthetic workload yields substantial solution sets, matching the paper's
qualitative setup (solutions grow ~exponentially with τ)."""

from __future__ import annotations

import numpy as np

from repro.core.bst import build_bst
from repro.core.search import make_batch_searcher

from .common import Csv, make_dataset, timeit


def run(csv: Csv, datasets=("review", "gist")) -> None:
    for name in datasets:
        cfg, db, queries = make_dataset(name)
        index = build_bst(db, cfg.b)
        counts = []
        for tau in range(1, 6):
            searcher = make_batch_searcher(index, tau)
            res = searcher(queries)
            avg = float(np.asarray(res.mask).sum(axis=1).mean())
            counts.append(avg)
            csv.add(f"table2/{name}/tau{tau}", 0.0, f"avg_solutions={avg:.1f}")
        # the paper's qualitative claim: |I| grows strongly with tau
        assert counts[-1] > counts[0], (name, counts)


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
