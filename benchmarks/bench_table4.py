"""Table IV: space usage of the similarity-search methods, measured on
the scaled DBs AND extrapolated analytically to the paper's billion-scale
n — checking the headline claim (SI-bST ~10 GiB vs SIH ~32/29 GiB on
SIFT at n = 10^9)."""

from __future__ import annotations

import numpy as np

from repro.configs.registry import PAPER_DATASETS
from repro.core.baselines import MIH, SIH, HmSearch
from repro.core.bst import build_bst
from repro.core.multi_index import build_multi_index

from .common import Csv, make_dataset


def run(csv: Csv, datasets=("review", "sift")) -> None:
    for name in datasets:
        cfg, db, _ = make_dataset(name)
        n_scaled = db.shape[0]
        sizes = {
            "SI-bST": build_bst(db, cfg.b).array_bytes(),
            "MI-bST": build_multi_index(db, cfg.b, m=2).array_bytes(),
            "SIH": SIH.build(db, cfg.b).array_bytes(),
            "MIH": MIH.build(db, cfg.b, m=2).array_bytes(),
            "HmSearch": HmSearch.build(db, cfg.b, 3).array_bytes(),
        }
        for k, v in sizes.items():
            csv.add(f"table4/{name}/{k}", 0.0,
                    f"MiB={v / 2**20:.1f};bytes_per_sketch={v / n_scaled:.1f}")
        assert sizes["SI-bST"] == min(sizes.values()), sizes

        # analytic billion-scale extrapolation: bytes/sketch held fixed
        n_full = PAPER_DATASETS[name].n
        for k in ("SI-bST", "SIH"):
            gib = sizes[k] / n_scaled * n_full / 2**30
            csv.add(f"table4/{name}/extrapolated/{k}", 0.0,
                    f"GiB_at_n={n_full}={gib:.1f}")
        ratio = sizes["SIH"] / sizes["SI-bST"]
        csv.add(f"table4/{name}/ratio", 0.0, f"sih_over_bst={ratio:.2f}x")


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
