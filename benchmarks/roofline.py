"""Roofline table assembler: reads the dry-run JSON cache and renders the
per-(arch x shape x mesh) three-term table for EXPERIMENTS.md §Roofline."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_records(results_dir: str = RESULTS_DIR) -> List[dict]:
    records = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            records.append(json.load(f))
    return records


def fmt_row(r: dict) -> str:
    if r.get("status") != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} | "
                f"ERROR: {r.get('error','')[:60]} | | | | | |")
    roof = r["roofline"]
    mem = r.get("memory", {})
    return ("| {arch} | {shape} | {mesh} | {tc:.4f} | {tm:.4f} | {tcoll:.4f} "
            "| {bn} | {uf:.2f} | {gb:.1f} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        tc=roof["t_compute_s"], tm=roof["t_memory_s"],
        tcoll=roof["t_collective_s"], bn=roof["bottleneck"],
        uf=roof.get("useful_flops_ratio", 0.0),
        gb=mem.get("total_bytes", 0) / 1e9)


def render_table(records: List[dict], mesh: Optional[str] = None) -> str:
    head = ("| arch | shape | mesh | T_comp (s) | T_mem (s) | T_coll (s) "
            "| bottleneck | useful-FLOPs | bytes/dev (GB) |\n"
            "|---|---|---|---|---|---|---|---|---|")
    rows = [fmt_row(r) for r in records
            if mesh is None or r.get("mesh") == mesh]
    return "\n".join([head] + rows)


def run(csv=None) -> None:
    records = load_records()
    ok = [r for r in records if r.get("status") == "ok"]
    err = [r for r in records if r.get("status") != "ok"]
    print(render_table(records))
    print(f"\n{len(ok)} ok, {len(err)} errors")
    if csv is not None:
        for r in ok:
            roof = r["roofline"]
            csv.add(f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}", 0.0,
                    f"Tc={roof['t_compute_s']:.4f};Tm={roof['t_memory_s']:.4f};"
                    f"Tcoll={roof['t_collective_s']:.4f};"
                    f"bottleneck={roof['bottleneck']}")


if __name__ == "__main__":
    run()
