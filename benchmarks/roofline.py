"""Roofline table assembler: reads the dry-run JSON cache and renders the
per-(arch x shape x mesh) three-term table for EXPERIMENTS.md §Roofline,
plus the analytic arithmetic-intensity model of the query-tiled verify
kernel — the "why" behind BLOCK_M batching."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


# ---------------------------------------------------------------------------
# verify-kernel arithmetic intensity as a function of BLOCK_M
# ---------------------------------------------------------------------------

def verify_intensity(block_m: int, block_n: int = 2048, b: int = 4,
                     W: int = 1) -> Dict[str, float]:
    """Int-ops and HBM bytes of one (BLOCK_M, BLOCK_N) grid cell of
    ``sparse_verify_batch_pallas``.

    Bytes: the (b, W, BLOCK_N) db block is loaded ONCE per cell and
    amortized over BLOCK_M queries; the query tile, base-distance plane,
    and the two output planes scale with BLOCK_M.  Ops per (query, lane):
    b XORs + (b-1) ORs over W words, W popcounts, (W-1)+1 adds (word sum
    + base add), 1 compare, 1 min.  At BLOCK_M=1 this is the original
    ~1.5 int-ops/byte memory-bound scan; intensity grows ~linearly with
    BLOCK_M until the per-query planes dominate the byte count."""
    db_bytes = b * W * block_n * 4
    q_bytes = b * W * block_m * 4
    plane_bytes = block_m * block_n * 4          # base in, mask out, dist out
    bytes_total = db_bytes + q_bytes + 3 * plane_bytes
    ops_per_pair = (b * W) + (b - 1) * W + W + W + 2
    ops_total = block_m * block_n * ops_per_pair
    return {"ops": float(ops_total), "bytes": float(bytes_total),
            "intensity": ops_total / bytes_total,
            "db_streams_per_batch": 1.0 / block_m}


def render_intensity_table(block_ms=(1, 2, 4, 8, 16, 32, 64),
                           block_n: int = 2048, b: int = 4,
                           W: int = 1) -> str:
    head = (f"| BLOCK_M | int-ops/cell | HBM bytes/cell | intensity "
            f"(ops/byte) | db streams per m queries |\n|---|---|---|---|---|")
    rows = []
    for bm in block_ms:
        r = verify_intensity(bm, block_n=block_n, b=b, W=W)
        rows.append(f"| {bm} | {r['ops']:.0f} | {r['bytes']:.0f} | "
                    f"{r['intensity']:.2f} | m/{bm} |")
    return "\n".join([head] + rows)


def load_records(results_dir: str = RESULTS_DIR) -> List[dict]:
    records = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            records.append(json.load(f))
    return records


def fmt_row(r: dict) -> str:
    if r.get("status") != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} | "
                f"ERROR: {r.get('error','')[:60]} | | | | | |")
    roof = r["roofline"]
    mem = r.get("memory", {})
    return ("| {arch} | {shape} | {mesh} | {tc:.4f} | {tm:.4f} | {tcoll:.4f} "
            "| {bn} | {uf:.2f} | {gb:.1f} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        tc=roof["t_compute_s"], tm=roof["t_memory_s"],
        tcoll=roof["t_collective_s"], bn=roof["bottleneck"],
        uf=roof.get("useful_flops_ratio", 0.0),
        gb=mem.get("total_bytes", 0) / 1e9)


def render_table(records: List[dict], mesh: Optional[str] = None) -> str:
    head = ("| arch | shape | mesh | T_comp (s) | T_mem (s) | T_coll (s) "
            "| bottleneck | useful-FLOPs | bytes/dev (GB) |\n"
            "|---|---|---|---|---|---|---|---|---|")
    rows = [fmt_row(r) for r in records
            if mesh is None or r.get("mesh") == mesh]
    return "\n".join([head] + rows)


def run(csv=None) -> None:
    records = load_records()
    ok = [r for r in records if r.get("status") == "ok"]
    err = [r for r in records if r.get("status") != "ok"]
    print(render_table(records))
    print(f"\n{len(ok)} ok, {len(err)} errors")
    print("\n# verify-kernel arithmetic intensity vs BLOCK_M "
          "(b=4, W=1, BLOCK_N=2048):")
    print(render_intensity_table())
    if csv is not None:
        for r in ok:
            roof = r["roofline"]
            csv.add(f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}", 0.0,
                    f"Tc={roof['t_compute_s']:.4f};Tm={roof['t_memory_s']:.4f};"
                    f"Tcoll={roof['t_collective_s']:.4f};"
                    f"bottleneck={roof['bottleneck']}")
        base = verify_intensity(1)["intensity"]
        for bm in (1, 8, 64):
            r = verify_intensity(bm)
            csv.add(f"roofline/verify_intensity/bm{bm}", 0.0,
                    f"ops_per_byte={r['intensity']:.2f};"
                    f"gain_vs_bm1={r['intensity'] / base:.2f}x;"
                    f"db_streams=m/{bm}")
        # intensity must grow with the query tile — the why of the kernel
        # (saturates near ops/12-bytes once the per-query base/mask/dist
        # planes dominate; the db-stream term keeps falling as m/BLOCK_M)
        assert (verify_intensity(8)["intensity"]
                > 1.8 * verify_intensity(1)["intensity"])


if __name__ == "__main__":
    run()
