"""§V-C preliminary experiment: vertical-format bit-parallel Hamming vs
the naive per-character loop (paper: >10x on 32-dim 4-bit sketches), plus
the Pallas kernel path (interpret mode on CPU — the BlockSpec tiling is
the TPU artifact, validated for correctness here and in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hamming import (hamming_naive, hamming_vertical,
                                pack_vertical)
from repro.kernels import ops

from . import common
from .common import Csv, timeit


def run(csv: Csv) -> None:
    rng = np.random.default_rng(0)
    n, L, b = common.cap_n(1 << 18), 32, 4
    db = rng.integers(0, 1 << b, size=(n, L), dtype=np.uint8)
    q = rng.integers(0, 1 << b, size=(L,), dtype=np.uint8)

    db_j = jnp.asarray(db)
    q_j = jnp.asarray(q)
    naive = jax.jit(hamming_naive)
    t_naive = timeit(naive, db_j, q_j)

    planes = jnp.asarray(pack_vertical(db, b))       # (n, b, W)
    q_planes = jnp.asarray(pack_vertical(q[None], b)[0])
    vert = jax.jit(hamming_vertical)
    t_vert = timeit(vert, planes, q_planes)

    db_lane = jnp.asarray(np.transpose(pack_vertical(db, b), (1, 2, 0)).copy())
    q_lane = jnp.asarray(np.transpose(pack_vertical(q[None], b), (1, 2, 0)).copy())
    t_kernel = timeit(lambda: ops.hamming_distances(db_lane, q_lane))

    csv.add("vertical/naive", t_naive * 1e6, f"n={n};L={L};b={b}")
    csv.add("vertical/vertical", t_vert * 1e6,
            f"speedup_vs_naive={t_naive / t_vert:.1f}x")
    csv.add("vertical/pallas_interpret", t_kernel * 1e6,
            "CPU interpret mode; TPU perf is the BlockSpec design")
    if not common.SMOKE:  # timing claim is noise at smoke shapes
        assert t_vert < t_naive, (t_vert, t_naive)


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
