"""Figure 7: similarity-search method comparison — SI-bST, MI-bST, SIH,
MIH, HmSearch — average search time per query across τ.

Hardware-adaptation caveat (DESIGN.md §2): the paper's figure compares
CPU wall-clock of a pointer DFS against CPU hash tables; our bST is the
*TPU-shaped* level-synchronous traversal, which on this 1-core container
pays static-shape overheads a hash probe does not.  The TRANSFERABLE
claims — asserted here — are the scaling ones: (i) SIH's signature
enumeration explodes with τ and b (the paper's 10 s timeout; we cap at
200k signatures), while bST search time stays flat; (ii) SI-bST beats
SIH at moderate τ; (iii) MI-bST stays competitive at τ=5.

Frontier capacities use the expected-case ladder: start tight, double on
overflow (exactness preserved — the same discipline as core.search)."""

from __future__ import annotations

import numpy as np

from repro.core.baselines import MIH, SIH, HmSearch, LinearScan
from repro.core.bst import build_bst
from repro.core.multi_index import build_multi_index, make_mi_searcher
from repro.core.search import make_batch_searcher

from . import common
from .common import Csv, make_dataset, timeit

SIG_LIMIT = 200_000   # stands in for the paper's 10 s/query abort


def _ladder_searcher(index, queries, tau, cap0=512, cap_hi=1 << 17):
    """Smallest-capacity searcher with zero overflow on this query set."""
    cap = cap0
    while True:
        searcher = make_batch_searcher(index, tau, cap_max=cap)
        res = searcher(queries)
        if int(np.asarray(res.overflow).sum()) == 0 or cap >= cap_hi:
            return searcher
        cap *= 4


def run(csv: Csv, datasets=("review", "sift")) -> None:
    for name in datasets:
        cfg, db, queries_np = make_dataset(name)
        import jax.numpy as jnp
        queries = jnp.asarray(queries_np)
        si = build_bst(db, cfg.b)
        mi = build_multi_index(db, cfg.b, m=2)
        sih = SIH.build(db, cfg.b)
        mih = MIH.build(db, cfg.b, m=2)
        hms = {t: HmSearch.build(db, cfg.b, t) for t in (1, 3, 5)}
        results = {}
        for tau in (1, 3, 5):
            row = {}
            s1 = _ladder_searcher(si, queries, tau)
            row["SI-bST"] = timeit(s1, queries) / len(queries)
            s2 = make_mi_searcher(mi, tau)
            row["MI-bST"] = timeit(
                lambda qs: [s2(q) for q in qs], queries) / len(queries)

            def sih_all(qs):
                return [sih.search(q, tau, limit=SIG_LIMIT) for q in qs]
            t = timeit(sih_all, queries_np, repeats=1)
            trunc = any(tr for _, tr in sih_all(queries_np))
            row["SIH"] = t / len(queries)
            row["SIH_truncated"] = trunc

            def mih_all(qs):
                return [mih.search(q, tau, limit=SIG_LIMIT) for q in qs]
            row["MIH"] = timeit(mih_all, queries_np, repeats=1) / len(queries)

            hm = hms[tau]
            def hm_all(qs):
                return [hm.search(q, tau) for q in qs]
            row["HmSearch"] = timeit(hm_all, queries_np, repeats=1) / len(queries)

            for k, v in row.items():
                if k == "SIH_truncated":
                    continue
                suffix = ";TRUNCATED" if (k == "SIH" and trunc) else ""
                csv.add(f"fig7/{name}/tau{tau}/{k}", v * 1e6,
                        f"ms_per_query={v * 1e3:.3f}{suffix}")
            results[tau] = row

        # Transferable paper claims (see module docstring).  Cross-family
        # absolute wall-clock (vectorized traversal vs host hash probe on
        # one CPU core) is reported but NOT asserted.  Timing-relational
        # claims are meaningless at --smoke shapes and skipped there.
        if common.SMOKE:
            continue
        # (i) bST search time is flat in τ ...
        assert results[5]["SI-bST"] < 5 * results[1]["SI-bST"], results
        # ... while SIH's signature enumeration explodes (or hits the cap,
        # the analogue of the paper's 10 s abort)
        assert (results[5]["SIH_truncated"]
                or results[5]["SIH"] > 5 * results[1]["SIH"]), results
        # (ii) within our family, MI-bST is the τ=5 configuration
        # (paper: "For τ=5, MI-bST can be used instead of SI-bST")
        assert results[5]["MI-bST"] < results[5]["SI-bST"], results[5]


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
