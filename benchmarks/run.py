"""Benchmark orchestrator: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick]`` prints
``name,us_per_call,derived`` CSV rows (plus the roofline table from the
dry-run cache if present).  ``--out BENCH_<name>.json`` additionally
writes every row machine-readable (name, us_per_call, parsed derived
k=v config) — the perf-trajectory artifact CI uploads per run."""

from __future__ import annotations

import argparse
import json
import platform
import sys
import traceback

import jax

from . import (bench_batch, bench_fig7, bench_fig8, bench_ingest,
               bench_serving, bench_table2, bench_table3, bench_table4,
               bench_topk, bench_vertical, common, roofline)
from .common import Csv


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller datasets / skip slow suites")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-shape anti-bitrot mode (CI): every suite "
                         "executes end to end; perf-relational assertions "
                         "are skipped")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names (table2,table3,...)")
    ap.add_argument("--out", default=None, metavar="BENCH_<name>.json",
                    help="write machine-readable results (per-row "
                         "QPS/latency + parsed config) to this JSON file")
    args = ap.parse_args(argv)
    if args.smoke:
        common.set_smoke()
    quick = args.quick or args.smoke

    suites = {
        "fig8": lambda c: bench_fig8.run(c),
        "table2": lambda c: bench_table2.run(
            c, datasets=("review",) if quick else ("review", "gist")),
        "vertical": lambda c: bench_vertical.run(c),
        "table3": lambda c: bench_table3.run(
            c, datasets=("review",) if quick else ("review", "cp")),
        "table4": lambda c: bench_table4.run(
            c, datasets=("review",) if quick else ("review", "sift")),
        "fig7": lambda c: bench_fig7.run(
            c, datasets=("review",) if quick else ("review", "sift")),
        "topk": lambda c: bench_topk.run(
            c, datasets=("review",) if quick else ("review", "sift"),
            ks=(1, 10) if quick else (1, 10, 100)),
        "batch": lambda c: bench_batch.run(
            c, datasets=("review",),
            ms=(1, 8) if args.smoke else (1, 8, 64) if args.quick
            else (1, 8, 64, 256)),
        "ingest": lambda c: bench_ingest.run(c, datasets=("review",)),
        "serving": lambda c: bench_serving.run(
            c, datasets=("review",),
            clients=4 if quick else 8,
            ops_per_client=10 if quick else 40),
        "roofline": lambda c: roofline.run(c),
    }
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    csv = Csv()
    csv.header()
    failures = []
    for name, fn in suites.items():
        print(f"# --- {name} ---", flush=True)
        try:
            fn(csv)
        except Exception as e:
            failures.append((name, e))
            traceback.print_exc()
    if args.out:
        payload = {
            "config": {"quick": args.quick, "smoke": args.smoke,
                       "only": args.only,
                       "backend": jax.default_backend(),
                       "python": platform.python_version(),
                       "platform": platform.platform()},
            "suites": sorted(suites),
            "failed": [n for n, _ in failures],
            "rows": csv.records,
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {len(csv.records)} rows to {args.out}")
    if failures:
        print(f"FAILED suites: {[n for n, _ in failures]}")
        return 1
    print(f"# all {len(suites)} suites passed ({len(csv.rows)} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
