"""Top-k kNN serving benchmark: the τ-escalation ladder + compiled-searcher
cache vs. a brute-force full-scan baseline (Pallas Hamming kernel over the
whole database + ``lax.top_k``).

Rows:
  * ``topk/<ds>/k<k>/cold``  — first batched call (jit + ladder search)
  * ``topk/<ds>/k<k>/warm``  — steady-state serving call (cache hit)
  * ``topk/<ds>/k<k>/scan``  — full-scan baseline, warm
plus a correctness cross-check of the two on every run.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hamming import pack_sets, pack_vertical
from repro.core.bst import build_bst
from repro.core.search import clear_searcher_cache, topk_batch
from repro.core.segments import SegmentedIndex
from repro.kernels import ops

from . import common
from .common import Csv, make_dataset, timeit

# two-stage re-rank rows: payload geometry + the perf gate (DESIGN.md
# §10) — stage 2 is ONE extra fused dispatch, so a warm re-ranked query
# must stay under this multiple of the plain ladder
RERANK_VOCAB = 128
RERANK_GATE = 1.5


def _scan_topk(db_vert, q_vert, k):
    """Brute-force baseline: full distance matrix + top_k."""
    @jax.jit
    def run(qv):
        d = ops.hamming_distances(db_vert, qv)        # (m, n)
        neg, idx = jax.lax.top_k(-d, k)
        return -neg, idx
    return run


def run(csv: Csv, datasets=("review",), ks=(1, 10, 100)) -> None:
    for name in datasets:
        cfg, db, queries = make_dataset(name, n=common.cap_n(1 << 16))
        index = build_bst(db, cfg.b)
        planes = pack_vertical(db, cfg.b)
        db_vert = jnp.asarray(np.transpose(planes, (1, 2, 0)).copy())
        q_planes = pack_vertical(queries, cfg.b)
        q_vert = jnp.asarray(np.transpose(q_planes, (1, 2, 0)).copy())
        m = len(queries)
        for k in ks:
            clear_searcher_cache()
            t0 = time.perf_counter()
            res = topk_batch(index, queries, k)
            cold = time.perf_counter() - t0
            csv.add(f"topk/{name}/k{k}/cold", cold * 1e6 / m,
                    f"tau_star={res.tau}")
            warm = timeit(lambda: topk_batch(index, queries, k))
            csv.add(f"topk/{name}/k{k}/warm", warm * 1e6 / m, "")

            scan = _scan_topk(db_vert, q_vert, k)
            scan_t = timeit(lambda: scan(q_vert))
            csv.add(f"topk/{name}/k{k}/scan", scan_t * 1e6 / m, "")

            # exactness cross-check vs. the scan baseline
            sd, sid = scan(q_vert)
            sd, sid = np.asarray(sd), np.asarray(sid)
            np.testing.assert_array_equal(np.asarray(res.dists), sd)
            np.testing.assert_array_equal(np.asarray(res.ids), sid)

        rerank_overhead(csv, name, cfg, db, queries, k=10)


def rerank_overhead(csv, name, cfg, db, queries, k=10):
    """Two-stage overhead rows: the same dynamic index answers the same
    warm query batch with and without the exact re-rank pass.  Stage 2
    is one extra fused dispatch per request, so the warm ratio is gated
    at ``RERANK_GATE`` (skipped in smoke — timings are meaningless at
    tiny shapes, but both paths still execute)."""
    rng = np.random.default_rng(7)
    wp = (RERANK_VOCAB + 31) // 32
    pays = pack_sets(
        (rng.random((len(db), RERANK_VOCAB)) < 0.15).astype(np.uint8),
        RERANK_VOCAB)
    q_pays = pack_sets(
        (rng.random((len(queries), RERANK_VOCAB)) < 0.15).astype(np.uint8),
        RERANK_VOCAB)
    idx = SegmentedIndex(cfg.L, cfg.b, delta_cap=4096, payload_words=wp)
    idx.insert(db, payloads=pays)
    m = len(queries)
    off = timeit(lambda: idx.topk_batch(queries, k))
    on = timeit(lambda: idx.topk_batch(queries, k, rerank="jaccard",
                                       q_payloads=q_pays))
    ratio = on / off
    csv.add(f"topk/{name}/k{k}/rerank_off", off * 1e6 / m, "")
    csv.add(f"topk/{name}/k{k}/rerank_on", on * 1e6 / m,
            f"ratio={ratio:.3f};vocab={RERANK_VOCAB}")
    if not common.SMOKE:
        assert ratio < RERANK_GATE, (
            f"re-rank overhead {ratio:.2f}x exceeds {RERANK_GATE}x gate")


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
