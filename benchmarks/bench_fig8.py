"""Figure 8 / Appendix A: the analytic cost model — cost_S and cost_M
for (n, L) = (2^32, 32), b in {2, 4}, m in {2, 3, 4}, τ in 1..5.

Pure arithmetic (Eq. 2-4); asserts the paper's two qualitative readings:
cost_S explodes with τ and b, and larger m flattens the τ-dependence of
cost_M."""

from __future__ import annotations

from repro.core.cost_model import cost_multi, cost_single

from .common import Csv


def run(csv: Csv) -> None:
    n, L = 2.0 ** 32, 32
    for b in (2, 4):
        singles = []
        for tau in range(1, 6):
            cs = cost_single(b, L, tau, n)
            singles.append(cs)
            csv.add(f"fig8/b{b}/cost_S/tau{tau}", 0.0, f"cost={cs:.3e}")
        assert singles[-1] > singles[0] * 1e3   # exponential blow-up in tau
        for m in (2, 3, 4):
            multis = []
            for tau in range(1, 6):
                cm = cost_multi(b, L, tau, n, m)
                multis.append(cm)
                csv.add(f"fig8/b{b}/cost_M/m{m}/tau{tau}", 0.0,
                        f"cost={cm:.3e}")
            assert multis[-1] < singles[-1]     # multi-index wins at tau=5


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
