"""Closed-loop serving-runtime benchmark (DESIGN.md §5).

A threaded ``repro.serving.Scheduler`` fronts one collection preloaded
with a synthetic corpus; C closed-loop clients each submit one request,
wait for its future, and immediately submit the next — the classic
closed-loop load model, so offered load adapts to service rate and the
reported QPS is *sustained*, not offered.  The request mix is
read-heavy with interleaved writes (defaults: 70% topk, 20% search,
5% insert, 5% delete), exercising the read-coalescing + write-fencing
path the scheduler exists for.

Rows:
  * ``serving/<ds>/qps``        — sustained requests/sec over the run
  * ``serving/<ds>/topk_p50``   — end-to-end (queue + exec) ms
  * ``serving/<ds>/topk_p99``
  * ``serving/<ds>/search_p99``
  * ``serving/<ds>/topk_queue_p99`` / ``topk_exec_p99`` — the p99
                                  request *decomposed* from its span
                                  tree (DESIGN.md §11): time queued vs
                                  time in the batch's device dispatch —
                                  where the e2e p99 actually goes
  * ``serving/<ds>/fill``       — batch-fill ratio (coalesced queries /
                                  dispatched bucket rows)
  * ``serving/<ds>/sweep_seg{1,4,16}_p99`` — fixed-corpus segment-count
                                  sweep: end-to-end topk p99 through the
                                  scheduler at 1/4/16 sealed segments —
                                  flat under the fused arena
                                  (DESIGN.md §6; asserted non-smoke)
  * ``serving/<ds>/burst_goodput`` / ``burst_degraded_frac`` /
    ``burst_victim_p99_ratio`` — overload-control rows (DESIGN.md §12)
                                  from the chaos harness's 10× burst +
                                  slow-dispatch-fault scenario
                                  (``tools/overload_smoke.run_burst``):
                                  co-tenant within-deadline goodput,
                                  fraction of victim answers served
                                  degraded, and the victim's p99/p50 —
                                  deadline-bounded, never unbounded

Standalone: ``PYTHONPATH=src python -m benchmarks.bench_serving
[--smoke] [--clients C] [--ops N] [--out BENCH.json]``.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import threading
import time

import numpy as np

from repro.obs import Tracer
from repro.serving import (CollectionConfig, OverloadError, Scheduler,
                           SchedulerConfig)

from . import common
from .common import Csv, cap_n, make_dataset

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "tools"))
import overload_smoke  # noqa: E402  (the chaos harness's burst scenario)

# op mix: (name, cumulative probability)
MIX = (("topk", 0.70), ("search", 0.90), ("insert", 0.95), ("delete", 1.0))


def _submit_with_retry(submit):
    """Closed-loop overload handling: back off and re-submit until the
    queue admits the request, so every client iteration completes exactly
    one op (the reported totals stay honest under overload)."""
    while True:
        try:
            return submit()
        except OverloadError:
            time.sleep(0.001)


def _client(sched: Scheduler, docs: np.ndarray, ids_pool: list,
            lock: threading.Lock, rng: np.random.Generator, ops: int,
            k: int, tau: int, errors: list) -> None:
    n = len(docs)
    for _ in range(ops):
        r = rng.random()
        try:
            if r < MIX[0][1]:
                doc = docs[rng.integers(0, n)]
                fut = _submit_with_retry(
                    lambda: sched.submit_topk("bench", doc, k))
            elif r < MIX[1][1]:
                doc = docs[rng.integers(0, n)]
                fut = _submit_with_retry(
                    lambda: sched.submit_search("bench", doc, tau))
            elif r < MIX[2][1]:
                rows = docs[rng.integers(0, n, size=4)]
                fut = _submit_with_retry(
                    lambda: sched.submit_insert("bench", rows))
            else:
                with lock:
                    victim = ids_pool[rng.integers(0, len(ids_pool))]
                fut = _submit_with_retry(
                    lambda: sched.submit_delete("bench", victim))
            res = fut.result(timeout=300)
            if r >= MIX[1][1] and r < MIX[2][1]:     # insert: bank new ids
                with lock:
                    ids_pool.extend(res.tolist())
        except Exception as e:                       # noqa: BLE001
            errors.append(e)
            return


def run(csv: Csv, datasets=("review",), clients: int = 8,
        ops_per_client: int = 40, k: int = 10, tau: int = 2) -> None:
    if common.SMOKE:
        clients, ops_per_client = 4, 6
    for name in datasets:
        cfg, db, _ = make_dataset(name, n=cap_n(1 << 14))
        n = len(db)
        tracer = Tracer(capacity=8192)      # span every request of the run
        sched = Scheduler(config=SchedulerConfig(
            max_batch=max(8, clients), max_queue=4 * clients + 64,
            max_wait_ms=1.0), tracer=tracer)
        sched.create_collection("bench", CollectionConfig(
            L=cfg.L, b=cfg.b, delta_cap=max(256, n // 4)))
        preload = sched.submit_insert("bench", db)
        sched.start()
        ids_pool = list(preload.result(timeout=600).tolist())
        # pre-jit every power-of-two shape bucket the mix can dispatch
        # before timing — first-request compiles never pollute the p99
        sched.warmup(ks=(k,), taus=(tau,))

        lock = threading.Lock()
        errors: list = []
        threads = [
            threading.Thread(target=_client, args=(
                sched, db, ids_pool, lock,
                np.random.default_rng(1000 + c), ops_per_client, k, tau,
                errors))
            for c in range(clients)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
        sched.stop()
        if errors:
            raise errors[0]

        total = clients * ops_per_client
        snap = sched.stats()
        lat = snap["latency"]
        qps = total / dt
        csv.add(f"serving/{name}/qps", dt / total * 1e6,
                f"qps={qps:.0f};clients={clients};ops={total};"
                f"rejected={snap['counters'].get('rejected_total', 0)}")
        for op in ("topk", "search"):
            if op in lat:
                csv.add(f"serving/{name}/{op}_p50", lat[op]["p50_ms"] * 1e3,
                        f"p50_ms={lat[op]['p50_ms']:.2f}")
                csv.add(f"serving/{name}/{op}_p99", lat[op]["p99_ms"] * 1e3,
                        f"p99_ms={lat[op]['p99_ms']:.2f}")
        fill = snap["batch_fill_ratio"]
        csv.add(f"serving/{name}/fill", 0.0,
                f"fill={fill:.3f};cache_traces="
                f"{snap['searcher_cache']['traces']}")

        # span-derived decomposition: where the topk p99 goes — queue
        # wait vs device execution (from each request's span tree, not
        # the aggregate windows)
        queue_s, exec_s = [], []
        for root in tracer.roots():
            if root.args.get("op") != "topk":
                continue
            wait = root.find("queue_wait")
            execute = root.find("execute")
            if wait is not None:
                queue_s.append(wait.dur)
            if execute is not None:
                exec_s.append(execute.dur)
        if queue_s and exec_s:
            qp99 = float(np.percentile(np.asarray(queue_s), 99)) * 1e3
            ep99 = float(np.percentile(np.asarray(exec_s), 99)) * 1e3
            csv.add(f"serving/{name}/topk_queue_p99", qp99 * 1e3,
                    f"p99_ms={qp99:.2f};spans={len(queue_s)}")
            csv.add(f"serving/{name}/topk_exec_p99", ep99 * 1e3,
                    f"p99_ms={ep99:.2f};spans={len(exec_s)}")
        if not common.SMOKE:
            # relational sanity: the runtime must actually coalesce —
            # with 8 closed-loop clients the mean read batch must beat 1
            batches = sum(v for kk, v in snap["counters"].items()
                          if kk.startswith("batches_total:"))
            reads = sum(lat[op]["count"] for op in ("topk", "search")
                        if op in lat)
            assert batches < reads, (batches, reads)

        # segment-count sweep: end-to-end read latency through the
        # scheduler must stay flat (not linear) in the collection's
        # sealed segment count — the fused arena's one-dispatch claim
        # observed from the client side
        n_sweep = min(n, cap_n(1 << 12))
        sweep_ops = 8 if common.SMOKE else 24
        sweep_p99 = {}
        for n_seg in (1, 4, 16):
            sw = Scheduler(config=SchedulerConfig(
                max_batch=8, max_queue=1024, max_wait_ms=1.0))
            sw.create_collection("sweep", CollectionConfig(
                L=cfg.L, b=cfg.b, delta_cap=n_sweep + 1, auto_merge=False))
            sidx = sw.registry.get("sweep").index
            chunk = n_sweep // n_seg
            for lo in range(0, n_seg * chunk, chunk):
                sidx.insert(db[lo:lo + chunk])
                sidx.flush()
            for i in range(2):       # warm bucket 1 — the dispatch shape
                f = sw.submit_topk("sweep", db[i], k)
                sw.pump()
                f.result(timeout=600)
            sw.metrics.latency.clear()          # drop warmup samples
            rng = np.random.default_rng(7)
            for _ in range(sweep_ops):          # one dispatch per pump
                f = sw.submit_topk("sweep",
                                   db[rng.integers(0, n_sweep)], k)
                sw.pump()
                f.result(timeout=600)
            lat = sw.stats()["latency"]["topk"]
            sweep_p99[n_seg] = lat["p50_ms"]
            csv.add(f"serving/{name}/sweep_seg{n_seg}_p99",
                    lat["p99_ms"] * 1e3,
                    f"segments={n_seg};p50_ms={lat['p50_ms']:.2f};"
                    f"rows={n_sweep}")
        if not common.SMOKE:
            # flat, not linear, in n_segments (p50 — the p99 of a short
            # run is a single sample and may catch a ladder escalation)
            assert sweep_p99[16] < 6 * max(sweep_p99[1], 1e-3), sweep_p99

        # overload-control burst scenario (DESIGN.md §12): one tenant
        # fires a 10x open-loop burst under slow-dispatch faults; the
        # chaos harness measures co-tenant goodput, the degraded
        # fraction, and the victim's deadline-bounded tail
        burst_kw = dict(n_docs=1024, burst=120) if common.SMOKE else {}
        res = overload_smoke.run_burst(**burst_kw)
        csv.add(f"serving/{name}/burst_goodput", res["goodput"] * 1e6,
                f"goodput={res['goodput']:.3f};"
                f"cotenant_ops={res['cotenant_total']};"
                f"deadline_exceeded={res['deadline_exceeded']};"
                f"breaker_trips={res['breaker_trips']}")
        csv.add(f"serving/{name}/burst_degraded_frac",
                res["degraded_frac"] * 1e6,
                f"degraded_frac={res['degraded_frac']:.3f};"
                f"stages={','.join(res['degraded_stages']) or 'none'}")
        csv.add(f"serving/{name}/burst_victim_p99_ratio",
                res["victim_p99_ratio"] * 1e6,
                f"p99_over_p50={res['victim_p99_ratio']:.2f};"
                f"p50_ms={res['victim_p50_ms']:.1f};"
                f"p99_ms={res['victim_p99_ms']:.1f}")
        if not common.SMOKE:
            overload_smoke.check_burst(res)     # the CI-enforced SLO


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--ops", type=int, default=40,
                    help="requests per closed-loop client")
    ap.add_argument("--out", default=None,
                    help="also write machine-readable JSON rows here")
    args = ap.parse_args(argv)
    if args.smoke:
        from . import common
        common.set_smoke()
    csv = Csv()
    csv.header()
    run(csv, clients=args.clients, ops_per_client=args.ops)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"suite": "serving", "smoke": args.smoke,
                       "rows": csv.records}, f, indent=2)
        print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
