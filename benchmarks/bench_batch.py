"""Batched serving throughput: queries/sec of the natively batched
searcher (2D frontier + query-tiled verify kernel) across batch sizes
m ∈ {1, 8, 64, 256}, against the legacy per-query loop over the
single-query searcher.

The point of the tentpole optimisation is that the collapsed-path array
is streamed from HBM ⌈m/BLOCK_M⌉ times instead of m — on this CPU
container the kernel runs in interpret mode, so the *assertable* part is
correctness (native batch bit-identical to the per-query path) and the
amortisation trend, while the roofline suite carries the analytic
intensity model (benchmarks/roofline.py).

Rows:
  * ``batch/<ds>/m<m>/native`` — one natively batched call, warm
  * ``batch/<ds>/m<m>/loop``   — m single-query calls, warm
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bst import build_bst
from repro.core.search import (clear_searcher_cache, make_batch_searcher,
                               make_searcher)

from .common import Csv, make_dataset, timeit


def run(csv: Csv, datasets=("review",), ms=(1, 8, 64, 256),
        tau: int = 2) -> None:
    for name in datasets:
        cfg, db, _ = make_dataset(name)
        rng = np.random.default_rng(1)
        index = build_bst(db, cfg.b)
        m_max = max(ms)
        queries = np.concatenate([
            db[rng.integers(0, len(db), m_max // 2)],
            rng.integers(0, 1 << cfg.b, size=(m_max - m_max // 2, cfg.L),
                         dtype=np.uint8)])
        clear_searcher_cache()
        single = make_searcher(index, tau)
        for m in ms:
            qs = jnp.asarray(queries[:m])
            batched = make_batch_searcher(index, tau)
            t_native = timeit(batched, qs)
            csv.add(f"batch/{name}/m{m}/native", t_native * 1e6 / m,
                    f"qps={m / t_native:.0f}")
            t_loop = timeit(
                lambda: jax.block_until_ready([single(q) for q in qs]))
            csv.add(f"batch/{name}/m{m}/loop", t_loop * 1e6 / m,
                    f"qps={m / t_loop:.0f}")

            # bit-exactness of the native batch vs the per-query path
            bres = batched(qs)
            for i in range(m):
                sres = single(qs[i])
                np.testing.assert_array_equal(np.asarray(bres.mask[i]),
                                              np.asarray(sres.mask))
                np.testing.assert_array_equal(np.asarray(bres.dist[i]),
                                              np.asarray(sres.dist))


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
