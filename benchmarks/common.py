"""Shared benchmark utilities: scaled paper datasets, timing, CSV rows.

Scale note (DESIGN.md §9): the container is one CPU core with 35 GB RAM;
benchmarks use synthetic sketch databases at n = 2^16..2^20 with the
paper's exact (L, b) per dataset, reproducing *relative* claims (bST vs
LOUDS space ratios, SIH blow-up in τ and b, SI/MI crossover).  Space
models are additionally evaluated analytically at the paper's billion-
scale n (bench_table4).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import PAPER_DATASETS, SketchDatasetConfig

# scaled-down database sizes per dataset (same L, b as the paper)
SCALED_N = {"review": 1 << 17, "cp": 1 << 17, "sift": 1 << 17, "gist": 1 << 16}
N_QUERIES = 20

# --smoke: tiny-shape anti-bitrot mode (CI) — every suite must *execute*
# end to end; perf-relational assertions are skipped (meaningless at
# these shapes) while structural/space assertions still hold.
SMOKE = False
SMOKE_N = 1 << 10


def set_smoke() -> None:
    global SMOKE, N_QUERIES
    SMOKE = True
    N_QUERIES = 4
    for k in SCALED_N:
        SCALED_N[k] = SMOKE_N


def cap_n(n: int) -> int:
    """Clamp a suite's hard-coded database size in smoke mode."""
    return min(n, SMOKE_N) if SMOKE else n


def make_dataset(name: str, n: Optional[int] = None, seed: int = 0):
    """Synthetic b-bit sketch DB with the paper's (L, b).  Near-uniform
    random characters — the distribution minhash/CWS produce (paper §V)."""
    cfg = PAPER_DATASETS[name]
    n = n or SCALED_N[name]
    rng = np.random.default_rng(seed)
    db = rng.integers(0, 1 << cfg.b, size=(n, cfg.L), dtype=np.uint8)
    # queries: half perturbed DB rows (guaranteed near neighbours), half random
    q = db[rng.integers(0, n, N_QUERIES)].copy()
    for i in range(N_QUERIES // 2, N_QUERIES):
        q[i] = rng.integers(0, 1 << cfg.b, size=cfg.L, dtype=np.uint8)
    for i in range(N_QUERIES // 2):
        flips = rng.integers(0, cfg.L, size=2)
        q[i, flips] = rng.integers(0, 1 << cfg.b, size=2)
    return cfg, db, q


def timeit(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r) if hasattr(r, "block_until_ready") else None
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args)
        if hasattr(r, "block_until_ready"):
            r.block_until_ready()
        elif isinstance(r, (tuple, list)) and r and hasattr(r[0], "block_until_ready"):
            r[0].block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def parse_derived(derived: str) -> Dict[str, object]:
    """Parse a ``k=v;k=v`` derived string into a typed dict (ints and
    floats coerced; bare tokens land under ``"notes"``).  The
    machine-readable side of every benchmark row (``run.py --out``).

    >>> parse_derived("qps=120;fill=0.5;mode=scan")
    {'qps': 120, 'fill': 0.5, 'mode': 'scan'}
    """
    out: Dict[str, object] = {}
    notes = []
    for tok in filter(None, (t.strip() for t in derived.split(";"))):
        if "=" not in tok:
            notes.append(tok)
            continue
        k, v = tok.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    if notes:
        out["notes"] = notes
    return out


class Csv:
    """Benchmark row sink: human CSV lines + machine-readable records
    (``records`` feeds ``run.py --out BENCH_<name>.json``)."""

    def __init__(self):
        self.rows: List[str] = []
        self.records: List[Dict[str, object]] = []

    def add(self, name: str, us_per_call: float, derived: str = "") -> None:
        row = f"{name},{us_per_call:.2f},{derived}"
        self.rows.append(row)
        self.records.append({"name": name,
                             "us_per_call": round(float(us_per_call), 2),
                             "derived": parse_derived(derived)})
        print(row, flush=True)

    def header(self) -> None:
        print("name,us_per_call,derived", flush=True)
