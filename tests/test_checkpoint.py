"""Checkpoint/restart + fault tolerance: atomic saves, bitwise-identical
resume, elastic re-shard, straggler policy, failure-injection drill."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SketchDedupPipeline
from repro.distributed import checkpoint as ckpt
from repro.distributed import compression
from repro.distributed.fault_tolerance import (FailurePlan, SimulatedFailure,
                                               StragglerMonitor,
                                               resume_or_init)
from repro.models import model as M
from repro.optim.adamw import Hyper, adamw_init
from repro.train.steps import make_train_step

ARCH = "smollm-135m"


def _setup(tmp_path, steps=6, fail_at=None, ckpt_every=2):
    cfg = get_config(ARCH, smoke=True)
    hyper = Hyper(total_steps=steps, warmup_steps=1)
    data = SketchDedupPipeline(DataConfig(vocab=cfg.vocab, batch=4, seq=16))
    step_fn = jax.jit(make_train_step(cfg, hyper,
                                      compute_dtype=jnp.float32))
    return cfg, data, step_fn


def _run(cfg, data, step_fn, ckpt_dir, start, steps, params, opt,
         plan=None, ckpt_every=2):
    losses = {}
    for step in range(start, steps):
        if plan is not None:
            plan.maybe_fail(step)
        params, opt, metrics = step_fn(params, opt, data.batch_for_step(step))
        losses[step] = float(metrics["loss"])
        if (step + 1) % ckpt_every == 0:
            ckpt.save_checkpoint(ckpt_dir, step + 1,
                                 {"params": params, "opt": opt})
    return params, opt, losses


def test_restart_is_bitwise_identical(tmp_path):
    cfg, data, step_fn = _setup(tmp_path)
    d = str(tmp_path / "ck")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)

    # uninterrupted run
    p_full, _, losses_full = _run(cfg, data, step_fn, d + "_a", 0, 6,
                                  params, opt)

    # interrupted at step 4 -> restart from checkpoint at step 4
    params2 = M.init_params(jax.random.PRNGKey(0), cfg)
    opt2 = adamw_init(params2)
    plan = FailurePlan(fail_at_step=4)
    with pytest.raises(SimulatedFailure):
        _run(cfg, data, step_fn, d, 0, 6, params2, opt2, plan=plan)

    step = ckpt.latest_checkpoint(d)
    assert step == 4
    abstract = {"params": M.abstract_params(cfg),
                "opt": jax.eval_shape(adamw_init, M.abstract_params(cfg))}
    state, start = resume_or_init(d, abstract, lambda: None)
    assert start == 4
    # deterministic data: replay must continue identically
    p_resumed, _, losses_resumed = _run(
        cfg, SketchDedupPipeline(DataConfig(vocab=cfg.vocab, batch=4, seq=16)),
        step_fn, d, start, 6, state["params"], state["opt"])

    for s in (4, 5):
        assert losses_full[s] == losses_resumed[s], (s, losses_full,
                                                     losses_resumed)
    for a, b in zip(jax.tree_util.tree_leaves(p_full),
                    jax.tree_util.tree_leaves(p_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_no_partial_checkpoints(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"w": jnp.ones((4, 4))}
    ckpt.save_checkpoint(d, 1, tree)
    # a stale tmp dir (simulated crash mid-write) must be invisible
    os.makedirs(os.path.join(d, "step_0000002.tmp-999"), exist_ok=True)
    assert ckpt.list_checkpoints(d) == [1]


def test_sweep_stale_tmp_dirs(tmp_path):
    """A crashed writer's ``step_*.tmp-<pid>`` / ``.old-<pid>`` / ``.rm``
    leftovers are garbage-collected on startup (and only those — live
    checkpoints survive the sweep)."""
    d = str(tmp_path / "ck")
    ckpt.save_checkpoint(d, 1, {"w": jnp.ones((2, 2))})
    stale = [os.path.join(d, "step_0000002.tmp-999"),
             os.path.join(d, "step_0000001.old-999"),
             os.path.join(d, "step_0000000.rm")]
    for p in stale:
        os.makedirs(p, exist_ok=True)
        with open(os.path.join(p, "junk.bin"), "wb") as f:
            f.write(b"x" * 64)
    removed = ckpt.sweep_stale(d)
    assert sorted(removed) == sorted(stale)
    for p in stale:
        assert not os.path.exists(p)
    assert ckpt.list_checkpoints(d) == [1]          # survivors intact
    # startup paths run the sweep automatically
    for p in stale:
        os.makedirs(p, exist_ok=True)
    ckpt.AsyncCheckpointer(d, keep=2)
    assert not any(os.path.exists(p) for p in stale)
    d2 = str(tmp_path / "ck2")                      # empty-dir resume path
    stale2 = os.path.join(d2, "step_0000004.tmp-999")
    os.makedirs(stale2)
    state, start = resume_or_init(d2, None, lambda: "fresh")
    assert (state, start) == ("fresh", 0)
    assert not os.path.exists(stale2)


def test_sweep_keeps_own_inflight_tmp(tmp_path):
    """The sweep must not race a live AsyncCheckpointer thread of this
    process: tmp dirs tagged with our own pid are left alone."""
    d = str(tmp_path / "ck")
    mine = os.path.join(d, f"step_0000009.tmp-{os.getpid()}")
    os.makedirs(mine)
    assert ckpt.sweep_stale(d) == []
    assert os.path.isdir(mine)


def test_elastic_restore_different_mesh(tmp_path):
    """Save unsharded-logical, restore with shardings for the current
    (different) mesh — the elastic-scaling path."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ck")
    tree = {"embed": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt.save_checkpoint(d, 3, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"embed": NamedSharding(mesh, P("data", None))}
    restored = ckpt.restore_checkpoint(
        d, 3, {"embed": jax.ShapeDtypeStruct((8, 8), jnp.float32)}, sh)
    np.testing.assert_array_equal(np.asarray(restored["embed"]),
                                  np.asarray(tree["embed"]))
    assert restored["embed"].sharding == sh["embed"]


def test_straggler_monitor_flags_slow_worker():
    mon = StragglerMonitor(n_workers=4, warmup=2)
    for _ in range(5):
        mon.observe([1.0, 1.1, 0.9, 4.5])
    assert mon.check() == [3]
    mon2 = StragglerMonitor(n_workers=4, warmup=2)
    for _ in range(5):
        mon2.observe([1.0, 1.1, 0.9, 1.2])
    assert mon2.check() == []


def test_grad_compression_roundtrip_and_error_feedback():
    rng = np.random.default_rng(0)
    grads = {"a": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32),
             "b": jnp.asarray(rng.standard_normal((7,)), jnp.float32)}
    err = compression.init_error_feedback(grads)
    c, err1 = compression.compress(grads, err)
    out = compression.decompress(c)
    # int8 quantization error bounded by scale/2
    for k in grads:
        scale = float(jnp.max(jnp.abs(grads[k]))) / 127.0
        assert float(jnp.abs(out[k] - grads[k]).max()) <= scale * 0.5 + 1e-7
    # error feedback: residual + quantized == original
    for k in grads:
        np.testing.assert_allclose(
            np.asarray(out[k] + err1[k]), np.asarray(grads[k]), atol=1e-6)
    # payload ~4x smaller than f32
    assert compression.compressed_bytes(c) < sum(
        g.size * 4 for g in jax.tree_util.tree_leaves(grads)) / 3.5


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ck")
    acp = ckpt.AsyncCheckpointer(d, keep=2)
    for s in (1, 2, 3):
        acp.save(s, {"w": jnp.full((2,), s, jnp.float32)})
    acp.wait()
    assert ckpt.list_checkpoints(d) == [2, 3]
    got = ckpt.restore_checkpoint(
        d, 3, {"w": jax.ShapeDtypeStruct((2,), jnp.float32)})
    np.testing.assert_array_equal(np.asarray(got["w"]), [3.0, 3.0])
