"""Hypothesis property tests on SYSTEM invariants (end-to-end, not
per-module): search exactness over arbitrary databases, monotonicity in
τ, shard-count invariance, optimizer descent, checkpoint round-trip."""

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean env: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.core.bst import build_bst
from repro.core.distributed_search import (build_sharded_bst, gather_ids,
                                           make_sharded_searcher)
from repro.core.hamming import hamming_pairwise_naive
from repro.core.search import make_batch_searcher
from repro.optim.adamw import Hyper, adamw_init, adamw_update


@st.composite
def sketch_db(draw):
    b = draw(st.integers(1, 4))
    L = draw(st.integers(2, 10))
    n = draw(st.integers(1, 120))
    seed = draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << b, size=(n, L), dtype=np.uint8), b


@settings(max_examples=15, deadline=None)
@given(sketch_db(), st.integers(0, 4))
def test_search_exactness(db_b, tau):
    """For ANY database and query drawn from it or not, bST search equals
    brute force — the core correctness invariant."""
    db, b = db_b
    index = build_bst(db, b)
    q = np.concatenate([db[:2], (db[:1] + 1) % (1 << b)])
    res = make_batch_searcher(index, tau)(jnp.asarray(q))
    got = np.asarray(res.mask)
    want = np.asarray(hamming_pairwise_naive(
        jnp.asarray(q), jnp.asarray(db))) <= tau
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(sketch_db())
def test_tau_monotonicity(db_b):
    """Solution sets are nested in τ: I(τ) ⊆ I(τ+1)."""
    db, b = db_b
    index = build_bst(db, b)
    q = jnp.asarray(db[:1])
    prev = None
    for tau in range(0, 4):
        mask = np.asarray(make_batch_searcher(index, tau)(q).mask)[0]
        if prev is not None:
            assert (prev <= mask).all(), tau
        prev = mask


@settings(max_examples=8, deadline=None)
@given(sketch_db(), st.integers(1, 4), st.integers(0, 2))
def test_shard_count_invariance(db_b, n_shards, tau):
    """The sharded search result set is independent of the shard count
    (elastic-scaling invariant for the retrieval plane)."""
    db, b = db_b
    if db.shape[0] < n_shards:
        return
    q = jnp.asarray(db[:2])
    ref = build_sharded_bst(db, b, 1)
    got1 = gather_ids(ref, np.asarray(make_sharded_searcher(ref, tau)(q)[0]))
    idx = build_sharded_bst(db, b, n_shards)
    gotN = gather_ids(idx, np.asarray(make_sharded_searcher(idx, tau)(q)[0]))
    for a, c in zip(got1, gotN):
        np.testing.assert_array_equal(np.sort(a), np.sort(c))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**16))
def test_adamw_descends_quadratic(seed):
    """AdamW reduces a convex quadratic from any start."""
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.standard_normal(8), jnp.float32)}
    target = jnp.asarray(rng.standard_normal(8), jnp.float32)
    h = Hyper(base_lr=5e-2, warmup_steps=1, total_steps=100,
              weight_decay=0.0)
    state = adamw_init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(grads, state, params, h)
    assert float(loss(params)) < l0 * 0.5


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**16))
def test_checkpoint_roundtrip_any_tree(seed):
    import tempfile
    from repro.distributed.checkpoint import (restore_checkpoint,
                                              save_checkpoint)
    rng = np.random.default_rng(seed)
    tree = {"a": {"x": jnp.asarray(rng.standard_normal((3, 5)), jnp.float32)},
            "b": [jnp.asarray(rng.integers(0, 9, 4), jnp.int32),
                  jnp.asarray(rng.standard_normal(2), jnp.float32)]}
    d = tempfile.mkdtemp(prefix="ck_prop_")
    save_checkpoint(d, 1, tree)
    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back = restore_checkpoint(d, 1, abstract)
    for a, c in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
