"""Per-kernel validation: Pallas body (interpret mode on CPU) vs pure-jnp
oracle, swept over shapes / b / L / block sizes, plus hypothesis properties."""

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean env: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.core import hamming as H
from repro.kernels import ops, ref
from repro.kernels.hamming_kernel import hamming_distances_pallas, sparse_verify_pallas


def make_db(rng, n, L, b):
    db = rng.integers(0, 1 << b, size=(n, L)).astype(np.uint8)
    planes = H.pack_vertical(db, b)  # (n, b, W)
    vert = np.transpose(planes, (1, 2, 0))  # (b, W, n)
    return db, jnp.asarray(vert)


@pytest.mark.parametrize("b,L", [(2, 16), (2, 32), (4, 32), (8, 64), (1, 8), (4, 100)])
@pytest.mark.parametrize("n,m,block_n", [(256, 3, 128), (512, 1, 512), (130, 2, 128)])
def test_hamming_kernel_matches_oracle(b, L, n, m, block_n):
    rng = np.random.default_rng(b * 1000 + L + n)
    db, db_vert = make_db(rng, n, L, b)
    q, q_vert = make_db(rng, m, L, b)
    got = np.asarray(ops.hamming_distances(db_vert, q_vert, block_n=block_n, use_kernel=True))
    want = np.asarray(ref.hamming_distances_ref(db_vert, q_vert))
    np.testing.assert_array_equal(got, want)
    brute = (q[:, None, :] != db[None, :, :]).sum(axis=2)
    np.testing.assert_array_equal(got, brute)


def test_big_sentinel_consistent():
    """The kernel package's pruned-lane sentinel must equal core.bst.BIG."""
    from repro.core.bst import BIG
    from repro.kernels.hamming_kernel import BIG as KBIG
    assert int(BIG) == int(KBIG) == int(ref.BIG)


@pytest.mark.parametrize("b,L,tau", [(2, 16, 2), (4, 32, 5), (8, 64, 3), (2, 16, 0)])
def test_sparse_verify_matches_oracle(b, L, tau):
    rng = np.random.default_rng(b + L + tau)
    n = 384
    db, paths_vert = make_db(rng, n, L, b)
    q, q_vert = make_db(rng, 1, L, b)
    q_vert = q_vert[..., 0]
    base = rng.integers(0, tau + 2, size=n).astype(np.int32)
    got, got_d = ops.sparse_verify(paths_vert, q_vert, jnp.asarray(base),
                                   tau=tau, block_n=128, use_kernel=True)
    want, want_d = ref.sparse_verify_ref(paths_vert, q_vert,
                                         jnp.asarray(base), tau)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want).astype(np.int32))
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want_d))
    # distances are exact: base + suffix Hamming distance
    suffix = (db != q[0][None]).sum(axis=1)
    np.testing.assert_array_equal(np.asarray(got_d), base + suffix)


def test_kernel_direct_no_padding():
    """Exercise the raw pallas_call (n, m exact multiples of the tiles)."""
    rng = np.random.default_rng(0)
    b, L, n, m = 4, 32, 1024, 4
    _, db_vert = make_db(rng, n, L, b)
    _, q_vert = make_db(rng, m, L, b)
    got = np.asarray(hamming_distances_pallas(db_vert, q_vert, block_m=2,
                                              block_n=256, interpret=True))
    want = np.asarray(ref.hamming_distances_ref(db_vert, q_vert))
    np.testing.assert_array_equal(got, want)


def test_small_path_uses_oracle():
    rng = np.random.default_rng(1)
    _, db_vert = make_db(rng, 10, 16, 2)
    _, q_vert = make_db(rng, 2, 16, 2)
    got = np.asarray(ops.hamming_distances(db_vert, q_vert))  # n < block -> oracle
    want = np.asarray(ref.hamming_distances_ref(db_vert, q_vert))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 8), st.integers(1, 70), st.integers(1, 300), st.integers(0, 6), st.randoms())
def test_verify_property(b, L, n, tau, rnd):
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    db, paths_vert = make_db(rng, n, L, b)
    q, q_vert = make_db(rng, 1, L, b)
    base = rng.integers(0, 4, size=n).astype(np.int32)
    got, got_d = ops.sparse_verify(paths_vert, q_vert[..., 0], jnp.asarray(base),
                                   tau=tau, block_n=128)
    suffix = (db != q[0][None]).sum(axis=1)
    want = ((base + suffix) <= tau).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(got), want)
    np.testing.assert_array_equal(np.asarray(got_d), base + suffix)
