"""Two-stage exact re-rank (DESIGN.md §10).

Stage 2 must be *exact*: the fused re-rank kernel is bit-identical to
the interpretable oracle and to a host numpy brute force for every
metric, including pad rows, tile-misaligned lane counts, and fully
empty survivor tiles.  Threaded through the index it must stay exact
across the whole LSM lifecycle (insert -> delete -> merge -> compact)
on every backend, cost exactly ONE extra device launch per request
(never per segment), and its payload columns must show up in the space
ledger and the tier staging counters."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean env: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.core import (SegmentedIndex, ShardedSegmentedIndex,
                        dispatch_stats, reset_dispatch_stats,
                        reset_tier_stats, tier_stats)
from repro.core.hamming import pack_sets
from repro.core.segments import BIG_I
from repro.kernels import ops
from repro.kernels.ref import RERANK_METRICS, exact_rerank_ref

L, B = 12, 2
VOCAB = 96
WP = (VOCAB + 31) // 32


# -- host oracle ---------------------------------------------------------

def popcount_rows(x):
    x = np.ascontiguousarray(x, np.uint32)
    return np.unpackbits(x.view(np.uint8), axis=-1).sum(axis=-1)


def brute(metric, q_pay, pay, surv):
    """Row-major numpy oracle: q_pay (m, Wp), pay (n, Wp), surv (m, n)
    -> (m, n) float32 scores with the -1.0 non-survivor sentinel, using
    the kernel's exact f32 arithmetic."""
    inter = popcount_rows(
        q_pay[:, None, :] & pay[None, :, :]).astype(np.float32)
    sa = popcount_rows(q_pay).astype(np.float32)[:, None]
    sb = popcount_rows(pay).astype(np.float32)[None, :]
    if metric == "jaccard":
        den = sa + sb - inter
    elif metric == "cosine":
        den = np.sqrt(sa * sb).astype(np.float32)
    else:                               # containment: |A ∩ B| / |A|
        den = np.broadcast_to(sa, inter.shape)
    den_safe = np.where(den > 0, den, np.float32(1))
    sc = np.where(den > 0, (inter / den_safe).astype(np.float32),
                  np.float32(0))
    return np.where(surv, sc, np.float32(-1.0))


def brute_topk(metric, q_pay, pay, dist, ids, k):
    """Exact two-stage reference: score survivors (dist < BIG) of the
    stage-1 plane, order by (score desc, id asc), pad to k with the
    (-1, BIG_I, -1.0) sentinels."""
    surv = np.asarray(dist) < BIG_I
    sc = brute(metric, q_pay, pay, surv)
    out_i, out_d, out_s = [], [], []
    for r in range(sc.shape[0]):
        order = sorted(range(sc.shape[1]),
                       key=lambda j: (-sc[r, j], ids[j]))
        sel = [j for j in order if sc[r, j] >= 0][:k]
        pad = k - len(sel)
        out_i.append([ids[j] for j in sel] + [-1] * pad)
        out_d.append([dist[r, j] for j in sel] + [BIG_I] * pad)
        out_s.append([sc[r, j] for j in sel] + [np.float32(-1.0)] * pad)
    return (np.array(out_i, np.int64), np.array(out_d, np.int64),
            np.array(out_s, np.float32))


def make_rows(rng, n, vocab=VOCAB, max_tokens=20):
    sets = [rng.choice(vocab, size=int(rng.integers(1, max_tokens)),
                       replace=False) for _ in range(n)]
    pay = pack_sets(sets, vocab)
    sk = rng.integers(0, 1 << B, size=(n, L), dtype=np.uint8)
    return sk, pay


def check_rerank(idx, qs, qp, k, metric, want_rerank_launches=1):
    """One re-rank request vs the host two-stage oracle, with the
    dispatch spy asserting the one-extra-launch contract."""
    reset_dispatch_stats()
    res = idx.topk_batch(qs, k, rerank=metric, q_payloads=qp)
    ds = dispatch_stats()
    assert ds["rerank"] == want_rerank_launches, ds
    dist, col_ids, _ = idx._search_columns(qs, res.tau)
    bi, bd, bs = brute_topk(metric, qp, idx._payload_rows(),
                            np.asarray(dist), np.asarray(col_ids, np.int64),
                            k)
    np.testing.assert_array_equal(np.asarray(res.ids), bi)
    np.testing.assert_array_equal(np.asarray(res.dists), bd)
    np.testing.assert_array_equal(np.asarray(res.scores), bs)
    return res


# -- kernel vs oracle vs numpy ------------------------------------------

@pytest.mark.parametrize("metric", RERANK_METRICS)
@pytest.mark.parametrize("m,n", [(1, 70), (5, 64), (3, 130), (8, 200)])
def test_kernel_bit_exact_vs_oracle_and_numpy(metric, m, n):
    """Pad rows (m % block_m != 0), tile-misaligned n, m=1 — the pallas
    kernel, the jnp oracle, and the numpy brute force all agree bit for
    bit, -1.0 sentinels included."""
    rng = np.random.default_rng(m * 1000 + n)
    pay = rng.integers(0, 1 << 32, size=(n, WP), dtype=np.uint32)
    qp = rng.integers(0, 1 << 32, size=(m, WP), dtype=np.uint32)
    surv = (rng.random((m, n)) < 0.6).astype(np.int32)
    want = brute(metric, qp, pay, surv.astype(bool))
    got_ref = np.asarray(exact_rerank_ref(pay.T, qp.T, surv, metric))
    got_ker = np.asarray(ops.exact_rerank(
        pay.T, qp.T, surv, metric=metric, block_m=8, block_n=64,
        use_kernel=True))
    np.testing.assert_array_equal(got_ref, want)
    np.testing.assert_array_equal(got_ker, want)


@pytest.mark.parametrize("metric", RERANK_METRICS)
def test_kernel_empty_survivor_tiles_and_zero_sets(metric):
    """A whole survivor tile of zeros stays the -1.0 sentinel, and
    all-zero payload sets hit the zero-denominator -> 0.0 branch rather
    than NaN/inf."""
    rng = np.random.default_rng(9)
    m, n = 4, 192                          # 3 tiles of block_n=64
    pay = rng.integers(0, 1 << 32, size=(n, WP), dtype=np.uint32)
    pay[10] = 0                            # |B| = 0
    qp = rng.integers(0, 1 << 32, size=(m, WP), dtype=np.uint32)
    qp[2] = 0                              # |A| = 0 for one query row
    surv = np.ones((m, n), np.int32)
    surv[:, 64:128] = 0                    # middle tile fully dead
    surv[1] = 0                            # one query with zero survivors
    want = brute(metric, qp, pay, surv.astype(bool))
    got = np.asarray(ops.exact_rerank(
        pay.T, qp.T, surv, metric=metric, block_m=8, block_n=64,
        use_kernel=True))
    np.testing.assert_array_equal(got, want)
    assert (got[:, 64:128] == -1.0).all()
    assert (got[1] == -1.0).all()
    assert np.isfinite(got).all()


def test_small_scan_routes_to_oracle():
    """Below one lane tile the wrapper answers from the jnp oracle
    (use_kernel=None) — same bits either way."""
    rng = np.random.default_rng(3)
    pay = rng.integers(0, 1 << 32, size=(17, WP), dtype=np.uint32)
    qp = rng.integers(0, 1 << 32, size=(2, WP), dtype=np.uint32)
    surv = np.ones((2, 17), np.int32)
    auto = np.asarray(ops.exact_rerank(pay.T, qp.T, surv, metric="jaccard"))
    forced = np.asarray(ops.exact_rerank(pay.T, qp.T, surv,
                                         metric="jaccard", use_kernel=True))
    np.testing.assert_array_equal(auto, forced)


def test_unknown_metric_rejected():
    z = np.zeros((WP, 4), np.uint32)
    with pytest.raises(ValueError):
        ops.exact_rerank(z, z[:, :1], np.ones((1, 4), np.int32),
                         metric="dice")


# -- lifecycle property: exact across the whole LSM lifecycle -----------

@settings(max_examples=2, deadline=None)
@given(st.randoms())
def test_rerank_exact_through_lifecycle_all_backends(rnd):
    """insert -> delete -> merge -> compact, then ``topk(rerank=...)``:
    bit-identical (ids, dists, scores, pads) to the host two-stage
    brute force on every backend/layout/arena combination, with exactly
    one re-rank launch per request regardless of segment count."""
    rng = np.random.default_rng(rnd.randint(0, 2 ** 31))
    combos = [("bst", "suffix", True), ("bst", "full", True),
              ("bst", "suffix", False), ("multi", "suffix", True),
              ("sharded", "suffix", True)]
    for backend, layout, use_arena in combos:
        idx = SegmentedIndex(L, B, delta_cap=25, backend=backend,
                             layout=layout, use_arena=use_arena,
                             payload_words=WP, auto_merge=False)
        sk, pay = make_rows(rng, 60)
        ids = idx.insert(sk, payloads=pay)
        idx.delete(ids[5:15])
        idx.merge()
        sk2, pay2 = make_rows(rng, 30)
        idx.insert(sk2, payloads=pay2)     # seals + leaves a live delta
        idx.delete(ids[40:44])
        idx.compact()
        assert len(idx.segments) >= 1
        qs = rng.integers(0, 1 << B, size=(3, L), dtype=np.uint8)
        qp = pack_sets([rng.choice(VOCAB, size=7, replace=False)
                        for _ in range(3)], VOCAB)
        for metric in RERANK_METRICS:
            check_rerank(idx, qs, qp, 8, metric)


def test_rerank_exact_on_sharded_index():
    rng = np.random.default_rng(17)
    sh = ShardedSegmentedIndex(L, B, n_shards=3, delta_cap=20,
                               payload_words=WP)
    sk, pay = make_rows(rng, 50)
    ids = sh.insert(sk, payloads=pay)
    sh.delete(ids[::7])
    sh.merge()
    qs = rng.integers(0, 1 << B, size=(2, L), dtype=np.uint8)
    qp = pack_sets([rng.choice(VOCAB, size=5, replace=False)
                    for _ in range(2)], VOCAB)
    for metric in RERANK_METRICS:
        check_rerank(sh, qs, qp, 6, metric)


def test_one_rerank_launch_even_with_many_segments():
    """The acceptance contract: +1 fused dispatch per request, not per
    segment.  Six sealed segments + a live delta still cost exactly one
    re-rank launch, and plain topk costs zero."""
    rng = np.random.default_rng(23)
    idx = SegmentedIndex(L, B, delta_cap=10, payload_words=WP,
                         auto_merge=False)
    for _ in range(6):
        sk, pay = make_rows(rng, 10)
        idx.insert(sk, payloads=pay)
    sk, pay = make_rows(rng, 4)            # live delta rows
    idx.insert(sk, payloads=pay)
    assert len(idx.segments) == 6 and idx.stats()["delta_rows"] == 4
    qs = rng.integers(0, 1 << B, size=(2, L), dtype=np.uint8)
    qp = pack_sets([rng.choice(VOCAB, size=6, replace=False)
                    for _ in range(2)], VOCAB)
    check_rerank(idx, qs, qp, 5, "jaccard", want_rerank_launches=1)
    reset_dispatch_stats()
    idx.topk_batch(qs, 5)
    assert dispatch_stats()["rerank"] == 0


def test_rerank_scores_improve_or_match_sketch_order():
    """Sanity on the knob itself: the query's own payload re-ranks its
    exact duplicate to the top with score 1.0 under every metric."""
    rng = np.random.default_rng(31)
    idx = SegmentedIndex(L, B, delta_cap=16, payload_words=WP)
    sk, pay = make_rows(rng, 40)
    ids = idx.insert(sk, payloads=pay)
    probe = 11
    for metric in RERANK_METRICS:
        res = idx.topk(sk[probe], 3, rerank=metric,
                       q_payloads=pay[probe])
        assert int(res.ids[0]) == int(ids[probe])
        assert float(res.scores[0]) == 1.0


# -- argument contract ---------------------------------------------------

def test_rerank_argument_contract():
    rng = np.random.default_rng(5)
    q = np.zeros((1, L), np.uint8)
    qp = np.zeros((1, WP), np.uint32)
    plain = SegmentedIndex(L, B)
    with pytest.raises(ValueError):        # no payload plane configured
        plain.topk_batch(q, 2, rerank="jaccard", q_payloads=qp)
    with pytest.raises(ValueError):        # payloads without rerank=
        plain.topk_batch(q, 2, q_payloads=qp)
    idx = SegmentedIndex(L, B, payload_words=WP)
    with pytest.raises(ValueError):        # rerank= without payloads
        idx.topk_batch(q, 2, rerank="jaccard")
    with pytest.raises(ValueError):        # unknown metric
        idx.topk_batch(q, 2, rerank="dice", q_payloads=qp)
    with pytest.raises(ValueError):        # wrong payload width
        idx.topk_batch(q, 2, rerank="jaccard",
                       q_payloads=np.zeros((1, WP + 1), np.uint32))
    with pytest.raises(ValueError):        # insert without payloads
        idx.insert(rng.integers(0, 1 << B, size=(3, L), dtype=np.uint8))
    with pytest.raises(ValueError):        # payloads on a plain index
        plain.insert(rng.integers(0, 1 << B, size=(3, L), dtype=np.uint8),
                     payloads=np.zeros((3, WP), np.uint32))


# -- space accounting ----------------------------------------------------

def test_payload_columns_in_space_ledger():
    """Configuring the payload plane grows the ledger by at least the
    payload bitmap bytes on both device (vertical columns / delta plane)
    and host (row-major recovery copies)."""
    rng = np.random.default_rng(41)
    sk, pay = make_rows(rng, 48)
    base = SegmentedIndex(L, B, delta_cap=16, auto_merge=False)
    base.insert(sk)
    with_pay = SegmentedIndex(L, B, delta_cap=16, payload_words=WP,
                              auto_merge=False)
    with_pay.insert(sk, payloads=pay)
    q = sk[:1]
    base.topk_batch(q, 2)                  # materialize the column store
    with_pay.topk_batch(q, 2)
    led0, led1 = base.space_ledger(), with_pay.space_ledger()
    sealed_pay_bytes = sum(s.payloads.nbytes for s in with_pay.segments)
    assert led1["host_bytes"] - led0["host_bytes"] >= sealed_pay_bytes
    assert led1["device_bytes"] - led0["device_bytes"] >= sealed_pay_bytes
    assert led1["model_bits"] == led0["model_bits"]  # succinct model unchanged


def test_cold_tier_rerank_counts_staged_payload_bytes():
    """Under a tiny hot budget the re-rank pass serves demoted blocks
    via the payload staging slab — visible as ``staged_payload_bytes``
    (plain topk on the same index stages only sketch columns)."""
    rng = np.random.default_rng(43)
    idx = SegmentedIndex(L, B, delta_cap=16, payload_words=WP,
                         auto_merge=False, hot_bytes=1)
    sk, pay = make_rows(rng, 48)
    idx.insert(sk, payloads=pay)
    assert idx._refresh_store().pay_bytes("cold") > 0
    qs = rng.integers(0, 1 << B, size=(2, L), dtype=np.uint8)
    qp = pack_sets([rng.choice(VOCAB, size=6, replace=False)
                    for _ in range(2)], VOCAB)
    reset_tier_stats()
    idx.topk_batch(qs, 4)
    assert tier_stats()["staged_payload_bytes"] == 0
    check_rerank(idx, qs, qp, 4, "jaccard")
    ts = tier_stats()
    assert ts["staged_payload_bytes"] > 0
    assert ts["staged_bytes"] >= ts["staged_payload_bytes"]
