"""Serving runtime: the micro-batching scheduler must be semantically
invisible — for any interleaved request stream, per-request results
(masks, dists, ids) are bit-identical to executing each request alone,
in submission order, against the same index state — while coalescing
reads into power-of-two shape buckets (zero new searcher-cache misses
or jit traces after warmup), fencing reads on writes, and rejecting
overload explicitly."""

import threading

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean env: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.core import SegmentedIndex, clear_searcher_cache, \
    searcher_cache_info
from repro.serving import (CollectionConfig, OverloadError, Scheduler,
                           SchedulerConfig, bucket_table)

L, B, TAU, K = 10, 2, 2, 3


def make_stream(rnd, n_ops=18):
    """A deterministic interleaved request stream: bootstrap corpus
    insert, then mixed reads/writes.  Returns [(op, payload), ...]."""
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    corpus = rng.integers(0, 1 << B, size=(24, L), dtype=np.uint8)
    stream = [("insert", corpus)]
    n_inserted = len(corpus)
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.55:
            q = corpus[rng.integers(0, len(corpus))] if rng.random() < 0.7 \
                else rng.integers(0, 1 << B, size=L, dtype=np.uint8)
            stream.append(("search", q) if rng.random() < 0.5
                          else ("topk", q))
        elif r < 0.8:
            rows = rng.integers(0, 1 << B,
                                size=(int(rng.integers(1, 4)), L),
                                dtype=np.uint8)
            stream.append(("insert", rows))
            n_inserted += len(rows)
        else:
            stream.append(
                ("delete", rng.integers(0, n_inserted, size=2)))
    return stream


def run_sequential(stream):
    """The oracle: every request executed alone, in order, on a fresh
    index."""
    idx = SegmentedIndex(L, B, delta_cap=16)
    out = []
    for op, payload in stream:
        if op == "insert":
            out.append(idx.insert(payload))
        elif op == "delete":
            out.append(idx.delete(payload))
        elif op == "search":
            res = idx.search(payload, TAU)
            out.append((np.asarray(res.mask), np.asarray(res.dist)))
        else:
            nn = idx.topk(payload, K)
            out.append((np.asarray(nn.ids), np.asarray(nn.dists)))
    return out


def submit_stream(sched, stream):
    futs = []
    for op, payload in stream:
        if op == "insert":
            futs.append(sched.submit_insert("c", payload))
        elif op == "delete":
            futs.append(sched.submit_delete("c", payload))
        elif op == "search":
            futs.append(sched.submit_search("c", payload, TAU))
        else:
            futs.append(sched.submit_topk("c", payload, K))
    return futs


def check_results(stream, futs, want):
    for (op, _), fut, ref in zip(stream, futs, want):
        got = fut.result(timeout=300)
        if op == "insert":
            np.testing.assert_array_equal(got, ref)
        elif op == "delete":
            assert got == ref
        elif op == "search":
            np.testing.assert_array_equal(got.mask, ref[0])
            np.testing.assert_array_equal(got.dist, ref[1])
        else:  # topk: ids/dists exact; the tau rung is batch-shared
            np.testing.assert_array_equal(got.ids, ref[0])
            np.testing.assert_array_equal(got.dists, ref[1])


def make_sched(**kw):
    cfg = dict(max_batch=8, max_queue=10_000, max_wait_ms=1.0)
    cfg.update(kw)
    sched = Scheduler(config=SchedulerConfig(**cfg))
    sched.create_collection("c", CollectionConfig(L=L, b=B, delta_cap=16))
    return sched


# ---------------------------------------------------------------------------
# the core property: scheduling is semantically invisible
# ---------------------------------------------------------------------------

@settings(max_examples=3, deadline=None)
@given(st.randoms())
def test_interleaved_stream_bit_identical_to_sequential(rnd):
    stream = make_stream(rnd)
    want = run_sequential(stream)
    sched = make_sched()
    futs = submit_stream(sched, stream)     # whole stream queued at once
    sched.pump()                            # sync drive: deterministic
    check_results(stream, futs, want)


def test_incremental_pumping_matches_sequential():
    """Draining the queue in arbitrary chunks (pump between submits)
    must not change any result."""
    import random
    stream = make_stream(random.Random(7), n_ops=12)
    want = run_sequential(stream)
    sched = make_sched()
    futs = []
    for i, item in enumerate(stream):
        futs.extend(submit_stream(sched, [item]))
        if i % 3 == 0:
            sched.pump()
    sched.pump()
    check_results(stream, futs, want)


def test_threaded_mode_matches_sequential():
    """Same property with the worker thread + max-wait flush in play
    (single producer, so submission order is still deterministic)."""
    import random
    stream = make_stream(random.Random(11), n_ops=10)
    want = run_sequential(stream)
    sched = make_sched(max_wait_ms=5.0).start()
    futs = submit_stream(sched, stream)
    check_results(stream, futs, want)
    sched.stop()
    assert sched.queue_depth() == 0


# ---------------------------------------------------------------------------
# batching mechanics
# ---------------------------------------------------------------------------

def test_reads_coalesce_into_one_bucketed_dispatch():
    rng = np.random.default_rng(1)
    sched = make_sched()
    docs = rng.integers(0, 1 << B, size=(30, L), dtype=np.uint8)
    sched.submit_insert("c", docs)
    futs = [sched.submit_search("c", docs[i], TAU) for i in range(5)]
    sched.pump()
    snap = sched.stats()
    # 5 same-key reads -> ONE dispatch, padded 5 -> bucket 8
    assert snap["counters"]["batches_total:search"] == 1
    assert snap["batch_fill_ratio"] == pytest.approx(5 / 8)
    hits = [int(f.result().mask[i]) for i, f in enumerate(futs)]
    assert hits == [1] * 5                  # each query finds itself


def test_mixed_key_reads_split_into_separate_batches():
    rng = np.random.default_rng(2)
    sched = make_sched()
    docs = rng.integers(0, 1 << B, size=(20, L), dtype=np.uint8)
    sched.submit_insert("c", docs)
    f1 = [sched.submit_search("c", docs[i], 1) for i in range(2)]
    f2 = [sched.submit_search("c", docs[i], 2) for i in range(2)]
    f3 = [sched.submit_topk("c", docs[i], K) for i in range(2)]
    sched.pump()
    snap = sched.stats()
    assert snap["counters"]["batches_total:search"] == 2   # tau=1 and tau=2
    assert snap["counters"]["batches_total:topk"] == 1
    for i, f in enumerate(f1 + f2):
        assert int(f.result().mask[i % 2]) == 1
    for i, f in enumerate(f3):
        assert int(f.result().ids[0]) == i


def test_write_fences_reads():
    """A read submitted before a write must not observe it; a read after
    must."""
    sched = make_sched()
    base = np.zeros((4, L), np.uint8)
    sched.submit_insert("c", base)
    probe = np.full(L, 1, np.uint8)
    before = sched.submit_search("c", probe, 0)
    sched.submit_insert("c", probe[None])           # exact match lands
    after = sched.submit_search("c", probe, 0)
    sched.pump()
    assert before.result().mask.sum() == 0          # pre-insert state
    assert after.result().mask.sum() == 1
    assert after.result().mask.shape[0] == 5        # plane grew


def test_overload_rejection():
    sched = make_sched(max_queue=3)
    q = np.zeros(L, np.uint8)
    for _ in range(3):
        sched.submit_search("c", q, TAU)
    with pytest.raises(OverloadError):
        sched.submit_search("c", q, TAU)
    assert sched.stats()["counters"]["rejected_total"] == 1
    assert sched.queue_depth("c") == 3
    sched.pump()                                    # queued work drains
    assert sched.queue_depth("c") == 0


def test_collection_registry_errors():
    sched = make_sched()
    with pytest.raises(KeyError):
        sched.submit_search("nope", np.zeros(L, np.uint8), 1)
    with pytest.raises(ValueError):
        sched.create_collection("c", CollectionConfig(L=L, b=B))
    assert sched.registry.names() == ["c"]
    assert bucket_table(8) == [1, 2, 4, 8]


# ---------------------------------------------------------------------------
# steady state: varying-m traffic never re-jits (acceptance criterion)
# ---------------------------------------------------------------------------

def test_varying_batch_stream_zero_new_cache_misses():
    rng = np.random.default_rng(3)
    sched = make_sched()
    docs = rng.integers(0, 1 << B, size=(64, L), dtype=np.uint8)
    ids = sched.submit_insert("c", docs)
    sched.pump()
    ids = ids.result()
    idx = sched.registry.get("c").index
    idx.flush()                       # single sealed segment, empty delta

    def burst(sizes, offset):
        for g in sizes:
            futs = [sched.submit_search("c", docs[(offset + j) % 60], TAU)
                    for j in range(g)]
            futs += [sched.submit_topk("c", docs[(offset + j) % 60], 1,
                                       tau0=TAU) for j in range(g)]
            sched.pump()
            for f in futs:
                f.result(timeout=300)

    clear_searcher_cache()
    burst((1, 2, 4, 8), offset=0)               # warm every bucket
    sched.submit_delete("c", ids[60:62])        # tombstones are traced data
    sched.pump()
    warm = searcher_cache_info()
    burst((1, 3, 5, 2, 7, 8, 4, 6), offset=5)   # varying-m steady state
    sched.submit_delete("c", ids[62:64])
    sched.pump()
    burst((8, 1, 6, 3), offset=11)
    info = searcher_cache_info()
    assert info["misses"] == warm["misses"], (warm, info)
    assert info["traces"] == warm["traces"], (warm, info)
    assert info["hits"] > warm["hits"]


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_snapshot_and_text_dump():
    sched = make_sched()
    rng = np.random.default_rng(4)
    docs = rng.integers(0, 1 << B, size=(16, L), dtype=np.uint8)
    sched.submit_insert("c", docs)
    for i in range(3):
        sched.submit_topk("c", docs[i], K)
    sched.pump()
    snap = sched.stats()
    assert snap["counters"]["requests_total:topk"] == 3
    assert snap["latency"]["topk"]["count"] == 3
    assert snap["latency"]["topk"]["p99_ms"] >= \
        snap["latency"]["topk"]["p50_ms"]
    assert snap["queue_depth"]["c"] == 0
    assert snap["collections"]["c"]["n_live"] == 16
    text = sched.render_stats()
    for needle in ('serving_requests_total{op="topk"} 3',
                   'serving_latency_p99_ms{op="topk"}',
                   'index_n_live{collection="c"} 16',
                   "serving_batch_fill_ratio",
                   "searcher_cache_traces"):
        assert needle in text, needle


def test_overload_error_carries_context_and_per_op_counter():
    """A shed request's OverloadError names what was rejected, and the
    rejection counters split per op alongside the aggregate."""
    sched = make_sched(max_queue=2)
    q = np.zeros(L, np.uint8)
    sched.submit_search("c", q, TAU)
    sched.submit_search("c", q, TAU)
    with pytest.raises(OverloadError) as ei:
        sched.submit_topk("c", q, K)
    err = ei.value
    assert (err.collection, err.op, err.queue_depth) == ("c", "topk", 2)
    with pytest.raises(OverloadError):
        sched.submit_delete("c", np.asarray([0], np.int64))
    counters = sched.stats()["counters"]
    assert counters["rejected_total"] == 2
    assert counters["rejected_total:topk"] == 1
    assert counters["rejected_total:delete"] == 1
    assert 'serving_rejected_total{op="topk"} 1' in sched.render_stats()
    sched.pump()                                    # queued work drains


def test_executor_exception_fails_batch_but_worker_survives():
    """An exception inside batch execution must surface on the batch's
    futures and increment executor_errors_total — and the queue's only
    worker must keep serving afterwards."""
    rng = np.random.default_rng(6)
    sched = make_sched().start()
    docs = rng.integers(0, 1 << B, size=(8, L), dtype=np.uint8)
    sched.submit_insert("c", docs).result(timeout=300)
    bad = np.full((2, L), 1 << B, np.uint8)         # character out of Σ
    with pytest.raises(ValueError):
        sched.submit_insert("c", bad).result(timeout=300)
    # same worker, next request: still alive and correct
    nn = sched.submit_topk("c", docs[0], 1).result(timeout=300)
    assert int(nn.dists[0]) == 0
    snap = sched.stats()
    assert snap["counters"]["executor_errors_total"] == 1
    assert snap["collections"]["c"]["n_live"] == 8  # bad rows never landed
    sched.stop()


def test_metrics_and_dispatch_counters_survive_threaded_hammering():
    """The process-level dispatch counters and one ServingMetrics are
    bumped from every worker thread — concurrent increments (plus
    snapshots mid-flight) must lose nothing."""
    from repro.core.segments import _dispatch, dispatch_stats
    from repro.serving.metrics import ServingMetrics
    m = ServingMetrics()
    before = dispatch_stats()
    per_thread, n_threads = 400, 8

    def hammer(_):
        for i in range(per_thread):
            _dispatch("fused")
            m.inc("stress_total")
            m.record_latency("op", 1e-3)
            m.record_batch("op", 1, 2)
            if i % 100 == 0:
                m.snapshot()

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    total = per_thread * n_threads
    after = dispatch_stats()
    assert after["total"] - before["total"] == total
    assert after["fused"] - before["fused"] == total
    snap = m.snapshot()
    assert snap["counters"]["stress_total"] == total
    assert snap["counters"]["batches_total:op"] == total
    assert snap["latency"]["op"]["count"] == total
    assert m.batch_fill_ratio() == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# two-stage re-rank requests (DESIGN.md §10)
# ---------------------------------------------------------------------------

RVOCAB = 64
RWP = (RVOCAB + 31) // 32


def _rerank_fixture(seed, n_docs=30):
    from repro.core.hamming import pack_sets
    rng = np.random.default_rng(seed)
    sk = rng.integers(0, 1 << B, size=(n_docs, L), dtype=np.uint8)
    sets = [rng.choice(RVOCAB, size=int(rng.integers(2, 12)), replace=False)
            for _ in range(n_docs)]
    return rng, sk, pack_sets(sets, RVOCAB)


def make_rerank_sched(**kw):
    sched = make_sched(**kw)
    sched.create_collection(
        "r", CollectionConfig(L=L, b=B, delta_cap=16, payload_words=RWP))
    return sched


def test_mixed_rerank_and_plain_stream_bit_identical_to_sequential():
    """Interleaved ``rerank=``/plain topk traffic (plus writes) through
    the scheduler is bit-identical — ids, dists, AND exact scores — to
    executing each request alone, in order; plain responses carry no
    scores."""
    rng, sk, pays = _rerank_fixture(19)
    idx = SegmentedIndex(L, B, delta_cap=16, payload_words=RWP)
    sched = make_rerank_sched()
    # build the mixed stream: (op, args...) executed both ways
    stream = [("insert", sk[:20], pays[:20])]
    for i in range(12):
        if i % 4 == 3:
            stream.append(("insert", sk[20 + i // 4:21 + i // 4],
                           pays[20 + i // 4:21 + i // 4]))
        elif i % 3 == 0:
            stream.append(("topk", sk[i]))
        else:
            metric = "jaccard" if i % 2 else "cosine"
            stream.append(("rerank", sk[i], pays[i], metric))
    stream.append(("delete", np.arange(3, dtype=np.int64)))
    stream.append(("rerank", sk[5], pays[5], "containment"))
    want = []
    for op, *a in stream:
        if op == "insert":
            want.append(idx.insert(a[0], payloads=a[1]))
        elif op == "delete":
            want.append(idx.delete(a[0]))
        elif op == "topk":
            want.append(idx.topk(a[0], K))
        else:
            want.append(idx.topk(a[0], K, rerank=a[2], q_payloads=a[1]))
    futs = []
    for op, *a in stream:
        if op == "insert":
            futs.append(sched.submit_insert("r", a[0], payloads=a[1]))
        elif op == "delete":
            futs.append(sched.submit_delete("r", a[0]))
        elif op == "topk":
            futs.append(sched.submit_topk("r", a[0], K))
        else:
            futs.append(sched.submit_topk("r", a[0], K, rerank=a[2],
                                          q_payload=a[1]))
    sched.pump()
    for (op, *a), fut, ref in zip(stream, futs, want):
        got = fut.result(timeout=300)
        if op == "insert":
            np.testing.assert_array_equal(got, ref)
        elif op == "delete":
            assert got == ref
        else:
            np.testing.assert_array_equal(got.ids, np.asarray(ref.ids))
            np.testing.assert_array_equal(got.dists, np.asarray(ref.dists))
            if op == "topk":
                assert got.scores is None
            else:
                np.testing.assert_array_equal(got.scores,
                                              np.asarray(ref.scores))


def test_rerank_coalesces_only_within_same_metric_key():
    """The batch key is (op, k, τ0, metric): plain and per-metric
    re-rank requests at the same k split into separate dispatches, and
    same-key requests still coalesce (fill ratio counts all three)."""
    rng, sk, pays = _rerank_fixture(29)
    sched = make_rerank_sched()
    sched.submit_insert("r", sk, pays)
    sched.pump()
    futs = [sched.submit_topk("r", sk[i], K) for i in range(3)]
    futs += [sched.submit_topk("r", sk[i], K, rerank="jaccard",
                               q_payload=pays[i]) for i in range(2)]
    futs += [sched.submit_topk("r", sk[i], K, rerank="cosine",
                               q_payload=pays[i]) for i in range(2)]
    sched.pump()
    snap = sched.stats()
    # one batch per key: plain, jaccard, cosine — never merged
    assert snap["counters"]["batches_total:topk"] == 3
    # 3->4, 2->2, 2->2: the coalescing still packs within each key
    assert snap["batch_fill_ratio"] == pytest.approx(7 / 8)
    for i, f in enumerate(futs[:3]):
        assert int(f.result().ids[0]) == i and f.result().scores is None
    for i, f in enumerate(futs[3:5]):
        assert int(f.result().ids[0]) == i
        assert float(f.result().scores[0]) == 1.0
    for f in futs[5:]:
        assert f.result().scores is not None


def test_concurrent_submitters_all_complete():
    """Multiple producer threads against the threaded scheduler: every
    future completes with a sane result (ordering across producers is
    unspecified; completion and shape are not)."""
    rng = np.random.default_rng(5)
    sched = make_sched(max_queue=10_000).start()
    docs = rng.integers(0, 1 << B, size=(40, L), dtype=np.uint8)
    sched.submit_insert("c", docs).result(timeout=300)
    results, errs = [], []

    def client(seed):
        try:
            r = np.random.default_rng(seed)
            for _ in range(5):
                i = int(r.integers(0, len(docs)))
                nn = sched.submit_topk("c", docs[i], 1).result(timeout=300)
                results.append((i, int(nn.ids[0]), int(nn.dists[0])))
        except Exception as e:              # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=client, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    sched.stop()
    assert not errs
    assert len(results) == 20
    for i, nn_id, nn_dist in results:
        assert nn_dist == 0                 # the doc itself (or a dup twin)
        np.testing.assert_array_equal(docs[nn_id], docs[i])
