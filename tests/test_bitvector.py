"""Unit + property tests for the succinct bitvector (rank/select)."""

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean env: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.core.bitvector import BitVector, pack_bits_matrix


def brute_rank(bits, i):
    return int(np.sum(bits[:i]))


def brute_select(bits, k):
    ones = np.flatnonzero(bits)
    if k < 1 or k > len(ones):
        return len(bits)
    return int(ones[k - 1])


def test_paper_example():
    # B = [01101011] -> rank(B,5)=3, select(B,4)=7 with the paper's
    # 1-indexed inclusive rank; ours is exclusive 0-indexed: rank(5)=#1s in [0,5)
    bits = np.array([0, 1, 1, 0, 1, 0, 1, 1])
    bv = BitVector.from_bits(bits)
    assert int(bv.rank(5)) == 3  # paper rank(B,5)=3
    assert int(bv.select(4)) == 6  # paper select(B,4)=7 (1-indexed) -> 0-indexed 6
    assert int(bv.select(99)) == 8  # out of range -> N


def test_rank_select_small_dense():
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, size=257).astype(np.uint8)
    bv = BitVector.from_bits(bits)
    idx = np.arange(258)
    got = np.asarray(bv.rank(jnp.asarray(idx)))
    want = np.array([brute_rank(bits, i) for i in idx])
    np.testing.assert_array_equal(got, want)
    total = int(bits.sum())
    ks = np.arange(1, total + 1)
    got_s = np.asarray(bv.select(jnp.asarray(ks)))
    want_s = np.array([brute_select(bits, k) for k in ks])
    np.testing.assert_array_equal(got_s, want_s)


def test_get():
    bits = np.array([1, 0, 0, 1, 1] * 20, dtype=np.uint8)
    bv = BitVector.from_bits(bits)
    got = np.asarray(bv.get(jnp.arange(len(bits))))
    np.testing.assert_array_equal(got, bits)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 1), min_size=1, max_size=300), st.data())
def test_rank_select_property(bit_list, data):
    bits = np.array(bit_list, dtype=np.uint8)
    bv = BitVector.from_bits(bits)
    i = data.draw(st.integers(0, len(bits)))
    assert int(bv.rank(i)) == brute_rank(bits, i)
    total = int(bits.sum())
    if total:
        k = data.draw(st.integers(1, total))
        assert int(bv.select(k)) == brute_select(bits, k)
    # rank/select inverse: rank(select(k)) == k-1 for valid k
    if total:
        k = data.draw(st.integers(1, total))
        pos = int(bv.select(k))
        assert int(bv.rank(pos)) == k - 1
        assert int(bv.get(pos)) == 1


def test_pack_bits_matrix():
    rng = np.random.default_rng(1)
    bits = rng.integers(0, 2, size=(5, 70)).astype(np.uint8)
    words, pops = pack_bits_matrix(bits)
    assert words.shape == (5, 3)
    np.testing.assert_array_equal(pops, bits.sum(axis=1))
    # unpack round-trip
    for r in range(5):
        unpacked = []
        for w in words[r]:
            unpacked.extend([(int(w) >> i) & 1 for i in range(32)])
        np.testing.assert_array_equal(np.array(unpacked[:70], dtype=np.uint8), bits[r])
