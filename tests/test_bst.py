"""End-to-end correctness of the trie indexes: every searcher must return
exactly the brute-force Hamming-threshold solution set."""

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean env: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.core import (build_bst, build_fst_style, build_louds, build_multi_index,
                        make_batch_searcher, make_searcher, mi_search, search)
from repro.core.trie_builder import build_trie_levels, pick_layers
from repro.core.baselines import SIH, MIH, HmSearch, LinearScan


def brute_mask(db, q, tau):
    return (db != q[None, :]).sum(axis=1) <= tau


def random_db(rng, n, L, b, dup_frac=0.3):
    """Random DB with deliberate duplicates (leaves must aggregate ids)."""
    n_uniq = max(1, int(n * (1 - dup_frac)))
    base = rng.integers(0, 1 << b, size=(n_uniq, L)).astype(np.uint8)
    extra = base[rng.integers(0, n_uniq, size=n - n_uniq)]
    db = np.concatenate([base, extra], axis=0)
    rng.shuffle(db)
    return db


def clustered_db(rng, n, L, b):
    """Clustered DB (realistic: sketches of similar items share prefixes)."""
    n_centers = max(1, n // 20)
    centers = rng.integers(0, 1 << b, size=(n_centers, L)).astype(np.uint8)
    which = rng.integers(0, n_centers, size=n)
    db = centers[which]
    flips = rng.random((n, L)) < 0.1
    noise = rng.integers(0, 1 << b, size=(n, L)).astype(np.uint8)
    return np.where(flips, noise, db).astype(np.uint8)


PAPER_SETTINGS = [(16, 2), (32, 2), (32, 4), (64, 8)]  # (L, b) of the 4 datasets


@pytest.mark.parametrize("L,b", PAPER_SETTINGS)
@pytest.mark.parametrize("tau", [0, 1, 3])
def test_bst_exact_vs_bruteforce(L, b, tau):
    rng = np.random.default_rng(L * 10 + b + tau)
    db = random_db(rng, 300, L, b)
    idx = build_bst(db, b)
    for qi in range(4):
        q = db[rng.integers(0, len(db))] if qi % 2 == 0 else \
            rng.integers(0, 1 << b, size=L).astype(np.uint8)
        res = search(idx, q, tau)
        assert int(res.overflow) == 0
        np.testing.assert_array_equal(np.asarray(res.mask), brute_mask(db, q, tau))


@pytest.mark.parametrize("builder", [build_bst, build_louds, build_fst_style])
def test_all_structures_agree(builder):
    rng = np.random.default_rng(0)
    db = clustered_db(rng, 400, 16, 2)
    idx = builder(db, 2)
    for tau in [1, 2, 4]:
        q = db[5]
        res = search(idx, q, tau)
        assert int(res.overflow) == 0
        np.testing.assert_array_equal(np.asarray(res.mask), brute_mask(db, q, tau))


def test_layer_structure_sane():
    rng = np.random.default_rng(1)
    # uniform random sketches over a small alphabet -> nontrivial dense layer
    db = rng.integers(0, 4, size=(4096, 16)).astype(np.uint8)
    trie = build_trie_levels(db, 2)
    lm, ls = pick_layers(trie)
    assert 0 <= lm <= ls <= 16
    assert trie.t[16] == len(np.unique(db.view(f"V16").reshape(-1)))
    # dense layer really is complete
    for lev in range(1, lm + 1):
        assert trie.t[lev] == 4 ** lev
    idx = build_bst(db, 2, trie=trie)
    assert idx.lm == lm and idx.ls == ls
    # space accounting is positive and the model is below pointer-trie scale
    t_total = sum(trie.t[1:])
    assert 0 < idx.model_bits() < 64 * t_total


def test_batched_searcher():
    rng = np.random.default_rng(2)
    db = random_db(rng, 200, 16, 2)
    idx = build_bst(db, 2)
    qs = np.stack([db[3], db[7], rng.integers(0, 4, size=16).astype(np.uint8)])
    run = make_batch_searcher(idx, tau=2)
    res = run(jnp.asarray(qs))
    assert res.mask.shape == (3, 200)
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(res.mask[i]), brute_mask(db, qs[i], 2))


def test_multi_index_exact():
    rng = np.random.default_rng(3)
    db = clustered_db(rng, 500, 32, 2)
    for m in [2, 3, 4]:
        mi = build_multi_index(db, 2, m)
        for tau in [2, 5]:
            q = db[11]
            res = mi_search(mi, q, tau)
            assert int(res.overflow) == 0
            np.testing.assert_array_equal(np.asarray(res.mask), brute_mask(db, q, tau))
            # filtering really filters: candidates < n (clustered DB)
            assert int(res.candidates) <= 500


def test_baselines_exact():
    rng = np.random.default_rng(4)
    db = random_db(rng, 250, 16, 2)
    q = db[0]
    tau = 2
    want = brute_mask(db, q, tau)
    sih = SIH.build(db, 2)
    got, truncated = sih.search(q, tau)
    assert not truncated
    np.testing.assert_array_equal(got, want)
    mih = MIH.build(db, 2, m=2)
    got, truncated, ncand = mih.search(q, tau)
    assert not truncated
    np.testing.assert_array_equal(got, want)
    hm = HmSearch.build(db, 2, tau)
    got, ncand = hm.search(q, tau)
    np.testing.assert_array_equal(got, want)
    lin = LinearScan.build(db, 2)
    np.testing.assert_array_equal(lin.search(q, tau), want)


def test_hmsearch_b8_no_wildcard_collision():
    rng = np.random.default_rng(5)
    db = rng.integers(250, 256, size=(100, 8)).astype(np.uint8)  # chars near 255
    q = db[1]
    hm = HmSearch.build(db, 8, tau=2)
    got, _ = hm.search(q, 2)
    np.testing.assert_array_equal(got, brute_mask(db, q, 2))


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 4), st.integers(4, 20), st.integers(20, 120),
       st.integers(0, 4), st.randoms())
def test_bst_property(b, L, n, tau, rnd):
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    db = random_db(rng, n, L, b)
    idx = build_bst(db, b)
    q = rng.integers(0, 1 << b, size=L).astype(np.uint8)
    res = search(idx, q, tau)
    assert int(res.overflow) == 0
    np.testing.assert_array_equal(np.asarray(res.mask), brute_mask(db, q, tau))


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 3), st.integers(2, 4), st.integers(1, 5), st.randoms())
def test_multi_index_property(b, m, tau, rnd):
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    L = 4 * m
    db = random_db(rng, 80, L, b)
    mi = build_multi_index(db, b, m)
    q = rng.integers(0, 1 << b, size=L).astype(np.uint8)
    res = mi_search(mi, q, tau)
    np.testing.assert_array_equal(np.asarray(res.mask), brute_mask(db, q, tau))


def test_overflow_ladder_recovers():
    """Force a tiny capacity; the ladder must still deliver exact results."""
    rng = np.random.default_rng(6)
    db = random_db(rng, 300, 16, 2, dup_frac=0.0)
    idx = build_bst(db, 2)
    q = db[0]
    res = search(idx, q, tau=4, cap_max=4)  # absurdly small start
    np.testing.assert_array_equal(np.asarray(res.mask), brute_mask(db, q, 4))
