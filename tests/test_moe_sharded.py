"""shard_map expert-parallel MoE == GSPMD-local MoE == dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import moe_apply, moe_apply_dense, moe_init
from repro.models.moe_sharded import moe_apply_sharded


def _setup(E=8, k=2, d=32, ff=16, shared=0, seed=0):
    params = moe_init(jax.random.PRNGKey(seed), d, E, ff, shared,
                      jnp.float32)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, 12, d)), jnp.float32)
    return params, x


@pytest.mark.parametrize("shared", [0, 1])
def test_sharded_matches_local(shared):
    params, x = _setup(shared=shared)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    lossless = 8 / 2  # cap covers all tokens -> no drops
    y_local = moe_apply(params, x, top_k=2, act="silu",
                        capacity_factor=lossless)
    y_sh = moe_apply_sharded(params, x, mesh, top_k=2, act="silu",
                             capacity_factor=lossless)
    np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_local),
                               rtol=1e-5, atol=1e-5)
    y_dense = moe_apply_dense(params, x, top_k=2, act="silu")
    np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_dense),
                               rtol=1e-5, atol=1e-5)


def test_sharded_grads_match_local():
    params, x = _setup()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    lossless = 4.0

    def loss_local(p, x):
        return moe_apply(p, x, top_k=2, act="silu",
                         capacity_factor=lossless).sum()

    def loss_sh(p, x):
        return moe_apply_sharded(p, x, mesh, top_k=2, act="silu",
                                 capacity_factor=lossless).sum()

    g1 = jax.grad(loss_local)(params, x)
    g2 = jax.grad(loss_sh)(params, x)
    for (p1, a), (p2, b) in zip(
            jax.tree_util.tree_leaves_with_path(g1),
            jax.tree_util.tree_leaves_with_path(g2)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-4, err_msg=str(p1))


def test_sharded_capacity_drops_match_local():
    """With a tight capacity both implementations drop the SAME tokens
    (same deterministic cumsum order)."""
    params, x = _setup(seed=3)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    y_local = moe_apply(params, x, top_k=2, act="silu", capacity_factor=0.5)
    y_sh = moe_apply_sharded(params, x, mesh, top_k=2, act="silu",
                             capacity_factor=0.5)
    np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_local),
                               rtol=1e-5, atol=1e-5)
