"""Query-tiled batched verify kernel + natively batched traversal.

Kernel: interpret-mode bit-exactness of ``ops.sparse_verify_batch``
against the per-query oracle across tile-misaligned m and n, the m=1
degenerate tile, BIG clamping, and pad lanes; the grid really is
(⌈m/block_m⌉, ⌈n/block_n⌉) — the database is streamed once per query
TILE, not once per query.

Traversal: ``make_batch_searcher`` (the 2D-frontier batch trace) is
bit-identical to the per-query searcher, and ``topk_batch`` equals a
per-query ``topk`` loop.
"""

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean env: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.core import hamming as H
from repro.core.bst import BIG, build_bst, build_louds
from repro.core.search import (get_searcher, make_batch_searcher, topk,
                               topk_batch)
from repro.kernels import hamming_kernel, ops, ref
from repro.kernels.hamming_kernel import sparse_verify_batch_pallas


def make_db(rng, n, L, b):
    db = rng.integers(0, 1 << b, size=(n, L)).astype(np.uint8)
    planes = H.pack_vertical(db, b)          # (n, b, W)
    vert = np.transpose(planes, (1, 2, 0))   # (b, W, n)
    return db, jnp.asarray(vert)


# ---------------------------------------------------------------------------
# kernel bit-exactness vs the per-query oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,L,tau", [(2, 16, 2), (4, 32, 5), (8, 64, 3)])
@pytest.mark.parametrize("m,n,block_m,block_n", [
    (5, 390, 2, 128),    # neither m nor n a tile multiple
    (8, 384, 4, 128),    # both exact multiples
    (1, 200, 4, 128),    # m=1 degenerate tile (m < block_m)
    (3, 100, 8, 256),    # n < block_n entirely inside one padded block
])
def test_batch_verify_matches_per_query_oracle(b, L, tau, m, n, block_m,
                                               block_n):
    rng = np.random.default_rng(b * 100 + L + m + n)
    db, paths_vert = make_db(rng, n, L, b)
    qs, q_vert = make_db(rng, m, L, b)
    base = rng.integers(0, tau + 3, size=(m, n)).astype(np.int32)
    got, got_d = ops.sparse_verify_batch(paths_vert, q_vert,
                                         jnp.asarray(base), tau=tau,
                                         block_m=block_m, block_n=block_n,
                                         use_kernel=True)
    got, got_d = np.asarray(got), np.asarray(got_d)
    assert got.shape == got_d.shape == (m, n)
    for i in range(m):
        want, want_d = ref.sparse_verify_ref(paths_vert, q_vert[..., i],
                                             jnp.asarray(base[i]), tau)
        np.testing.assert_array_equal(got[i], np.asarray(want).astype(np.int32))
        np.testing.assert_array_equal(got_d[i], np.asarray(want_d))
    # distances are exact: base + per-query suffix Hamming distance
    suffix = (qs[:, None, :] != db[None, :, :]).sum(axis=2)
    np.testing.assert_array_equal(got_d, base + suffix)


def test_batch_verify_big_clamps_and_pad_lanes_never_survive():
    """BIG base distances (pruned subtries) clamp to exactly BIG, and the
    raw kernel's pad lanes (base = BIG beyond n) emit mask 0."""
    rng = np.random.default_rng(7)
    b, L, m, n, block_m, block_n = 2, 16, 4, 128, 2, 128
    _, paths_vert = make_db(rng, n, L, b)
    _, q_vert = make_db(rng, m, L, b)
    base = np.zeros((m, n), np.int32)
    base[1, :] = int(BIG)                  # query 1: everything pruned
    base[0, ::2] = int(BIG)                # query 0: alternate leaves pruned
    mask, dist = ops.sparse_verify_batch(paths_vert, q_vert,
                                         jnp.asarray(base), tau=L,
                                         block_m=block_m, block_n=block_n,
                                         use_kernel=True)
    mask, dist = np.asarray(mask), np.asarray(dist)
    pruned = base >= int(BIG)
    assert (mask[pruned] == 0).all()
    assert (dist[pruned] == int(BIG)).all()
    assert mask[1].sum() == 0
    # raw kernel with explicit pads: pad base lanes carry BIG -> mask 0
    pad_n = 2 * block_n
    paths_p = jnp.pad(paths_vert, ((0, 0), (0, 0), (0, pad_n - n)))
    base_p = jnp.pad(jnp.asarray(base), ((0, 0), (0, pad_n - n)),
                     constant_values=jnp.int32(BIG))
    pmask, pdist = sparse_verify_batch_pallas(paths_p, q_vert, base_p,
                                              tau=L, block_m=block_m,
                                              block_n=block_n, interpret=True)
    assert (np.asarray(pmask)[:, n:] == 0).all()
    assert (np.asarray(pdist)[:, n:] == int(BIG)).all()


def test_batch_verify_grid_streams_db_once_per_query_tile(monkeypatch):
    """The pallas grid is (⌈m/block_m⌉, ⌈n/block_n⌉): the HBM-traffic
    claim — the database block axis is walked once per query TILE."""
    captured = {}
    real_call = hamming_kernel.pl.pallas_call

    def spy(kernel, **kw):
        captured["grid"] = kw.get("grid")
        return real_call(kernel, **kw)

    monkeypatch.setattr(hamming_kernel.pl, "pallas_call", spy)
    rng = np.random.default_rng(3)
    b, L, m, n, block_m, block_n = 2, 16, 19, 1000, 4, 128
    _, paths_vert = make_db(rng, n, L, b)
    _, q_vert = make_db(rng, m, L, b)
    base = jnp.zeros((m, n), jnp.int32)
    ops.sparse_verify_batch(paths_vert, q_vert, base, tau=3,
                            block_m=block_m, block_n=block_n,
                            use_kernel=True)
    m_tiles = -(-m // block_m)
    n_tiles = -(-n // block_n)
    assert captured["grid"] == (m_tiles, n_tiles), captured


def test_hamming_distances_query_tiled_matches_oracle():
    rng = np.random.default_rng(9)
    b, L, m, n = 4, 32, 11, 700
    db, db_vert = make_db(rng, n, L, b)
    qs, q_vert = make_db(rng, m, L, b)
    got = np.asarray(ops.hamming_distances(db_vert, q_vert, block_m=4,
                                           block_n=128, use_kernel=True))
    brute = (qs[:, None, :] != db[None, :, :]).sum(axis=2)
    np.testing.assert_array_equal(got, brute)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(2, 40), st.integers(1, 9),
       st.integers(1, 260), st.integers(0, 5), st.randoms())
def test_batch_verify_property(b, L, m, n, tau, rnd):
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    db, paths_vert = make_db(rng, n, L, b)
    qs, q_vert = make_db(rng, m, L, b)
    base = rng.integers(0, 4, size=(m, n)).astype(np.int32)
    got, got_d = ops.sparse_verify_batch(paths_vert, q_vert,
                                         jnp.asarray(base), tau=tau,
                                         block_m=4, block_n=128,
                                         use_kernel=True)
    suffix = (qs[:, None, :] != db[None, :, :]).sum(axis=2)
    np.testing.assert_array_equal(np.asarray(got),
                                  ((base + suffix) <= tau).astype(np.int32))
    np.testing.assert_array_equal(np.asarray(got_d), base + suffix)


# ---------------------------------------------------------------------------
# natively batched traversal == per-query path
# ---------------------------------------------------------------------------

def random_db(rng, n, L, b, dup_frac=0.3):
    n_uniq = max(1, int(n * (1 - dup_frac)))
    base = rng.integers(0, 1 << b, size=(n_uniq, L)).astype(np.uint8)
    extra = base[rng.integers(0, n_uniq, size=n - n_uniq)]
    db = np.concatenate([base, extra], axis=0)
    rng.shuffle(db)
    return db


@pytest.mark.parametrize("builder", [build_bst, build_louds])
@pytest.mark.parametrize("tau", [0, 2, 4])
def test_batch_searcher_bit_identical_to_per_query(builder, tau):
    rng = np.random.default_rng(tau * 7 + 1)
    db = random_db(rng, 260, 14, 2)
    idx = builder(db, 2)
    qs = np.concatenate([db[:3], rng.integers(0, 4, size=(3, 14),
                                              dtype=np.uint8)])
    bres = make_batch_searcher(idx, tau, block_m=2)(jnp.asarray(qs))
    assert bres.overflow.shape == (len(qs),)
    for i in range(len(qs)):
        sres = get_searcher(idx, tau)(jnp.asarray(qs[i]))
        np.testing.assert_array_equal(np.asarray(bres.mask[i]),
                                      np.asarray(sres.mask))
        np.testing.assert_array_equal(np.asarray(bres.dist[i]),
                                      np.asarray(sres.dist))
        assert int(bres.overflow[i]) == int(sres.overflow)
        assert int(bres.traversed[i]) == int(sres.traversed)


def test_mi_search_batch_bit_identical_to_per_query():
    """The batched multi-index path (per-block 2D-frontier traces +
    per-query candidate compaction/verification) equals the single-query
    searcher and brute force."""
    from repro.core.multi_index import (build_multi_index, make_mi_searcher,
                                        mi_search_batch)
    rng = np.random.default_rng(19)
    db = random_db(rng, 280, 32, 2)
    mi = build_multi_index(db, 2, 2)
    tau = 4
    qs = np.stack([db[5], db[60],
                   rng.integers(0, 4, size=32).astype(np.uint8)])
    bres = mi_search_batch(mi, qs, tau)
    single = make_mi_searcher(mi, tau)
    for i in range(len(qs)):
        sres = single(jnp.asarray(qs[i]))
        np.testing.assert_array_equal(np.asarray(bres.mask[i]),
                                      np.asarray(sres.mask))
        np.testing.assert_array_equal(np.asarray(bres.dist[i]),
                                      np.asarray(sres.dist))
        assert int(bres.candidates[i]) == int(sres.candidates)
        d = (db != qs[i][None, :]).sum(axis=1)
        np.testing.assert_array_equal(np.asarray(bres.mask[i]), d <= tau)
        got_d = np.asarray(bres.dist[i])
        np.testing.assert_array_equal(got_d[d <= tau], d[d <= tau])
        assert (got_d[d > tau] == int(BIG)).all()


def test_sharded_scan_kernel_path_under_shard_vmap():
    """Shards large enough that the auto backend picks the pallas kernel
    (t_Lmax >= one block): the batch verify must vmap over the shard
    axis and still match brute force."""
    from repro.core.distributed_search import (build_sharded_bst, gather_ids,
                                               make_sharded_searcher)
    from repro.core.hamming import hamming_pairwise_naive
    rng = np.random.default_rng(21)
    n, L, b, tau, m = 6000, 12, 2, 1, 5
    db = rng.integers(0, 1 << b, size=(n, L)).astype(np.uint8)
    queries = np.concatenate(
        [db[:2], rng.integers(0, 1 << b, size=(m - 2, L), dtype=np.uint8)])
    index = build_sharded_bst(db, b, 2)
    assert index.paths_vert.shape[-1] >= hamming_kernel.DEFAULT_BLOCK_N
    masks, sdists, overflow = make_sharded_searcher(
        index, tau, cap_max=1 << 15, block_m=2)(jnp.asarray(queries))
    assert int(overflow) == 0
    got = gather_ids(index, np.asarray(masks))
    dists = np.asarray(hamming_pairwise_naive(jnp.asarray(queries),
                                              jnp.asarray(db)))
    for qi in range(m):
        want = np.flatnonzero(dists[qi] <= tau)
        np.testing.assert_array_equal(got[qi], want)
        dvec = np.asarray(sdists[qi])[index.shard_of, index.pos_of]
        np.testing.assert_array_equal(dvec[want], dists[qi][want])


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 3), st.integers(2, 30), st.integers(1, 6),
       st.randoms())
def test_topk_batch_equals_per_query_topk_loop(b, k, m, rnd):
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    L = {1: 20, 2: 14, 3: 10}[b]
    db = random_db(rng, 180, L, b)
    idx = build_bst(db, b)
    qs = np.stack([db[rng.integers(0, len(db))] if i % 2 == 0 else
                   rng.integers(0, 1 << b, size=L).astype(np.uint8)
                   for i in range(m)])
    bres = topk_batch(idx, qs, k)
    for i in range(m):
        # same final tau rung so the compiled searcher (and result) agree
        sres = topk(idx, qs[i], k, tau0=bres.tau)
        np.testing.assert_array_equal(np.asarray(bres.ids[i]),
                                      np.asarray(sres.ids))
        np.testing.assert_array_equal(np.asarray(bres.dists[i]),
                                      np.asarray(sres.dists))
