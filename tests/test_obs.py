"""Observability (DESIGN.md §11): ``explain=True`` must be bit-identical
to the plain call on every backend; tracing disabled must add zero
device dispatches (the instrumentation points are shared no-ops); the
trace ring is bounded; the Chrome export loads and nests; the metrics
exposition round-trips through a strict Prometheus parser; and a fresh
``ServingMetrics`` never sees another instance's process-global traffic.
"""

import json
import os
import sys

import numpy as np
import pytest

from repro.core.segments import (SegmentedIndex, ShardedSegmentedIndex,
                                 dispatch_stats)
from repro.core.hamming import pack_sets
from repro.obs import (QueryExplain, SlowQueryLog, Span, Tracer, attach,
                       chrome_trace, format_value, parse_exposition, span)
from repro.obs.prom import Histogram
from repro.obs.trace import _NULL, current
from repro.serving import (CollectionConfig, Scheduler, SchedulerConfig,
                           ServingMetrics)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import trace_report  # noqa: E402

L, B = 12, 2
RNG = np.random.default_rng(7)
SKETCHES = RNG.integers(0, 1 << B, size=(180, L), dtype=np.uint8)
QUERY = SKETCHES[11]


def _filled(index):
    index.insert(SKETCHES)
    if hasattr(index, "flush"):
        index.flush()
    return index


# -- explain bit-identity ------------------------------------------------

@pytest.mark.parametrize("backend", ["bst", "multi"])
def test_explain_topk_bit_identical(backend):
    idx = _filled(SegmentedIndex(L=L, b=B, delta_cap=64, backend=backend))
    plain = idx.topk(QUERY, k=4)
    res, ex = idx.topk(QUERY, k=4, explain=True)
    np.testing.assert_array_equal(np.asarray(plain.ids), np.asarray(res.ids))
    np.testing.assert_array_equal(np.asarray(plain.dists),
                                  np.asarray(res.dists))
    assert plain.tau == res.tau and plain.overflow == res.overflow
    assert isinstance(ex, QueryExplain)
    assert ex.op == "topk" and ex.backend == backend
    assert ex.tau_final == res.tau and ex.k == 4
    assert ex.n_live == idx.n_live
    assert len(ex.rungs) >= 1 and ex.rungs[-1].tau == res.tau
    for rung in ex.rungs:
        assert rung.candidates >= 0
        assert len(rung.survivors) == len(rung.pruned) == 1
        # pruned + survivors partition the physical candidate columns
        assert rung.survivors[0] + rung.pruned[0] == rung.candidates
    assert ex.candidates_verified == sum(r.survivors[0] for r in ex.rungs)
    assert "rung tau=" in ex.summary()


def test_explain_sharded_bit_identical():
    idx = _filled(ShardedSegmentedIndex(L=L, b=B, delta_cap=64, n_shards=2))
    plain = idx.topk(QUERY, k=4)
    res, ex = idx.topk(QUERY, k=4, explain=True)
    np.testing.assert_array_equal(np.asarray(plain.ids), np.asarray(res.ids))
    np.testing.assert_array_equal(np.asarray(plain.dists),
                                  np.asarray(res.dists))
    assert ex.backend == "sharded-stacks"
    sres, sex = idx.search(QUERY, tau=3, explain=True)
    assert sex.op == "search" and sex.tau0 == 3


def test_explain_search_and_batch():
    idx = _filled(SegmentedIndex(L=L, b=B, delta_cap=64))
    plain = idx.search_batch(SKETCHES[:3], tau=3)
    res, ex = idx.search_batch(SKETCHES[:3], tau=3, explain=True)
    np.testing.assert_array_equal(np.asarray(plain.mask),
                                  np.asarray(res.mask))
    np.testing.assert_array_equal(np.asarray(plain.dist),
                                  np.asarray(res.dist))
    assert ex.n_queries == 3
    # per-query survivor counts match the dense mask row sums
    np.testing.assert_array_equal(
        np.asarray(ex.rungs[-1].survivors),
        np.asarray(plain.mask).sum(axis=1))


def test_explain_rerank_bit_identical():
    sets = [RNG.choice(64, size=9, replace=False) for _ in range(len(SKETCHES))]
    pays = pack_sets(sets, 64)
    idx = SegmentedIndex(L=L, b=B, delta_cap=64,
                         payload_words=pays.shape[1])
    idx.insert(SKETCHES, payloads=pays)
    idx.flush()
    plain = idx.topk(QUERY, k=4, rerank="jaccard", q_payloads=pays[11])
    res, ex = idx.topk(QUERY, k=4, rerank="jaccard", q_payloads=pays[11],
                       explain=True)
    np.testing.assert_array_equal(np.asarray(plain.ids), np.asarray(res.ids))
    np.testing.assert_array_equal(np.asarray(plain.scores),
                                  np.asarray(res.scores))
    assert ex.rerank == "jaccard"
    assert ex.rerank_survivors == ex.rungs[-1].survivors


def test_explain_frontier_widths_bst_only():
    idx = _filled(SegmentedIndex(L=L, b=B, delta_cap=64))
    _, ex = idx.topk(QUERY, k=4, explain=True)
    fr = ex.rungs[-1].frontier
    assert fr is not None and len(fr) == 1      # one query
    assert len(fr[0]) == L                      # one width per trie level
    assert fr[0][0] >= 1                        # root level is live
    _, ex_multi = _filled(SegmentedIndex(
        L=L, b=B, delta_cap=64, backend="multi")).topk(
            QUERY, k=4, explain=True)
    assert ex_multi.rungs[-1].frontier is None


# -- tracing: disabled is free, enabled nests ----------------------------

def test_span_disabled_is_shared_noop():
    assert current() is None
    assert span("anything", cat="x", a=1) is _NULL
    with span("nested"):        # no context attached: nothing recorded
        pass
    assert current() is None


def test_tracing_disabled_zero_extra_dispatches():
    idx = _filled(SegmentedIndex(L=L, b=B, delta_cap=64))
    idx.topk(QUERY, k=4)                        # warm the compiled program
    d0 = dispatch_stats()
    plain = idx.topk(QUERY, k=4)
    d_plain = {k: v - d0[k] for k, v in dispatch_stats().items()}

    root = Span("request")
    d1 = dispatch_stats()
    with attach(root):
        traced = idx.topk(QUERY, k=4)
    d_traced = {k: v - d1[k] for k, v in dispatch_stats().items()}
    # spans are host wall-clock only: the device ledger is identical
    assert d_traced == d_plain
    np.testing.assert_array_equal(np.asarray(plain.ids),
                                  np.asarray(traced.ids))
    assert root.find("rung_dispatch") is not None
    assert root.find("topk_readback") is not None


def test_tracer_ring_bounded():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.add(Span(f"r{i}"))
    assert len(tr) == 4
    assert [s.name for s in tr.roots()] == ["r6", "r7", "r8", "r9"]
    tr.clear()
    assert len(tr) == 0


# -- scheduler span trees + Chrome export --------------------------------

def _traced_run(tmp_path):
    tracer = Tracer()
    sched = Scheduler(config=SchedulerConfig(slow_ms=0.0), tracer=tracer)
    sched.create_collection("c", CollectionConfig(L=L, b=B))
    sched.submit_insert("c", SKETCHES)
    futs = [sched.submit_topk("c", SKETCHES[i], k=3) for i in range(5)]
    futs.append(sched.submit_search("c", QUERY, 3))
    sched.pump()
    for f in futs:
        f.result()
    return tracer, sched


def test_scheduler_span_tree_and_chrome_json(tmp_path):
    tracer, sched = _traced_run(tmp_path)
    roots = tracer.roots()
    assert len(roots) == 7                      # 1 insert + 5 topk + 1 search
    read = next(r for r in roots if r.args["op"] == "topk")
    names = [c.name for c in read.children]
    assert names[0] == "queue_wait" and "batch" in names
    batch = read.find("batch")
    assert batch.find("execute") is not None
    assert batch.find("rung_dispatch") is not None
    # queue_wait + batch cover the request end-to-end exactly
    qw = read.find("queue_wait")
    assert abs((qw.dur + batch.dur) - read.dur) < 1e-6

    path = tracer.write_chrome(str(tmp_path / "trace.json"))
    events = json.load(open(path))
    xs = [e for e in events if e["ph"] == "X"]
    assert {e["name"] for e in xs} >= {"request", "queue_wait", "batch",
                                       "execute", "rung_dispatch"}
    # the shared batch span emits once despite 5 linking roots
    assert sum(e["name"] == "batch" and e["args"]["op"] == "topk"
               for e in xs) == 1
    # trace_report accepts it: nesting valid, >=1 complete request tree
    assert trace_report.check_nesting(events) >= 2
    trees = trace_report.request_trees(events)
    assert any(qw is not None and b is not None for _, qw, b in trees)
    assert trace_report.report(str(tmp_path), check=True) == 0

    # slow_ms=0.0: every request also landed in the slow-query log
    assert len(sched.slowlog) == 7
    entry = sched.slowlog.entries()[-1]
    assert entry["spans"]["name"] == "request" and entry["e2e_ms"] >= 0


def test_slowlog_ring_and_jsonl(tmp_path):
    p = str(tmp_path / "slow.jsonl")
    log = SlowQueryLog(capacity=2, path=p)
    for i in range(5):
        sp = Span(f"request")
        sp.dur = i / 1e3
        log.record(sp, op="topk")
    assert len(log) == 2 and log.dropped == 3
    lines = [json.loads(x) for x in open(p)]
    assert len(lines) == 5                      # the file keeps everything
    assert lines[-1]["op"] == "topk"


# -- Prometheus exposition ----------------------------------------------

def test_format_value_round_trips():
    for v in (0, 3, -17, 0.1, 0.30000000000000004, 1e-9, 2.5, 3.0,
              float("inf"), float("-inf")):
        s = format_value(v)
        assert float(s) == float(v) or (s in ("+Inf", "-Inf"))
    assert format_value(3.0) == "3"
    assert format_value(True) == "1"
    assert format_value(float("nan")) == "NaN"


def test_histogram_cumulative_monotone():
    h = Histogram(buckets=(0.001, 0.01, 0.1))
    for v in (0.0005, 0.005, 0.05, 0.5, 0.05):
        h.observe(v)
    cum = h.cumulative()
    assert cum[-1] == ("+Inf", 5)
    counts = [c for _, c in cum]
    assert counts == sorted(counts)
    lines = h.sample_lines("lat", 'op="topk"')
    assert lines[-1] == "lat_count{op=\"topk\"} 5"


def test_render_text_parses_as_prometheus():
    sched = Scheduler()
    sched.create_collection("c", CollectionConfig(L=L, b=B))
    sched.submit_insert("c", SKETCHES)
    futs = [sched.submit_topk("c", SKETCHES[i], k=3) for i in range(3)]
    sched.pump()
    for f in futs:
        f.result()
    text = sched.render_stats()
    parsed = parse_exposition(text)
    names = {s[0] for s in parsed["samples"]}
    assert "serving_latency_seconds_bucket" in names
    assert "serving_queue_latency_seconds_count" in names
    assert parsed["types"]["serving_latency_seconds"] == "histogram"
    assert ("serving_requests_total", {"op": "topk"}, 3.0) in \
        parsed["samples"]
    assert 'index_n_live{collection="c"}' in text


def test_parser_rejects_malformed():
    with pytest.raises(ValueError):
        parse_exposition("# TYPE x bogus\nx 1\n")
    with pytest.raises(ValueError):
        parse_exposition("# TYPE x counter\nx{op=} 1\n")
    with pytest.raises(ValueError):
        parse_exposition("# TYPE x counter\nx notanumber\n")
    with pytest.raises(ValueError):
        parse_exposition("orphan_sample 1\n")   # no TYPE line


# -- cross-instance metrics isolation (satellite a) ----------------------

def test_metrics_deltas_not_bled_across_instances():
    idx = _filled(SegmentedIndex(L=L, b=B, delta_cap=64))
    idx.topk(QUERY, k=4)                # traffic before the scheduler
    m = ServingMetrics()                # baselines at construction
    snap = m.snapshot()
    assert all(v == 0 for v in snap["device_dispatch"].values())
    assert snap["searcher_cache"]["hits"] == 0
    assert snap["searcher_cache"]["misses"] == 0
    assert snap["searcher_cache"]["traces"] == 0
    assert all(v == 0 for v in snap["tier"].values())
    idx.topk(SKETCHES[5], k=4)          # traffic after: the delta sees it
    snap2 = m.snapshot()
    assert snap2["device_dispatch"]["total"] >= 1
    m.rebaseline()
    assert all(v == 0
               for v in m.snapshot()["device_dispatch"].values())
