"""Compiled-searcher cache behavior: the FIFO bound really evicts,
``clear_searcher_cache`` resets every counter, and the power-of-two
m-bucketing makes a varying-batch-size query stream hit one compiled
trace per bucket (zero new misses AND zero new jit traces after
warmup)."""

import numpy as np
import jax.numpy as jnp
import pytest

import importlib

from repro.core import (build_bst, bucket_m, clear_searcher_cache,
                        get_searcher, make_batch_searcher,
                        searcher_cache_info)

# the package re-exports the search() *function* under the same name, so
# fetch the module itself for monkeypatching
search_mod = importlib.import_module("repro.core.search")


@pytest.fixture
def idx():
    rng = np.random.default_rng(5)
    db = rng.integers(0, 4, size=(220, 14), dtype=np.uint8)
    return build_bst(db, 2)


def test_bucket_m_values():
    assert [bucket_m(m) for m in (1, 2, 3, 4, 5, 7, 8, 9, 63, 64)] == \
        [1, 2, 4, 4, 8, 8, 8, 16, 64, 64]
    with pytest.raises(ValueError):
        bucket_m(0)


def test_fifo_bound_actually_evicts(idx, monkeypatch):
    monkeypatch.setattr(search_mod, "_SEARCHER_CACHE_CAP", 3)
    clear_searcher_cache()
    for tau in range(5):                      # 5 distinct rungs, cap 3
        get_searcher(idx, tau)
    info = searcher_cache_info()
    assert info["size"] == 3
    assert info["misses"] == 5
    # FIFO: the oldest rungs (tau=0, 1) were evicted -> fresh misses;
    # the newest (tau=4) is still resident -> a hit
    get_searcher(idx, 4)
    assert searcher_cache_info()["hits"] == 1
    get_searcher(idx, 0)
    assert searcher_cache_info()["misses"] == 6


def test_clear_resets_counters(idx):
    get_searcher(idx, 1)(jnp.asarray(np.zeros(14, np.uint8)))
    assert searcher_cache_info()["misses"] >= 1
    clear_searcher_cache()
    assert searcher_cache_info() == {"hits": 0, "misses": 0, "traces": 0,
                                     "size": 0}


def test_bucketed_dispatch_is_cache_hit_across_m(idx):
    """Satellite bugfix: variable client batch sizes must not re-jit.
    m ∈ {1, 3, 7, 8} covers buckets {1, 4, 8}; after one warmup per
    bucket, every further dispatch is a Python-cache hit AND reuses an
    existing jit trace (``traces`` frozen)."""
    rng = np.random.default_rng(6)
    qs_all = rng.integers(0, 4, size=(8, 14), dtype=np.uint8)
    clear_searcher_cache()
    for m in (1, 3, 7, 8):                    # warmup: buckets 1, 4, 8
        make_batch_searcher(idx, 2, block_m=2)(jnp.asarray(qs_all[:m]))
    warm = searcher_cache_info()
    assert warm["misses"] == 1                # one (index, tau, ...) key
    assert warm["traces"] == 3                # one trace per bucket
    for _ in range(2):
        for m in (1, 3, 7, 8):                # re-fetch per call, as a
            # serving loop does: every fetch must be a Python-cache hit
            res = make_batch_searcher(idx, 2, block_m=2)(
                jnp.asarray(qs_all[:m]))
            assert res.mask.shape[0] == m     # results sliced back to m
    info = searcher_cache_info()
    assert info["misses"] == warm["misses"]   # zero new misses
    assert info["traces"] == warm["traces"]   # zero new jit traces
    assert info["hits"] > warm["hits"]


def test_bucketed_batch_bit_identical_to_per_query(idx):
    """Padding rows up to the bucket and slicing back must not perturb
    any real row (pad rows repeat the last query, results dropped)."""
    rng = np.random.default_rng(7)
    qs = rng.integers(0, 4, size=(5, 14), dtype=np.uint8)   # bucket 8
    bres = make_batch_searcher(idx, 3, block_m=2)(jnp.asarray(qs))
    single = get_searcher(idx, 3)
    for i in range(len(qs)):
        sres = single(jnp.asarray(qs[i]))
        np.testing.assert_array_equal(np.asarray(bres.mask[i]),
                                      np.asarray(sres.mask))
        np.testing.assert_array_equal(np.asarray(bres.dist[i]),
                                      np.asarray(sres.dist))
        assert int(bres.overflow[i]) == int(sres.overflow)
