"""Minimal deterministic stand-in for ``hypothesis`` so the tier-1 suite
collects AND runs in a clean environment (the container ships no dev
extras; see requirements-dev.txt for the real thing).

Implements exactly the subset this repo's property tests use:
``@given`` over positional strategies, ``@settings(max_examples, deadline)``,
and ``st.integers / lists / randoms / data / composite``.  Draws come from
a per-test ``random.Random`` seeded from a CRC of the test name, so runs
are reproducible without hypothesis's database or shrinking.  When the
real hypothesis is importable the test modules never load this file.
"""

from __future__ import annotations

import random as _random
import zlib


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn


class _Data:
    """Stand-in for the object ``st.data()`` yields: interactive draws."""

    def __init__(self, rng: _random.Random):
        self._rng = rng

    def draw(self, strategy: _Strategy):
        return strategy._draw(self._rng)


class _St:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def lists(elements: _Strategy, min_size=0, max_size=10):
        def draw(rng):
            size = rng.randint(min_size, max_size)
            return [elements._draw(rng) for _ in range(size)]
        return _Strategy(draw)

    @staticmethod
    def randoms():
        return _Strategy(lambda rng: _random.Random(rng.randint(0, 2**31)))

    @staticmethod
    def data():
        return _Strategy(lambda rng: _Data(rng))

    @staticmethod
    def composite(fn):
        def build(*args, **kwargs):
            return _Strategy(
                lambda rng: fn(lambda s: s._draw(rng), *args, **kwargs))
        return build


st = _St()


def given(*strategies):
    def decorate(fn):
        # NOT functools.wraps: pytest must see a zero-arg signature, or it
        # would treat the wrapped test's strategy params as fixtures.
        def run():
            # @settings may sit above @given (stamps run) or below it
            # (stamps the raw fn) — honor either order
            n = getattr(run, "_max_examples",
                        getattr(fn, "_max_examples", 20))
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = _random.Random(seed)
            for _ in range(n):
                drawn = [s._draw(rng) for s in strategies]
                fn(*drawn)
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        return run
    return decorate


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def decorate(fn):
        fn._max_examples = max_examples
        return fn
    return decorate
