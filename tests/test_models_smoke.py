"""Per-architecture smoke tests: a REDUCED config of the same family runs
one forward + one train step (and, where the shape grid includes them,
prefill + decode) on CPU — asserting output shapes and no NaNs.

The full assigned configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation) — see launch/dryrun.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, valid_shapes
from repro.models import model as M
from repro.models.io import synthetic_batch
from repro.optim.adamw import Hyper, abstract_opt_state, adamw_init
from repro.train.steps import make_decode_step, make_prefill_step, make_train_step

BATCH, SEQ = 2, 32


def _smoke_setup(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = synthetic_batch(cfg, BATCH, SEQ, step=0)
    return cfg, params, batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch):
    cfg, params, batch = _smoke_setup(arch)
    logits = M.forward(params, cfg, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg, params, batch = _smoke_setup(arch)
    step = make_train_step(cfg, Hyper(total_steps=10, warmup_steps=2),
                           num_microbatches=2, compute_dtype=jnp.float32)
    opt = adamw_init(params)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_opt.step) == 1
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize(
    "arch", [a for a in ARCH_IDS if "decode_32k" in valid_shapes(a)
             or "long_500k" in valid_shapes(a)])
def test_prefill_decode_consistency(arch):
    """Prefill then one decode step must match the full-sequence forward
    logits at the next position (same params, same tokens)."""
    cfg, params, _ = _smoke_setup(arch)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (BATCH, SEQ)), jnp.int32)

    prefill = make_prefill_step(cfg, s_max=SEQ + 4, compute_dtype=jnp.float32)
    decode = make_decode_step(cfg, compute_dtype=jnp.float32)

    logits_last, cache, cache_len = prefill(params, {"tokens": toks[:, :-1]})
    dec_logits, _ = decode(params, toks[:, -1:], cache, cache_len)

    full = M.forward(params, cfg, {"tokens": toks})
    ref = full[:, -1]
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_abstract_params_match_concrete(arch):
    cfg = get_config(arch, smoke=True)
    abstract = M.abstract_params(cfg)
    concrete = M.init_params(jax.random.PRNGKey(0), cfg)
    a_leaves = jax.tree_util.tree_leaves_with_path(abstract)
    c_leaves = jax.tree_util.tree_leaves_with_path(concrete)
    assert len(a_leaves) == len(c_leaves)
    for (pa, la), (pc, lc) in zip(a_leaves, c_leaves):
        assert la.shape == lc.shape and la.dtype == lc.dtype, (pa, la, lc)


def test_full_config_param_counts():
    """6·N·D bookkeeping: full (unpadded) configs land near the published
    parameter counts."""
    expected = {
        "gemma2-27b": 27e9, "command-r-35b": 35e9, "smollm-135m": 135e6,
        "yi-9b": 8.8e9, "deepseek-moe-16b": 16e9, "chameleon-34b": 34e9,
        "zamba2-2.7b": 2.7e9, "mamba2-1.3b": 1.3e9, "hubert-xlarge": 1e9,
        "granite-moe-3b-a800m": 3.3e9,
    }
    for arch, target in expected.items():
        n = get_config(arch).param_count()
        assert 0.5 * target < n < 1.6 * target, (arch, n, target)
