"""Tests for vertical-format Hamming distance and similarity-preserving hashing."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean env: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.core import hamming as H
from repro.core import sketch as S


@pytest.mark.parametrize("b,L,n", [(2, 16, 33), (4, 32, 17), (8, 64, 9), (2, 5, 11), (4, 33, 8)])
def test_vertical_matches_naive(b, L, n):
    rng = np.random.default_rng(b * 100 + L)
    db = rng.integers(0, 1 << b, size=(n, L)).astype(np.uint8)
    q = rng.integers(0, 1 << b, size=(L,)).astype(np.uint8)
    planes = H.pack_vertical(db, b)
    qp = H.pack_vertical(q[None], b)[0]
    got = np.asarray(H.hamming_vertical(jnp.asarray(planes), jnp.asarray(qp)))
    want = np.asarray(H.hamming_naive(jnp.asarray(db), jnp.asarray(q)))
    np.testing.assert_array_equal(got, want)


def test_pack_vertical_jax_matches_host():
    rng = np.random.default_rng(7)
    for b, L in [(2, 16), (4, 32), (3, 40)]:
        db = rng.integers(0, 1 << b, size=(6, L)).astype(np.uint8)
        host = H.pack_vertical(db, b)
        dev = np.asarray(H.pack_vertical_jax(jnp.asarray(db), b))
        np.testing.assert_array_equal(host, dev)


def test_paper_figure6_example():
    # s = abd, q = acd with a=00,b=01,c=10,d=11 -> ham = 1
    to_c = {"a": 0, "b": 1, "c": 2, "d": 3}
    s = np.array([to_c[ch] for ch in "abd"], dtype=np.uint8)
    q = np.array([to_c[ch] for ch in "acd"], dtype=np.uint8)
    sp = H.pack_vertical(s[None], 2)[0]
    qp = H.pack_vertical(q[None], 2)[0]
    assert int(H.hamming_vertical(jnp.asarray(sp[None]), jnp.asarray(qp))[0]) == 1


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 80), st.integers(1, 12), st.randoms())
def test_vertical_property(b, L, n, rnd):
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    db = rng.integers(0, 1 << b, size=(n, L)).astype(np.uint8)
    q = rng.integers(0, 1 << b, size=(L,)).astype(np.uint8)
    planes = H.pack_vertical(db, b)
    qp = H.pack_vertical(q[None], b)[0]
    got = np.asarray(H.hamming_vertical(jnp.asarray(planes), jnp.asarray(qp)))
    want = (db != q[None]).sum(axis=1)
    np.testing.assert_array_equal(got, want)


def test_minhash_approximates_jaccard():
    key = jax.random.PRNGKey(0)
    # two sets with known overlap: |A|=|B|=60, |A∩B|=40 -> J = 40/80 = 0.5
    a = np.arange(60)
    bset = np.arange(20, 80)
    items = jnp.asarray(np.stack([a, bset]).astype(np.int32))
    mask = jnp.ones_like(items, dtype=bool)
    L, b = 512, 8  # large alphabet -> collision correction negligible
    sk = S.bbit_minhash(key, items, mask, L=L, b=b)
    match = float((sk[0] == sk[1]).mean())
    assert abs(match - 0.5) < 0.08, match
    j = float(S.jaccard(items[:1], mask[:1], items[1:], mask[1:])[0])
    assert abs(j - 0.5) < 1e-6


def test_zbit_cws_approximates_minmax():
    key = jax.random.PRNGKey(1)
    rng = np.random.default_rng(3)
    w1 = rng.uniform(0, 1, size=64).astype(np.float32)
    w2 = w1.copy()
    w2[:16] = rng.uniform(0, 1, size=16)  # perturb a quarter
    w = jnp.asarray(np.stack([w1, w2]))
    L, b = 512, 8
    sk = S.zbit_cws(key, w, L=L, b=b)
    match = float((sk[0] == sk[1]).mean())
    k = float(S.minmax_kernel(w[0], w[1]))
    # 0-bit CWS collision prob ~ minmax kernel (upward bias from b-bit truncation is tiny at b=8)
    assert abs(match - k) < 0.1, (match, k)


def test_sketch_determinism_and_range():
    key = jax.random.PRNGKey(2)
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 1000, size=(4, 50)), dtype=jnp.int32)
    s1 = S.sketch_tokens(key, toks, L=16, b=2)
    s2 = S.sketch_tokens(key, toks, L=16, b=2)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert s1.shape == (4, 16)
    assert int(jnp.max(s1)) < 4
