"""Pallas fused flash-attention forward vs the jnp oracle — interpret
mode (same kernel body, executed on CPU), over shape x dtype x mask
sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn_kernel import flash_attention_fwd_pallas


def oracle(q, k, v, *, causal, window=0, cap=0.0):
    B, H, S, D = q.shape
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(D)
    if cap:
        s = jnp.tanh(s / cap) * cap
    q_pos = jnp.arange(S)
    k_pos = jnp.arange(k.shape[2])
    mask = jnp.ones((S, k.shape[2]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


CASES = [
    dict(causal=True, window=0, cap=0.0, dtype=jnp.float32, S=256, D=64),
    dict(causal=True, window=96, cap=0.0, dtype=jnp.float32, S=256, D=64),
    dict(causal=True, window=0, cap=30.0, dtype=jnp.float32, S=256, D=128),
    dict(causal=False, window=0, cap=0.0, dtype=jnp.float32, S=256, D=64),
    dict(causal=True, window=0, cap=0.0, dtype=jnp.bfloat16, S=384, D=128),
]


@pytest.mark.parametrize("case", CASES)
def test_pallas_flash_fwd_matches_oracle(case):
    B, H, S, D = 2, 3, case["S"], case["D"]
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, H, S, D)), case["dtype"])
    k = jnp.asarray(rng.standard_normal((B, H, S, D)), case["dtype"])
    v = jnp.asarray(rng.standard_normal((B, H, S, D)), case["dtype"])
    out = flash_attention_fwd_pallas(
        q, k, v, causal=case["causal"], window=case["window"],
        cap=case["cap"], bq=128, bk=128, interpret=True)
    ref = oracle(q, k, v, causal=case["causal"], window=case["window"],
                 cap=case["cap"])
    tol = 2e-2 if case["dtype"] == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_pallas_flash_lowers_for_tpu_shapes():
    """The BlockSpec tiling must at least abstractly evaluate for the
    production shapes (full lowering needs a TPU backend)."""
    B, H, S, D = 1, 4, 4096, 128
    q = jax.ShapeDtypeStruct((B, H, S, D), jnp.bfloat16)
    out = jax.eval_shape(
        lambda a, b, c: flash_attention_fwd_pallas(
            a, b, c, causal=True, interpret=True), q, q, q)
    assert out.shape == (B, H, S, D)
