"""Overload control plane (DESIGN.md §12): deadline propagation cancels
expired work *before* any device dispatch, the CoDel-style admission
controller escalates only on a standing queue, the degradation ladder
produces answers bit-identical to an undegraded run at the same
effective parameters (and says so in the response), the per-collection
circuit breaker walks closed → open → half-open → closed, ``stop()``
failures are loud, warmup absorbs every shape-bucket compile, and every
new signal round-trips through the strict Prometheus parser."""

import threading
import time

import numpy as np
import pytest

from repro.core.segments import dispatch_stats
from repro.obs.prom import parse_exposition
from repro.serving import (AdmissionConfig, AdmissionController,
                           BreakerConfig, CircuitBreaker, CollectionConfig,
                           DeadlineExceeded, DegradePolicy, OverloadError,
                           Scheduler, SchedulerConfig, SlowDispatchInjector)
from repro.serving.overload import estimate_units

L, B = 8, 2


def corpus(n=64, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << B, size=(n, L), dtype=np.uint8)


def make_sched(admission=False, degrade=False, breaker=None, faults=None,
               n=64, **kw):
    sched = Scheduler(config=SchedulerConfig(
        max_batch=4, max_queue=256, max_wait_ms=1.0,
        admission=AdmissionConfig(cost_capacity=1024.0) if admission
        else None,
        degrade=DegradePolicy() if degrade else None,
        breaker=breaker, **kw), faults=faults)
    sched.create_collection("docs", CollectionConfig(L=L, b=B))
    sched.submit_insert("docs", corpus(n))
    sched.pump()
    return sched


def force_level(ctrl, level):
    """Fabricate a standing queue with timestamps far in the future so
    real pops (near-zero delays at the real clock) can't close a CoDel
    interval underneath the test."""
    start = time.perf_counter() + 1e9
    for i in range(level + 1):
        ctrl.note_delay(0.05, now=start + 0.11 * i)


# -- deadlines --------------------------------------------------------------

def test_expired_requests_never_reach_the_device():
    sched = make_sched(admission=True)
    docs = corpus()
    futs = [sched.submit_topk("docs", docs[i], 3, deadline_ms=0.01)
            for i in range(8)]
    time.sleep(0.01)                    # every budget is now blown
    before = dispatch_stats()["total"]
    sched.pump()
    assert dispatch_stats()["total"] == before   # zero device launches
    for f in futs:
        with pytest.raises(DeadlineExceeded) as ei:
            f.result(timeout=5)
        assert ei.value.collection == "docs" and ei.value.op == "topk"
        assert ei.value.retry_after_ms >= 0.0
    snap = sched.stats()
    assert snap["counters"]["deadline_exceeded_total"] == 8
    assert snap["counters"]["deadline_exceeded_total:topk"] == 8


def test_live_requests_unaffected_by_expired_neighbours():
    sched = make_sched(admission=True)
    docs = corpus()
    dead = sched.submit_topk("docs", docs[0], 3, deadline_ms=0.01)
    live = sched.submit_topk("docs", docs[1], 3, deadline_ms=60_000.0)
    time.sleep(0.01)
    sched.pump()
    with pytest.raises(DeadlineExceeded):
        dead.result(timeout=5)
    res = live.result(timeout=5)
    direct = sched.registry.get("docs").index.topk_batch(
        docs[1][None, :], 3)
    assert np.array_equal(res.ids, np.asarray(direct.ids)[0])
    assert res.degraded is None


def test_default_deadline_comes_from_collection_config():
    sched = Scheduler(config=SchedulerConfig(max_batch=4, max_queue=256))
    sched.create_collection("docs", CollectionConfig(
        L=L, b=B, default_deadline_ms=0.01))
    sched.submit_insert("docs", corpus())
    sched.pump()
    fut = sched.submit_topk("docs", corpus()[0], 3)   # inherits 0.01ms
    time.sleep(0.01)
    sched.pump()
    with pytest.raises(DeadlineExceeded):
        fut.result(timeout=5)


# -- admission --------------------------------------------------------------

def test_codel_escalates_on_standing_queue_only():
    ctrl = AdmissionController(AdmissionConfig())
    t = 1000.0
    ctrl.note_delay(0.05, now=t)                  # opens the interval
    ctrl.note_delay(0.001, now=t + 0.05)          # one dip under target
    ctrl.note_delay(0.05, now=t + 0.11)           # closes: min was 1ms
    assert ctrl.pressure() == 0                   # burst absorbed
    ctrl.note_delay(0.05, now=t + 0.22)           # closes: min 50ms
    ctrl.note_delay(0.05, now=t + 0.33)
    assert ctrl.pressure() == 2                   # standing queue
    ctrl.note_empty()                             # CoDel exit condition
    assert ctrl.pressure() == 0


def test_cost_budget_sheds_but_min_queue_always_admits():
    cfg = AdmissionConfig(cost_capacity=4.0, min_queue=2)
    ctrl = AdmissionController(cfg)
    for _ in range(8):
        ctrl.on_admit(1.0)
    # budget is 2x blown, but a shallow queue is always admitted
    assert ctrl.admit(1.0, queue_len=1, priority=0) is None
    shed = ctrl.admit(1.0, queue_len=8, priority=0)
    assert shed is not None and shed >= 1.0       # retry_after_ms hint


def test_estimate_units_scales_with_k_and_clamps():
    idx = Scheduler()
    idx.create_collection("docs", CollectionConfig(L=L, b=B))
    idx.submit_insert("docs", corpus(64))
    idx.pump()
    index = idx.registry.get("docs").index
    small = estimate_units(index, "topk", ("topk", 2, None, None), {})
    big = estimate_units(index, "topk", ("topk", 32, None, None), {})
    assert 1 / 16 <= small <= big <= 64


# -- degradation ladder -----------------------------------------------------

def test_degrade_policy_reports_only_what_changed():
    pol = DegradePolicy()
    assert pol.reject_level == 4
    # level 1 = rerank_off: a plain topk is untouched -> no stage
    assert pol.apply_topk(1, 5, None, None) == (5, None, None, None)
    # ... but a rerank request is downgraded
    k, tau0, metric, stage = pol.apply_topk(1, 5, None, "l2")
    assert metric is None and stage == "rerank_off"
    # level 2 shrinks k (never below k_floor)
    k, _, _, stage = pol.apply_topk(2, 8, None, None)
    assert k == 4 and stage == "shrink_k"
    assert pol.apply_topk(2, 1, None, None)[0] == 1
    # level 3 forces the cheap ladder start / caps search tau
    _, tau0, _, stage = pol.apply_topk(3, 8, None, None)
    assert tau0 == 0 and stage == "cheap_tau"
    assert pol.apply_search(3, 4) == (1, "cheap_tau")
    assert pol.apply_search(3, 1) == (1, None)    # already cheap


def test_degraded_answers_bit_identical_and_labelled():
    sched = make_sched(admission=True, degrade=True)
    idx = sched.registry.get("docs").index
    docs = corpus()
    force_level(sched._states["docs"].ctrl, 2)
    fut = sched.submit_topk("docs", docs[3], 8)
    sched.pump()
    res = fut.result(timeout=5)
    pol = sched.config.degrade
    k_eff, tau0_eff, _, stage = pol.apply_topk(2, 8, None, None)
    assert res.degraded == stage == "shrink_k"
    direct = idx.topk_batch(docs[3][None, :], k_eff, tau0=tau0_eff)
    assert np.array_equal(res.ids, np.asarray(direct.ids)[0])
    assert np.array_equal(res.dists, np.asarray(direct.dists)[0])
    snap = sched.stats()
    assert snap["counters"]["degraded_total:shrink_k"] == 1


def test_pressure_reject_sheds_new_work_but_spares_priority():
    sched = make_sched(admission=True, degrade=True)
    docs = corpus()
    state = sched._states["docs"]
    force_level(state.ctrl, sched.config.degrade.reject_level)
    # a deep queue + reject-level pressure sheds priority-0 work
    for i in range(state.ctrl.config.min_queue):
        sched.submit_topk("docs", docs[i], 3, priority=1)
    with pytest.raises(OverloadError) as ei:
        sched.submit_topk("docs", docs[0], 3)
    assert ei.value.reason == "pressure"
    assert ei.value.retry_after_ms >= 0.0
    fut = sched.submit_topk("docs", docs[0], 3, priority=1)   # exempt
    sched.pump()
    fut.result(timeout=5)


# -- circuit breaker --------------------------------------------------------

def test_breaker_walks_closed_open_halfopen_closed():
    clock = [0.0]
    br = CircuitBreaker(BreakerConfig(window=8, min_samples=4,
                                      fail_frac=0.5, open_ms=100.0,
                                      probes=2), clock=lambda: clock[0])
    assert br.state() == "closed"
    for _ in range(4):
        br.record(False)
    assert br.state() == "open" and br.trips_total == 1
    ok, retry = br.allow()
    assert not ok and retry > 0.0
    clock[0] = 0.15                     # open window elapses
    assert br.state() == "half_open"
    assert br.allow()[0] and br.allow()[0]        # probe budget
    assert not br.allow()[0]
    br.record(True)
    br.record(True)
    assert br.state() == "closed"


def test_breaker_reopen_backs_off_and_cancel_refunds_probe():
    clock = [0.0]
    br = CircuitBreaker(BreakerConfig(window=8, min_samples=2,
                                      fail_frac=0.5, open_ms=100.0,
                                      probes=1, backoff=2.0),
                        clock=lambda: clock[0])
    br.record(False), br.record(False)            # trip #1: 100ms
    clock[0] = 0.15
    assert br.allow()[0]
    br.record(False)                              # failed probe: 200ms
    assert br.trips_total == 2
    clock[0] = 0.30                    # 150ms into a 200ms open window
    assert not br.allow()[0]
    clock[0] = 0.40
    assert br.allow()[0]               # half-open, probe slot taken
    br.cancel()                        # admission rejected it instead
    assert br.allow()[0]               # the slot was refunded


def test_breaker_trips_in_scheduler_and_sheds_with_retry_hint():
    sched = make_sched(admission=True, breaker=BreakerConfig(
        window=8, min_samples=4, fail_frac=0.5, open_ms=50.0, probes=2))
    docs = corpus()
    for i in range(8):
        sched.submit_topk("docs", docs[i], 3, deadline_ms=0.01)
    time.sleep(0.01)
    sched.pump()                       # purge -> 8 failures -> OPEN
    assert sched._states["docs"].breaker.state() == "open"
    with pytest.raises(OverloadError) as ei:
        sched.submit_topk("docs", docs[0], 3)
    assert ei.value.reason == "breaker_open"
    assert ei.value.retry_after_ms > 0.0
    time.sleep(0.08)                   # open window elapses; probes heal
    for _ in range(2):
        f = sched.submit_topk("docs", docs[0], 3)
        sched.pump()
        f.result(timeout=5)
    assert sched._states["docs"].breaker.state() == "closed"


# -- threaded burst + faults ------------------------------------------------

def test_burst_under_faults_keeps_cotenant_clean_threaded():
    inj = SlowDispatchInjector(delay_s=0.02, match="execute:docs:topk")
    sched = make_sched(admission=True, degrade=True, faults=inj)
    sched.create_collection("quiet", CollectionConfig(L=L, b=B))
    sched.submit_insert("quiet", corpus())
    sched.pump()
    docs = corpus()
    sched.start()
    futs = [sched.submit_topk("docs", docs[i % 64], 3, deadline_ms=150.0)
            for i in range(48)]
    ok = err = 0
    for f in futs:
        try:
            f.result(timeout=30)
            ok += 1
        except DeadlineExceeded:
            err += 1
    # the co-tenant's collection is untouched by the victim's faults
    t0 = time.perf_counter()
    q = sched.submit_topk("quiet", docs[0], 3, deadline_ms=5_000.0)
    q.result(timeout=30)
    assert (time.perf_counter() - t0) < 5.0
    sched.stop()
    assert ok + err == 48 and err >= 1            # faults bit something
    assert inj.fired >= 1
    assert not sched.stopped_dirty


def test_stop_join_failure_is_loud_and_quarantines(caplog):
    inj = SlowDispatchInjector(delay_s=0.5, match="execute:docs")
    sched = make_sched(admission=True, faults=inj, join_timeout_s=0.05)
    docs = corpus()
    sched.start()
    fut = sched.submit_topk("docs", docs[0], 3)   # worker naps 0.5s
    time.sleep(0.05)                              # let it enter the fault
    import logging
    with caplog.at_level(logging.ERROR, logger="repro.serving.scheduler"):
        sched.stop()
    assert sched.stopped_dirty
    assert sched.stats()["counters"]["stopped_dirty_total"] == 1
    assert any("join" in r.message for r in caplog.records)
    assert sched.pump() == 0           # dirty collections are quarantined
    fut.result(timeout=30)             # the stuck worker still finishes


# -- warmup -----------------------------------------------------------------

def test_warmup_absorbs_all_bucket_compiles():
    from repro.core import clear_searcher_cache
    clear_searcher_cache()
    sched = make_sched()
    rep = sched.warmup(ks=(3,), taus=(1,))
    assert rep["buckets"] >= 1 and rep["traces"] >= 1
    assert rep["calls"] == 2 * rep["buckets"]
    again = sched.warmup(ks=(3,), taus=(1,))
    assert again["traces"] == 0        # idempotent: everything compiled
    sched.create_collection("empty", CollectionConfig(L=L, b=B))
    assert sched.warmup(collection="empty")["calls"] == 0


# -- observability ----------------------------------------------------------

def test_new_signals_round_trip_through_prom_parser():
    sched = make_sched(admission=True, degrade=True,
                       breaker=BreakerConfig())
    docs = corpus()
    dead = sched.submit_topk("docs", docs[0], 3, deadline_ms=0.01)
    time.sleep(0.01)
    force_level(sched._states["docs"].ctrl, 2)
    live = sched.submit_topk("docs", docs[1], 8)
    sched.pump()
    with pytest.raises(DeadlineExceeded):
        dead.result(timeout=5)
    assert live.result(timeout=5).degraded == "shrink_k"
    parsed = parse_exposition(sched.render_stats())
    names = {s[0] for s in parsed["samples"]}
    for family in ("serving_deadline_exceeded_total",
                   "serving_degraded_total", "serving_breaker_state",
                   "serving_pressure_level", "serving_queued_cost_units"):
        assert family in names, (family, sorted(names))
    by = {(s[0], tuple(sorted(s[1].items()))): s[2]
          for s in parsed["samples"]}
    assert by[("serving_breaker_state",
               (("collection", "docs"),))] == 0.0  # closed
    assert by[("serving_pressure_level",
               (("collection", "docs"),))] >= 0.0
