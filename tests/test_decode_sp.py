"""Sequence-parallel decode attention == reference decode attention."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.decode_sp import decode_attention_seq_sharded
from repro.models.layers import decode_attention


@pytest.mark.parametrize("cap", [0.0, 30.0])
@pytest.mark.parametrize("kv", [2, 4])
def test_seq_sharded_matches_reference(cap, kv):
    B, S, Hq, D = 2, 64, 8, 16
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
    k_new = jnp.asarray(rng.standard_normal((B, 1, kv, D)), jnp.float32)
    v_new = jnp.asarray(rng.standard_normal((B, 1, kv, D)), jnp.float32)
    k_cache = jnp.asarray(rng.standard_normal((B, S, kv, D)), jnp.float32)
    v_cache = jnp.asarray(rng.standard_normal((B, S, kv, D)), jnp.float32)
    cache_len = jnp.int32(37)

    out, kc, vc = decode_attention_seq_sharded(
        q, k_new, v_new, k_cache, v_cache, cache_len, mesh, cap=cap)

    # reference: write then attend
    k_ref = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, 37, axis=1)
    v_ref = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, 37, axis=1)
    ref = decode_attention(q, k_ref, v_ref, cache_len + 1, cap=cap)

    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(kc), np.asarray(k_ref), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(vc), np.asarray(v_ref), rtol=1e-6)


def test_cache_write_goes_to_owner_rank_only():
    """With 2 model ranks the new KV lands exactly once (slot ownership)."""
    try:
        mesh = jax.make_mesh((1, 2), ("data", "model"))
    except ValueError:
        pytest.skip("needs 2 devices")
    B, S, kv, D = 1, 8, 1, 4
    q = jnp.ones((B, 1, 2, D), jnp.float32)
    k_new = jnp.full((B, 1, kv, D), 7.0)
    v_new = jnp.full((B, 1, kv, D), 9.0)
    kc0 = jnp.zeros((B, S, kv, D), jnp.float32)
    out, kc, vc = decode_attention_seq_sharded(
        q, k_new, v_new, kc0, kc0, jnp.int32(5), mesh)
    expect = kc0.at[:, 5].set(7.0)
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(expect))
