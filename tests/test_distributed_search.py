"""Sharded bST search == single-index search == brute force, across
shard counts; plus the SPMD property that one program serves all shards
(common layer plan, padded shapes, dynamic sizes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed_search import (ShardedBST, build_sharded_bst,
                                           gather_ids, gather_topk,
                                           make_sharded_searcher)
from repro.core.hamming import hamming_pairwise_naive


def _db(n, L, b, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << b, size=(n, L), dtype=np.uint8)


@pytest.mark.parametrize("n_shards", [1, 2, 4, 8])
@pytest.mark.parametrize("tau", [0, 1, 3])
@pytest.mark.parametrize("verify", ["gather", "scan"])
def test_sharded_matches_bruteforce(n_shards, tau, verify):
    n, L, b, m = 600, 12, 2, 7
    db = _db(n, L, b)
    queries = np.concatenate([db[:3], _db(m - 3, L, b, seed=9)])

    index = build_sharded_bst(db, b, n_shards)
    searcher = make_sharded_searcher(index, tau, verify=verify)
    masks, sdists, overflow = searcher(jnp.asarray(queries))
    assert int(overflow) == 0
    got = gather_ids(index, np.asarray(masks))

    dists = np.asarray(hamming_pairwise_naive(
        jnp.asarray(queries), jnp.asarray(db)))
    for qi in range(m):
        want = np.flatnonzero(dists[qi] <= tau)
        np.testing.assert_array_equal(got[qi], want,
                                      err_msg=f"shards={n_shards} q={qi}")
        # the distance planes are exact on the solution set
        dvec = np.asarray(sdists[qi])[index.shard_of, index.pos_of]
        np.testing.assert_array_equal(dvec[want], dists[qi][want],
                                      err_msg=f"shards={n_shards} q={qi}")


@pytest.mark.parametrize("n_shards", [1, 3])
def test_gather_topk_ties_by_id(n_shards):
    """gather_topk merges shard distance planes into global (distance, id)
    order — duplicate-heavy DB makes boundary ties routine."""
    n, L, b, tau, k = 240, 10, 2, 4, 7
    rng = np.random.default_rng(8)
    base = rng.integers(0, 1 << b, size=(40, L), dtype=np.uint8)
    db = base[rng.integers(0, 40, size=n)]          # many exact duplicates
    queries = db[:3]
    index = build_sharded_bst(db, b, n_shards)
    _, sdists, overflow = make_sharded_searcher(index, tau)(
        jnp.asarray(queries))
    assert int(overflow) == 0
    ids, dk = gather_topk(index, np.asarray(sdists), k)
    dists = np.asarray(hamming_pairwise_naive(
        jnp.asarray(queries), jnp.asarray(db)))
    for qi in range(len(queries)):
        d = np.where(dists[qi] <= tau, dists[qi], 1 << 20)
        want = np.lexsort((np.arange(n), d))[:k]
        real = d[want] < (1 << 20)
        np.testing.assert_array_equal(ids[qi], np.where(real, want, -1))
        np.testing.assert_array_equal(dk[qi], d[want])


def test_common_plan_is_shared():
    """The layer plan (kinds, lm, ls) must be static and identical across
    shards — the SPMD requirement."""
    db = _db(2000, 16, 2)
    idx = build_sharded_bst(db, 2, 4)
    assert isinstance(idx.kinds, tuple)
    assert idx.lm <= idx.ls <= idx.L
    # all per-shard stacked arrays share a leading shard axis of 4
    assert idx.paths_vert.shape[0] == 4
    assert idx.id_leaf.shape[0] == 4


def test_shard_loss_rebuild():
    """Fault-tolerance of the retrieval plane: losing one shard and
    rebuilding it from its slice of the raw data reproduces identical
    results (index build is a pure function of the shard's data)."""
    n, L, b, tau = 400, 12, 2, 2
    db = _db(n, L, b)
    idx1 = build_sharded_bst(db, b, 4)
    idx2 = build_sharded_bst(db, b, 4)   # "rebuilt" after failure
    q = jnp.asarray(_db(5, L, b, seed=3))
    m1 = np.asarray(make_sharded_searcher(idx1, tau)(q)[0])
    m2 = np.asarray(make_sharded_searcher(idx2, tau)(q)[0])
    np.testing.assert_array_equal(m1, m2)


def test_sharded_lowers_on_spmd_mesh():
    """The searcher must lower with the shard axis partitioned over a
    device mesh (the paper-technique dry-run cell in miniature)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    db = _db(512, 12, 2)
    n_dev = len(jax.devices())
    idx = build_sharded_bst(db, 2, max(n_dev, 2))
    searcher = make_sharded_searcher(idx, 1)
    q = jnp.asarray(_db(4, 12, 2, seed=5))
    lowered = jax.jit(lambda qq: searcher(qq)).lower(q)
    compiled = lowered.compile()
    masks = np.asarray(compiled(q)[0])
    assert masks.shape[0] == 4
