"""Durability: segment snapshots + delta-buffer WAL + crash recovery
(DESIGN.md §8).

Three layers of guarantees, each held by its own tests:

  * **WAL framing** — append/replay round-trip; a torn or corrupt tail
    (truncated record, flipped payload byte, broken sequence) ends
    replay at the last good record — dropped, never crashed on — and
    reopening the log cuts the bad tail so new appends extend the good
    prefix.
  * **Snapshot/restore round-trip** — a recovered index is
    bit-identical to the pre-crash one on every backend (bst / multi /
    sharded segments, plus the multi-stack ``ShardedSegmentedIndex``):
    same search/topk results, same id allocator, same segment serials,
    same space ledger.
  * **Crash-at-every-point recovery** — the fault harness first runs a
    canonical workload in *counting* mode to enumerate every
    fsync/rename boundary the store crosses (WAL syncs, segment and
    manifest renames, live-lane rewrites, WAL truncations), then a
    pytest parametrization replays the workload once per boundary:
    crash there, recover with a fresh store, finish the workload, and
    require the final state bit-identical (segment ids/columns/
    tombstones, delta buffer, space ledger) to a never-crashed
    reference index.

Deterministic by construction (no hypothesis dependency) so the suite
runs on a bare no-extras interpreter.
"""

import os
import tempfile

import numpy as np
import pytest

from repro.core.segments import (SegmentedIndex, ShardedSegmentedIndex)
from repro.serving import CollectionConfig, CollectionRegistry
from repro.store import (OP_DELETE, OP_INSERT, CollectionStore, CrashPoint,
                         FaultInjector, WriteAheadLog, decode_delete,
                         decode_insert, encode_delete, encode_insert,
                         read_wal)

L, B = 8, 2
ROWS = np.random.default_rng(7).integers(0, 1 << B, size=(32, L),
                                         dtype=np.uint8)

# The canonical workload: exercises every lifecycle path — auto-flush,
# size-tiered merge, tombstones in sealed segments and in the delta
# buffer, compaction, live-lane rewrites, and WAL truncation.
OPS = [
    ("insert", (0, 12)),        # auto-flush -> seg(12)
    ("delete", (2, 5, 11)),
    ("insert", (12, 18)),       # 6 delta rows
    ("insert", (18, 22)),       # flush seg(10) + merge -> seg(19)
    ("delete", (0, 1, 13, 17)),
    ("compact", None),          # seg(19) -> seg(15)
    ("insert", (22, 26)),       # 4 delta rows
    ("delete", (3, 22)),        # one sealed + one delta tombstone
    ("insert", (26, 32)),       # flush seg(9), live rewrite, merge
]
# global ids ever assigned after each op completes (the in-flight-op
# probe of the crash harness: an insert is already recovered iff the
# id allocator advanced to this value)
N_IDS_AFTER = [12, 12, 18, 22, 22, 22, 26, 26, 32]

KINDS = ("bst", "multi", "stacks")


def _make_index(kind):
    if kind == "stacks":
        return ShardedSegmentedIndex(L, B, 2, delta_cap=4)
    return SegmentedIndex(L, B, delta_cap=8, backend=kind)


def _stacks(index):
    return list(index.shards) if hasattr(index, "shards") else [index]


def _apply(index, op):
    kind, arg = op
    if kind == "insert":
        index.insert(ROWS[arg[0]:arg[1]])
    elif kind == "delete":
        index.delete(np.asarray(arg, np.int64))
    else:
        index.compact(min_dead_frac=0.0)


_REF_CACHE = {}


def _reference(kind):
    """The never-crashed, never-persisted reference index (built once)."""
    if kind not in _REF_CACHE:
        index = _make_index(kind)
        for op in OPS:
            _apply(index, op)
        _REF_CACHE[kind] = index
    return _REF_CACHE[kind]


_POINT_CACHE = {}


def _n_points(kind):
    """Counting mode: run the workload once with an unarmed injector to
    enumerate every crash point the store crosses."""
    if kind not in _POINT_CACHE:
        with tempfile.TemporaryDirectory() as d:
            fi = FaultInjector()
            store = CollectionStore(os.path.join(d, "c"), fsync_every=1,
                                    faults=fi)
            index = store.attach(_make_index(kind))
            for op in OPS:
                _apply(index, op)
            _POINT_CACHE[kind] = fi.count
    return _POINT_CACHE[kind]


def _assert_state_equal(rec, ref):
    """Bit-identical index state: segment ids / packed columns /
    tombstones (in stack order), delta buffers, allocator, ledger.
    Serials are process-monotonic and therefore *not* value-compared
    across independently built indexes."""
    assert rec.n_ids == ref.n_ids
    assert rec.n_live == ref.n_live
    for sr, sf in zip(_stacks(rec), _stacks(ref)):
        assert len(sr.segments) == len(sf.segments)
        for a, b in zip(sr.segments, sf.segments):
            np.testing.assert_array_equal(a.ids, b.ids)
            np.testing.assert_array_equal(a.packed, b.packed)
            np.testing.assert_array_equal(a.live, b.live)
        np.testing.assert_array_equal(sr._delta_ids, sf._delta_ids)
        np.testing.assert_array_equal(sr._delta_sk, sf._delta_sk)
        np.testing.assert_array_equal(sr._delta_live, sf._delta_live)
    assert (rec.space_ledger()["model_bits"]
            == ref.space_ledger()["model_bits"])


def _assert_queries_equal(rec, ref):
    """The observable contract: identical search planes, top-k results,
    and (after one identical warm query on each side) space ledgers."""
    qs = ROWS[:4]
    a, b = rec.topk_batch(qs, 3), ref.topk_batch(qs, 3)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
    assert a.tau == b.tau
    ra, rb = rec.search_batch(qs, 2), ref.search_batch(qs, 2)
    np.testing.assert_array_equal(ra.mask, rb.mask)
    np.testing.assert_array_equal(ra.dist, rb.dist)
    assert rec.space_ledger() == ref.space_ledger()


# ---------------------------------------------------------------------------
# WAL framing
# ---------------------------------------------------------------------------

def _fill_wal(path, n=5):
    wal = WriteAheadLog(path, fsync_every=1)
    for i in range(n):
        if i % 3 == 2:
            wal.append(OP_DELETE,
                       encode_delete(np.arange(i, dtype=np.int64)))
        else:
            ids = np.arange(i * 3, i * 3 + 3, dtype=np.int64)
            wal.append(OP_INSERT, encode_insert(ids, ROWS[:3]))
    wal.close()


def test_wal_roundtrip(tmp_path):
    path = str(tmp_path / "wal.log")
    _fill_wal(path)
    base, records, dropped = read_wal(path)
    assert (base, dropped) == (0, 0)
    assert [seq for seq, _, _ in records] == [0, 1, 2, 3, 4]
    ids, sk = decode_insert(records[0][2])
    np.testing.assert_array_equal(ids, [0, 1, 2])
    np.testing.assert_array_equal(sk, ROWS[:3])
    assert records[2][1] == OP_DELETE
    np.testing.assert_array_equal(decode_delete(records[2][2]), [0, 1])


def test_wal_torn_tail_dropped_and_cut(tmp_path):
    path = str(tmp_path / "wal.log")
    _fill_wal(path)
    with open(path, "r+b") as f:            # tear the last record
        f.truncate(os.path.getsize(path) - 7)
    base, records, dropped = read_wal(path)
    assert len(records) == 4 and dropped > 0
    # reopening cuts the torn tail so new appends extend the good prefix
    wal = WriteAheadLog(path, fsync_every=1)
    assert wal.dropped_bytes > 0 and wal.next_seq == 4
    wal.append(OP_DELETE, encode_delete(np.asarray([9], np.int64)))
    wal.close()
    _, records, dropped = read_wal(path)
    assert [seq for seq, _, _ in records] == [0, 1, 2, 3, 4]
    assert dropped == 0
    np.testing.assert_array_equal(decode_delete(records[-1][2]), [9])


def test_wal_crc_corruption_ends_replay(tmp_path):
    path = str(tmp_path / "wal.log")
    _fill_wal(path)
    _, records, _ = read_wal(path)
    frame = 21                              # <IQBII> record frame bytes
    off = 13                                # <4sBQ> file header bytes
    for seq, _, payload in records[:2]:
        off += frame + len(payload)
    with open(path, "r+b") as f:            # flip a byte in record 2's
        f.seek(off + frame + 1)             # payload: CRC must reject it
        byte = f.read(1)
        f.seek(off + frame + 1)
        f.write(bytes([byte[0] ^ 0xFF]))
    _, records, dropped = read_wal(path)
    assert [seq for seq, _, _ in records] == [0, 1]
    assert dropped > 0


def test_wal_reset_continues_sequence(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path, fsync_every=1)
    for i in range(3):
        wal.append(OP_DELETE, encode_delete(np.asarray([i], np.int64)))
    wal.reset()
    base, records, dropped = read_wal(path)
    assert (base, records, dropped) == (3, [], 0)
    assert wal.append(OP_DELETE,
                      encode_delete(np.asarray([7], np.int64))) == 3
    wal.close()
    _, records, _ = read_wal(path)
    assert [seq for seq, _, _ in records] == [3]   # seqs never repeat


def test_wal_garbage_header_dropped(tmp_path):
    path = str(tmp_path / "wal.log")
    with open(path, "wb") as f:
        f.write(b"not a wal at all")
    base, records, dropped = read_wal(path)
    assert (base, records) == (0, []) and dropped > 0
    wal = WriteAheadLog(path, fsync_every=1)   # rewrites a fresh header
    assert wal.next_seq == 0 and wal.dropped_bytes > 0
    wal.append(OP_DELETE, encode_delete(np.asarray([1], np.int64)))
    wal.close()
    assert len(read_wal(path)[1]) == 1


# ---------------------------------------------------------------------------
# snapshot/restore round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["bst", "multi", "sharded", "stacks"])
def test_snapshot_restore_roundtrip(tmp_path, kind):
    def mk():
        if kind == "stacks":
            return ShardedSegmentedIndex(L, B, 2, delta_cap=8)
        return SegmentedIndex(L, B, delta_cap=8, backend=kind)

    d = str(tmp_path / "c")
    store = CollectionStore(d, fsync_every=4)
    index = store.attach(mk())
    ids = index.insert(ROWS[:30])
    index.delete(ids[::5])
    index.insert(ROWS[30:])                 # leaves unsealed delta rows
    store.wal.sync()
    qs = ROWS[:3]
    pre = index.topk_batch(qs, 3)
    pre_serials = [tuple(s.serial for s in st.segments)
                   for st in _stacks(index)]
    pre_ledger = index.space_ledger()       # after the warm query
    # hard kill: abandon the store without close()

    store2 = CollectionStore(d, fsync_every=4)
    rec = store2.recover(mk())
    post = rec.topk_batch(qs, 3)
    np.testing.assert_array_equal(np.asarray(pre.ids), np.asarray(post.ids))
    np.testing.assert_array_equal(np.asarray(pre.dists),
                                  np.asarray(post.dists))
    assert pre.tau == post.tau
    assert rec.n_ids == index.n_ids and rec.n_live == index.n_live
    # segment serials are restored verbatim from the manifests
    assert [tuple(s.serial for s in st.segments)
            for st in _stacks(rec)] == pre_serials
    assert rec.space_ledger() == pre_ledger

    # the id allocator resumes collision-free ...
    n0 = rec.n_ids
    new_ids = rec.insert(ROWS[:2])
    np.testing.assert_array_equal(new_ids, [n0, n0 + 1])
    # ... and so does the serial counter: freshly sealed segments must
    # never reuse a recovered serial (compiled-cache key invariant)
    top = max(s for serials in pre_serials for s in serials)
    rec.flush()
    fresh = [s.serial for st in _stacks(rec) for s in st.segments
             if s.serial not in {x for ser in pre_serials for x in ser}]
    assert fresh and min(fresh) > top
    store2.close()


# ---------------------------------------------------------------------------
# checkpoint / truncation / sweep mechanics
# ---------------------------------------------------------------------------

def test_wal_truncated_once_deltas_seal(tmp_path):
    store = CollectionStore(str(tmp_path / "c"), fsync_every=1)
    index = store.attach(SegmentedIndex(L, B, delta_cap=8))
    index.insert(ROWS[:16])                 # flush seals everything
    assert store.counters["wal_truncations"] >= 1
    header_only = store.wal.size_bytes()
    assert store.wal.base_seq >= 1          # seqs never restart at 0
    index.insert(ROWS[16:19])               # unsealed rows journal again
    store.wal.sync()
    assert store.wal.size_bytes() > header_only
    store.close()


def test_store_sweeps_stale_tmp_and_orphan_segments(tmp_path):
    d = str(tmp_path / "c")
    store = CollectionStore(d, fsync_every=1)
    index = store.attach(SegmentedIndex(L, B, delta_cap=8))
    index.insert(ROWS[:12])
    store.close()
    # a crash between a segment rename and its manifest write leaves an
    # orphan segment dir; a crash mid-write leaves a stale tmp file
    orphan = os.path.join(d, "seg_000000009999")
    os.makedirs(orphan)
    with open(os.path.join(orphan, "junk.bin"), "wb") as f:
        f.write(b"x" * 32)
    with open(os.path.join(d, "MANIFEST.json.tmp-999"), "w") as f:
        f.write("{")
    store2 = CollectionStore(d, fsync_every=1)
    assert store2.counters["swept_tmp"] == 1
    rec = store2.recover(SegmentedIndex(L, B, delta_cap=8))
    assert not os.path.exists(orphan)
    assert rec.n_live == 12
    store2.close()


def test_registry_open_recovers_collections(tmp_path):
    d = str(tmp_path / "data")
    reg = CollectionRegistry(data_dir=d, fsync_every=4)
    alpha = reg.create("alpha", CollectionConfig(L=L, b=B, delta_cap=8))
    beta = reg.create("beta.2",
                      CollectionConfig(L=L, b=B, delta_cap=4, n_stacks=2))
    ids = alpha.index.insert(ROWS[:20])
    alpha.index.delete(ids[:4])
    beta.index.insert(ROWS[:10])
    pre = alpha.index.topk_batch(ROWS[:3], 3)
    reg.close()

    reg2 = CollectionRegistry.open(d)
    assert reg2.names() == ["alpha", "beta.2"]
    a2 = reg2.get("alpha")
    assert a2.config == alpha.config        # config round-trips via json
    post = a2.index.topk_batch(ROWS[:3], 3)
    np.testing.assert_array_equal(np.asarray(pre.ids), np.asarray(post.ids))
    np.testing.assert_array_equal(np.asarray(pre.dists),
                                  np.asarray(post.dists))
    assert a2.index.n_live == 16
    assert reg2.get("beta.2").index.n_live == 10
    with pytest.raises(ValueError):         # durable names hit the disk
        reg2.create("bad/name", CollectionConfig(L=L, b=B))
    reg2.close()


# ---------------------------------------------------------------------------
# crash-at-every-point recovery
# ---------------------------------------------------------------------------

def _crash_recover_verify(tmp_path, kind, point):
    """Crash the canonical workload at fault point ``point``, recover
    with a fresh store, finish the workload, and require the result
    bit-identical to the never-crashed reference."""
    d = str(tmp_path / "c")
    done = 0
    try:
        # even creating the empty WAL is an atomic write with crash
        # points — construction stays inside the blast radius
        store = CollectionStore(d, fsync_every=1,
                                faults=FaultInjector(crash_at=point))
        index = store.attach(_make_index(kind))
        for op in OPS:
            _apply(index, op)
            done += 1
    except CrashPoint:
        pass
    # hard kill: the store object is abandoned (no close(), which would
    # rescue buffered-but-unsynced WAL records)

    store2 = CollectionStore(d, fsync_every=1)
    rec = store2.recover(_make_index(kind))
    if done < len(OPS):
        kind_op, arg = OPS[done]
        if kind_op == "insert":
            # the in-flight insert is already recovered iff its WAL
            # record reached the log before the crash (allocator probe)
            if rec.n_ids < N_IDS_AFTER[done]:
                _apply(rec, OPS[done])
            assert rec.n_ids == N_IDS_AFTER[done]
        else:
            _apply(rec, OPS[done])          # deletes/compacts: idempotent
        for op in OPS[done + 1:]:
            _apply(rec, op)

    ref = _reference(kind)
    _assert_state_equal(rec, ref)
    # recovered serials stay unique (compiled-cache key invariant)
    serials = [s.serial for st in _stacks(rec) for s in st.segments]
    assert len(set(serials)) == len(serials)
    if point % 10 == 0 or point == _n_points(kind) - 1:
        _assert_queries_equal(rec, ref)
    store2.close()


@pytest.mark.parametrize("point", range(_n_points("bst")))
def test_crash_at_every_point_bst(tmp_path, point):
    _crash_recover_verify(tmp_path, "bst", point)


@pytest.mark.parametrize(
    "point", sorted(set(range(0, _n_points("multi"), 5))
                    | {_n_points("multi") - 1}))
def test_crash_at_point_multi(tmp_path, point):
    _crash_recover_verify(tmp_path, "multi", point)


@pytest.mark.parametrize(
    "point", sorted(set(range(0, _n_points("stacks"), 7))
                    | {_n_points("stacks") - 1}))
def test_crash_at_point_sharded_stacks(tmp_path, point):
    _crash_recover_verify(tmp_path, "stacks", point)
