"""Data pipeline: determinism (straggler/elasticity contract) and bST
near-duplicate filtering (the paper's technique inside the data plane)."""

import jax.numpy as jnp
import numpy as np

from repro.core.hamming import hamming_pairwise_naive
from repro.data.pipeline import DataConfig, SketchDedupPipeline


def test_determinism_across_instances():
    cfg = DataConfig(vocab=1000, batch=4, seq=32, seed=7)
    a = SketchDedupPipeline(cfg)
    b = SketchDedupPipeline(cfg)
    for step in (0, 3, 11):
        ba, bb = a.batch_for_step(step), b.batch_for_step(step)
        np.testing.assert_array_equal(np.asarray(ba["tokens"]),
                                      np.asarray(bb["tokens"]))
        np.testing.assert_array_equal(np.asarray(ba["targets"]),
                                      np.asarray(bb["targets"]))


def test_targets_are_shifted_tokens():
    cfg = DataConfig(vocab=1000, batch=2, seq=16, seed=0)
    p = SketchDedupPipeline(cfg)
    b = p.batch_for_step(0)
    assert b["tokens"].shape == (2, 16) and b["targets"].shape == (2, 16)
    # targets[t] == continuation of tokens: both views of one (seq+1) draw
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["targets"][:, :-1]))


def test_dedup_rejects_near_duplicates():
    cfg = DataConfig(vocab=500, batch=8, seq=64, seed=1, dedup=True,
                     oversample=2, dup_frac=0.5, dedup_tau=2)
    p = SketchDedupPipeline(cfg)
    for step in range(5):
        p.batch_for_step(step)
    assert p.stats["rejected_in_batch"] > 0, p.stats
    # history index kicks in after the first batch
    assert p.stats["rejected_history"] >= 0
    assert p.stats["candidates"] == 5 * 16


def test_dedup_batch_internally_distant():
    """Within a kept batch, no two documents' sketches are within tau —
    unless the fallback refill had to pad with rejected docs."""
    from repro.core.sketch import sketch_tokens
    import jax
    cfg = DataConfig(vocab=500, batch=4, seq=64, seed=2, dedup=True,
                     oversample=4, dup_frac=0.3, dedup_tau=1)
    p = SketchDedupPipeline(cfg)
    b = p.batch_for_step(0)
    sk = sketch_tokens(jax.random.PRNGKey(cfg.seed ^ 0x5E7C),
                       b["tokens"], L=cfg.dedup_L, b=cfg.dedup_b)
    d = np.array(hamming_pairwise_naive(sk, sk))  # writable copy
    np.fill_diagonal(d, 99)
    assert d.min() > cfg.dedup_tau, d


def test_embeds_pipeline():
    cfg = DataConfig(vocab=64, batch=2, seq=8, embeds_dim=16)
    p = SketchDedupPipeline(cfg)
    b = p.batch_for_step(0)
    assert b["embeds"].shape == (2, 8, 16)
    assert b["targets"].shape == (2, 8)
    assert int(b["targets"].max()) < 64
