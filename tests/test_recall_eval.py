"""Recall eval harness (DESIGN.md §10): what the two stages actually buy.

On the seeded ground-truth corpus the two-stage path must dominate:
reranked recall@10 >= sketch-only recall@10 for every b in {1, 2, 4}
(the exact re-rank restores every ground-truth row the trie sweep kept
alive), reranked recall clears a fixed floor, and the b-sweep shows the
Li & König trade-off (more bits never hurt sketch-only recall on
aggregate)."""

import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))

import eval_recall  # noqa: E402

# one tiny sweep shared by every assertion in this module
_REPORT = None


def report():
    global _REPORT
    if _REPORT is None:
        _REPORT = eval_recall.evaluate(n_docs=600, n_queries=20, L=32,
                                       delta_cap=256, k=10)
    return _REPORT


def test_reranked_recall_dominates_sketch_only_and_floor():
    rows = report()["rows"]
    assert [r["b"] for r in rows] == [1, 2, 4]
    for row in rows:
        assert row["reranked"] >= row["sketch"], row
        assert row["reranked"] >= eval_recall.RECALL_FLOOR, row


def test_ground_truth_is_exact_jaccard_order():
    """The harness's own oracle: top-k rows really are the exact-Jaccard
    maximizers, ties by id."""
    rng = np.random.default_rng(0)
    docs = eval_recall.build_corpus(rng, 50, 64)
    qs = [eval_recall.perturb(rng, docs[3], 64)]
    from repro.core.hamming import pack_sets
    dp, qp = pack_sets(docs, 64), pack_sets(qs, 64)
    top = eval_recall.exact_jaccard_topk(qp, dp, 5)[0]
    jac = []
    for d in docs:
        a, b = set(map(int, qs[0])), set(map(int, d))
        jac.append(len(a & b) / len(a | b))
    want = sorted(range(50), key=lambda i: (-jac[i], i))[:5]
    assert list(map(int, top)) == want


def test_minhash_sketch_collision_rate_tracks_jaccard():
    """b-bit minhash sanity: a near-duplicate pair collides on more
    sketch positions than an unrelated pair (in expectation; seeded)."""
    rng = np.random.default_rng(1)
    base = eval_recall.build_corpus(rng, 1, 128, set_min=20, set_max=30)[0]
    near = eval_recall.perturb(rng, base, 128, frac=0.1)
    far = eval_recall.build_corpus(rng, 1, 128, set_min=20, set_max=30)[0]
    sk = eval_recall.minhash_sketch([base, near, far], 64, 2, 128)
    agree_near = int((sk[0] == sk[1]).sum())
    agree_far = int((sk[0] == sk[2]).sum())
    assert agree_near > agree_far


def test_recall_at_k_counts_pads_as_misses():
    truth = np.array([[1, 2, 3, 4]])
    assert eval_recall.recall_at_k(np.array([[1, 2, -1, -1]]), truth) \
        == 0.5


def test_cli_smoke_check_passes(tmp_path, capsys):
    out = tmp_path / "recall.json"
    rc = eval_recall.main(["--smoke", "--check", "--out", str(out)])
    assert rc == 0
    assert out.exists()
    text = capsys.readouterr().out
    assert "recall gate passed" in text
