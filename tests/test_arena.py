"""One-dispatch segment arena (DESIGN.md §6): the fused query path must
be bit-identical to the per-segment reference fan-out (and therefore to
a static rebuild over survivors) across random lifecycle interleavings,
every backend, and every batch shape — while issuing exactly ONE device
dispatch per ladder rung regardless of segment count.  Plus the arena
verify kernel's exactness against its oracle, incremental arena
maintenance, monotonic segment serials, and the bucketed delta scan."""

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean env: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.core import (SegmentedIndex, ShardedSegmentedIndex, bucket_m,
                        build_bst, dispatch_stats, reset_dispatch_stats,
                        searcher_cache_info, topk_batch)
from repro.core.bst import BIG
from repro.kernels import ops, ref

BIG_I = int(BIG)
_B = 2


def reference_columns(idx, qs, tau):
    """The per-segment fan-out, regardless of the index's arena flag."""
    return idx._search_columns(np.asarray(qs, np.uint8), tau)


def assert_columns_equal(idx, qs, tau):
    dist_r, ids_r, _ = reference_columns(idx, qs, tau)
    dist_f, ids_f, _ = idx._fused_columns(np.asarray(qs, np.uint8), tau)
    np.testing.assert_array_equal(ids_r, ids_f)
    np.testing.assert_array_equal(dist_r, dist_f)


def assert_topk_equal(idx, qs, k, tau0=None):
    got = idx.topk_batch(qs, k, tau0=tau0)
    flag = idx.use_arena
    idx.use_arena = False
    try:
        want = idx.topk_batch(qs, k, tau0=tau0)
    finally:
        idx.use_arena = flag
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))
    np.testing.assert_array_equal(np.asarray(got.dists),
                                  np.asarray(want.dists))
    assert got.tau == want.tau


# ---------------------------------------------------------------------------
# kernel exactness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,T,block_n,block_m", [
    (300, 5, 17, 128, 8),      # pad on both axes
    (256, 1, 3, 128, 8),       # m=1 degenerate tile, aligned n
    (130, 9, 200, 128, 4),     # tile-misaligned both ways, T > n block
])
def test_arena_kernel_matches_oracle(n, m, T, block_n, block_m):
    rng = np.random.default_rng(n + m)
    b, W = 3, 2
    paths = jnp.asarray(rng.integers(0, 2 ** 32, (b, W, n), np.uint64)
                        .astype(np.uint32))
    q = jnp.asarray(rng.integers(0, 2 ** 32, (b, W, m), np.uint64)
                    .astype(np.uint32))
    base = np.where(rng.random((m, T)) < 0.3, BIG_I,
                    rng.integers(0, 5, (m, T))).astype(np.int32)
    idx = rng.integers(0, T, n).astype(np.int32)
    live = rng.random(n) < 0.8
    mk, dk = ops.sparse_verify_arena(
        paths, q, jnp.asarray(base), jnp.asarray(idx), jnp.asarray(live),
        tau=20, block_n=block_n, block_m=block_m, use_kernel=True)
    mo, do = ref.sparse_verify_arena_ref(
        paths, q, jnp.asarray(base), jnp.asarray(idx), jnp.asarray(live), 20)
    np.testing.assert_array_equal(np.asarray(mk),
                                  np.asarray(mo).astype(np.int32))
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(do))


def test_arena_kernel_dead_and_pruned_lanes_clamp_to_big():
    b, W, n, m = 2, 1, 256, 2
    paths = jnp.zeros((b, W, n), jnp.uint32)
    q = jnp.zeros((b, W, m), jnp.uint32)
    base = jnp.asarray([[0, BIG_I]] * m, jnp.int32)       # slot 1 pruned
    idx = jnp.asarray(([0] * 128) + ([1] * 128), jnp.int32)
    live = jnp.asarray(([True] * 64) + ([False] * 192))
    mask, dist = ops.sparse_verify_arena(paths, q, base, idx, live,
                                         tau=3, block_n=128,
                                         use_kernel=True)
    mask, dist = np.asarray(mask), np.asarray(dist)
    assert mask[:, :64].all()                  # live + reached, dist 0
    assert (dist[:, :64] == 0).all()
    assert not mask[:, 64:].any()              # dead or pruned
    assert (dist[:, 64:] == BIG_I).all()


# ---------------------------------------------------------------------------
# the headline property: fused == per-segment reference == static rebuild
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 16))
def test_fused_bit_identical_across_lifecycle_property(seed):
    """Random insert→delete→merge→compact interleavings: the fused arena
    path returns the same column planes, ids, and top-k as the reference
    fan-out AND a fresh static build over the survivors."""
    rng = np.random.default_rng(seed)
    L = int(rng.integers(6, 13))
    n = int(rng.integers(60, 300))
    k = int(rng.integers(1, 10))
    db = rng.integers(0, 1 << _B, size=(n, L), dtype=np.uint8)
    idx = SegmentedIndex(L, _B, delta_cap=int(rng.integers(16, 96)))
    surv = np.zeros(n, bool)
    inserted = 0
    while inserted < n:
        step = int(rng.integers(1, 48))
        ids = idx.insert(db[inserted:inserted + step])
        surv[ids] = True
        inserted += step
        if rng.random() < 0.4 and surv.any():
            victims = np.flatnonzero(surv)
            victims = victims[rng.random(victims.size) < 0.25]
            idx.delete(victims)
            surv[victims] = False
        if rng.random() < 0.3:
            idx.merge()
        if rng.random() < 0.2:
            idx.compact()
        # query mid-stream: sealed segments + live delta buffer together
        if rng.random() < 0.5:
            qs = db[rng.integers(0, n, 2)]
            assert_columns_equal(idx, qs, int(rng.integers(0, L // 2 + 1)))
    if not surv.any():
        return
    qs = np.concatenate([db[rng.integers(0, n, 2)],
                         rng.integers(0, 1 << _B, size=(1, L),
                                      dtype=np.uint8)])
    assert_columns_equal(idx, qs, 2)
    assert_topk_equal(idx, qs, k)
    # and against the static oracle over survivors
    surv_ids = np.flatnonzero(surv)
    static = topk_batch(build_bst(db[surv], _B), qs, k)
    mapped = np.where(np.asarray(static.ids) >= 0,
                      surv_ids[np.maximum(np.asarray(static.ids), 0)], -1)
    got = idx.topk_batch(qs, k)
    np.testing.assert_array_equal(np.asarray(got.dists),
                                  np.asarray(static.dists))
    np.testing.assert_array_equal(np.asarray(got.ids), mapped)


@pytest.mark.parametrize("backend,kw", [
    ("bst", {}), ("multi", {"mi_blocks": 2}), ("sharded", {"n_shards": 2}),
])
@pytest.mark.parametrize("m", [1, 3, 8])
def test_fused_matches_reference_all_backends_and_batch_shapes(backend, kw,
                                                               m):
    rng = np.random.default_rng(hash((backend, m)) % 2 ** 31)
    L = 12
    db = rng.integers(0, 1 << _B, size=(260, L), dtype=np.uint8)
    idx = SegmentedIndex(L, _B, delta_cap=10 ** 9, backend=backend,
                         auto_merge=False, **kw)
    for lo in range(0, 240, 80):
        idx.insert(db[lo:lo + 80])
        idx.flush()
    ids = np.arange(240)
    idx.delete(ids[rng.choice(240, 40, replace=False)])
    idx.insert(db[240:])             # live delta buffer rides along
    assert len(idx.segments) == 3
    qs = np.concatenate([db[rng.integers(0, 260, max(m - 1, 1))][:m - 1],
                         rng.integers(0, 1 << _B, size=(1, L),
                                      dtype=np.uint8)])
    assert qs.shape[0] == m
    assert_columns_equal(idx, qs, 3)
    assert_topk_equal(idx, qs, 6)


def test_sharded_segmented_index_uses_arena_and_matches():
    rng = np.random.default_rng(77)
    L = 10
    db = rng.integers(0, 1 << _B, size=(300, L), dtype=np.uint8)
    sh = ShardedSegmentedIndex(L, _B, n_shards=3, delta_cap=40)
    sh_ref = ShardedSegmentedIndex(L, _B, n_shards=3, delta_cap=40,
                                   use_arena=False)
    ids = sh.insert(db)
    sh_ref.insert(db)
    dels = ids[rng.choice(300, 50, replace=False)]
    sh.delete(dels)
    sh_ref.delete(dels)
    qs = db[[3, 99]]
    got, want = sh.topk_batch(qs, 5), sh_ref.topk_batch(qs, 5)
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))
    np.testing.assert_array_equal(np.asarray(got.dists),
                                  np.asarray(want.dists))
    res_a = sh.search_batch(qs, 2)
    res_r = sh_ref.search_batch(qs, 2)
    np.testing.assert_array_equal(res_a.mask, res_r.mask)
    np.testing.assert_array_equal(res_a.dist, res_r.dist)


# ---------------------------------------------------------------------------
# dispatch accounting: ONE launch per rung, independent of segment count
# ---------------------------------------------------------------------------

def sixteen_segment_index(with_delta=True):
    rng = np.random.default_rng(5)
    L = 12
    db = rng.integers(0, 1 << _B, size=(520, L), dtype=np.uint8)
    idx = SegmentedIndex(L, _B, delta_cap=10 ** 9, auto_merge=False)
    for lo in range(0, 512, 32):
        idx.insert(db[lo:lo + 32])
        idx.flush()
    if with_delta:
        idx.insert(db[512:])
    assert len(idx.segments) == 16
    return idx, db


def ladder_rungs(tau0, tau_final, L):
    """Replay the deterministic τ schedule: rungs executed from tau0
    until the ladder stopped at tau_final."""
    t, c = tau0, 1
    while t < tau_final:
        t = min(L, max(t + 1, 2 * t))
        c += 1
    return c


def test_dispatch_spy_one_launch_per_rung_at_16_segments():
    idx, db = sixteen_segment_index()
    qs = db[[3, 77, 200]]
    # single-rung top-k (tau0=L can never escalate)
    reset_dispatch_stats()
    idx.topk_batch(qs, 5, tau0=idx.L)
    spy = dispatch_stats()
    assert spy == {"total": 1, "fused": 1, "fanout": 0, "rerank": 0}, spy
    # multi-rung top-k: exactly one launch per rung
    reset_dispatch_stats()
    res = idx.topk_batch(qs, 5, tau0=0)
    spy = dispatch_stats()
    rungs = ladder_rungs(0, res.tau, idx.L)
    assert rungs > 1
    assert spy["total"] == spy["fused"] == rungs, (spy, rungs)
    # range search: one launch, and the column contract carries it
    reset_dispatch_stats()
    res = idx.search_columns_batch(qs, 3)
    assert dispatch_stats()["total"] == 1
    assert res.dist.shape == (3, 520) and res.ids.shape == (520,)
    # the reference fan-out pays one launch per segment + delta instead
    idx.use_arena = False
    reset_dispatch_stats()
    idx.topk_batch(qs, 5, tau0=idx.L)
    spy = dispatch_stats()
    assert spy["total"] >= 17 and spy["fused"] == 0, spy


def test_dispatch_spy_flat_in_segment_count_for_search():
    rng = np.random.default_rng(6)
    L = 10
    db = rng.integers(0, 1 << _B, size=(256, L), dtype=np.uint8)
    for n_seg in (1, 4, 16):
        idx = SegmentedIndex(L, _B, delta_cap=10 ** 9, auto_merge=False)
        chunk = 256 // n_seg
        for lo in range(0, 256, chunk):
            idx.insert(db[lo:lo + chunk])
            idx.flush()
        assert len(idx.segments) == n_seg
        reset_dispatch_stats()
        idx.search_columns_batch(db[:2], 2)
        assert dispatch_stats()["total"] == 1, n_seg


# ---------------------------------------------------------------------------
# arena maintenance: incremental updates, not per-query re-uploads
# ---------------------------------------------------------------------------

def test_arena_appends_on_flush_and_rebuilds_on_merge():
    rng = np.random.default_rng(7)
    db = rng.integers(0, 4, size=(120, 8), dtype=np.uint8)
    idx = SegmentedIndex(8, 2, delta_cap=10 ** 9, auto_merge=False)
    idx.insert(db[:40])
    idx.flush()
    idx.topk_batch(db[:2], 3)            # builds the column store
    ar = idx._arena
    assert ar.n_cols == 40
    idx.insert(db[40:80])
    idx.flush()                          # append path: same store object
    idx.topk_batch(db[:2], 3)
    assert idx._arena is ar
    assert ar.n_cols == 80
    assert len(ar.serials) == 2
    idx.merge()                          # non-append change: full rebuild
    idx.topk_batch(db[:2], 3)
    assert idx._arena.n_cols == 80
    assert len(idx._arena.serials) == 1


def test_full_layout_arena_appends_on_flush_too():
    """The full-length reference layout keeps the PR-5 incremental
    maintenance: flush appends to the same ``_ColumnArena`` arrays."""
    rng = np.random.default_rng(7)
    db = rng.integers(0, 4, size=(80, 8), dtype=np.uint8)
    idx = SegmentedIndex(8, 2, delta_cap=10 ** 9, auto_merge=False,
                         layout="full")
    idx.insert(db[:40])
    idx.flush()
    idx.topk_batch(db[:2], 3)
    ar = idx._arena
    assert ar.cols.shape[-1] == ar.n_cols == 40
    idx.insert(db[40:])
    idx.flush()
    idx.topk_batch(db[:2], 3)
    assert idx._arena is ar and ar.cols.shape[-1] == 80


def test_delete_flips_device_liveness_lane_in_place():
    rng = np.random.default_rng(8)
    db = rng.integers(0, 4, size=(60, 8), dtype=np.uint8)
    idx = SegmentedIndex(8, 2, delta_cap=10 ** 9, auto_merge=False)
    ids = idx.insert(db)
    idx.flush()
    res = idx.search(db[17], 0)
    assert res.mask[ids[17]]
    ar = idx._arena
    idx.delete(ids[17])                  # no rebuild: same arena arrays
    assert idx._arena is ar
    assert not idx.search(db[17], 0).mask[ids[17]]
    assert not bool(np.asarray(ar.live)[17])


def test_segment_serials_are_unique_and_survive_merge_away():
    rng = np.random.default_rng(9)
    db = rng.integers(0, 4, size=(90, 8), dtype=np.uint8)
    idx = SegmentedIndex(8, 2, delta_cap=10 ** 9, backend="sharded",
                         n_shards=2, auto_merge=False)
    for lo in range(0, 90, 30):
        idx.insert(db[lo:lo + 30])
        idx.flush()
    serials = [seg.serial for seg in idx.segments]
    assert len(set(serials)) == len(serials) == 3
    idx.topk_batch(db[:2], 3)            # populate per-serial caches
    idx.merge()
    idx.merge()
    assert [seg.serial for seg in idx.segments] != serials
    # a post-merge query must hit the NEW segments' searchers, never a
    # stale cache entry for a merged-away index
    assert_topk_equal(idx, db[[5, 41]], 4)


# ---------------------------------------------------------------------------
# bucketed delta scan + compile-cache steady state
# ---------------------------------------------------------------------------

def test_delta_planes_bucket_to_power_of_two():
    rng = np.random.default_rng(10)
    idx = SegmentedIndex(8, 2, delta_cap=10 ** 9)
    for total in (1, 2, 3, 5, 9):
        idx.insert(rng.integers(0, 4, size=(total - len(idx._delta_ids), 8),
                                dtype=np.uint8))
        assert idx._delta_planes().shape[-1] == bucket_m(total)


def test_streaming_inserts_within_bucket_do_not_retrace():
    rng = np.random.default_rng(11)
    db = rng.integers(0, 4, size=(80, 8), dtype=np.uint8)
    idx = SegmentedIndex(8, 2, delta_cap=10 ** 9, auto_merge=False)
    idx.insert(db[:40])
    idx.flush()
    idx.insert(db[40:45])                 # delta bucket 8
    q = db[:2]
    idx.topk_batch(q, 3, tau0=2)          # warm (bucket nd=5 -> 8)
    warm = searcher_cache_info()
    for row in range(45, 48):             # 6, 7, 8 rows: same bucket
        idx.insert(db[row:row + 1])
        idx.topk_batch(q, 3, tau0=2)
    info = searcher_cache_info()
    assert info["traces"] == warm["traces"], (warm, info)
    assert info["misses"] == warm["misses"], (warm, info)


# ---------------------------------------------------------------------------
# column-compressed primary contract
# ---------------------------------------------------------------------------

def test_column_contract_is_primary_and_dense_plane_wraps_it():
    rng = np.random.default_rng(12)
    db = rng.integers(0, 4, size=(100, 10), dtype=np.uint8)
    idx = SegmentedIndex(10, 2, delta_cap=40, auto_merge=False)
    ids = idx.insert(db)
    idx.delete(ids[:30])
    idx.compact()                         # physical rows shrink to 70+delta
    qs = db[[40, 90]]
    cols = idx.search_columns_batch(qs, 3)
    R = cols.dist.shape[1]
    assert R == idx.n_live                # churn cost tracks live corpus
    assert R < idx.n_ids                  # ... not ids-ever-assigned
    np.testing.assert_array_equal(np.sort(cols.ids),
                                  np.arange(30, 100))
    dense = idx.search_batch(qs, 3)       # opt-in dense plane
    assert dense.dist.shape == (2, idx.n_ids)
    plane = np.full((2, idx.n_ids), BIG_I, np.int32)
    plane[:, cols.ids] = cols.dist
    np.testing.assert_array_equal(dense.dist, plane)
    np.testing.assert_array_equal(dense.mask, plane <= 3)
