"""Fused-program cache behavior (`segments._FUSED_CACHE`): the FIFO
bound really evicts AND eviction releases the device columns the
compiled closures pin; ``clear_fused_cache`` empties everything; and the
dead-generation purge drops an index's stale entries the moment its
stack serials or placement generation move on — no compiled program can
outlive the column layout it closed over."""

import gc
import importlib
import weakref

import numpy as np

from repro.core import SegmentedIndex, clear_fused_cache

segments_mod = importlib.import_module("repro.core.segments")


def _sealed_idx(n=80, n_seg=2, seed=3, **kw):
    rng = np.random.default_rng(seed)
    db = rng.integers(0, 4, size=(n, 8), dtype=np.uint8)
    idx = SegmentedIndex(8, 2, delta_cap=10 ** 9, auto_merge=False, **kw)
    per = n // n_seg
    for s in range(n_seg):
        idx.insert(db[s * per:(s + 1) * per])
        idx.flush()
    return idx, db


def test_fused_fifo_bound_actually_evicts(monkeypatch):
    monkeypatch.setattr(segments_mod, "_FUSED_CACHE_CAP", 3)
    clear_fused_cache()
    idx, _ = _sealed_idx()
    for tau in range(5):                 # 5 distinct rung keys, cap 3
        idx._fused_fn("cols", tau, 0, None)
    cache = segments_mod._FUSED_CACHE
    assert len(cache) == 3
    assert sorted(k[6] for k in cache) == [2, 3, 4]   # FIFO: oldest out


def test_fifo_eviction_frees_pinned_device_columns(monkeypatch):
    """An evicted entry's closure is the last reference to the column
    plan it compiled against — eviction must actually free those device
    arrays, not just shrink the dict."""
    monkeypatch.setattr(segments_mod, "_FUSED_CACHE_CAP", 2)
    clear_fused_cache()
    idx_a, _ = _sealed_idx(seed=3)
    idx_a._fused_fn("cols", 2, 0, None)
    ref = weakref.ref(idx_a._refresh_store().plan()[0].cols_hot)
    del idx_a
    gc.collect()
    assert ref() is not None             # the cache entry pins the plan
    idx_b, _ = _sealed_idx(seed=4)
    for tau in range(2):                 # fill the cap: A's entry evicts
        idx_b._fused_fn("cols", tau, 0, None)
    gc.collect()
    assert ref() is None


def test_clear_fused_cache_drops_everything():
    idx, db = _sealed_idx(seed=5)
    idx.topk_batch(db[:2], 3)
    assert len(segments_mod._FUSED_CACHE) > 0
    clear_fused_cache()
    assert len(segments_mod._FUSED_CACHE) == 0


def test_dead_generation_purge_on_flush():
    """A flush moves the serial fingerprint monotonically: the next
    cache fetch must drop every entry this index keyed on the old
    serials (they are permanently unreachable)."""
    clear_fused_cache()
    idx, _ = _sealed_idx(n=80, n_seg=1, seed=6)
    idx._fused_fn("cols", 2, 0, None)
    old_serials = idx._seg_serials()
    mine = [k for k in segments_mod._FUSED_CACHE if k[2] == idx._fused_id]
    assert mine and all(k[3] == old_serials for k in mine)
    rng = np.random.default_rng(7)
    idx.insert(rng.integers(0, 4, size=(20, 8), dtype=np.uint8))
    idx.flush()
    idx._fused_fn("cols", 2, 0, None)
    mine = [k for k in segments_mod._FUSED_CACHE if k[2] == idx._fused_id]
    assert mine and all(k[3] == idx._seg_serials() for k in mine)
    assert not any(k[3] == old_serials for k in mine)


def test_tier_flip_purges_old_generation_and_frees_closures():
    """A placement change (demotion) bumps the store generation: the
    pre-flip programs closed over device columns that no longer exist in
    that tier — the next fetch must purge them (freeing the old plan's
    concatenated columns) and answers must stay bit-identical."""
    clear_fused_cache()
    idx, db = _sealed_idx(n=80, n_seg=2, seed=8)
    r0 = idx.topk_batch(db[:2], 3)       # all-hot programs in cache
    store = idx._refresh_store()
    ref = weakref.ref(store.plan()[0].cols_hot)
    gen0 = store.gen
    store.hot_bytes = 0
    store._enforce_budget()              # demote everything: gen flips
    assert store.gen > gen0
    del store
    gc.collect()
    assert ref() is not None             # old-gen entries still pin it
    r1 = idx.topk_batch(db[:2], 3)       # purge + rebuild against slabs
    gc.collect()
    assert ref() is None
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r0.ids))
    np.testing.assert_array_equal(np.asarray(r1.dists),
                                  np.asarray(r0.dists))
    gen = idx._refresh_store().gen
    mine = [k for k in segments_mod._FUSED_CACHE if k[2] == idx._fused_id]
    assert mine and all(k[4] == gen for k in mine)
