"""Dynamic segmented index: streaming insert/delete/merge/compact must be
indistinguishable from a fresh static build over the surviving sketches —
bit-identical top-k (dists AND ids, after the monotone global-id mapping)
and range results — plus lifecycle mechanics (tombstones, size-tiered
merges, space accounting) and every backend (bst / multi / sharded)."""

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean env: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.core import (SegmentedIndex, ShardedSegmentedIndex, bucket_m,
                        build_bst, tombstone_bits, topk_batch)
from repro.core.bst import BIG
from repro.core.hamming import hamming_pairwise_naive

BIG_I = int(BIG)
_B = 2  # alphabet bits shared by the fixed-shape tests


def brute(qs, db):
    return np.asarray(hamming_pairwise_naive(jnp.asarray(qs),
                                             jnp.asarray(db)))


def check_roundtrip(idx, db, surv, qs, k):
    """Segmented results == static build over survivors: static row r
    corresponds to the r-th surviving global id (insertion order is
    monotone in global id, so (distance, id) tie order matches)."""
    surv_ids = np.flatnonzero(surv)
    ref = topk_batch(build_bst(db[surv], idx.b), qs, k)
    mapped = np.where(np.asarray(ref.ids) >= 0,
                      surv_ids[np.maximum(np.asarray(ref.ids), 0)], -1)
    got = idx.topk_batch(qs, k)
    np.testing.assert_array_equal(np.asarray(got.dists),
                                  np.asarray(ref.dists))
    np.testing.assert_array_equal(np.asarray(got.ids), mapped)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 16))
def test_insert_delete_merge_compact_roundtrip_property(seed):
    """The headline property: a random interleaving of
    insert→delete→merge→compact round-trips to bit-identical top-k
    results vs a fresh static build on the surviving sketches."""
    rng = np.random.default_rng(seed)
    L = int(rng.integers(6, 14))
    n = int(rng.integers(50, 500))
    k = int(rng.integers(1, 12))
    db = rng.integers(0, 1 << _B, size=(n, L), dtype=np.uint8)
    idx = SegmentedIndex(L, _B, delta_cap=int(rng.integers(16, 128)))
    surv = np.zeros(n, bool)
    inserted = 0
    while inserted < n:
        step = int(rng.integers(1, 64))
        ids = idx.insert(db[inserted:inserted + step])
        surv[ids] = True
        inserted += step
        if rng.random() < 0.5 and surv.any():
            victims = np.flatnonzero(surv)
            victims = victims[rng.random(victims.size) < 0.2]
            assert idx.delete(victims) == victims.size
            surv[victims] = False
    idx.flush()
    idx.merge()
    idx.compact()
    if not surv.any():
        assert idx.n_live == 0
        return
    qs = np.concatenate([db[rng.integers(0, n, 2)],
                         rng.integers(0, 1 << _B, size=(1, L),
                                      dtype=np.uint8)])
    check_roundtrip(idx, db, surv, qs, k)


@pytest.mark.parametrize("backend,kw", [
    ("bst", {}), ("multi", {"mi_blocks": 2}), ("sharded", {"n_shards": 2}),
])
def test_backends_roundtrip(backend, kw):
    rng = np.random.default_rng(7)
    L = 16
    db = rng.integers(0, 1 << _B, size=(300, L), dtype=np.uint8)
    idx = SegmentedIndex(L, _B, delta_cap=90, backend=backend, **kw)
    ids = idx.insert(db)
    dels = ids[rng.choice(300, 50, replace=False)]
    idx.delete(dels)
    idx.flush()
    surv = np.ones(300, bool)
    surv[dels] = False
    qs = np.stack([db[0], db[123],
                   rng.integers(0, 1 << _B, L).astype(np.uint8)])
    check_roundtrip(idx, db, surv, qs, 9)


def test_range_search_matches_bruteforce_mid_stream():
    """Queries mid-stream (sealed segments + a live delta buffer) return
    the exact τ-ball over live ids, with exact distances."""
    rng = np.random.default_rng(8)
    L, tau = 12, 3
    db = rng.integers(0, 1 << _B, size=(400, L), dtype=np.uint8)
    idx = SegmentedIndex(L, _B, delta_cap=128)
    ids = idx.insert(db[:350])
    idx.delete(ids[::5])
    idx.insert(db[350:])           # stays in the delta buffer
    assert len(idx._delta_ids) > 0
    surv = np.ones(400, bool)
    surv[ids[::5]] = False
    qs = db[[1, 51, 201]]
    res = idx.search_batch(qs, tau)
    assert res.overflow == 0
    d = brute(qs, db)
    want = (d <= tau) & surv[None, :]
    np.testing.assert_array_equal(res.mask, want)
    np.testing.assert_array_equal(res.dist[want], d[want])
    assert (res.dist[~want] == BIG_I).all()


def test_deleted_ids_never_return():
    rng = np.random.default_rng(9)
    db = rng.integers(0, 4, size=(120, 10), dtype=np.uint8)
    idx = SegmentedIndex(10, 2, delta_cap=60)
    ids = idx.insert(db)
    # delete the exact-match target: it must vanish from results
    assert idx.delete(ids[17]) == 1
    res = idx.search(db[17], 0)
    assert not res.mask[ids[17]]
    # deleting again (or an unknown id) is a no-op
    assert idx.delete(ids[17]) == 0
    assert idx.delete(np.int64(10 ** 9)) == 0
    # duplicate ids in one call count once
    assert idx.delete(np.array([ids[20], ids[20], ids[20]])) == 1


def test_size_tiered_merge_policy_bounds_segment_count():
    rng = np.random.default_rng(10)
    db = rng.integers(0, 4, size=(1024, 8), dtype=np.uint8)
    idx = SegmentedIndex(8, 2, delta_cap=64, auto_merge=True)
    for lo in range(0, 1024, 64):
        idx.insert(db[lo:lo + 64])
    # size-tiered invariant: at most one segment per ⌊log2 n⌋ tier
    tiers = [max(seg.n, 1).bit_length() for seg in idx.segments]
    assert len(tiers) == len(set(tiers))
    assert idx.counters["merges"] > 0
    assert idx.n_live == 1024


def test_compact_reclaims_tombstones_and_preserves_results():
    rng = np.random.default_rng(11)
    db = rng.integers(0, 4, size=(200, 10), dtype=np.uint8)
    idx = SegmentedIndex(10, 2, delta_cap=64)
    ids = idx.insert(db)
    idx.flush()
    idx.delete(ids[:80])
    before = idx.space_bits()
    assert idx.compact() >= 1
    assert idx.space_bits() < before
    assert sum(seg.n for seg in idx.segments) == 120
    surv = np.zeros(200, bool)
    surv[80:] = True
    check_roundtrip(idx, db, surv, db[[90, 150]], 5)


def test_fully_deleted_segment_is_dropped_and_empty_index_answers():
    rng = np.random.default_rng(12)
    db = rng.integers(0, 4, size=(50, 8), dtype=np.uint8)
    idx = SegmentedIndex(8, 2, delta_cap=10)
    ids = idx.insert(db)
    idx.delete(ids)
    assert idx.n_live == 0
    res = idx.topk_batch(db[:2], 3)
    assert (np.asarray(res.ids) == -1).all()
    assert (np.asarray(res.dists) == BIG_I).all()
    idx.compact()
    assert len(idx.segments) == 0


def test_sharded_segmented_index_roundtrip():
    rng = np.random.default_rng(13)
    L = 12
    db = rng.integers(0, 4, size=(500, L), dtype=np.uint8)
    sh = ShardedSegmentedIndex(L, 2, n_shards=3, delta_cap=40)
    ids = sh.insert(db)
    dels = ids[rng.choice(500, 70, replace=False)]
    assert sh.delete(dels) == 70
    sh.flush()
    sh.merge()
    surv = np.ones(500, bool)
    surv[dels] = False
    qs = np.stack([db[5], rng.integers(0, 4, L).astype(np.uint8)])
    surv_ids = np.flatnonzero(surv)
    ref = topk_batch(build_bst(db[surv], 2), qs, 7)
    mapped = np.where(np.asarray(ref.ids) >= 0,
                      surv_ids[np.maximum(np.asarray(ref.ids), 0)], -1)
    got = sh.topk_batch(qs, 7)
    np.testing.assert_array_equal(np.asarray(got.dists),
                                  np.asarray(ref.dists))
    np.testing.assert_array_equal(np.asarray(got.ids), mapped)
    # range plane agrees with brute force too
    res = sh.search_batch(qs, 3)
    d = brute(qs, db)
    want = (d <= 3) & surv[None, :]
    np.testing.assert_array_equal(res.mask, want)


def test_with_live_searcher_matches_postfilter_and_does_not_rejit():
    """The traced-liveness searcher (get_searcher with_live=True) equals
    post-filtering the plain searcher, and flipping tombstones reuses
    the same compiled fn (liveness is data, not a trace constant)."""
    from repro.core import clear_searcher_cache, get_searcher, \
        searcher_cache_info
    rng = np.random.default_rng(14)
    db = rng.integers(0, 4, size=(250, 12), dtype=np.uint8)
    idx = build_bst(db, 2)
    qs = jnp.asarray(db[:4])
    live = np.ones(250, bool)
    live[rng.choice(250, 100, replace=False)] = False
    clear_searcher_cache()
    fn = get_searcher(idx, 3, batch=True, with_live=True)
    res = fn(qs, jnp.asarray(live))
    plain = get_searcher(idx, 3, batch=True)(qs)
    np.testing.assert_array_equal(
        np.asarray(res.mask), np.asarray(plain.mask) & live[None, :])
    want_d = np.where(np.asarray(plain.mask) & live[None, :],
                      np.asarray(plain.dist), BIG_I)
    np.testing.assert_array_equal(np.asarray(res.dist), want_d)
    misses = searcher_cache_info()["misses"]
    live2 = ~live
    fn2 = get_searcher(idx, 3, batch=True, with_live=True)
    fn2(qs, jnp.asarray(live2))
    assert searcher_cache_info()["misses"] == misses  # no re-jit on delete


def test_tombstone_space_accounting():
    assert tombstone_bits(1) == 32 + 64
    assert tombstone_bits(64) == 64 + 96
    rng = np.random.default_rng(15)
    db = rng.integers(0, 4, size=(100, 8), dtype=np.uint8)
    idx = SegmentedIndex(8, 2, delta_cap=1000)
    idx.insert(db)
    # delta-only: bucket-padded verify planes + one tombstone bitmap
    # (bucket_m(100) == 128 rows of b*W uint32 planes actually allocated)
    assert idx.space_bits() == bucket_m(100) * 2 * 1 * 32 + tombstone_bits(100)
    idx.flush()
    seg = idx.segments[0]
    # sealed: succinct index + tombstones + the 9 B/row arena lanes
    # (base_idx int32 + gids int32 + live bool) the fused path allocates
    assert idx.space_bits() == (seg.index.model_bits() + tombstone_bits(seg.n)
                                + seg.n * (4 + 4 + 1) * 8)
    led = idx.space_ledger()
    assert set(led) == {"model_bits", "device_bytes", "host_bytes"}
    assert led["model_bits"] == idx.space_bits()
    assert led["host_bytes"] >= int(seg.packed.nbytes)


def test_stable_ids_survive_merge_and_compact():
    rng = np.random.default_rng(16)
    db = rng.integers(0, 4, size=(160, 10), dtype=np.uint8)
    idx = SegmentedIndex(10, 2, delta_cap=40, auto_merge=False)
    ids = idx.insert(db)
    idx.flush()
    idx.delete(ids[10:20])
    while idx.merge():
        pass
    idx.compact()
    # the exact-match query still reports its original global id
    res = idx.topk(db[42], 1)
    assert int(res.ids[0]) == int(ids[42])
    assert int(res.dists[0]) == 0
