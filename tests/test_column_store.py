"""Tiered suffix column store (DESIGN.md §7).

The layout layer must be *bit-identical* to the full-length arena and
the per-segment reference across the whole lifecycle (the suffix
columns drop exactly the bits the traversal's prefix distance already
carries); the placement layer must answer from the cold tier at the
same one-fused-dispatch-per-rung cost as the hot tier; and the
accounting must show the suffix layout's bytes-per-row win (>= 2x on
the review geometry L=16, b=2)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean env: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.core import (SegmentedIndex, ShardedSegmentedIndex,
                        dispatch_stats, geometry_for, reset_dispatch_stats,
                        reset_tier_stats, tier_stats)
from repro.core.column_store import TIER_COLD, TIER_HOT
from repro.core.hamming import n_words, pack_suffix_words, pack_vertical, \
    unpack_vertical

_KW = dict(delta_cap=50, auto_merge=False)


def _popcount32(x):
    return np.unpackbits(
        np.asarray(x, np.uint32).view(np.uint8)).astype(np.int64) \
        .reshape(np.shape(x) + (32,)).sum(axis=-1)


# -- layout primitives ---------------------------------------------------

def test_pack_unpack_vertical_roundtrip():
    rng = np.random.default_rng(0)
    for b, L, n in ((1, 5, 7), (2, 16, 33), (3, 40, 11)):
        sk = rng.integers(0, 2 ** b, size=(n, L), dtype=np.uint8)
        np.testing.assert_array_equal(
            unpack_vertical(pack_vertical(sk, b), b, L), sk)


def test_pack_suffix_words_distance_identity():
    """popcount(OR over the b in-word bit fields of the XOR) is the
    Hamming distance — the single-word analogue of the plane identity."""
    rng = np.random.default_rng(1)
    b, S = 2, 16
    a = rng.integers(0, 4, size=(50, S), dtype=np.uint8)
    c = rng.integers(0, 4, size=(50, S), dtype=np.uint8)
    x = pack_suffix_words(a, b) ^ pack_suffix_words(c, b)
    field = np.uint32((1 << S) - 1)
    acc = (x & field) | ((x >> np.uint32(S)) & field)
    np.testing.assert_array_equal(_popcount32(acc), (a != c).sum(axis=1))
    with pytest.raises(ValueError):
        pack_suffix_words(np.zeros((1, 20), np.uint8), 2)   # 2*20 > 32


def test_geometry_for_picks_packed_vs_plane():
    assert geometry_for(16, 2, 4) == (12, True, 1)     # b*S = 24 <= 32
    g = geometry_for(64, 8, 0)                         # b*S = 512
    assert not g.packed and g.row_words == 8 * n_words(64)


# -- lifecycle bit-identity ----------------------------------------------

def _snapshots(idx, db, qs, k):
    """Query after every lifecycle stage: flush -> delete -> merge-to-one
    -> compact.  Chunked inserts leave a multi-segment stack so the
    merge stage actually merges."""
    out = []
    chunk = max(1, len(db) // 4)
    ids = np.concatenate([idx.insert(db[lo:lo + chunk])
                          for lo in range(0, len(db), chunk)])
    idx.flush()
    out.append(idx.topk_batch(qs, k))
    idx.delete(ids[15:45])
    out.append(idx.topk_batch(qs, k))
    while idx.merge():
        pass
    out.append(idx.topk_batch(qs, k))
    idx.compact()
    out.append(idx.topk_batch(qs, k))
    return [(np.asarray(r.ids), np.asarray(r.dists)) for r in out]


def test_lifecycle_bit_identity_suffix_full_reference():
    rng = np.random.default_rng(11)
    db = rng.integers(0, 4, size=(160, 16), dtype=np.uint8)
    qs = db[:5]
    got = {layout: _snapshots(SegmentedIndex(16, 2, layout=layout, **_KW),
                              db, qs, 5)
           for layout in ("suffix", "full")}
    ref = _snapshots(SegmentedIndex(16, 2, use_arena=False, **_KW),
                     db, qs, 5)
    for stage, (r_ref_ids, r_ref_d) in enumerate(ref):
        for layout in ("suffix", "full"):
            ids, d = got[layout][stage]
            np.testing.assert_array_equal(ids, r_ref_ids)
            np.testing.assert_array_equal(d, r_ref_d)


@settings(max_examples=3, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6))
def test_lifecycle_bit_identity_property(seed):
    """Random corpus + queries: suffix == full == reference after a full
    insert -> delete -> merge -> compact pass (ids AND dists)."""
    rng = np.random.default_rng(seed)
    db = rng.integers(0, 4, size=(96, 8), dtype=np.uint8)
    qs = rng.integers(0, 4, size=(3, 8), dtype=np.uint8)
    kw = dict(delta_cap=30, auto_merge=False)
    runs = [_snapshots(SegmentedIndex(8, 2, layout="suffix", **kw),
                       db, qs, 4),
            _snapshots(SegmentedIndex(8, 2, layout="full", **kw),
                       db, qs, 4),
            _snapshots(SegmentedIndex(8, 2, use_arena=False, **kw),
                       db, qs, 4)]
    for stage in range(len(runs[0])):
        for run in runs[1:]:
            np.testing.assert_array_equal(run[stage][0], runs[0][stage][0])
            np.testing.assert_array_equal(run[stage][1], runs[0][stage][1])


def test_plane_fallback_geometry_bit_identical():
    """L=24, b=2: segments collapse shallow enough that b*S > 32, so the
    store takes the plane-packed fallback path — still bit-identical to
    the full-length arena."""
    rng = np.random.default_rng(12)
    db = rng.integers(0, 4, size=(120, 24), dtype=np.uint8)
    kw = dict(delta_cap=60, auto_merge=False)
    s = SegmentedIndex(24, 2, layout="suffix", **kw)
    f = SegmentedIndex(24, 2, layout="full", **kw)
    for idx in (s, f):
        idx.insert(db)
        idx.flush()
    rs, rf = s.topk_batch(db[:4], 6), f.topk_batch(db[:4], 6)
    np.testing.assert_array_equal(np.asarray(rs.ids), np.asarray(rf.ids))
    np.testing.assert_array_equal(np.asarray(rs.dists), np.asarray(rf.dists))
    assert any(not blk.geom.packed for blk in s._refresh_store().blocks)


def test_layout_validated():
    with pytest.raises(ValueError):
        SegmentedIndex(8, 2, layout="columnar")


# -- placement: cold tier ------------------------------------------------

def test_cold_tier_bit_identical_one_fused_dispatch_per_rung():
    """hot_bytes=0 forces every sealed block cold: answers must match the
    hot store bit for bit, at the SAME number of fused launches (staging
    is a transfer, not a program launch) and zero per-segment fan-out."""
    rng = np.random.default_rng(13)
    db = rng.integers(0, 4, size=(120, 16), dtype=np.uint8)
    qs = rng.integers(0, 4, size=(4, 16), dtype=np.uint8)
    kw = dict(delta_cap=10 ** 9, auto_merge=False)
    hot = SegmentedIndex(16, 2, layout="suffix", **kw)
    cold = SegmentedIndex(16, 2, layout="suffix", hot_bytes=0, **kw)
    full = SegmentedIndex(16, 2, layout="full", **kw)
    for idx in (hot, cold, full):
        for lo in range(0, 120, 40):            # 3 sealed segments
            idx.insert(db[lo:lo + 40])
            idx.flush()
    reset_dispatch_stats()
    rh = hot.topk_batch(qs, 5)
    d_hot = dispatch_stats()
    reset_tier_stats()
    reset_dispatch_stats()
    rc = cold.topk_batch(qs, 5)
    d_cold = dispatch_stats()
    rf = full.topk_batch(qs, 5)
    np.testing.assert_array_equal(np.asarray(rc.ids), np.asarray(rh.ids))
    np.testing.assert_array_equal(np.asarray(rc.dists), np.asarray(rh.dists))
    np.testing.assert_array_equal(np.asarray(rc.ids), np.asarray(rf.ids))
    assert d_cold["fanout"] == 0 and d_cold["total"] == d_cold["fused"]
    assert d_cold["fused"] == d_hot["fused"]    # cold adds no launches
    ts = tier_stats()
    assert ts["demotions"] == 3                 # 3 sealed blocks, all cold
    assert ts["prefetches"] >= 3 and ts["staged_bytes"] > 0
    tier = cold.stats()["tier"]
    assert tier["hot_blocks"] == 0 and tier["cold_blocks"] == 3
    assert tier["hot_bytes"] == 0 and tier["cold_bytes"] > 0


def test_lru_demotion_and_promotion_under_budget():
    rng = np.random.default_rng(14)
    db = rng.integers(0, 4, size=(120, 16), dtype=np.uint8)
    qs = db[:3]
    idx = SegmentedIndex(16, 2, layout="suffix", delta_cap=10 ** 9,
                         auto_merge=False)
    for lo in range(0, 120, 40):                # 3 sealed segments
        idx.insert(db[lo:lo + 40])
        idx.flush()
    r0 = idx.topk_batch(qs, 4)
    store = idx._refresh_store()
    assert store.tier_summary()["hot_blocks"] == 3
    blk_bytes = store.blocks[0].col_bytes       # 40 rows * 1 word = 160 B
    assert blk_bytes == 40 * 4
    reset_tier_stats()
    store.hot_bytes = 2 * blk_bytes
    store._enforce_budget()                     # LRU: oldest block demotes
    assert store.tier_summary() == {
        "hot_blocks": 2, "cold_blocks": 1,
        "hot_bytes": 2 * blk_bytes, "cold_bytes": blk_bytes}
    assert store.blocks[0].tier == TIER_COLD
    assert tier_stats()["demotions"] == 1
    gen0 = store.gen
    r1 = idx.topk_batch(qs, 4)                  # mixed hot/cold answer
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r0.ids))
    np.testing.assert_array_equal(np.asarray(r1.dists),
                                  np.asarray(r0.dists))
    store.hot_bytes = 10 ** 9                   # budget grew: promote back
    store._enforce_budget()
    assert store.tier_summary()["cold_blocks"] == 0
    assert store.blocks[0].tier == TIER_HOT
    assert tier_stats()["promotions"] == 1 and store.gen > gen0
    r2 = idx.topk_batch(qs, 4)
    np.testing.assert_array_equal(np.asarray(r2.dists),
                                  np.asarray(r0.dists))


def test_sharded_stacks_split_hot_budget():
    rng = np.random.default_rng(15)
    db = rng.integers(0, 4, size=(120, 16), dtype=np.uint8)
    qs = db[:3]
    cold = ShardedSegmentedIndex(16, 2, 2, delta_cap=30, auto_merge=False,
                                 hot_bytes=0)
    ref = ShardedSegmentedIndex(16, 2, 2, delta_cap=30, auto_merge=False,
                                layout="full")
    for idx in (cold, ref):
        idx.insert(db)
        idx.flush()
    rc, rr = cold.topk_batch(qs, 5), ref.topk_batch(qs, 5)
    np.testing.assert_array_equal(np.asarray(rc.ids), np.asarray(rr.ids))
    np.testing.assert_array_equal(np.asarray(rc.dists), np.asarray(rr.dists))
    st_ = cold.stats()
    assert st_["host_bytes"] > 0 and "device_bytes" in st_


# -- accounting ----------------------------------------------------------

def test_suffix_layout_at_least_halves_device_column_bytes():
    """The acceptance ratio on the review geometry (L=16, b=2): the
    full-length layout spends 2 uint32 words per row, the packed suffix
    exactly one -> suffix column bytes <= half, integer-exact."""
    rng = np.random.default_rng(16)
    db = rng.integers(0, 4, size=(160, 16), dtype=np.uint8)
    s = SegmentedIndex(16, 2, layout="suffix", **_KW)
    f = SegmentedIndex(16, 2, layout="full", **_KW)
    for idx in (s, f):
        idx.insert(db)
        idx.flush()
        idx.topk_batch(db[:2], 3)               # builds the store/arena
    sfx = s._refresh_store().col_bytes()
    ful = f._refresh_arena().col_bytes()
    assert sfx > 0 and ful >= 2 * sfx
    st_s, st_f = s.stats(), f.stats()
    # one consistent ledger: same model bits either way (the model is the
    # succinct index + lanes, not the layout), device bytes strictly less
    assert st_s["space_bits"] == st_f["space_bits"]
    assert st_s["device_bytes"] < st_f["device_bytes"]
    # forced cold: column payload leaves the device entirely
    c = SegmentedIndex(16, 2, layout="suffix", hot_bytes=0, **_KW)
    c.insert(db)
    c.flush()
    c.topk_batch(db[:2], 3)
    store = c._refresh_store()
    assert store.tier_summary()["hot_bytes"] == 0
    assert store.host_bytes() == store.col_bytes() == sfx
