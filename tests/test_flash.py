"""Flash attention (custom-vjp backward) vs the naive full-softmax oracle:
values AND gradients, across causal x window x softcap x GQA."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash import flash_attention
from repro.models.layers import blockwise_attention, softcap


def naive_attention(q, k, v, *, causal, window=0, cap=0.0):
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(D)
    if cap:
        s = jnp.tanh(s / cap) * cap
    q_pos = jnp.arange(Sq)
    k_pos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


CASES = [
    dict(causal=True, window=0, cap=0.0, hq=4, hkv=4),
    dict(causal=True, window=7, cap=0.0, hq=4, hkv=2),     # sliding + GQA
    dict(causal=True, window=0, cap=30.0, hq=4, hkv=4),    # softcap
    dict(causal=False, window=0, cap=0.0, hq=4, hkv=4),    # encoder
    dict(causal=True, window=5, cap=50.0, hq=8, hkv=2),    # everything
]


@pytest.mark.parametrize("case", CASES)
def test_flash_matches_naive(case):
    B, S, D = 2, 48 if case["causal"] else 64, 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, S, case["hq"], D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, case["hkv"], D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, case["hkv"], D)), jnp.float32)

    kw = dict(causal=case["causal"], window=case["window"], cap=case["cap"])
    out_flash = flash_attention(q, k, v, q_block=16, kv_block=16, **kw)
    out_ref = naive_attention(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out_flash), np.asarray(out_ref),
                               rtol=2e-5, atol=2e-5)

    def loss_flash(q, k, v):
        return (flash_attention(q, k, v, q_block=16, kv_block=16, **kw)
                * jnp.cos(jnp.arange(D))).sum()

    def loss_ref(q, k, v):
        return (naive_attention(q, k, v, **kw)
                * jnp.cos(jnp.arange(D))).sum()

    g_flash = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for gf, gr, name in zip(g_flash, g_ref, "qkv"):
        np.testing.assert_allclose(
            np.asarray(gf), np.asarray(gr), rtol=5e-4, atol=5e-4,
            err_msg=f"d{name} mismatch for {case}")


def test_flash_matches_blockwise_forward():
    """flash forward == existing blockwise forward (same math)."""
    B, S, H, D = 2, 40, 4, 16
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, q_block=16, kv_block=16)
    b = blockwise_attention(q, k, v, causal=True, q_block=16, kv_block=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)
