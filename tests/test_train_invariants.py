"""Training-step invariants: gradient accumulation is microbatch-count
invariant, remat does not change values, and the bf16 compute cast is
confined to matrices."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import model as M
from repro.models.io import synthetic_batch
from repro.optim.adamw import Hyper, adamw_init
from repro.train.steps import cast_for_compute, make_train_step

ARCH = "smollm-135m"


def _setup():
    cfg = get_config(ARCH, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = synthetic_batch(cfg, 4, 32, step=0)
    return cfg, params, batch


def test_microbatch_invariance():
    """mb=1, 2, 4 produce the same updated params (mean-of-means holds
    because microbatches are equal-sized)."""
    cfg, params, batch = _setup()
    hyper = Hyper(total_steps=10, warmup_steps=1)
    results = []
    for mb in (1, 2, 4):
        step = make_train_step(cfg, hyper, num_microbatches=mb,
                               compute_dtype=jnp.float32)
        opt = adamw_init(params)
        new_p, _, metrics = jax.jit(step)(params, opt, batch)
        results.append((mb, new_p, float(metrics["loss"])))
    _, p1, l1 = results[0]
    for mb, pn, ln in results[1:]:
        assert abs(l1 - ln) < 1e-4, (mb, l1, ln)
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(pn)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5,
                                       err_msg=f"mb={mb}")


def test_remat_value_invariance():
    """remat=True/False give identical losses (recompute, same math)."""
    cfg, params, batch = _setup()
    l_no = M.loss_fn(params, cfg, batch, remat=False)
    l_yes = M.loss_fn(params, cfg, batch, remat=True)
    np.testing.assert_allclose(float(l_no), float(l_yes), rtol=1e-6)
    g_no = jax.grad(lambda p: M.loss_fn(p, cfg, batch, remat=False))(params)
    g_yes = jax.grad(lambda p: M.loss_fn(p, cfg, batch, remat=True))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g_no),
                    jax.tree_util.tree_leaves(g_yes)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_cast_for_compute_scope():
    """Only float32 matrices are cast; norms/scalars/int buffers keep
    their dtype (f32 master-weight contract)."""
    cfg, params, _ = _setup()
    cast = cast_for_compute(params, jnp.bfloat16)
    for (path, orig), (_, new) in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree_util.tree_leaves_with_path(cast)):
        if orig.dtype == jnp.float32 and orig.ndim >= 2:
            assert new.dtype == jnp.bfloat16, path
        else:
            assert new.dtype == orig.dtype, path


def test_loss_masking():
    """targets < 0 are excluded from the loss."""
    cfg, params, batch = _setup()
    full = float(M.loss_fn(params, cfg, batch))
    masked_batch = dict(batch)
    masked_batch["targets"] = batch["targets"].at[:, ::2].set(-1)
    masked = float(M.loss_fn(params, cfg, masked_batch))
    assert np.isfinite(masked) and masked != full
    all_masked = dict(batch)
    all_masked["targets"] = jnp.full_like(batch["targets"], -1)
    assert float(M.loss_fn(params, cfg, all_masked)) == 0.0
