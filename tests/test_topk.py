"""Top-k kNN engine: exact agreement with the brute-force Hamming oracle
(ties broken by id), the τ-escalation ladder, the distance vector carried
by SearchResult, and the compiled-searcher cache (no re-jit on repeated
(index, τ) calls)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (build_bst, build_louds, build_multi_index,
                        clear_searcher_cache, mi_search, search,
                        searcher_cache_info, topk, topk_batch)
from repro.core.bst import BIG


def brute_dists(db, q):
    return (db != q[None, :]).sum(axis=1).astype(np.int32)


def oracle_topk(db, q, k):
    """k smallest distances, ties broken by id; trimmed to n if k > n."""
    d = brute_dists(db, q)
    order = np.lexsort((np.arange(len(db)), d))[: min(k, len(db))]
    return order, d[order]


def random_db(rng, n, L, b, dup_frac=0.3):
    n_uniq = max(1, int(n * (1 - dup_frac)))
    base = rng.integers(0, 1 << b, size=(n_uniq, L)).astype(np.uint8)
    extra = base[rng.integers(0, n_uniq, size=n - n_uniq)]
    db = np.concatenate([base, extra], axis=0)
    rng.shuffle(db)
    return db


@pytest.mark.parametrize("b", [1, 2, 4])
@pytest.mark.parametrize("k", [1, 7, 64])
def test_topk_matches_bruteforce(b, k):
    rng = np.random.default_rng(b * 31 + k)
    L = {1: 24, 2: 16, 4: 12}[b]
    db = random_db(rng, 300, L, b)
    idx = build_bst(db, b)
    for qi in range(3):
        q = db[rng.integers(0, len(db))] if qi % 2 == 0 else \
            rng.integers(0, 1 << b, size=L).astype(np.uint8)
        res = topk(idx, q, k)
        assert res.overflow == 0
        want_ids, want_d = oracle_topk(db, q, k)
        np.testing.assert_array_equal(np.asarray(res.ids), want_ids)
        np.testing.assert_array_equal(np.asarray(res.dists), want_d)


def test_topk_escalates_past_initial_tau():
    """k far above the survivors of the cost-model's starting τ: the
    ladder must escalate and still return the exact answer."""
    rng = np.random.default_rng(11)
    db = random_db(rng, 150, 16, 2, dup_frac=0.0)
    idx = build_bst(db, 2)
    q = rng.integers(0, 4, size=16).astype(np.uint8)
    # tau0=0 survivors are (almost surely) zero for a random query
    res = topk(idx, q, 25, tau0=0)
    want_ids, want_d = oracle_topk(db, q, 25)
    np.testing.assert_array_equal(np.asarray(res.ids), want_ids)
    np.testing.assert_array_equal(np.asarray(res.dists), want_d)
    assert res.tau > 0  # the ladder really escalated


def test_topk_k_exceeds_n_pads():
    rng = np.random.default_rng(12)
    db = random_db(rng, 40, 12, 2)
    idx = build_bst(db, 2)
    q = db[0]
    res = topk(idx, q, 64)
    want_ids, want_d = oracle_topk(db, q, 64)
    np.testing.assert_array_equal(np.asarray(res.ids)[:40], want_ids)
    np.testing.assert_array_equal(np.asarray(res.dists)[:40], want_d)
    assert (np.asarray(res.ids)[40:] == -1).all()
    assert (np.asarray(res.dists)[40:] == int(BIG)).all()


@pytest.mark.parametrize("builder", [build_bst, build_louds])
def test_topk_batch_matches_bruteforce(builder):
    rng = np.random.default_rng(13)
    db = random_db(rng, 200, 14, 2)
    idx = builder(db, 2)
    qs = np.stack([db[3], db[50],
                   rng.integers(0, 4, size=14).astype(np.uint8)])
    res = topk_batch(idx, qs, 9)
    for i in range(len(qs)):
        want_ids, want_d = oracle_topk(db, qs[i], 9)
        np.testing.assert_array_equal(np.asarray(res.ids)[i], want_ids)
        np.testing.assert_array_equal(np.asarray(res.dists)[i], want_d)


@pytest.mark.parametrize("tau", [0, 2, 4])
def test_search_result_distances_exact(tau):
    """SearchResult.dist is the exact Hamming distance inside the τ-ball
    and BIG outside — the invariant topk's selection relies on."""
    rng = np.random.default_rng(14)
    db = random_db(rng, 250, 16, 2)
    idx = build_bst(db, 2)
    q = db[9]
    res = search(idx, q, tau)
    assert int(res.overflow) == 0
    d = brute_dists(db, q)
    got = np.asarray(res.dist)
    np.testing.assert_array_equal(got[d <= tau], d[d <= tau])
    assert (got[d > tau] == int(BIG)).all()


def test_multi_index_distances_exact():
    rng = np.random.default_rng(15)
    db = random_db(rng, 300, 32, 2)
    mi = build_multi_index(db, 2, 2)
    q = db[21]
    tau = 4
    res = mi_search(mi, q, tau)
    d = brute_dists(db, q)
    got = np.asarray(res.dist)
    np.testing.assert_array_equal(np.asarray(res.mask), d <= tau)
    np.testing.assert_array_equal(got[d <= tau], d[d <= tau])
    assert (got[d > tau] == int(BIG)).all()


def test_tiny_cap_ladder_converges_to_exact_mask():
    """Regression for the overflow ladder: an absurdly small starting
    capacity must still converge to the exact solution set."""
    rng = np.random.default_rng(16)
    db = random_db(rng, 300, 16, 2, dup_frac=0.0)
    idx = build_bst(db, 2)
    q = db[0]
    res = search(idx, q, tau=4, cap_max=2)
    assert int(res.overflow) == 0
    d = brute_dists(db, q)
    np.testing.assert_array_equal(np.asarray(res.mask), d <= 4)
    np.testing.assert_array_equal(np.asarray(res.dist)[d <= 4], d[d <= 4])


def test_repeated_search_hits_searcher_cache():
    """Repeated search() at a fixed (index, τ) must be served from the
    process-level compiled-searcher cache: miss count frozen, hits grow."""
    rng = np.random.default_rng(17)
    db = random_db(rng, 200, 16, 2)
    idx = build_bst(db, 2)
    clear_searcher_cache()
    search(idx, db[0], 2)
    after_first = searcher_cache_info()
    assert after_first["misses"] == 1 and after_first["hits"] == 0
    for i in range(5):
        search(idx, db[i], 2)
    after_more = searcher_cache_info()
    assert after_more["misses"] == after_first["misses"]  # no re-jit
    assert after_more["hits"] == after_first["hits"] + 5
    # a different tau is a different compiled rung
    search(idx, db[0], 3)
    assert searcher_cache_info()["misses"] == 2


def test_topk_repeated_calls_do_not_rejit():
    rng = np.random.default_rng(18)
    db = random_db(rng, 200, 16, 2)
    idx = build_bst(db, 2)
    clear_searcher_cache()
    first = topk(idx, db[0], 5)
    misses = searcher_cache_info()["misses"]
    again = topk(idx, db[1], 5)
    assert searcher_cache_info()["misses"] == misses
    assert first.tau == again.tau
