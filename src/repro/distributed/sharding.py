"""Sharding rules: logical axes -> mesh axes, param/cache/batch specs.

The physical mesh is ``(pod, data, model)`` (multi-pod) or
``(data, model)`` (single pod).  Logical axes used by the model code:

  * ``batch``  -> ("pod", "data")  — activation batch, MoE dispatch groups
  * ``data``   -> "data"           — FSDP shard axis for parameters
  * ``model``  -> "model"          — tensor parallel (heads / ffn / vocab /
                                      experts / SSM heads)

Parameters are therefore FSDP-sharded over ``data`` *and* tensor-sharded
over ``model`` (ZeRO-3 + TP), replicated across ``pod`` — the pod axis is
pure data parallelism, so the only cross-pod traffic is the gradient
all-reduce, which is what makes the 2-pod dry-run's collective schedule
legible (see EXPERIMENTS.md §Dry-run).

``constrain`` is the activation-annotation hook used inside model code:
it resolves logical names against a process-global mesh (set by the
launcher) and silently no-ops on CPU smoke tests (no mesh) or when a
dimension does not divide the axis (e.g. batch=1 long-context decode —
the spec degrades to replicated rather than padding 31/32 of the array).
"""

from __future__ import annotations

import contextlib
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_LOGICAL = {
    "batch": ("pod", "data"),
    "data": ("data",),
    "fsdp": ("data",),
    "model": ("model",),
    "expert": ("model",),
}

_GLOBAL_MESH: Optional[Mesh] = None


def set_global_mesh(mesh: Optional[Mesh]) -> None:
    global _GLOBAL_MESH
    _GLOBAL_MESH = mesh


def get_global_mesh() -> Optional[Mesh]:
    return _GLOBAL_MESH


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = get_global_mesh()
    set_global_mesh(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        set_global_mesh(prev)


def _resolve(spec: Sequence, mesh: Mesh, shape: Tuple[int, ...]) -> P:
    """Logical spec -> PartitionSpec, dropping axes that are absent from
    the mesh or that do not divide the dimension."""
    out = []
    for dim, name in enumerate(spec):
        if name is None:
            out.append(None)
            continue
        axes = []
        for logical in ([name] if isinstance(name, str) else list(name)):
            axes.extend(a for a in _LOGICAL.get(logical, (logical,))
                        if a in mesh.axis_names)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if axes and total > 1 and shape[dim] % total == 0:
            out.append(tuple(axes) if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return P(*out)


def constrain(x: jnp.ndarray, spec: Sequence) -> jnp.ndarray:
    mesh = get_global_mesh()
    if mesh is None or not hasattr(x, "shape") or x.ndim != len(spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, _resolve(spec, mesh, x.shape)))


# ---------------------------------------------------------------------------
# parameter specs (path-based rules)
# ---------------------------------------------------------------------------

def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "name"):
            names.append(str(k.name))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
    return tuple(names)


def _param_logical(path_names: Tuple[str, ...], ndim: int) -> Tuple:
    """Logical spec for one parameter leaf.  Stacked unit params carry a
    leading ``n_units`` axis; rules are written for the *unstacked* rank
    and get ``None`` prepended for any extra leading axes."""
    name = path_names[-1]
    in_moe = "moe" in path_names or "router" in path_names

    base = None
    if name == "embed":
        base = ("model", "data")                 # (V, d) vocab-TP + FSDP
    elif name == "lm_head":
        base = ("data", "model")                 # (d, V)
    elif name in ("wq", "wk", "wv"):
        base = ("data", "model", None)           # (d, H, hd)
    elif name == "wo":
        base = ("model", None, "data")           # (H, hd, d)
    elif name == "router":
        base = ("data", None)                    # (d, E) — replicated over model
    elif name in ("w_gate", "w_up"):
        base = ("model", "data", None) if in_moe else ("data", "model")
    elif name == "w_down":
        base = ("model", None, "data") if in_moe else ("model", "data")
    elif name in ("wz", "wx"):
        base = ("data", "model")                 # (d, d_inner)
    elif name in ("wB", "wC", "wdt"):
        base = ("data", None)
    elif name == "out_proj":
        base = ("model", "data")                 # (d_inner, d)
    elif name == "conv_x":
        base = (None, "model")                   # (K, d_inner)
    if base is None:
        base = (None,) * ndim                    # norms, biases, A_log, ...
    if len(base) < ndim:
        base = (None,) * (ndim - len(base)) + tuple(base)
    return base


def param_specs(params: Any, mesh: Mesh):
    """ShapeDtypeStruct/array pytree -> NamedSharding pytree."""
    def leaf_spec(path, leaf):
        names = _path_names(path)
        spec = _param_logical(names, leaf.ndim)
        return NamedSharding(mesh, _resolve(spec, mesh, leaf.shape))
    return jax.tree_util.tree_map_with_path(leaf_spec, params)


# ---------------------------------------------------------------------------
# cache / batch specs
# ---------------------------------------------------------------------------

def cache_specs(cache: Any, mesh: Mesh, kv_shard: str = "heads"):
    """Decode-cache pytree specs.  KV leaves are (u, B, S, Kv, hd); SSM
    conv (u, B, K-1, C) and state (u, B, H, P, N) — batch over
    (pod, data), heads/channels over model (or the SEQUENCE axis over
    model when kv_shard="seq" — §Perf P9), with divisibility fallback."""
    def leaf_spec(path, leaf):
        if leaf.ndim == 5:      # KV cache or SSM state
            names = _path_names(path)
            if "state" in names:
                spec = (None, "batch", "model", None, None)
            elif kv_shard == "seq":
                spec = (None, "batch", "model", None, None)
            else:
                spec = (None, "batch", None, "model", None)
        elif leaf.ndim == 4:    # conv window (u, B, K-1, C)
            spec = (None, "batch", None, "model")
        else:
            spec = (None,) * leaf.ndim
        return NamedSharding(mesh, _resolve(spec, mesh, leaf.shape))
    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def batch_specs(batch: Any, mesh: Mesh):
    def leaf_spec(leaf):
        spec = ("batch",) + (None,) * (leaf.ndim - 1)
        return NamedSharding(mesh, _resolve(spec, mesh, leaf.shape))
    return jax.tree_util.tree_map(leaf_spec, batch)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
