"""Gradient compression for cross-pod all-reduce.

Two tiers (DESIGN.md §5):

1. **bf16 collectives** (default, always on): parameters are cast to
   bf16 inside the differentiated function (train.steps), so every
   gradient collective GSPMD inserts — FSDP reduce-scatter over "data",
   DP all-reduce over "pod" — carries bf16.  Nothing to do here; the
   dry-run HLO verifies it.

2. **int8 + error feedback** (optional, for bandwidth-starved inter-pod
   links): per-tensor symmetric quantization with a residual buffer so
   the quantization error is re-injected next step (1-bit-Adam-style
   convergence behaviour).  ``compress`` runs *before* the pod
   all-reduce boundary; ``decompress`` after.  In a shard_map deployment
   the int8 payload is what crosses the pod axis — an ~4x byte reduction
   on the slowest links.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class CompressedGrads(NamedTuple):
    q: PyTree        # int8 payloads
    scale: PyTree    # f32 per-tensor scales


def init_error_feedback(params: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads: PyTree, error: PyTree) -> Tuple[CompressedGrads, PyTree]:
    """Quantize grads+error to int8; returns payload and the new residual."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        residual = g - q.astype(jnp.float32) * scale
        return q, scale, residual

    flat, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat, flat_e)]
    q = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    scale = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_err = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return CompressedGrads(q=q, scale=scale), new_err


def decompress(c: CompressedGrads) -> PyTree:
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, c.q, c.scale)


def compressed_bytes(c: CompressedGrads) -> int:
    leaves = jax.tree_util.tree_leaves(c.q)
    return sum(l.size for l in leaves) + 4 * len(leaves)
