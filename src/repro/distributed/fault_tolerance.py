"""Fault-tolerance policies: resume-or-init, elastic re-shard, straggler
detection, and deterministic replay.

Posture for 1000+ nodes (DESIGN.md §5), with the single-process container
exercising each mechanism end-to-end:

* **Checkpoint/restart** — ``resume_or_init`` restores the latest complete
  checkpoint (atomic directories mean a crash mid-write can never be
  picked up) or initializes fresh.  Tested by killing/restoring mid-run
  and asserting bitwise-identical continuation (test_checkpoint.py).
* **Elastic re-shard** — checkpoints are logical (unsharded), so a
  restore may target a *different* mesh; ``param_specs`` on the new mesh
  re-shards at ``device_put`` time.  A 512-chip run can resume on 256.
* **Straggler mitigation** — the data pipeline is a pure function of
  (arch, step), so a replacement worker regenerates any step's shard
  without coordination; ``StragglerMonitor`` implements the detection
  policy (EWMA step time, flag at ``factor``x) that a pod-level
  controller would act on (re-slice the straggler's data shard).
* **Preemption drills** — ``SimulatedFailure`` raises at a planned step;
  used by tests to prove the restart path.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional, Tuple

import jax

from . import checkpoint as ckpt
from ..store.faults import CrashPoint
from .sharding import param_specs

PyTree = Any


def resume_or_init(ckpt_dir: str, abstract_tree: PyTree,
                   init_fn: Callable[[], PyTree],
                   mesh=None) -> Tuple[PyTree, int]:
    """Restore the latest checkpoint onto the *current* mesh, or init.
    Returns (tree, start_step)."""
    ckpt.sweep_stale(ckpt_dir)      # GC a crashed writer's tmp/old dirs
    step = ckpt.latest_checkpoint(ckpt_dir)
    if step is None:
        return init_fn(), 0
    shardings = param_specs(abstract_tree, mesh) if mesh is not None else None
    tree = ckpt.restore_checkpoint(ckpt_dir, step, abstract_tree, shardings)
    return tree, step


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker; ``check`` returns the list of flagged
    worker ids.  On a real pod this feeds the controller's re-sharding /
    hot-spare decision; here it is the policy object under test."""

    n_workers: int
    alpha: float = 0.2
    factor: float = 2.0
    warmup: int = 3
    _ewma: Optional[List[float]] = None
    _count: int = 0

    def observe(self, worker_times: List[float]) -> None:
        assert len(worker_times) == self.n_workers
        if self._ewma is None:
            self._ewma = list(worker_times)
        else:
            self._ewma = [self.alpha * t + (1 - self.alpha) * e
                          for t, e in zip(worker_times, self._ewma)]
        self._count += 1

    def check(self) -> List[int]:
        if self._ewma is None or self._count < self.warmup:
            return []
        med = sorted(self._ewma)[self.n_workers // 2]
        return [i for i, e in enumerate(self._ewma) if e > self.factor * med]


class SimulatedFailure(CrashPoint):
    """Planned-step failure (restart drills).  Subclasses the store's
    :class:`repro.store.faults.CrashPoint` so one except clause covers
    both planned-step and planned-I/O-boundary kills."""

    def __init__(self, message: str):
        RuntimeError.__init__(self, message)


@dataclasses.dataclass
class FailurePlan:
    """Deterministic failure injection for restart drills."""
    fail_at_step: int
    fired: bool = False

    def maybe_fail(self, step: int) -> None:
        if not self.fired and step == self.fail_at_step:
            self.fired = True
            raise SimulatedFailure(f"injected node failure at step {step}")
