"""Checkpointing: sharded-array save/restore with manifest + atomic rename,
an async writer thread, and *elastic* restore (any mesh shape).

Layout per step::

    <dir>/step_0000042.tmp-<pid>/   (written)  ->  <dir>/step_0000042/
        manifest.json     {step, keys, shapes, dtypes}
        arrays.npz        one entry per flattened key path

Arrays are stored *unsharded-logical* (gathered to host), so a restore
can target any mesh whose axes divide the dimensions — the elastic
re-shard story (node count changed between runs) is just
``device_put(value, NamedSharding(new_mesh, spec))``.  On a real
multi-host pod each host would write its address-space slice and the
manifest would carry the global shape; the format here is the
single-process projection of that design (DESIGN.md §5).

The atomic tmp-pid → fsync → rename protocol lives in
``repro.store.atomic`` (shared with the durable index store,
DESIGN.md §8); this module uses those helpers rather than its own copy.
A writer that crashes mid-save leaves a stale ``step_*.tmp-<pid>``
(or ``.old-<pid>`` / ``.rm``) directory behind — ``sweep_stale`` removes
them and runs automatically on startup paths (``list_checkpoints``,
``AsyncCheckpointer``, ``resume_or_init``).
"""

from __future__ import annotations

import json
import os
import re
import threading
import time
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..store.atomic import atomic_write_dir, sweep_stale_tmp

PyTree = Any

_STEP_RE = re.compile(r"^step_(\d{7})$")


def sweep_stale(ckpt_dir: str) -> List[str]:
    """Garbage-collect leftovers of crashed writers: ``step_*.tmp-<pid>``
    staging dirs, ``.old-<pid>`` displaced predecessors, and half-deleted
    ``.rm`` dirs.  This process's own in-flight tmp writes (a live
    ``AsyncCheckpointer`` thread) are left alone.  Returns removed paths."""
    return sweep_stale_tmp(ckpt_dir)


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keyed = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                       for k in path)
        keyed[key] = leaf
    return keyed, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree) -> str:
    """Synchronous save; returns the final path.  Atomic: the directory
    appears under its final name only when complete (staged + fsynced +
    renamed by ``repro.store.atomic.atomic_write_dir``)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:07d}")
    keyed, _ = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in keyed.items()}
    manifest = {
        "step": step,
        "keys": sorted(arrays),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
        "time": time.time(),
    }

    def populate(tmp: str) -> None:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)

    if os.path.exists(final):  # overwrite-resume: displace, don't destroy
        os.rename(final, final + f".old-{os.getpid()}")
    atomic_write_dir(final, populate, label="checkpoint")
    return final


class AsyncCheckpointer:
    """Fetches device arrays to host synchronously (cheap), then writes on
    a background thread so the train loop never blocks on disk."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._pending: List[threading.Thread] = []
        sweep_stale(ckpt_dir)   # GC a crashed predecessor's leftovers

    def save(self, step: int, tree: PyTree) -> None:
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        t = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True)
        t.start()
        self._pending.append(t)

    def _write(self, step: int, host_tree: PyTree) -> None:
        save_checkpoint(self.ckpt_dir, step, host_tree)
        self._gc()

    def _gc(self) -> None:
        steps = list_checkpoints(self.ckpt_dir)
        for s in steps[:-self.keep]:
            path = os.path.join(self.ckpt_dir, f"step_{s:07d}")
            tmp = path + ".rm"
            try:
                os.rename(path, tmp)
            except OSError:
                continue
            for root, dirs, files in os.walk(tmp, topdown=False):
                for fn in files:
                    os.unlink(os.path.join(root, fn))
                for d in dirs:
                    os.rmdir(os.path.join(root, d))
            os.rmdir(tmp)

    def wait(self) -> None:
        for t in self._pending:
            t.join()
        self._pending.clear()


def list_checkpoints(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_checkpoint(ckpt_dir: str) -> Optional[int]:
    steps = list_checkpoints(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, abstract_tree: PyTree,
                       shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of ``abstract_tree``; if ``shardings``
    (a NamedSharding pytree, e.g. from ``sharding.param_specs`` on the
    *current* mesh) is given, leaves are placed sharded — this is the
    elastic-restore path."""
    path = os.path.join(ckpt_dir, f"step_{step:07d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    keyed, treedef = _flatten(abstract_tree)
    missing = sorted(set(keyed) - set(manifest["keys"]))
    if missing:
        raise ValueError(f"checkpoint at step {step} lacks keys: {missing[:5]}")
    flat_sh = None
    if shardings is not None:
        sh_keyed, _ = _flatten(shardings)
        flat_sh = sh_keyed

    out = {}
    for key, ref in keyed.items():
        arr = data[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {ref.shape}")
        arr = arr.astype(ref.dtype)
        if flat_sh is not None:
            out[key] = jax.device_put(arr, flat_sh[key])
        else:
            out[key] = jnp.asarray(arr)

    leaves_in_order = [out[k] for k, _ in _flatten(abstract_tree)[0].items()]
    return jax.tree_util.tree_unflatten(treedef, leaves_in_order)
