"""Pallas TPU kernel: fused flash-attention forward.

WHY (§Perf P5): the XLA-compiled attention — even with the custom-VJP
flash schedule — spills every (qb x kb) probability tile to HBM between
the two matmuls (measured: ~6 TB/step/device on the command-r train
cell, the dominant roofline term).  A fused kernel keeps the tile in
VMEM: HBM traffic collapses to q, k, v in + o out.

Design (v5e: MXU 128x128, 8x128 VPU lanes, ~16 MiB VMEM/core):
  * grid = (B, H, nq, nk); the LAST axis is "arbitrary" (sequential),
    so VMEM scratch (m, l, acc) carries the online-softmax state across
    kv blocks of one q block — the kv loop never leaves the core.
  * BlockSpecs: q (1, 1, BQ, D), k/v (1, 1, BK, D), out (1, 1, BQ, D) —
    with BQ = BK = 128 and D up to 128, a step's working set is
    ~(3·128·128 + 128·128) f32 ≈ 260 KiB, leaving VMEM headroom for
    double-buffered prefetch of the next k/v blocks.
  * masks (causal / sliding window) are computed from program ids +
    iota inside the kernel — nothing is materialized in HBM.
  * accumulation f32; inputs may be bf16 (MXU-native).

Correctness: validated against ``ref.flash_attention_ref`` in interpret
mode (tests/test_kernels_flash.py) over shape x dtype x mask sweeps.
The backward on TPU would follow the same tiling (two additional
kernels); training in this repo uses the custom-VJP JAX path
(models/flash.py) which is TPU-correct everywhere, with this kernel as
the serving/prefill fast path and the §Roofline fused-attention model.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      bq: int, bk: int, causal: bool, window: int,
                      cap: float, scale: float, nk: int):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block (sequential)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
    if cap:
        s = jnp.tanh(s / cap) * cap

    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # (bq,)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * corr + p.sum(axis=-1)
    acc = acc_scr[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc / jnp.maximum(l_new, 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "cap", "bq", "bk", "interpret"))
def flash_attention_fwd_pallas(q: jnp.ndarray, k: jnp.ndarray,
                               v: jnp.ndarray, *, causal: bool = True,
                               window: int = 0, cap: float = 0.0,
                               bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                               interpret: bool = False) -> jnp.ndarray:
    """q, k, v: (B, H, S, D) (same H — GQA repeat done by the caller);
    returns (B, H, S, D).  S must be a multiple of bq and bk."""
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, Skv, bq, bk)
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / float(D) ** 0.5
    kernel = functools.partial(
        _flash_fwd_kernel, bq=bq, bk=bk, causal=causal, window=window,
        cap=cap, scale=scale, nk=nk)
    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m — running row max
            pltpu.VMEM((bq,), jnp.float32),       # l — running row sum
            pltpu.VMEM((bq, D), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(q, k, v)
