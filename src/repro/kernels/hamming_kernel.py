"""Pallas TPU kernel: vertical-format Hamming-threshold scan.

This is the measured hot spot of the paper's pipeline — the sparse-layer
path scan and the multi-index verification step both reduce to "stream a
packed sketch database past a query and popcount the XOR".  The workload
is integer and element-wise: it never touches the MXU, so the kernel is a
pure VPU streaming kernel and its roofline is the HBM bandwidth term.

Layout (see ref.py): the database is *fully vertical* — (b, W, n) uint32
with the sketch index on the last (lane) axis.  A block of
(b, W, BLOCK_N) therefore occupies b·W·BLOCK_N·4 bytes of VMEM and
vectorizes the whole XOR/OR/popcount chain across 128-sketch lanes with
the (tiny) b·W plane/word axes on sublanes.

Block-shape reasoning (v5e: 128 lanes, 8 sublanes, ~16 MiB VMEM/core):
  * BLOCK_N multiple of 128 (lane width).  Default 2048.
  * b·W ≤ 16 for every paper dataset (b=2,W=1 … b=8,W=2), so a block is at
    most 16·2048·4 = 128 KiB — VMEM pressure is negligible and the grid
    can double-buffer aggressively; arithmetic intensity is ~1.5 int-ops
    per byte, i.e. firmly memory-bound, which the roofline table reflects.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 2048

# Distance sentinel for pruned lanes.  Matches core.bst.BIG (kernels must
# not import core); verified equal in tests/test_kernels.py.
BIG = 1 << 20


def _hamming_kernel(db_ref, q_ref, out_ref, *, b: int, W: int):
    """One (query j, db block i) cell: distances for BLOCK_N sketches."""
    db = db_ref[...]          # (b, W, BLOCK_N) uint32
    q = q_ref[...]            # (b, W, 1) uint32
    diff = db ^ q             # broadcast over lanes
    acc = diff[0]
    for i in range(1, b):     # b is a python constant -> fully unrolled
        acc = acc | diff[i]
    pops = jax.lax.population_count(acc).astype(jnp.int32)  # (W, BLOCK_N)
    dist = pops[0]
    for w in range(1, W):
        dist = dist + pops[w]
    out_ref[...] = dist[None, :]  # (1, BLOCK_N)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def hamming_distances_pallas(db_vert: jnp.ndarray, q_vert: jnp.ndarray,
                             *, block_n: int = DEFAULT_BLOCK_N,
                             interpret: bool = False) -> jnp.ndarray:
    """(b, W, n) x (b, W, m) -> (m, n) int32 distances via pallas_call.

    Grid is (m, n/block_n): queries on the outer axis so each query's
    planes stay VMEM-resident while database blocks stream past.
    ``n`` must be a multiple of ``block_n`` (ops.py pads).
    """
    b, W, n = db_vert.shape
    m = q_vert.shape[-1]
    assert n % block_n == 0, (n, block_n)
    grid = (m, n // block_n)
    kernel = functools.partial(_hamming_kernel, b=b, W=W)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, W, block_n), lambda j, i: (0, 0, i)),
            pl.BlockSpec((b, W, 1), lambda j, i: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda j, i: (j, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(db_vert, q_vert)


def _verify_kernel(db_ref, q_ref, base_ref, mask_ref, dist_ref,
                   *, b: int, W: int, tau: int):
    """Fused sparse-layer verify: suffix distance + accumulated prefix
    distance, thresholded — emits an int32 0/1 survival mask plus the
    exact int32 total distance (clamped to BIG on pruned lanes)."""
    db = db_ref[...]
    q = q_ref[...]
    diff = db ^ q
    acc = diff[0]
    for i in range(1, b):
        acc = acc | diff[i]
    pops = jax.lax.population_count(acc).astype(jnp.int32)
    dist = pops[0]
    for w in range(1, W):
        dist = dist + pops[w]
    total = dist + base_ref[0, :]
    mask_ref[...] = (total <= tau).astype(jnp.int32)[None, :]
    dist_ref[...] = jnp.minimum(total, BIG)[None, :]


@functools.partial(jax.jit, static_argnames=("tau", "block_n", "interpret"))
def sparse_verify_pallas(paths_vert: jnp.ndarray, q_vert: jnp.ndarray,
                         base_dist: jnp.ndarray, *, tau: int,
                         block_n: int = DEFAULT_BLOCK_N,
                         interpret: bool = False):
    """(b, W, n) suffix paths + (b, W) query suffix + (n,) prefix distances
    -> ((n,) int32 survival mask, (n,) int32 total distance).  Distances
    are exact (prefix + suffix) for every non-pruned lane and clamped to
    BIG where the prefix was pruned (base >= BIG)."""
    b, W, n = paths_vert.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    kernel = functools.partial(_verify_kernel, b=b, W=W, tau=tau)
    mask, dist = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, W, block_n), lambda i: (0, 0, i)),
            pl.BlockSpec((b, W, 1), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, n), jnp.int32),
        ],
        interpret=interpret,
    )(paths_vert, q_vert[..., None], base_dist[None, :].astype(jnp.int32))
    return mask[0], dist[0]
