"""Pallas TPU kernels: query-tiled vertical-format Hamming scans.

This is the measured hot spot of the paper's pipeline — the sparse-layer
path scan and the multi-index verification step both reduce to "stream a
packed sketch database past a query and popcount the XOR".  The workload
is integer and element-wise: it never touches the MXU, so the kernel is a
pure VPU streaming kernel and its roofline is the HBM bandwidth term.

Layout (see ref.py): the database is *fully vertical* — (b, W, n) uint32
with the sketch index on the last (lane) axis.  A block of
(b, W, BLOCK_N) therefore occupies b·W·BLOCK_N·4 bytes of VMEM and
vectorizes the whole XOR/OR/popcount chain across 128-sketch lanes with
the (tiny) b·W plane/word axes on sublanes.

Query tiling (the batched-serving optimisation): a grid cell loads one
(b, W, BLOCK_N) database block ONCE and plays a whole (b, W, BLOCK_M)
query tile against it, emitting (BLOCK_M, BLOCK_N) output planes.  HBM
traffic for the database drops from m streams (one per query, the naive
vmap) to ⌈m/BLOCK_M⌉ streams, and the arithmetic intensity of the scan
scales ~linearly with BLOCK_M until the (BLOCK_M, BLOCK_N) output planes
dominate the byte count (see benchmarks/roofline.py).

Block-shape reasoning (v5e: 128 lanes, 8 sublanes, ~16 MiB VMEM/core):
  * BLOCK_N multiple of 128 (lane width).  Default 2048.
  * BLOCK_M on sublanes of the output tile; default 8 (one sublane
    register's worth) — the XOR intermediate is (b, W, BLOCK_M, BLOCK_N)
    = at most 16·8·2048·4 = 1 MiB of VMEM, leaving room to double-buffer.
  * b·W ≤ 16 for every paper dataset (b=2,W=1 … b=8,W=2); at BLOCK_M=1
    the kernel degenerates to the original memory-bound single-query
    scan at ~1.5 int-ops per byte.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 2048
DEFAULT_BLOCK_M = 8

# Distance sentinel for pruned lanes.  Matches core.bst.BIG (kernels must
# not import core); verified equal in tests/test_kernels.py.
BIG = 1 << 20


def _tile_distances(db, q, *, b: int, W: int):
    """(b, W, BLOCK_N) uint32 x (b, W, BLOCK_M) uint32 ->
    (BLOCK_M, BLOCK_N) int32 Hamming distances; b and W are python
    constants so both reductions fully unroll."""
    diff = db[:, :, None, :] ^ q[:, :, :, None]   # (b, W, BLOCK_M, BLOCK_N)
    acc = diff[0]
    for i in range(1, b):
        acc = acc | diff[i]
    pops = jax.lax.population_count(acc).astype(jnp.int32)  # (W, M, N)
    dist = pops[0]
    for w in range(1, W):
        dist = dist + pops[w]
    return dist


def _hamming_kernel(db_ref, q_ref, out_ref, *, b: int, W: int):
    """One (query tile j, db block i) cell: (BLOCK_M, BLOCK_N) distances."""
    out_ref[...] = _tile_distances(db_ref[...], q_ref[...], b=b, W=W)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def hamming_distances_pallas(db_vert: jnp.ndarray, q_vert: jnp.ndarray,
                             *, block_m: int = DEFAULT_BLOCK_M,
                             block_n: int = DEFAULT_BLOCK_N,
                             interpret: bool = False) -> jnp.ndarray:
    """(b, W, n) x (b, W, m) -> (m, n) int32 distances via pallas_call.

    Grid is (m/block_m, n/block_n): query tiles on the outer axis so each
    tile's planes stay VMEM-resident while database blocks stream past —
    the database is read ⌈m/block_m⌉ times total.  ``n`` must be a
    multiple of ``block_n`` and ``m`` of ``block_m`` (ops.py pads both).
    """
    b, W, n = db_vert.shape
    m = q_vert.shape[-1]
    assert n % block_n == 0, (n, block_n)
    assert m % block_m == 0, (m, block_m)
    grid = (m // block_m, n // block_n)
    kernel = functools.partial(_hamming_kernel, b=b, W=W)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, W, block_n), lambda j, i: (0, 0, i)),
            pl.BlockSpec((b, W, block_m), lambda j, i: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda j, i: (j, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(db_vert, q_vert)


def _verify_batch_kernel(db_ref, q_ref, base_ref, mask_ref, dist_ref,
                         *, b: int, W: int, tau: int):
    """Fused query-tiled sparse-layer verify: suffix distance + per-query
    accumulated prefix distance, thresholded — emits (BLOCK_M, BLOCK_N)
    int32 0/1 survival masks plus the exact int32 total distances
    (clamped to BIG on pruned lanes)."""
    dist = _tile_distances(db_ref[...], q_ref[...], b=b, W=W)
    total = dist + base_ref[...]                  # (BLOCK_M, BLOCK_N)
    mask_ref[...] = (total <= tau).astype(jnp.int32)
    dist_ref[...] = jnp.minimum(total, BIG)


@functools.partial(jax.jit,
                   static_argnames=("tau", "block_m", "block_n", "interpret"))
def sparse_verify_batch_pallas(paths_vert: jnp.ndarray, q_vert: jnp.ndarray,
                               base_dist: jnp.ndarray, *, tau: int,
                               block_m: int = DEFAULT_BLOCK_M,
                               block_n: int = DEFAULT_BLOCK_N,
                               interpret: bool = False):
    """(b, W, n) suffix paths + (b, W, m) query suffixes + (m, n) prefix
    distances -> ((m, n) int32 survival masks, (m, n) int32 totals).

    Grid (m/block_m, n/block_n): each cell loads one (b, W, block_n)
    database block once and XOR/popcounts it against a (b, W, block_m)
    query tile, so the collapsed-path array is streamed from HBM only
    ⌈m/block_m⌉ times for the whole batch.  Distances are exact
    (prefix + suffix) for every non-pruned lane and clamped to BIG where
    the prefix was pruned (base >= BIG)."""
    b, W, n = paths_vert.shape
    m = q_vert.shape[-1]
    assert n % block_n == 0, (n, block_n)
    assert m % block_m == 0, (m, block_m)
    assert base_dist.shape == (m, n), (base_dist.shape, m, n)
    grid = (m // block_m, n // block_n)
    kernel = functools.partial(_verify_batch_kernel, b=b, W=W, tau=tau)
    mask, dist = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, W, block_n), lambda j, i: (0, 0, i)),
            pl.BlockSpec((b, W, block_m), lambda j, i: (0, 0, j)),
            pl.BlockSpec((block_m, block_n), lambda j, i: (j, i)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda j, i: (j, i)),
            pl.BlockSpec((block_m, block_n), lambda j, i: (j, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int32),
            jax.ShapeDtypeStruct((m, n), jnp.int32),
        ],
        interpret=interpret,
    )(paths_vert, q_vert, base_dist.astype(jnp.int32))
    return mask, dist


def sparse_verify_pallas(paths_vert: jnp.ndarray, q_vert: jnp.ndarray,
                         base_dist: jnp.ndarray, *, tau: int,
                         block_n: int = DEFAULT_BLOCK_N,
                         interpret: bool = False):
    """Single-query verify: the m=1, block_m=1 degenerate tile of the
    batched kernel.  (b, W, n) + (b, W) + (n,) -> ((n,) mask, (n,) dist)."""
    mask, dist = sparse_verify_batch_pallas(
        paths_vert, q_vert[..., None], base_dist[None, :].astype(jnp.int32),
        tau=tau, block_m=1, block_n=block_n, interpret=interpret)
    return mask[0], dist[0]


def _packed_tile_distances(db, q, *, b: int, S: int):
    """(BLOCK_N,) uint32 packed suffixes x (BLOCK_M,) uint32 packed query
    suffixes -> (BLOCK_M, BLOCK_N) int32 Hamming distances over the S
    suffix positions.  All b planes of a row live in ONE word (plane i at
    bit offset i·S, see ``hamming.pack_suffix_words``), so the XOR/OR
    fold runs as b-1 shift+mask+OR word ops before a single popcount —
    the vertical-format identity at 1/W·b of the full-length traffic."""
    x = db[None, :] ^ q[:, None]                  # (BLOCK_M, BLOCK_N)
    field = jnp.uint32((1 << S) - 1) if S else jnp.uint32(0)
    acc = x & field
    for i in range(1, b):
        acc = acc | ((x >> jnp.uint32(i * S)) & field)
    return jax.lax.population_count(acc).astype(jnp.int32)


def _verify_arena_packed_kernel(db_ref, q_ref, base_ref, idx_ref, live_ref,
                                mask_ref, dist_ref, *, b: int, S: int,
                                tau: int):
    """Packed-suffix twin of ``_verify_arena_kernel``: identical base
    gather / liveness / threshold semantics, but the per-column payload
    is one uint32 word (the b bit planes of the S-symbol suffix below
    the segment's ℓ_s collapse depth) instead of (b, W) full-length
    words — the prefix part of the distance arrives through the gathered
    base plane (DESIGN.md §7)."""
    dist = _packed_tile_distances(db_ref[...], q_ref[...], b=b, S=S)
    base = jnp.take(base_ref[...], idx_ref[...], axis=1)  # (BLOCK_M, BLOCK_N)
    base = jnp.where(live_ref[...][None, :] != 0, base, BIG)
    total = dist + base
    mask_ref[...] = (total <= tau).astype(jnp.int32)
    dist_ref[...] = jnp.minimum(total, BIG)


@functools.partial(jax.jit,
                   static_argnames=("b", "S", "tau", "block_m", "block_n",
                                    "interpret"))
def sparse_verify_arena_packed_pallas(db_words: jnp.ndarray,
                                      q_words: jnp.ndarray,
                                      base_plane: jnp.ndarray,
                                      base_idx: jnp.ndarray,
                                      live: jnp.ndarray, *, b: int, S: int,
                                      tau: int,
                                      block_m: int = DEFAULT_BLOCK_M,
                                      block_n: int = DEFAULT_BLOCK_N,
                                      interpret: bool = False):
    """Arena verify over **single-word packed suffix columns**
    (DESIGN.md §7; requires b·S <= 32).

    db_words:   (n,) uint32 — one packed suffix word per column;
    q_words:    (m,) uint32 — the query suffixes in the same packing;
    base_plane: (m, T) int32 — concatenated per-(segment, root) *prefix*
                distances (BIG = pruned), slot 0 the delta's trivial 0;
    base_idx:   (n,) int32 segment-offset lane; live: (n,) int32.

    Same (m/block_m, n/block_n) query-tiled grid and return contract as
    ``sparse_verify_arena_pallas`` — only the column payload shrinks,
    from b·W words to one."""
    n = db_words.shape[-1]
    m = q_words.shape[-1]
    T = base_plane.shape[-1]
    assert n % block_n == 0, (n, block_n)
    assert m % block_m == 0, (m, block_m)
    assert base_plane.shape == (m, T), (base_plane.shape, m, T)
    assert base_idx.shape == (n,), (base_idx.shape, n)
    assert live.shape == (n,), (live.shape, n)
    grid = (m // block_m, n // block_n)
    kernel = functools.partial(_verify_arena_packed_kernel, b=b, S=S, tau=tau)
    mask, dist = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda j, i: (i,)),
            pl.BlockSpec((block_m,), lambda j, i: (j,)),
            pl.BlockSpec((block_m, T), lambda j, i: (j, 0)),
            pl.BlockSpec((block_n,), lambda j, i: (i,)),
            pl.BlockSpec((block_n,), lambda j, i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda j, i: (j, i)),
            pl.BlockSpec((block_m, block_n), lambda j, i: (j, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int32),
            jax.ShapeDtypeStruct((m, n), jnp.int32),
        ],
        interpret=interpret,
    )(db_words.astype(jnp.uint32), q_words.astype(jnp.uint32),
      base_plane.astype(jnp.int32), base_idx.astype(jnp.int32),
      live.astype(jnp.int32))
    return mask, dist


def _verify_arena_kernel(db_ref, q_ref, base_ref, idx_ref, live_ref,
                         mask_ref, dist_ref, *, b: int, W: int, tau: int):
    """One (query tile j, column block i) cell of the arena verify: the
    per-column base distance is *gathered* through the segment-offset
    lane instead of arriving as a dense (m, n) plane — ``base_ref`` is
    the whole (BLOCK_M, T) concatenated per-root base plane for this
    query tile, ``idx_ref`` the (BLOCK_N,) int32 plane index of each
    column in the block, ``live_ref`` its (BLOCK_N,) int32 liveness lane
    (0 = tombstoned; pruned exactly like an unreached subtrie)."""
    dist = _tile_distances(db_ref[...], q_ref[...], b=b, W=W)
    base = jnp.take(base_ref[...], idx_ref[...], axis=1)  # (BLOCK_M, BLOCK_N)
    base = jnp.where(live_ref[...][None, :] != 0, base, BIG)
    total = dist + base                                   # (BLOCK_M, BLOCK_N)
    mask_ref[...] = (total <= tau).astype(jnp.int32)
    dist_ref[...] = jnp.minimum(total, BIG)


@functools.partial(jax.jit,
                   static_argnames=("tau", "block_m", "block_n", "interpret"))
def sparse_verify_arena_pallas(paths_vert: jnp.ndarray, q_vert: jnp.ndarray,
                               base_plane: jnp.ndarray,
                               base_idx: jnp.ndarray, live: jnp.ndarray,
                               *, tau: int,
                               block_m: int = DEFAULT_BLOCK_M,
                               block_n: int = DEFAULT_BLOCK_N,
                               interpret: bool = False):
    """Fused multi-segment verify over a **column arena** (DESIGN.md §6).

    paths_vert: (b, W, n) uint32 — concatenated verify columns of every
                segment plus the delta buffer (one column per physical
                row, full-length vertical packing);
    q_vert:     (b, W, m) uint32 query planes;
    base_plane: (m, T) int32 — the concatenated per-(segment, ℓ_s-root)
                base-distance plane (slot semantics are the caller's:
                the segmented index stores 0 = reached / BIG = pruned,
                with slot 0 the delta buffer's trivial base);
    base_idx:   (n,) int32 — per-column index into ``base_plane``'s T
                axis (segment columns point at segment_root_offset +
                their ℓ_s root; delta columns at the trivial slot);
    live:       (n,) int32 — per-column liveness lane (0 = tombstoned).

    Returns ((m, n) int32 survival masks, (m, n) int32 totals clamped to
    BIG).  Grid is the same (m/block_m, n/block_n) as
    ``sparse_verify_batch_pallas`` — one launch sweeps every segment and
    the delta buffer — but HBM traffic for the base term drops from an
    (m, n) dense plane to (m, T) + (n,) int32 lanes (T = total ℓ_s
    roots ≪ n).  The in-kernel gather is a lane-axis ``jnp.take`` per
    (BLOCK_M, BLOCK_N) cell; on older Mosaic versions without dynamic
    lane gathers, fall back to ``sparse_verify_batch_pallas`` with a
    pre-gathered plane (``ops.sparse_verify_arena(use_kernel=False)``
    takes that path through the oracle)."""
    b, W, n = paths_vert.shape
    m = q_vert.shape[-1]
    T = base_plane.shape[-1]
    assert n % block_n == 0, (n, block_n)
    assert m % block_m == 0, (m, block_m)
    assert base_plane.shape == (m, T), (base_plane.shape, m, T)
    assert base_idx.shape == (n,), (base_idx.shape, n)
    assert live.shape == (n,), (live.shape, n)
    grid = (m // block_m, n // block_n)
    kernel = functools.partial(_verify_arena_kernel, b=b, W=W, tau=tau)
    mask, dist = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, W, block_n), lambda j, i: (0, 0, i)),
            pl.BlockSpec((b, W, block_m), lambda j, i: (0, 0, j)),
            pl.BlockSpec((block_m, T), lambda j, i: (j, 0)),
            pl.BlockSpec((block_n,), lambda j, i: (i,)),
            pl.BlockSpec((block_n,), lambda j, i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda j, i: (j, i)),
            pl.BlockSpec((block_m, block_n), lambda j, i: (j, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int32),
            jax.ShapeDtypeStruct((m, n), jnp.int32),
        ],
        interpret=interpret,
    )(paths_vert, q_vert, base_plane.astype(jnp.int32),
      base_idx.astype(jnp.int32), live.astype(jnp.int32))
    return mask, dist


def _rerank_kernel(pay_ref, q_ref, surv_ref, out_ref, *, Wp: int,
                   metric: str):
    """One (query tile j, column block i) cell of the exact re-rank plane:
    AND/popcount the (Wp, BLOCK_N) payload bitmaps against a
    (Wp, BLOCK_M) query tile, reduce the word axis, and emit the exact
    set-similarity score for every survivor lane.  Non-survivors (and
    zero-denominator survivors' 0.0) keep the layout of the Hamming
    plane so the downstream top-k sort needs no re-gather.  Like
    ``_tile_distances``, Wp is a python constant and the word reduction
    fully unrolls on the sublane axis."""
    pay = pay_ref[...]                            # (Wp, BLOCK_N)
    q = q_ref[...]                                # (Wp, BLOCK_M)
    both = jax.lax.population_count(q[:, :, None] & pay[:, None, :])
    pa = jax.lax.population_count(q).astype(jnp.int32)    # (Wp, BLOCK_M)
    pb = jax.lax.population_count(pay).astype(jnp.int32)  # (Wp, BLOCK_N)
    inter = both[0].astype(jnp.int32)
    sa, sb = pa[0], pb[0]
    for w in range(1, Wp):
        inter = inter + both[w].astype(jnp.int32)
        sa = sa + pa[w]
        sb = sb + pb[w]
    inter = inter.astype(jnp.float32)             # (BLOCK_M, BLOCK_N)
    sa = sa.astype(jnp.float32)[:, None]
    sb = sb.astype(jnp.float32)[None, :]
    if metric == "jaccard":
        den = sa + sb - inter
    elif metric == "cosine":
        den = jnp.sqrt(sa * sb)
    else:                                         # containment (A = query)
        den = jnp.broadcast_to(sa, inter.shape)
    score = jnp.where(den > 0, inter / den, jnp.float32(0.0))
    out_ref[...] = jnp.where(surv_ref[...] != 0, score, jnp.float32(-1.0))


@functools.partial(jax.jit,
                   static_argnames=("metric", "block_m", "block_n",
                                    "interpret"))
def exact_rerank_pallas(pay_vert: jnp.ndarray, q_vert: jnp.ndarray,
                        surv: jnp.ndarray, *, metric: str,
                        block_m: int = DEFAULT_BLOCK_M,
                        block_n: int = DEFAULT_BLOCK_N,
                        interpret: bool = False) -> jnp.ndarray:
    """Exact re-rank scan: (Wp, n) payload bitmaps x (Wp, m) query
    bitmaps x (m, n) survivor mask -> (m, n) float32 exact scores.

    Same query-tiled (m/block_m, n/block_n) grid discipline as
    ``hamming_distances_pallas`` — one launch scores every survivor of
    the whole arena, reading the payload store once per query tile.
    Scores are exact Jaccard / cosine / containment over the uint32
    set bitmaps (see ``kernels.ref.exact_rerank_ref`` for semantics);
    non-survivor lanes emit the -1.0 sentinel.
    """
    Wp, n = pay_vert.shape
    m = q_vert.shape[-1]
    assert metric in ("jaccard", "cosine", "containment"), metric
    assert n % block_n == 0, (n, block_n)
    assert m % block_m == 0, (m, block_m)
    assert surv.shape == (m, n), (surv.shape, m, n)
    grid = (m // block_m, n // block_n)
    kernel = functools.partial(_rerank_kernel, Wp=Wp, metric=metric)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Wp, block_n), lambda j, i: (0, i)),
            pl.BlockSpec((Wp, block_m), lambda j, i: (0, j)),
            pl.BlockSpec((block_m, block_n), lambda j, i: (j, i)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda j, i: (j, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(pay_vert.astype(jnp.uint32), q_vert.astype(jnp.uint32),
      surv.astype(jnp.int32))
