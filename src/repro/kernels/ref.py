"""Pure-jnp oracles for every Pallas kernel in this package.

The oracle is the *specification*: kernels are validated against these in
``tests/test_kernels.py`` across a (shape × dtype × b × L) sweep with
``assert_allclose`` (exact equality — integer kernels).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .hamming_kernel import BIG


def hamming_distances_ref(db_vert: jnp.ndarray, q_vert: jnp.ndarray) -> jnp.ndarray:
    """Batched vertical-format Hamming distances.

    db_vert: (b, W, n) uint32 — fully-vertical layout: plane-major, then
             word, with the *database axis on lanes* (TPU-native: the XOR/
             OR/popcount stream vectorizes over 128-wide sketch lanes).
    q_vert:  (b, W, m) uint32 — m queries in the same layout.
    returns: (m, n) int32 distances.
    """
    b, W, n = db_vert.shape
    m = q_vert.shape[-1]
    # (m, b, W, n)
    diff = db_vert[None] ^ jnp.transpose(q_vert, (2, 0, 1))[..., None]
    acc = diff[:, 0]
    for i in range(1, b):
        acc = acc | diff[:, i]
    pops = jax.lax.population_count(acc).astype(jnp.int32)  # (m, W, n)
    return pops.sum(axis=1)


def hamming_threshold_count_ref(db_vert: jnp.ndarray, q_vert: jnp.ndarray,
                                tau: jnp.ndarray) -> jnp.ndarray:
    """(m,) int32 — number of DB sketches within distance tau of each query."""
    d = hamming_distances_ref(db_vert, q_vert)
    return (d <= tau).sum(axis=1).astype(jnp.int32)


def sparse_verify_batch_ref(paths_vert: jnp.ndarray, q_vert: jnp.ndarray,
                            base_dist: jnp.ndarray, tau: int):
    """Query-batched sparse-layer verification oracle.

    paths_vert: (b, W, n) uint32 — collapsed root-to-leaf suffix paths;
    q_vert:     (b, W, m) uint32 — m query suffixes;
    base_dist:  (m, n) int32     — per-query Hamming distance accumulated
                                   down to the sparse-layer roots (per leaf);
    returns ((m, n) bool, (m, n) int32) — survival masks
    (base + suffix <= tau) and total distances, clamped to BIG on pruned
    lanes.
    """
    d = hamming_distances_ref(paths_vert, q_vert)        # (m, n)
    total = base_dist.astype(jnp.int32) + d
    return total <= tau, jnp.minimum(total, BIG)


def sparse_verify_arena_ref(paths_vert: jnp.ndarray, q_vert: jnp.ndarray,
                            base_plane: jnp.ndarray, base_idx: jnp.ndarray,
                            live: jnp.ndarray, tau: int):
    """Arena verification oracle — the fused multi-segment contract
    (DESIGN.md §6): the per-column base distance is an indirect lookup
    through the segment-offset lane rather than a dense (m, n) plane.

    paths_vert: (b, W, n) uint32 — concatenated per-row verify columns
                of every segment + the delta buffer;
    q_vert:     (b, W, m) uint32 — m query planes;
    base_plane: (m, T) int32    — concatenated per-(segment, root) base
                                  distances (BIG = pruned subtrie);
    base_idx:   (n,) int32      — per-column index into the T axis;
    live:       (n,) bool/int32 — per-column liveness (0 = tombstoned);
    returns ((m, n) bool, (m, n) int32) — survival masks
    (base + column dist <= tau) and totals, clamped to BIG on pruned or
    dead lanes.
    """
    d = hamming_distances_ref(paths_vert, q_vert)        # (m, n)
    base = base_plane.astype(jnp.int32)[:, base_idx]     # (m, n) gather
    base = jnp.where(live.astype(bool)[None, :], base, BIG)
    total = base + d
    return total <= tau, jnp.minimum(total, BIG)


def sparse_verify_arena_packed_ref(db_words: jnp.ndarray,
                                   q_words: jnp.ndarray,
                                   base_plane: jnp.ndarray,
                                   base_idx: jnp.ndarray, live: jnp.ndarray,
                                   b: int, S: int, tau: int):
    """Packed-suffix arena oracle (DESIGN.md §7): columns carry ONE
    uint32 word holding all b bit planes of the S-symbol suffix below a
    segment's ℓ_s collapse depth (plane i at bit offset i·S — see
    ``hamming.pack_suffix_words``; requires b·S <= 32).  XOR then
    OR-fold the b S-bit fields and popcount: the vertical-format
    identity restricted to the suffix.  Base-gather/liveness/threshold
    semantics are exactly ``sparse_verify_arena_ref``'s.

    db_words: (n,) uint32;  q_words: (m,) uint32;  base_plane: (m, T);
    base_idx: (n,) int32;  live: (n,);  returns ((m, n) bool, (m, n)
    int32 totals clamped to BIG).
    """
    x = db_words[None, :] ^ q_words[:, None]             # (m, n)
    field = jnp.uint32((1 << S) - 1) if S else jnp.uint32(0)
    acc = x & field
    for i in range(1, b):
        acc = acc | ((x >> jnp.uint32(i * S)) & field)
    d = jax.lax.population_count(acc).astype(jnp.int32)
    base = base_plane.astype(jnp.int32)[:, base_idx]     # (m, n) gather
    base = jnp.where(live.astype(bool)[None, :], base, BIG)
    total = base + d
    return total <= tau, jnp.minimum(total, BIG)


def sparse_verify_ref(paths_vert: jnp.ndarray, q_vert: jnp.ndarray,
                      base_dist: jnp.ndarray, tau: int):
    """Single-query verification oracle: the m=1 row of the batch oracle.

    paths_vert: (b, W, n);  q_vert: (b, W);  base_dist: (n,) int32;
    returns ((n,) bool, (n,) int32).
    """
    mask, dist = sparse_verify_batch_ref(
        paths_vert, q_vert[..., None],
        base_dist.astype(jnp.int32)[None, :], tau)
    return mask[0], dist[0]


RERANK_METRICS = ("jaccard", "cosine", "containment")


def exact_rerank_ref(pay_vert: jnp.ndarray, q_vert: jnp.ndarray,
                     surv: jnp.ndarray, metric: str) -> jnp.ndarray:
    """Exact set-similarity re-rank oracle over survivor lanes.

    pay_vert: (Wp, n) uint32 column-major payload bitmaps; q_vert:
    (Wp, m) uint32 query bitmaps; surv: (m, n) survivor mask (nonzero =
    re-score this lane).  Returns (m, n) float32 scores — exact Jaccard
    ``|A∩B| / |A∪B|``, cosine ``|A∩B| / sqrt(|A||B|)``, or asymmetric
    containment ``|A∩B| / |A|`` with A the query — where survivors with
    a zero denominator score 0.0 and non-survivors carry the sentinel
    -1.0 (sorts strictly below every real score).
    """
    if metric not in RERANK_METRICS:
        raise ValueError(f"unknown rerank metric {metric!r}")
    inter = jax.lax.population_count(
        q_vert.T[:, :, None] & pay_vert[None, :, :]).astype(jnp.int32)
    inter = inter.sum(axis=1).astype(jnp.float32)              # (m, n)
    sa = jax.lax.population_count(q_vert).astype(jnp.int32) \
        .sum(axis=0).astype(jnp.float32)[:, None]              # (m, 1)
    sb = jax.lax.population_count(pay_vert).astype(jnp.int32) \
        .sum(axis=0).astype(jnp.float32)[None, :]              # (1, n)
    if metric == "jaccard":
        den = sa + sb - inter
    elif metric == "cosine":
        den = jnp.sqrt(sa * sb)
    else:                                                      # containment
        den = jnp.broadcast_to(sa, inter.shape)
    score = jnp.where(den > 0, inter / den, jnp.float32(0.0))
    return jnp.where(surv != 0, score, jnp.float32(-1.0))
