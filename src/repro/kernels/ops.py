"""Public jit'd wrappers around the Pallas kernels.

Responsibilities: layout conversion ((n, b, W) <-> (b, W, n)), padding to
block multiples, backend selection (compiled Pallas on TPU, interpret mode
on CPU so correctness tests execute the *same kernel body*), and fallback
to the pure-jnp oracle for shapes where a kernel launch is not worth it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .hamming_kernel import (BIG, DEFAULT_BLOCK_N, hamming_distances_pallas,
                             sparse_verify_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def to_lane_major(planes: jnp.ndarray) -> jnp.ndarray:
    """(n, b, W) sketch-major -> (b, W, n) lane-major (kernel layout)."""
    return jnp.transpose(planes, (1, 2, 0))


def _pad_lanes(x: jnp.ndarray, block_n: int) -> jnp.ndarray:
    n = x.shape[-1]
    pad = (-n) % block_n
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x


def hamming_distances(db_vert: jnp.ndarray, q_vert: jnp.ndarray,
                      *, block_n: int = DEFAULT_BLOCK_N,
                      use_kernel: bool | None = None) -> jnp.ndarray:
    """(b, W, n) x (b, W, m) -> (m, n) int32.  Pads n to a block multiple,
    launches the kernel, and slices the pad back off (pad sketches are
    all-zero words -> garbage distances, dropped here)."""
    n = db_vert.shape[-1]
    if use_kernel is None:
        use_kernel = n >= block_n  # tiny scans: oracle is cheaper than launch
    if not use_kernel:
        return ref.hamming_distances_ref(db_vert, q_vert)
    db_p = _pad_lanes(db_vert, block_n)
    out = hamming_distances_pallas(db_p, q_vert, block_n=block_n,
                                   interpret=not _on_tpu())
    return out[:, :n]


def sparse_verify(paths_vert: jnp.ndarray, q_vert: jnp.ndarray,
                  base_dist: jnp.ndarray, *, tau: int,
                  block_n: int = DEFAULT_BLOCK_N,
                  use_kernel: bool | None = None):
    """Fused verify: ((n,) int32 mask of leaves with prefix+suffix dist
    <= tau, (n,) int32 exact total distances — BIG-clamped when pruned)."""
    n = paths_vert.shape[-1]
    if use_kernel is None:
        use_kernel = n >= block_n
    if not use_kernel:
        mask, dist = ref.sparse_verify_ref(paths_vert, q_vert, base_dist, tau)
        return mask.astype(jnp.int32), dist
    paths_p = _pad_lanes(paths_vert, block_n)
    # pad base distances with +inf-like so pad lanes never survive
    pad = paths_p.shape[-1] - n
    base_p = jnp.pad(base_dist.astype(jnp.int32), (0, pad), constant_values=jnp.int32(BIG))
    mask, dist = sparse_verify_pallas(paths_p, q_vert, base_p, tau=tau,
                                      block_n=block_n, interpret=not _on_tpu())
    return mask[:n], dist[:n]
