"""Public jit'd wrappers around the Pallas kernels.

Responsibilities: layout conversion ((n, b, W) <-> (b, W, n)), padding to
block multiples (both the lane/database axis and the query axis of the
query-tiled kernels), backend selection (compiled Pallas on TPU,
interpret mode on CPU so correctness tests execute the *same kernel
body*), and fallback to the pure-jnp oracle for shapes where a kernel
launch is not worth it.
"""

from __future__ import annotations

import functools
import threading

import jax
import jax.numpy as jnp

from . import ref
from .hamming_kernel import (BIG, DEFAULT_BLOCK_M, DEFAULT_BLOCK_N,
                             exact_rerank_pallas, hamming_distances_pallas,
                             sparse_verify_arena_packed_pallas,
                             sparse_verify_arena_pallas,
                             sparse_verify_batch_pallas, sparse_verify_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# Process-wide kernel-build ledger (DESIGN.md §11): one bump per wrapper
# entry, keyed by wrapper name, with a ``:ref`` suffix when the call fell
# back to the pure-jnp oracle.  Wrappers run at *trace* time, so under
# jit these count kernel bodies staged into compiled programs (a cached
# program replays without re-entering the wrapper) — the companion to
# ``segments.dispatch_stats()``, which counts program launches.
_KSTATS_LOCK = threading.Lock()
_KERNEL_STATS: dict = {}


def _count(name: str, use_kernel: bool) -> None:
    key = name if use_kernel else name + ":ref"
    with _KSTATS_LOCK:
        _KERNEL_STATS[key] = _KERNEL_STATS.get(key, 0) + 1


def kernel_stats() -> dict:
    """Per-wrapper trace-time call counts (``<name>`` kernel path,
    ``<name>:ref`` oracle fallback)."""
    with _KSTATS_LOCK:
        return dict(_KERNEL_STATS)


def reset_kernel_stats() -> None:
    with _KSTATS_LOCK:
        _KERNEL_STATS.clear()


def to_lane_major(planes: jnp.ndarray) -> jnp.ndarray:
    """(n, b, W) sketch-major -> (b, W, n) lane-major (kernel layout)."""
    return jnp.transpose(planes, (1, 2, 0))


def _pad_lanes(x: jnp.ndarray, block_n: int) -> jnp.ndarray:
    n = x.shape[-1]
    pad = (-n) % block_n
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x


def hamming_distances(db_vert: jnp.ndarray, q_vert: jnp.ndarray,
                      *, block_m: int = DEFAULT_BLOCK_M,
                      block_n: int = DEFAULT_BLOCK_N,
                      use_kernel: bool | None = None) -> jnp.ndarray:
    """(b, W, n) x (b, W, m) -> (m, n) int32.  Pads n and m to block
    multiples, launches the query-tiled kernel, and slices the pads back
    off (pad sketches/queries are all-zero words -> garbage rows/columns,
    dropped here)."""
    n = db_vert.shape[-1]
    m = q_vert.shape[-1]
    if use_kernel is None:
        use_kernel = n >= block_n  # tiny scans: oracle is cheaper than launch
    _count("hamming_distances", use_kernel)
    if not use_kernel:
        return ref.hamming_distances_ref(db_vert, q_vert)
    block_m = min(block_m, m)  # never compute more pad-query rows than m
    db_p = _pad_lanes(db_vert, block_n)
    q_p = _pad_lanes(q_vert, block_m)
    out = hamming_distances_pallas(db_p, q_p, block_m=block_m,
                                   block_n=block_n, interpret=not _on_tpu())
    return out[:m, :n]


def sparse_verify(paths_vert: jnp.ndarray, q_vert: jnp.ndarray,
                  base_dist: jnp.ndarray, *, tau: int,
                  live: jnp.ndarray | None = None,
                  block_n: int = DEFAULT_BLOCK_N,
                  use_kernel: bool | None = None):
    """Fused single-query verify: ((n,) int32 mask of leaves with
    prefix+suffix dist <= tau, (n,) int32 exact total distances —
    BIG-clamped when pruned).

    ``live`` is an optional (n,) bool tombstone mask (dynamic segmented
    index, DESIGN.md §4): dead lanes get a BIG base distance before the
    kernel launch, so tombstoned leaves are pruned by the verify exactly
    like subtries the traversal never reached — pruning == masking, no
    extra kernel pass."""
    if live is not None:
        base_dist = jnp.where(live, base_dist, jnp.int32(BIG))
    n = paths_vert.shape[-1]
    if use_kernel is None:
        use_kernel = n >= block_n
    _count("sparse_verify", use_kernel)
    if not use_kernel:
        mask, dist = ref.sparse_verify_ref(paths_vert, q_vert, base_dist, tau)
        return mask.astype(jnp.int32), dist
    paths_p = _pad_lanes(paths_vert, block_n)
    # pad base distances with +inf-like so pad lanes never survive
    pad = paths_p.shape[-1] - n
    base_p = jnp.pad(base_dist.astype(jnp.int32), (0, pad), constant_values=jnp.int32(BIG))
    mask, dist = sparse_verify_pallas(paths_p, q_vert, base_p, tau=tau,
                                      block_n=block_n, interpret=not _on_tpu())
    return mask[:n], dist[:n]


def sparse_verify_batch(paths_vert: jnp.ndarray, q_vert: jnp.ndarray,
                        base_dist: jnp.ndarray, *, tau: int,
                        live: jnp.ndarray | None = None,
                        block_m: int = DEFAULT_BLOCK_M,
                        block_n: int = DEFAULT_BLOCK_N,
                        use_kernel: bool | None = None):
    """Fused query-tiled verify over a whole batch.

    paths_vert: (b, W, n) collapsed suffix paths (shared database);
    q_vert:     (b, W, m) query suffixes;
    base_dist:  (m, n) per-query prefix distances (BIG = pruned subtrie);
    live:       optional (n,) bool tombstone mask shared by every query —
                dead lanes get a BIG base distance before the kernel
                launch (tombstoned leaves are pruned exactly like
                unreached subtries; DESIGN.md §4);
    returns ((m, n) int32 masks, (m, n) int32 exact totals, BIG-clamped).

    Pads n to a ``block_n`` multiple with BIG base distances (pad lanes
    can never survive) and m to a ``block_m`` multiple with all-zero
    queries (pad rows sliced off), then launches the (m/block_m,
    n/block_n)-grid kernel: the database is streamed ⌈m/block_m⌉ times
    instead of m."""
    if live is not None:
        base_dist = jnp.where(live[None, :], base_dist, jnp.int32(BIG))
    n = paths_vert.shape[-1]
    m = q_vert.shape[-1]
    if use_kernel is None:
        use_kernel = n >= block_n
    _count("sparse_verify_batch", use_kernel)
    if not use_kernel:
        mask, dist = ref.sparse_verify_batch_ref(paths_vert, q_vert,
                                                 base_dist, tau)
        return mask.astype(jnp.int32), dist
    block_m = min(block_m, m)  # never compute more pad-query rows than m
    paths_p = _pad_lanes(paths_vert, block_n)
    q_p = _pad_lanes(q_vert, block_m)
    pad_n = paths_p.shape[-1] - n
    pad_m = q_p.shape[-1] - m
    base_p = jnp.pad(base_dist.astype(jnp.int32),
                     ((0, pad_m), (0, pad_n)),
                     constant_values=jnp.int32(BIG))
    mask, dist = sparse_verify_batch_pallas(paths_p, q_p, base_p, tau=tau,
                                            block_m=block_m, block_n=block_n,
                                            interpret=not _on_tpu())
    return mask[:m, :n], dist[:m, :n]


def sparse_verify_arena(paths_vert: jnp.ndarray, q_vert: jnp.ndarray,
                        base_plane: jnp.ndarray, base_idx: jnp.ndarray,
                        live: jnp.ndarray, *, tau: int,
                        block_m: int = DEFAULT_BLOCK_M,
                        block_n: int = DEFAULT_BLOCK_N,
                        use_kernel: bool | None = None):
    """Fused multi-segment verify over a column arena (DESIGN.md §6).

    paths_vert: (b, W, n) concatenated verify columns (all segments +
                the delta buffer, one column per physical row);
    q_vert:     (b, W, m) query planes;
    base_plane: (m, T) per-(segment, root) base distances — T = total
                ℓ_s roots across segments + 1 trivial slot, ≪ n;
    base_idx:   (n,) int32 per-column index into the T axis (the
                segment-offset lane);
    live:       (n,) bool per-column liveness;
    returns ((m, n) int32 masks, (m, n) int32 totals, BIG-clamped).

    One launch sweeps every segment and the delta buffer: pads n to a
    ``block_n`` multiple with dead lanes (live=False -> BIG, can never
    survive), m to a ``block_m`` multiple with all-zero queries (rows
    sliced off), and T to a lane multiple with BIG (never indexed)."""
    n = paths_vert.shape[-1]
    m = q_vert.shape[-1]
    if use_kernel is None:
        use_kernel = n >= block_n
    _count("sparse_verify_arena", use_kernel)
    if not use_kernel:
        mask, dist = ref.sparse_verify_arena_ref(paths_vert, q_vert,
                                                 base_plane, base_idx,
                                                 live, tau)
        return mask.astype(jnp.int32), dist
    block_m = min(block_m, m)  # never compute more pad-query rows than m
    paths_p = _pad_lanes(paths_vert, block_n)
    q_p = _pad_lanes(q_vert, block_m)
    pad_n = paths_p.shape[-1] - n
    pad_m = q_p.shape[-1] - m
    pad_t = (-base_plane.shape[-1]) % 128    # lane-align the plane axis
    base_p = jnp.pad(base_plane.astype(jnp.int32),
                     ((0, pad_m), (0, pad_t)),
                     constant_values=jnp.int32(BIG))
    idx_p = jnp.pad(base_idx.astype(jnp.int32), (0, pad_n))
    live_p = jnp.pad(live.astype(jnp.int32), (0, pad_n))  # pads dead
    mask, dist = sparse_verify_arena_pallas(
        paths_p, q_p, base_p, idx_p, live_p, tau=tau, block_m=block_m,
        block_n=block_n, interpret=not _on_tpu())
    return mask[:m, :n], dist[:m, :n]


def sparse_verify_arena_packed(db_words: jnp.ndarray, q_words: jnp.ndarray,
                               base_plane: jnp.ndarray,
                               base_idx: jnp.ndarray, live: jnp.ndarray,
                               *, b: int, S: int, tau: int,
                               block_m: int = DEFAULT_BLOCK_M,
                               block_n: int = DEFAULT_BLOCK_N,
                               use_kernel: bool | None = None):
    """Arena verify over single-word packed suffix columns
    (DESIGN.md §7; requires b·S <= 32).

    db_words:   (n,) uint32 — one packed suffix word per column (the b
                bit planes of the S symbols below the segment's ℓ_s);
    q_words:    (m,) uint32 query suffixes in the same packing;
    base_plane: (m, T) per-(segment, root) *prefix* distances (BIG =
                pruned; the traversal's exact distance, not 0/BIG —
                total = prefix + suffix is the full-length Hamming
                distance bit for bit);
    base_idx:   (n,) int32 segment-offset lane;  live: (n,) bool;
    returns ((m, n) int32 masks, (m, n) int32 totals, BIG-clamped).

    Same padding discipline as ``sparse_verify_arena``: n pads with dead
    lanes, m with all-zero queries, T to a lane multiple with BIG."""
    n = db_words.shape[-1]
    m = q_words.shape[-1]
    if use_kernel is None:
        use_kernel = n >= block_n
    _count("sparse_verify_arena_packed", use_kernel)
    if not use_kernel:
        mask, dist = ref.sparse_verify_arena_packed_ref(
            db_words, q_words, base_plane, base_idx, live, b, S, tau)
        return mask.astype(jnp.int32), dist
    block_m = min(block_m, m)  # never compute more pad-query rows than m
    db_p = _pad_lanes(db_words.astype(jnp.uint32), block_n)
    q_p = _pad_lanes(q_words.astype(jnp.uint32), block_m)
    pad_n = db_p.shape[-1] - n
    pad_m = q_p.shape[-1] - m
    pad_t = (-base_plane.shape[-1]) % 128    # lane-align the plane axis
    base_p = jnp.pad(base_plane.astype(jnp.int32),
                     ((0, pad_m), (0, pad_t)),
                     constant_values=jnp.int32(BIG))
    idx_p = jnp.pad(base_idx.astype(jnp.int32), (0, pad_n))
    live_p = jnp.pad(live.astype(jnp.int32), (0, pad_n))  # pads dead
    mask, dist = sparse_verify_arena_packed_pallas(
        db_p, q_p, base_p, idx_p, live_p, b=b, S=S, tau=tau,
        block_m=block_m, block_n=block_n, interpret=not _on_tpu())
    return mask[:m, :n], dist[:m, :n]


def exact_rerank(pay_vert: jnp.ndarray, q_vert: jnp.ndarray,
                 surv: jnp.ndarray, *, metric: str,
                 block_m: int = DEFAULT_BLOCK_M,
                 block_n: int = DEFAULT_BLOCK_N,
                 use_kernel: bool | None = None) -> jnp.ndarray:
    """Exact re-rank pass over the survivor plane (DESIGN.md §10).

    pay_vert: (Wp, n) uint32 column-major payload bitmaps (the payload
              column store's concatenated arena); q_vert: (Wp, m) uint32
              query bitmaps; surv: (m, n) survivor mask (nonzero ==
              lane survived the trie sweep at the final τ rung);
    returns (m, n) float32 exact Jaccard / cosine / containment scores,
    -1.0 on non-survivor lanes.

    Pads n to a ``block_n`` multiple with all-zero payloads and surv=0
    (pad lanes score the -1.0 sentinel, sliced back off) and m to a
    ``block_m`` multiple with all-zero queries (rows sliced off)."""
    n = pay_vert.shape[-1]
    m = q_vert.shape[-1]
    if use_kernel is None:
        use_kernel = n >= block_n  # tiny scans: oracle is cheaper than launch
    _count("exact_rerank", use_kernel)
    if not use_kernel:
        return ref.exact_rerank_ref(pay_vert, q_vert, surv, metric)
    block_m = min(block_m, m)  # never compute more pad-query rows than m
    pay_p = _pad_lanes(pay_vert.astype(jnp.uint32), block_n)
    q_p = _pad_lanes(q_vert.astype(jnp.uint32), block_m)
    pad_n = pay_p.shape[-1] - n
    pad_m = q_p.shape[-1] - m
    surv_p = jnp.pad(surv.astype(jnp.int32), ((0, pad_m), (0, pad_n)))
    out = exact_rerank_pallas(pay_p, q_p, surv_p, metric=metric,
                              block_m=block_m, block_n=block_n,
                              interpret=not _on_tpu())
    return out[:m, :n]
