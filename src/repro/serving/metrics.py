"""Serving metrics: latency percentiles + histograms, throughput
counters, batching efficiency, and a ``/stats`` text dump in real
Prometheus exposition format (DESIGN.md §5, §11).

One ``ServingMetrics`` instance is shared by a scheduler and all its
collections.  Latencies are kept in bounded per-op ring buffers (recent
window, not full history) so a long-lived server's percentile cost stays
O(window), plus fixed-bucket cumulative ``Histogram``s (full history —
what a scraper rates over).  All mutators take an internal lock — the
scheduler records from its worker threads while ``snapshot()`` /
``render_text()`` may be called from any thread.

Cache / dispatch / tier efficiency come from *process-level* counters
(``repro.core.search.searcher_cache_info``,
``repro.core.segments.dispatch_stats``,
``repro.core.column_store.tier_stats``).  Those globals are shared by
every index in the process, so each ``ServingMetrics`` snapshots them at
construction and reports **deltas since its own start** — two schedulers
(or a test running after a warm-up) no longer see each other's traffic.
``rebaseline()`` re-zeros the deltas in place.
"""

from __future__ import annotations

import collections
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.column_store import tier_stats
from ..core.search import searcher_cache_info
from ..core.segments import dispatch_stats
from ..obs.prom import (DEFAULT_LATENCY_BUCKETS_S, Histogram, format_value,
                        render_family)

__all__ = ["LatencyWindow", "ServingMetrics"]


class LatencyWindow:
    """Bounded ring buffer of recent latency samples (seconds)."""

    def __init__(self, window: int = 2048):
        self.samples = collections.deque(maxlen=window)
        self.count = 0          # total ever recorded (not windowed)
        self.total = 0.0        # total seconds ever recorded

    def add(self, seconds: float) -> None:
        self.samples.append(seconds)
        self.count += 1
        self.total += seconds

    def percentile(self, p: float) -> float:
        if not self.samples:
            return 0.0
        return float(np.percentile(np.asarray(self.samples), p))

    def summary(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "mean_ms": (self.total / self.count * 1e3) if self.count else 0.0,
            "p50_ms": self.percentile(50) * 1e3,
            "p99_ms": self.percentile(99) * 1e3,
        }


class ServingMetrics:
    """Counters + latency windows/histograms for one scheduler.

    * ``record_latency(op, s)`` — end-to-end (enqueue -> complete).
    * ``record_exec(op, s)``    — device dispatch only.
    * ``record_queue(op, s)``   — queue wait (enqueue -> batch pop).
    * ``record_batch(op, size, bucket)`` — one coalesced read dispatch;
      feeds batches_total and the batch-fill ratio (Σsize / Σbucket).
    * ``inc(name, n)``          — plain counters (``requests_total:<op>``,
      ``rejected_total`` plus per-op ``rejected_total:<op>``,
      ``shed_total:<reason>``, ``deadline_exceeded_total`` plus per-op,
      ``degraded_total`` plus per-stage ``degraded_total:<stage>``,
      ``write_ops_total``, ``executor_errors_total``, ...).
    * ``set_gauge(name, v)``    — point-in-time gauges (DESIGN.md §12:
      ``serving_stopped_dirty``, ...); rendered as their own gauge
      families in the exposition.
    """

    def __init__(self, window: int = 2048,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S):
        self._lock = threading.Lock()
        self._window = window
        self._buckets = tuple(buckets)
        self.latency: Dict[str, LatencyWindow] = {}
        self.exec_latency: Dict[str, LatencyWindow] = {}
        self.queue_latency: Dict[str, LatencyWindow] = {}
        self._hists: Dict[Tuple[str, str], Histogram] = {}
        self.counters: Dict[str, int] = collections.defaultdict(int)
        self.gauges: Dict[str, float] = {}
        self.batch_sizes = 0
        self.batch_buckets = 0
        self.rebaseline()

    def rebaseline(self) -> None:
        """Re-zero the process-global cache/dispatch/tier deltas: every
        later ``snapshot()`` reports activity since this call (called
        once at construction — i.e. scheduler start)."""
        with self._lock:
            self._cache0 = searcher_cache_info()
            self._disp0 = dispatch_stats()
            self._tier0 = tier_stats()

    # -- recording -------------------------------------------------------

    def _win(self, table: Dict[str, LatencyWindow], op: str) -> LatencyWindow:
        win = table.get(op)
        if win is None:
            win = table[op] = LatencyWindow(self._window)
        return win

    def _hist(self, kind: str, op: str) -> Histogram:
        h = self._hists.get((kind, op))
        if h is None:
            h = self._hists[(kind, op)] = Histogram(self._buckets)
        return h

    def record_latency(self, op: str, seconds: float) -> None:
        with self._lock:
            self._win(self.latency, op).add(seconds)
            self._hist("latency", op).observe(seconds)

    def record_exec(self, op: str, seconds: float) -> None:
        with self._lock:
            self._win(self.exec_latency, op).add(seconds)
            self._hist("exec_latency", op).observe(seconds)

    def record_queue(self, op: str, seconds: float) -> None:
        with self._lock:
            self._win(self.queue_latency, op).add(seconds)
            self._hist("queue_latency", op).observe(seconds)

    def record_batch(self, op: str, size: int, bucket: int) -> None:
        with self._lock:
            self.counters[f"batches_total:{op}"] += 1
            self.batch_sizes += int(size)
            self.batch_buckets += int(bucket)

    def inc(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] += n

    def set_gauge(self, name: str, value: float) -> None:
        """Set a point-in-time gauge (full metric name, optionally with
        a ``{label="..."}`` suffix) exported by ``render_text``."""
        with self._lock:
            self.gauges[name] = value

    # -- export ----------------------------------------------------------

    def batch_fill_ratio(self) -> float:
        """Real queries / dispatched bucket rows across all read batches
        (1.0 = every dispatch exactly filled its power-of-two bucket)."""
        return self.batch_sizes / self.batch_buckets if self.batch_buckets \
            else 0.0

    def snapshot(self) -> Dict[str, object]:
        """One coherent dict of everything: counters, per-op latency
        summaries (count / mean / p50 / p99 ms), batch fill, and the
        compiled-searcher cache / dispatch / tier counters **as deltas
        since this instance's baseline** (``size`` stays absolute — it
        is an occupancy gauge, not a flow)."""
        with self._lock:
            out: Dict[str, object] = {
                "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "latency": {op: w.summary() for op, w in self.latency.items()},
                "exec_latency": {op: w.summary()
                                 for op, w in self.exec_latency.items()},
                "queue_latency": {op: w.summary()
                                  for op, w in self.queue_latency.items()},
                "batch_fill_ratio": self.batch_fill_ratio(),
            }
            cache0, disp0, tier0 = self._cache0, self._disp0, self._tier0
        cache_now = searcher_cache_info()
        cache = {k: cache_now[k] - cache0.get(k, 0)
                 for k in cache_now if k != "size"}
        cache["size"] = cache_now.get("size", 0)
        lookups = cache["hits"] + cache["misses"]
        cache["hit_rate"] = cache["hits"] / lookups if lookups else 0.0
        out["searcher_cache"] = cache
        out["device_dispatch"] = {k: v - disp0.get(k, 0)
                                  for k, v in dispatch_stats().items()}
        out["tier"] = {k: v - tier0.get(k, 0)
                       for k, v in tier_stats().items()}
        return out

    def render_text(self, extra: Optional[Dict[str, object]] = None) -> str:
        """``/stats`` dump in Prometheus text exposition format: every
        family gets ``# HELP`` / ``# TYPE`` lines and histogram families
        render cumulative ``_bucket``/``_sum``/``_count`` series — the
        output round-trips through ``repro.obs.prom.parse_exposition``
        (and therefore a real scraper).  ``extra`` appends pre-flattened
        gauge lines (queue depths, index occupancy) supplied by the
        scheduler."""
        snap = self.snapshot()
        out: List[str] = []
        typed: set = set()

        def emit(family: str, ftype: str, help_text: str,
                 lines: List[str]) -> None:
            if family not in typed:
                out.extend(render_family(family, ftype, help_text, lines))
                typed.add(family)
            else:
                out.extend(lines)

        fams: Dict[str, List[str]] = {}
        for name, val in sorted(snap["counters"].items()):
            if ":" in name:
                base, op = name.split(":", 1)
                fam = f"serving_{base}"
                line = f'{fam}{{op="{op}"}} {format_value(val)}'
            else:
                fam = f"serving_{name}"
                line = f"{fam} {format_value(val)}"
            fams.setdefault(fam, []).append(line)
        for fam in sorted(fams):
            emit(fam, "counter", "Scheduler request counter.", fams[fam])

        for table, label in ((snap["latency"], "latency"),
                             (snap["exec_latency"], "exec_latency"),
                             (snap["queue_latency"], "queue_latency")):
            for stat in ("p50_ms", "p99_ms", "mean_ms"):
                fam = f"serving_{label}_{stat}"
                lines = [f'{fam}{{op="{op}"}} {format_value(s[stat])}'
                         for op, s in sorted(table.items())]
                if lines:
                    emit(fam, "gauge",
                         f"Windowed {label} {stat} per op.", lines)

        emit("serving_batch_fill_ratio", "gauge",
             "Real queries / dispatched bucket rows.",
             ["serving_batch_fill_ratio "
              + format_value(snap["batch_fill_ratio"])])

        with self._lock:
            hist_items = sorted(self._hists.items())
            for (kind, op), h in hist_items:
                fam = f"serving_{kind}_seconds"
                emit(fam, "histogram",
                     f"Request {kind} histogram (seconds).",
                     h.sample_lines(fam, f'op="{op}"'))

        for k, v in sorted(snap["searcher_cache"].items()):
            emit(f"searcher_cache_{k}", "gauge",
                 "Compiled-searcher cache (delta since scheduler start).",
                 [f"searcher_cache_{k} {format_value(v)}"])
        for k, v in sorted(snap["device_dispatch"].items()):
            emit(f"device_dispatch_{k}", "counter",
                 "Device launches (delta since scheduler start).",
                 [f"device_dispatch_{k} {format_value(v)}"])
        for k, v in sorted(snap["tier"].items()):
            emit(f"tier_{k}", "counter",
                 "Column-store tier movement (delta since scheduler start).",
                 [f"tier_{k} {format_value(v)}"])
        merged = dict(snap["gauges"])
        merged.update(extra or {})
        for k, v in sorted(merged.items()):
            fam = k.split("{", 1)[0].split()[0]
            emit(fam, "gauge", "Scheduler gauge.",
                 [f"{k} {format_value(v)}"])
        return "\n".join(out) + "\n"
