"""Serving metrics: latency percentiles, throughput counters, batching
efficiency, and a ``/stats``-style text dump (DESIGN.md §5).

One ``ServingMetrics`` instance is shared by a scheduler and all its
collections.  Latencies are kept in bounded per-op ring buffers (recent
window, not full history) so a long-lived server's percentile cost stays
O(window); counters are plain monotone integers.  All mutators take an
internal lock — the scheduler records from its worker threads while
``snapshot()`` / ``render_text()`` may be called from any thread.

Cache efficiency is read straight from the process-level compiled-
searcher cache (``repro.core.search.searcher_cache_info``): ``hits`` /
``misses`` are Python-cache lookups, ``traces`` counts actual jit
traces — the number that must stop growing once every shape bucket is
warm.

Device-dispatch accounting comes from the segmented query path's
process-level counters (``repro.core.segments.dispatch_stats``): the
arena path costs one ``fused`` launch per τ rung regardless of segment
count, while the reference path counts one ``fanout`` launch per
segment — the dispatch counter is the per-segment accounting,
aggregated where it is exact (DESIGN.md §6).

Tier movement comes from the column store's process-level counters
(``repro.core.column_store.tier_stats``): promotions / demotions count
blocks crossing the hot/cold boundary, ``prefetches`` counts staged
copy-ahead transfers and ``staged_bytes`` the bytes they moved
(DESIGN.md §7).
"""

from __future__ import annotations

import collections
import threading
from typing import Dict, List, Optional

import numpy as np

from ..core.column_store import tier_stats
from ..core.search import searcher_cache_info
from ..core.segments import dispatch_stats

__all__ = ["LatencyWindow", "ServingMetrics"]


class LatencyWindow:
    """Bounded ring buffer of recent latency samples (seconds)."""

    def __init__(self, window: int = 2048):
        self.samples = collections.deque(maxlen=window)
        self.count = 0          # total ever recorded (not windowed)
        self.total = 0.0        # total seconds ever recorded

    def add(self, seconds: float) -> None:
        self.samples.append(seconds)
        self.count += 1
        self.total += seconds

    def percentile(self, p: float) -> float:
        if not self.samples:
            return 0.0
        return float(np.percentile(np.asarray(self.samples), p))

    def summary(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "mean_ms": (self.total / self.count * 1e3) if self.count else 0.0,
            "p50_ms": self.percentile(50) * 1e3,
            "p99_ms": self.percentile(99) * 1e3,
        }


class ServingMetrics:
    """Counters + latency windows for one scheduler.

    * ``record_latency(op, s)`` — end-to-end (enqueue -> complete).
    * ``record_exec(op, s)``    — device dispatch only.
    * ``record_batch(op, size, bucket)`` — one coalesced read dispatch;
      feeds batches_total and the batch-fill ratio (Σsize / Σbucket).
    * ``inc(name, n)``          — plain counters (``requests_total:<op>``,
      ``rejected_total`` plus per-op ``rejected_total:<op>``,
      ``write_ops_total``, ``executor_errors_total``, ...).
    """

    def __init__(self, window: int = 2048):
        self._lock = threading.Lock()
        self._window = window
        self.latency: Dict[str, LatencyWindow] = {}
        self.exec_latency: Dict[str, LatencyWindow] = {}
        self.counters: Dict[str, int] = collections.defaultdict(int)
        self.batch_sizes = 0
        self.batch_buckets = 0

    # -- recording -------------------------------------------------------

    def _win(self, table: Dict[str, LatencyWindow], op: str) -> LatencyWindow:
        win = table.get(op)
        if win is None:
            win = table[op] = LatencyWindow(self._window)
        return win

    def record_latency(self, op: str, seconds: float) -> None:
        with self._lock:
            self._win(self.latency, op).add(seconds)

    def record_exec(self, op: str, seconds: float) -> None:
        with self._lock:
            self._win(self.exec_latency, op).add(seconds)

    def record_batch(self, op: str, size: int, bucket: int) -> None:
        with self._lock:
            self.counters[f"batches_total:{op}"] += 1
            self.batch_sizes += int(size)
            self.batch_buckets += int(bucket)

    def inc(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] += n

    # -- export ----------------------------------------------------------

    def batch_fill_ratio(self) -> float:
        """Real queries / dispatched bucket rows across all read batches
        (1.0 = every dispatch exactly filled its power-of-two bucket)."""
        return self.batch_sizes / self.batch_buckets if self.batch_buckets \
            else 0.0

    def snapshot(self) -> Dict[str, object]:
        """One coherent dict of everything: counters, per-op latency
        summaries (count / mean / p50 / p99 ms), batch fill, and the
        compiled-searcher cache counters."""
        with self._lock:
            out: Dict[str, object] = {
                "counters": dict(self.counters),
                "latency": {op: w.summary() for op, w in self.latency.items()},
                "exec_latency": {op: w.summary()
                                 for op, w in self.exec_latency.items()},
                "batch_fill_ratio": self.batch_fill_ratio(),
            }
        cache = searcher_cache_info()
        lookups = cache["hits"] + cache["misses"]
        cache["hit_rate"] = cache["hits"] / lookups if lookups else 0.0
        out["searcher_cache"] = cache
        out["device_dispatch"] = dispatch_stats()
        out["tier"] = tier_stats()
        return out

    def render_text(self, extra: Optional[Dict[str, object]] = None) -> str:
        """``/stats``-style flat text dump: one ``name value`` line per
        metric (Prometheus-exposition flavored; labels use ``{op="..."}``).
        ``extra`` appends pre-flattened gauge lines (queue depths, index
        occupancy) supplied by the scheduler."""
        snap = self.snapshot()
        lines: List[str] = []
        for name, val in sorted(snap["counters"].items()):
            if ":" in name:
                base, op = name.split(":", 1)
                lines.append(f'serving_{base}{{op="{op}"}} {val}')
            else:
                lines.append(f"serving_{name} {val}")
        for table, label in ((snap["latency"], "latency"),
                             (snap["exec_latency"], "exec_latency")):
            for op, s in sorted(table.items()):
                for stat in ("p50_ms", "p99_ms", "mean_ms"):
                    lines.append(
                        f'serving_{label}_{stat}{{op="{op}"}} '
                        f"{s[stat]:.3f}")
        lines.append(f"serving_batch_fill_ratio "
                     f"{snap['batch_fill_ratio']:.4f}")
        for k, v in sorted(snap["searcher_cache"].items()):
            val = f"{v:.4f}" if isinstance(v, float) else str(v)
            lines.append(f"searcher_cache_{k} {val}")
        for k, v in sorted(snap["device_dispatch"].items()):
            lines.append(f"device_dispatch_{k} {v}")
        for k, v in sorted(snap["tier"].items()):
            lines.append(f"tier_{k} {v}")
        for k, v in sorted((extra or {}).items()):
            lines.append(f"{k} {v}")
        return "\n".join(lines) + "\n"
