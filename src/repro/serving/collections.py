"""Collection registry: multiple independent dynamic indexes behind one
scheduler (DESIGN.md §5).

A **collection** is one named corpus — its own ``SegmentedIndex`` (or
``ShardedSegmentedIndex``), its own (b, L) sketch geometry, backend, and
merge policy.  Tenants are isolated at the collection level: requests
queue per collection, a merge or compaction in one collection never
blocks another, and global ids are scoped per collection.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional

from ..core.segments import BACKENDS, SegmentedIndex, ShardedSegmentedIndex
from ..kernels.hamming_kernel import DEFAULT_BLOCK_M

__all__ = ["CollectionConfig", "Collection", "CollectionRegistry"]


@dataclasses.dataclass(frozen=True)
class CollectionConfig:
    """Per-collection geometry + maintenance policy.

    Attributes:
      L, b:         sketch length / bits per character (Σ = [0, 2^b)).
      backend:      segment backend — "bst" (default), "multi", "sharded".
      delta_cap:    delta-buffer rows before a segment seals.
      auto_merge:   run the size-tiered merge policy after auto-flushes.
      compact_dead_frac: when set, the scheduler opportunistically
                    compacts segments whose dead fraction exceeds this
                    after a delete (None = manual compaction only).
      n_stacks:     > 1 builds a ``ShardedSegmentedIndex`` with this many
                    independent per-shard segment stacks.
      use_arena:    serve reads through the fused one-dispatch segment
                    arena (DESIGN.md §6; default) — read latency stays
                    flat in the collection's segment count.
      layout:       sealed-column layout — "suffix" (default; packed
                    below each segment's traversal root, DESIGN.md §7)
                    or "full" (full-length reference layout).
      hot_bytes:    device budget for sealed columns.  None (default)
                    keeps every block device-resident; a byte budget
                    demotes least-recently-used blocks to the host cold
                    tier, served via staged copy-ahead slabs.
      mi_blocks / n_shards / lam / block_m: forwarded to the index.
    """

    L: int
    b: int
    backend: str = "bst"
    delta_cap: int = 4096
    auto_merge: bool = True
    compact_dead_frac: Optional[float] = None
    n_stacks: int = 1
    mi_blocks: int = 2
    n_shards: int = 4
    lam: float = 0.5
    block_m: int = DEFAULT_BLOCK_M
    use_arena: bool = True
    layout: str = "suffix"
    hot_bytes: Optional[int] = None

    def create(self):
        """Instantiate the configured dynamic index."""
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        kw = dict(delta_cap=self.delta_cap, backend=self.backend,
                  lam=self.lam, auto_merge=self.auto_merge,
                  block_m=self.block_m, use_arena=self.use_arena,
                  layout=self.layout, hot_bytes=self.hot_bytes)
        if self.n_stacks > 1:
            return ShardedSegmentedIndex(self.L, self.b, self.n_stacks, **kw)
        return SegmentedIndex(self.L, self.b, mi_blocks=self.mi_blocks,
                              n_shards=self.n_shards, **kw)


@dataclasses.dataclass
class Collection:
    """One registered collection: config + live index."""

    name: str
    config: CollectionConfig
    index: object

    def stats(self) -> Dict[str, object]:
        return self.index.stats()


class CollectionRegistry:
    """Thread-safe name -> Collection map.

    >>> reg = CollectionRegistry()
    >>> _ = reg.create("docs", CollectionConfig(L=8, b=2))
    >>> reg.names()
    ['docs']
    >>> reg.get("docs").config.b
    2
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._collections: Dict[str, Collection] = {}

    def create(self, name: str, config: CollectionConfig) -> Collection:
        with self._lock:
            if name in self._collections:
                raise ValueError(f"collection {name!r} already exists")
            coll = Collection(name=name, config=config, index=config.create())
            self._collections[name] = coll
            return coll

    def get(self, name: str) -> Collection:
        with self._lock:
            try:
                return self._collections[name]
            except KeyError:
                raise KeyError(f"unknown collection {name!r}") from None

    def drop(self, name: str) -> None:
        with self._lock:
            self._collections.pop(name, None)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._collections)

    def stats(self) -> Dict[str, Dict[str, object]]:
        """Per-collection index stats (occupancy, segments, tombstones)."""
        with self._lock:
            colls = list(self._collections.values())
        return {c.name: c.stats() for c in colls}
