"""Collection registry: multiple independent dynamic indexes behind one
scheduler (DESIGN.md §5).

A **collection** is one named corpus — its own ``SegmentedIndex`` (or
``ShardedSegmentedIndex``), its own (b, L) sketch geometry, backend, and
merge policy.  Tenants are isolated at the collection level: requests
queue per collection, a merge or compaction in one collection never
blocks another, and global ids are scoped per collection.
"""

from __future__ import annotations

import dataclasses
import os
import re
import threading
from typing import Dict, List, Optional

from ..core.segments import BACKENDS, SegmentedIndex, ShardedSegmentedIndex
from ..kernels.hamming_kernel import DEFAULT_BLOCK_M
from ..store import CollectionStore

__all__ = ["CollectionConfig", "Collection", "CollectionRegistry"]

# durable collection names become directory names — keep them portable
_NAME_RE = re.compile(r"^[A-Za-z0-9._-]+$")


@dataclasses.dataclass(frozen=True)
class CollectionConfig:
    """Per-collection geometry + maintenance policy.

    Attributes:
      L, b:         sketch length / bits per character (Σ = [0, 2^b)).
      backend:      segment backend — "bst" (default), "multi", "sharded".
      delta_cap:    delta-buffer rows before a segment seals.
      auto_merge:   run the size-tiered merge policy after auto-flushes.
      compact_dead_frac: when set, the scheduler opportunistically
                    compacts segments whose dead fraction exceeds this
                    after a delete (None = manual compaction only).
      n_stacks:     > 1 builds a ``ShardedSegmentedIndex`` with this many
                    independent per-shard segment stacks.
      use_arena:    serve reads through the fused one-dispatch segment
                    arena (DESIGN.md §6; default) — read latency stays
                    flat in the collection's segment count.
      layout:       sealed-column layout — "suffix" (default; packed
                    below each segment's traversal root, DESIGN.md §7)
                    or "full" (full-length reference layout).
      hot_bytes:    device budget for sealed columns.  None (default)
                    keeps every block device-resident; a byte budget
                    demotes least-recently-used blocks to the host cold
                    tier, served via staged copy-ahead slabs.
      payload_words: uint32 words per row payload bitmap (DESIGN.md §10).
                    When set, inserts carry ``payloads`` and topk
                    requests may ask for the exact two-stage
                    ``rerank=`` contract; None disables re-ranking.
      default_deadline_ms: latency budget applied to this collection's
                    requests that pass ``deadline_ms=None`` (DESIGN.md
                    §12); wins over the scheduler-wide default.  None
                    (default) = defer to the scheduler.
      priority:     default request priority for this collection's
                    tenants; > 0 bypasses cost-budget admission (still
                    subject to the hard ``max_queue`` backstop and the
                    circuit breaker).
      mi_blocks / n_shards / lam / block_m: forwarded to the index.
    """

    L: int
    b: int
    backend: str = "bst"
    delta_cap: int = 4096
    auto_merge: bool = True
    compact_dead_frac: Optional[float] = None
    n_stacks: int = 1
    mi_blocks: int = 2
    n_shards: int = 4
    lam: float = 0.5
    block_m: int = DEFAULT_BLOCK_M
    use_arena: bool = True
    layout: str = "suffix"
    hot_bytes: Optional[int] = None
    payload_words: Optional[int] = None
    default_deadline_ms: Optional[float] = None
    priority: int = 0

    def create(self):
        """Instantiate the configured dynamic index."""
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        kw = dict(delta_cap=self.delta_cap, backend=self.backend,
                  lam=self.lam, auto_merge=self.auto_merge,
                  block_m=self.block_m, use_arena=self.use_arena,
                  layout=self.layout, hot_bytes=self.hot_bytes,
                  payload_words=self.payload_words)
        if self.n_stacks > 1:
            return ShardedSegmentedIndex(self.L, self.b, self.n_stacks, **kw)
        return SegmentedIndex(self.L, self.b, mi_blocks=self.mi_blocks,
                              n_shards=self.n_shards, **kw)


@dataclasses.dataclass
class Collection:
    """One registered collection: config + live index (+ durable store
    when the registry has a ``data_dir``)."""

    name: str
    config: CollectionConfig
    index: object
    store: Optional[CollectionStore] = None

    def stats(self) -> Dict[str, object]:
        out = self.index.stats()
        if self.store is not None:
            out["store"] = self.store.stats()
        return out


class CollectionRegistry:
    """Thread-safe name -> Collection map.

    With a ``data_dir`` every collection is durable: creates bind a
    :class:`repro.store.CollectionStore` under ``<data_dir>/<name>/``
    (journaling writes, snapshotting sealed segments), and
    :meth:`CollectionRegistry.open` rebuilds the whole registry from disk
    after a crash or restart (DESIGN.md §8).

    >>> reg = CollectionRegistry()
    >>> _ = reg.create("docs", CollectionConfig(L=8, b=2))
    >>> reg.names()
    ['docs']
    >>> reg.get("docs").config.b
    2
    """

    def __init__(self, data_dir: Optional[str] = None, *,
                 fsync_every: int = 64):
        self._lock = threading.Lock()
        self._collections: Dict[str, Collection] = {}
        self.data_dir = data_dir
        self.fsync_every = int(fsync_every)

    @classmethod
    def open(cls, data_dir: str, *,
             fsync_every: int = 64) -> "CollectionRegistry":
        """Recover every collection persisted under ``data_dir``: load
        manifest segments, replay each WAL into the delta buffer, restore
        id allocators and the segment-serial floor.  Directories without
        a ``collection.json`` (never fully created) are skipped."""
        reg = cls(data_dir=data_dir, fsync_every=fsync_every)
        if not os.path.isdir(data_dir):
            return reg
        for name in sorted(os.listdir(data_dir)):
            root = os.path.join(data_dir, name)
            cfg_dict = CollectionStore.load_config(root)
            if not os.path.isdir(root) or cfg_dict is None:
                continue
            config = CollectionConfig(**cfg_dict)
            store = CollectionStore(root, fsync_every=fsync_every)
            index = store.recover(config.create())
            with reg._lock:
                reg._collections[name] = Collection(
                    name=name, config=config, index=index, store=store)
        return reg

    def create(self, name: str, config: CollectionConfig) -> Collection:
        with self._lock:
            if name in self._collections:
                raise ValueError(f"collection {name!r} already exists")
            store = None
            if self.data_dir is not None:
                if not _NAME_RE.match(name):
                    raise ValueError(
                        f"durable collection name {name!r} must match "
                        f"{_NAME_RE.pattern}")
                store = CollectionStore(os.path.join(self.data_dir, name),
                                        fsync_every=self.fsync_every)
            index = config.create()
            if store is not None:
                store.attach(index)
                store.save_config(dataclasses.asdict(config))
            coll = Collection(name=name, config=config, index=index,
                              store=store)
            self._collections[name] = coll
            return coll

    def get(self, name: str) -> Collection:
        with self._lock:
            try:
                return self._collections[name]
            except KeyError:
                raise KeyError(f"unknown collection {name!r}") from None

    def drop(self, name: str) -> None:
        """Unregister a collection.  A durable collection's store is
        closed (WAL synced) but its on-disk state is retained — a later
        ``open`` still recovers it."""
        with self._lock:
            coll = self._collections.pop(name, None)
        if coll is not None and coll.store is not None:
            coll.store.close()

    def close(self) -> None:
        """Sync and close every durable collection's store."""
        with self._lock:
            colls = list(self._collections.values())
        for coll in colls:
            if coll.store is not None:
                coll.store.close()

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._collections)

    def stats(self) -> Dict[str, Dict[str, object]]:
        """Per-collection index stats (occupancy, segments, tombstones)."""
        with self._lock:
            colls = list(self._collections.values())
        return {c.name: c.stats() for c in colls}
