"""Serving runtime: async micro-batching scheduler over the segmented
index (DESIGN.md §5).

The layer between clients and the compiled searchers: a per-collection
request queue with dynamic micro-batching (power-of-two shape buckets →
zero steady-state re-jits), write interleaving (inserts/deletes fence
reads but never recompile), bounded queues with explicit overload
rejection, a multi-tenant collection registry, and ``/stats``-style
metrics (Prometheus exposition format; request tracing and the
slow-query log live in ``repro.obs`` — pass ``tracer=`` / configure
``SchedulerConfig.slow_ms`` to turn them on).

Overload hardening (DESIGN.md §12) is opt-in per scheduler: set
``SchedulerConfig.admission`` / ``degrade`` / ``breaker`` to run
deadline-aware cost-budget admission, a graceful-degradation ladder,
and a per-collection circuit breaker in front of the ``max_queue``
backstop; every ``submit_*`` then accepts ``deadline_ms=`` /
``priority=``.

>>> import numpy as np
>>> from repro.serving import CollectionConfig, Scheduler
>>> sched = Scheduler()
>>> _ = sched.create_collection("docs", CollectionConfig(L=8, b=2))
>>> fut = sched.submit_insert("docs", np.zeros((3, 8), np.uint8))
>>> nn = sched.submit_topk("docs", np.zeros(8, np.uint8), k=2)
>>> _ = sched.pump()            # synchronous drive (or .start() threads)
>>> fut.result().tolist()
[0, 1, 2]
>>> nn.result().ids.tolist()
[0, 1]
"""

from .batching import bucket_m, bucket_table, pad_to_bucket
from .collections import Collection, CollectionConfig, CollectionRegistry
from .metrics import LatencyWindow, ServingMetrics
from .overload import (AdmissionConfig, AdmissionController, BreakerConfig,
                       CircuitBreaker, DeadlineExceeded, DegradePolicy,
                       SlowDispatchInjector)
from .scheduler import (OverloadError, Scheduler, SchedulerConfig,
                        SearchResponse, TopKResponse)

__all__ = [
    "bucket_m", "bucket_table", "pad_to_bucket",
    "Collection", "CollectionConfig", "CollectionRegistry",
    "LatencyWindow", "ServingMetrics",
    "AdmissionConfig", "AdmissionController", "BreakerConfig",
    "CircuitBreaker", "DeadlineExceeded", "DegradePolicy",
    "SlowDispatchInjector",
    "OverloadError", "Scheduler", "SchedulerConfig",
    "SearchResponse", "TopKResponse",
]
