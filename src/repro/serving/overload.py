"""Overload control plane (DESIGN.md §12).

The scheduler's only pre-existing defense against overload was a hard
``OverloadError`` at a fixed ``max_queue`` — one bursting tenant could
push every queued co-tenant request into multi-second tails before the
cliff fired.  This module replaces the cliff with a *pressure-aware*
control plane, built from the paper's own τ-ladder cost model plus the
classic resilience patterns (CoDel queue management, graceful
degradation, circuit breaking):

  * **Deadlines** — every request may carry a latency budget
    (``deadline_ms``); requests whose budget expires while queued are
    cancelled with :class:`DeadlineExceeded` *before* device dispatch
    (never a wasted fused launch), and :class:`DeadlineExceeded` /
    ``OverloadError`` both carry a machine-readable ``retry_after_ms``
    so clients can implement honest backoff.
  * **Adaptive admission** — :class:`AdmissionController` admits against
    the queue's outstanding *estimated cost* (paper Appendix A cost
    model, normalized so a reference top-k ≈ 1 unit) rather than its raw
    length, and watches a CoDel-style queue-delay target: an interval
    whose *minimum* delay never dips below target is sustained
    standing-queue pressure (not a burst absorbing into slack) and
    escalates the pressure level; a good interval resets it.
  * **Graceful degradation** — :class:`DegradePolicy` maps the pressure
    level onto an explicit ladder of cheaper answers
    (``rerank_off`` → ``shrink_k`` → ``cheap_tau`` → reject): under
    pressure a b-bit sketch trie query is answered *cheaper*, not
    *later*, and every degraded response is labelled with the stage that
    produced it (response ``degraded`` field, ``degraded_total:<stage>``
    counters, batch-span ``degrade`` args) so a degraded answer is
    always distinguishable from a full one.  Degraded answers are
    bit-identical to an undegraded run at the same effective
    (τ, k, rerank) settings — degradation changes parameters, never the
    kernels.
  * **Circuit breaking** — :class:`CircuitBreaker` trips a collection
    open after its recent window blows too many deadlines, rejects with
    ``retry_after_ms`` while open, and probes with a bounded number of
    half-open requests before closing again.
  * **Fault injection** — :class:`SlowDispatchInjector` reuses the
    ``store.faults`` ``hit(label)`` protocol at the scheduler's
    execution boundary (``execute:<collection>:<op>``) so the chaos
    harness (``tools/overload_smoke.py``) can inject deterministic
    slow-dispatch faults per tenant.

Everything here is host-side control logic: no device work, no new
kernels, and zero cost when the knobs are left at their ``None``
defaults (the scheduler then behaves exactly as before this module
existed, fixed ``max_queue`` cliff included).
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import List, Optional, Tuple

__all__ = [
    "DeadlineExceeded", "AdmissionConfig", "AdmissionController",
    "DegradePolicy", "BreakerConfig", "CircuitBreaker",
    "SlowDispatchInjector", "estimate_units", "REF_K",
]

# the admission controller's cost normalizer: 1 unit == the cost-model
# estimate of one top-REF_K lookup over the collection's current corpus
REF_K = 8


class DeadlineExceeded(RuntimeError):
    """A request's latency budget expired while it was still queued; it
    was cancelled before any device dispatch.  Carries the shed
    request's context plus ``retry_after_ms`` — the controller's
    current estimate of when the queue will have drained enough for a
    retry to meet the same budget."""

    def __init__(self, message: str, *, collection: Optional[str] = None,
                 op: Optional[str] = None,
                 deadline_ms: Optional[float] = None,
                 retry_after_ms: float = 0.0):
        super().__init__(message)
        self.collection = collection
        self.op = op
        self.deadline_ms = deadline_ms
        self.retry_after_ms = retry_after_ms


# ---------------------------------------------------------------------------
# cost estimation (paper Appendix A through core.segments.cost_hint)
# ---------------------------------------------------------------------------

def estimate_units(index, op: str, key: tuple, payload: dict) -> float:
    """Estimated cost of one request in normalized units (reference
    top-``REF_K`` ≈ 1.0) via the index's ``cost_hint`` (the PR-1 cost
    model over the collection's live (b, L, n)).  Clamped to
    [1/16, 64] so one mis-estimated request can neither starve nor
    flood the admission budget.  Indexes without a ``cost_hint``
    (custom backends) cost 1 unit flat."""
    hint = getattr(index, "cost_hint", None)
    if hint is None:
        return 1.0
    ref = max(float(hint("topk", k=REF_K)), 1e-9)
    if op == "topk":
        raw = float(hint("topk", k=int(key[1])))
        if key[3] is not None:          # two-stage rerank: one extra
            raw *= 1.25                 # fused dispatch + payload gather
    elif op == "search":
        raw = float(hint("search", tau=int(key[1])))
    elif op == "insert":
        raw = float(hint("write", rows=len(payload["sketches"])))
    else:                               # delete
        raw = float(hint("write", rows=len(payload["ids"])))
    return min(max(raw / ref, 1.0 / 16.0), 64.0)


# ---------------------------------------------------------------------------
# adaptive admission (cost budget + CoDel delay target)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Adaptive-admission knobs (DESIGN.md §12).

    Attributes:
      target_delay_ms: CoDel queue-delay target — the delay a healthy
                   queue should dip under at least once per interval.
      interval_ms: CoDel observation interval; one fully-bad interval
                   escalates the pressure level by one, one good
                   interval resets it to zero.
      cost_capacity: admission budget in normalized cost units
                   (``estimate_units``); outstanding queued cost beyond
                   it sheds new best-effort work at submit time.
      min_queue:   always admit while fewer than this many requests are
                   queued, whatever the cost ledger says (a bad cost
                   estimate must never dead-lock an idle queue).
      rate_init:   initial service-rate estimate (units/s) used for
                   ``retry_after_ms`` before any batch has completed.
      max_level:   pressure-level ceiling (bounds the ladder index).
    """

    target_delay_ms: float = 5.0
    interval_ms: float = 100.0
    cost_capacity: float = 64.0
    min_queue: int = 8
    rate_init: float = 256.0
    max_level: int = 8


class AdmissionController:
    """Per-collection adaptive admission state: a cost-unit ledger of
    queued work, an EWMA of the measured service rate, and the
    CoDel-style pressure level the degradation ladder indexes.

    All mutators take the internal lock — submits, workers, and metric
    scrapes touch one controller concurrently.  The clock is injectable
    for deterministic tests and must match the scheduler's
    (``time.perf_counter``)."""

    def __init__(self, config: Optional[AdmissionConfig] = None,
                 clock=time.perf_counter):
        self.config = config if config is not None else AdmissionConfig()
        self._clock = clock
        self._lock = threading.Lock()
        self._level = 0
        self._queued_units = 0.0
        self._rate = float(self.config.rate_init)     # units/s EWMA
        self._int_min = math.inf
        self._int_end: Optional[float] = None
        self.sheds = 0                                # cost-budget rejects

    # -- ledger ----------------------------------------------------------

    def on_admit(self, units: float) -> None:
        with self._lock:
            self._queued_units += units

    def on_pop(self, units: float) -> None:
        with self._lock:
            self._queued_units = max(0.0, self._queued_units - units)

    def queued_units(self) -> float:
        with self._lock:
            return self._queued_units

    # -- CoDel pressure ---------------------------------------------------

    def note_delay(self, delay_s: float,
                   now: Optional[float] = None) -> None:
        """Record one request's queue delay (called at batch pop).  The
        per-interval *minimum* is what escalates: a burst whose tail
        still dips under target within the interval is absorbed; a
        standing queue whose minimum never does is pressure."""
        now = self._clock() if now is None else now
        cfg = self.config
        with self._lock:
            if self._int_end is None:
                self._int_end = now + cfg.interval_ms / 1e3
            self._int_min = min(self._int_min, delay_s)
            if now >= self._int_end:
                if self._int_min * 1e3 > cfg.target_delay_ms:
                    self._level = min(self._level + 1, cfg.max_level)
                else:
                    self._level = 0
                self._int_min = math.inf
                self._int_end = now + cfg.interval_ms / 1e3

    def note_empty(self) -> None:
        """The queue drained: standing-queue pressure is over (CoDel's
        exit condition) — counts as a zero-delay sample."""
        with self._lock:
            self._int_min = 0.0
            self._level = 0

    def note_exec(self, units: float, seconds: float) -> None:
        """Fold one completed batch into the service-rate EWMA (feeds
        ``retry_after_ms``)."""
        if seconds <= 0 or units <= 0:
            return
        with self._lock:
            self._rate = 0.8 * self._rate + 0.2 * (units / seconds)

    def pressure(self) -> int:
        """Current pressure level (0 = healthy; indexes the ladder)."""
        with self._lock:
            return self._level

    def retry_after_ms(self) -> float:
        """Estimated drain time of the queued cost at the measured
        service rate — what shed requests report to clients."""
        with self._lock:
            ms = self._queued_units / max(self._rate, 1e-6) * 1e3
        return min(max(ms, 1.0), 5000.0)

    def admit(self, units: float, queue_len: int,
              priority: int = 0) -> Optional[float]:
        """Admission check for one request of ``units`` estimated cost.
        Returns None to admit, else the suggested ``retry_after_ms``.
        Positive-priority requests bypass the cost budget (they remain
        subject to the scheduler's hard ``max_queue`` backstop)."""
        if priority > 0 or queue_len < self.config.min_queue:
            return None
        with self._lock:
            if self._queued_units + units <= self.config.cost_capacity:
                return None
            self.sheds += 1
        return self.retry_after_ms()


# ---------------------------------------------------------------------------
# graceful-degradation ladder
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DegradePolicy:
    """The explicit degradation ladder (DESIGN.md §12): pressure level N
    applies the first N stages, cheapest-loss first.  Stage semantics:

      * ``rerank_off`` — two-stage ``rerank=`` lookups execute as plain
        sketch top-k (drops the exact re-rank dispatch; scores absent).
      * ``shrink_k``   — k divides by ``k_shrink`` (floor ``k_floor``):
        a smaller k seeds a cheaper τ ladder and a smaller readback.
      * ``cheap_tau``  — top-k ladders restart from ``tau0``; range
        searches clamp τ to ``tau_cap`` (a cheaper — narrower — answer).

    Beyond the last stage the scheduler sheds at submit time (the
    ``reject`` stage).  A stage that changes nothing for a given request
    (e.g. ``rerank_off`` on a plain lookup) does not mark the answer
    degraded — only actually-degraded answers are labelled."""

    stages: Tuple[str, ...] = ("rerank_off", "shrink_k", "cheap_tau")
    k_floor: int = 1
    k_shrink: int = 2
    tau0: int = 0
    tau_cap: int = 1

    @property
    def reject_level(self) -> int:
        """First pressure level at which new best-effort work is shed
        at submit time instead of degraded."""
        return len(self.stages) + 1

    def apply_topk(self, level: int, k: int, tau0: Optional[int],
                   metric: Optional[str]):
        """-> (k_eff, tau0_eff, metric_eff, stage | None) — the deepest
        stage that actually changed the request, or None."""
        applied: Optional[str] = None
        for stage in self.stages[:max(0, min(level, len(self.stages)))]:
            if stage == "rerank_off":
                if metric is not None:
                    metric = None
                    applied = stage
            elif stage == "shrink_k":
                k_new = max(self.k_floor, k // self.k_shrink)
                if k_new < k:
                    k = k_new
                    applied = stage
            elif stage == "cheap_tau":
                if tau0 is None or tau0 > self.tau0:
                    tau0 = self.tau0
                    applied = stage
        return k, tau0, metric, applied

    def apply_search(self, level: int, tau: int):
        """-> (tau_eff, stage | None)."""
        active = self.stages[:max(0, min(level, len(self.stages)))]
        if "cheap_tau" in active and tau > self.tau_cap:
            return self.tau_cap, "cheap_tau"
        return tau, None


# ---------------------------------------------------------------------------
# per-collection circuit breaker
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Circuit-breaker knobs (DESIGN.md §12).

    Attributes:
      window:      outcome ring length (one entry per completed or
                   deadline-cancelled request).
      fail_frac:   failure fraction of the window that trips OPEN.
      min_samples: never trip on fewer than this many outcomes.
      open_ms:     how long the breaker stays OPEN before probing.
      probes:      HALF_OPEN probe budget; all must succeed to close.
      backoff:     OPEN duration multiplier per consecutive re-trip.
      max_open_ms: OPEN duration ceiling under backoff.
    """

    window: int = 16
    fail_frac: float = 0.5
    min_samples: int = 8
    open_ms: float = 1000.0
    probes: int = 2
    backoff: float = 2.0
    max_open_ms: float = 30000.0


class CircuitBreaker:
    """closed → open → half-open → closed state machine over request
    outcomes (success = completed within its deadline).  ``allow()`` is
    the submit-time gate; ``record()`` feeds completions and deadline
    cancellations back.  The clock is injectable for deterministic
    tests."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"
    _CODES = {CLOSED: 0, OPEN: 1, HALF_OPEN: 2}

    def __init__(self, config: Optional[BreakerConfig] = None,
                 clock=time.perf_counter):
        self.config = config if config is not None else BreakerConfig()
        self._clock = clock
        self._lock = threading.Lock()
        self._state = self.CLOSED
        self._outcomes: List[bool] = []
        self._open_until = 0.0
        self._trips = 0                 # consecutive re-trips (backoff)
        self.trips_total = 0
        self._probes_inflight = 0
        self._probe_ok = 0

    # -- introspection ---------------------------------------------------

    def state(self) -> str:
        with self._lock:
            return self._effective_state(self._clock())

    def state_code(self) -> int:
        """Numeric state for Prometheus gauges: closed=0, open=1,
        half_open=2."""
        return self._CODES[self.state()]

    def _effective_state(self, now: float) -> str:
        """OPEN lazily becomes HALF_OPEN once its window elapses (the
        transition happens on the next observation — there is no
        timer thread)."""
        if self._state == self.OPEN and now >= self._open_until:
            self._state = self.HALF_OPEN
            self._probes_inflight = 0
            self._probe_ok = 0
        return self._state

    # -- submit-time gate ------------------------------------------------

    def allow(self) -> Tuple[bool, float]:
        """-> (admit, retry_after_ms).  HALF_OPEN admits at most
        ``probes`` in-flight probe requests."""
        now = self._clock()
        with self._lock:
            state = self._effective_state(now)
            if state == self.CLOSED:
                return True, 0.0
            if state == self.OPEN:
                return False, max((self._open_until - now) * 1e3, 1.0)
            if self._probes_inflight < self.config.probes:
                self._probes_inflight += 1
                return True, 0.0
            return False, max(self.config.open_ms / 2.0, 1.0)

    def cancel(self) -> None:
        """Undo one ``allow()`` that never enqueued (a later admission
        check rejected the request) so a HALF_OPEN probe slot is not
        leaked."""
        with self._lock:
            if self._state == self.HALF_OPEN and self._probes_inflight > 0:
                self._probes_inflight -= 1

    # -- outcome feed ----------------------------------------------------

    def record(self, ok: bool) -> None:
        cfg = self.config
        now = self._clock()
        with self._lock:
            state = self._effective_state(now)
            if state == self.HALF_OPEN:
                if self._probes_inflight > 0:
                    self._probes_inflight -= 1
                if ok:
                    self._probe_ok += 1
                    if self._probe_ok >= cfg.probes:
                        self._state = self.CLOSED
                        self._outcomes.clear()
                        self._trips = 0
                else:
                    self._trip(now)
                return
            if state == self.OPEN:
                return                  # queued stragglers draining out
            self._outcomes.append(bool(ok))
            if len(self._outcomes) > cfg.window:
                del self._outcomes[: len(self._outcomes) - cfg.window]
            fails = self._outcomes.count(False)
            if (len(self._outcomes) >= cfg.min_samples
                    and fails / len(self._outcomes) >= cfg.fail_frac):
                self._trip(now)

    def _trip(self, now: float) -> None:
        cfg = self.config
        open_ms = min(cfg.open_ms * (cfg.backoff ** self._trips),
                      cfg.max_open_ms)
        self._state = self.OPEN
        self._open_until = now + open_ms / 1e3
        self._trips += 1
        self.trips_total += 1
        self._outcomes.clear()


# ---------------------------------------------------------------------------
# chaos-harness fault injection
# ---------------------------------------------------------------------------

class SlowDispatchInjector:
    """Slow-dispatch fault injection at the scheduler's execution
    boundary, ``store.faults``-style: the scheduler calls
    ``hit("execute:<collection>:<op>")`` once per batch before running
    it; an injector armed with ``delay_s`` sleeps there when the label
    contains ``match`` — a deterministic "the device got slow for this
    tenant" fault with no device code involved.  ``points`` records
    every label seen (counting mode), ``fired`` how many actually
    slept.

    >>> inj = SlowDispatchInjector(delay_s=0.0, match="victim")
    >>> inj.hit("execute:victim:topk"); inj.hit("execute:cotenant:topk")
    >>> (inj.fired, inj.points)
    (1, ['execute:victim:topk', 'execute:cotenant:topk'])
    """

    def __init__(self, delay_s: float = 0.0, match: str = "",
                 limit: Optional[int] = None):
        self.delay_s = float(delay_s)
        self.match = match
        self.limit = limit
        self.points: List[str] = []
        self.fired = 0
        self._lock = threading.Lock()

    def hit(self, label: str) -> None:
        with self._lock:
            self.points.append(label)
            fire = (self.match in label
                    and (self.limit is None or self.fired < self.limit))
            if fire:
                self.fired += 1
        if fire and self.delay_s > 0:
            time.sleep(self.delay_s)
