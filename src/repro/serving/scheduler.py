"""Async micro-batching request scheduler over the segmented index
(DESIGN.md §5).

One ``Scheduler`` fronts a ``CollectionRegistry``: clients submit
single-request ``search`` / ``topk`` / ``insert`` / ``delete`` ops and
get a ``concurrent.futures.Future`` back.  Requests queue **per
collection** (tenant isolation: one collection's merge or burst never
blocks another's queue) and are executed by one worker per collection
(threaded mode) or by an explicit ``pump()`` (synchronous mode — used by
the deterministic property tests and single-threaded drivers).

Execution model, per collection queue:

  * **Reads coalesce, writes fence.**  The worker takes the longest
    prefix of queued reads that share the head request's batch key
    (``("search", τ)`` or ``("topk", k, τ0)``), up to
    ``SchedulerConfig.max_batch`` queries; a queued write is a barrier —
    reads behind it must observe it, so they stay queued.  Reads commute
    with reads, which makes any coalescing order bit-identical to
    sequential execution (the batched searchers are bit-identical per
    row; this is the scheduler's core correctness property, held by
    ``tests/test_serving.py``).
  * **Shape buckets.**  A group of g queries is padded to the
    power-of-two ``bucket_m(g)`` rows and results are sliced back, so
    every dispatch hits an already-compiled ``(index, τ/k, block_m,
    bucket)`` searcher after one warmup per bucket — a varying-size
    request stream causes zero steady-state re-jits.
  * **Max-wait flush.**  A partially filled read batch waits at most
    ``max_wait_ms`` (measured from its oldest request) for more
    arrivals; a write landing behind the read prefix flushes it
    immediately (nothing can join the prefix anymore).
  * **Admission control.**  Queues are bounded (``max_queue``); a full
    queue rejects new work with ``OverloadError`` at submit time instead
    of queueing unboundedly — overload is explicit, not silent latency.
    With ``SchedulerConfig.admission`` set, a pressure-aware control
    plane (``serving.overload``, DESIGN.md §12) runs *in front of* that
    backstop: cost-budget admission fed by the τ-ladder cost model,
    CoDel-style queue-delay pressure tracking, a graceful-degradation
    ladder applied per batch (``degrade``), and a per-collection circuit
    breaker (``breaker``).  Requests may carry a ``deadline_ms`` budget;
    a request whose budget expires while queued is cancelled with
    ``DeadlineExceeded`` before any device dispatch.
  * **Writes interleave re-jit-free.**  ``insert`` lands in the delta
    buffer, ``delete`` flips traced tombstone bits; neither invalidates
    a compiled searcher, so read batches stream on between writes.
"""

from __future__ import annotations

import dataclasses
import logging
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Deque, Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from ..core.search import TopKResult
from ..obs.slowlog import SlowQueryLog
from ..obs.trace import Span, Tracer, attach
from ..obs.trace import span as _obs_span
from .batching import bucket_m, bucket_table, pad_to_bucket
from .collections import Collection, CollectionConfig, CollectionRegistry
from .metrics import ServingMetrics
from .overload import (AdmissionConfig, AdmissionController, BreakerConfig,
                       CircuitBreaker, DeadlineExceeded, DegradePolicy,
                       estimate_units)

__all__ = ["OverloadError", "DeadlineExceeded", "SchedulerConfig",
           "Scheduler", "SearchResponse", "TopKResponse"]

_WRITES = ("insert", "delete")
_LOG = logging.getLogger(__name__)


class OverloadError(RuntimeError):
    """Raised at submit time when a collection sheds the request — queue
    full (the hard ``max_queue`` backstop), cost budget exhausted, the
    degradation ladder at its ``reject`` stage, or the circuit breaker
    open.  Carries the shed request's context so callers (and logs) can
    see *what* was rejected — ``collection``, ``op``, ``queue_depth``,
    ``reason`` — and a machine-readable ``retry_after_ms`` backoff
    hint."""

    def __init__(self, message: str, *, collection: Optional[str] = None,
                 op: Optional[str] = None,
                 queue_depth: Optional[int] = None,
                 retry_after_ms: float = 0.0,
                 reason: str = "queue_full"):
        super().__init__(message)
        self.collection = collection
        self.op = op
        self.queue_depth = queue_depth
        self.retry_after_ms = retry_after_ms
        self.reason = reason


class SearchResponse(NamedTuple):
    mask: np.ndarray     # (n_ids,) bool — live ids within τ
    dist: np.ndarray     # (n_ids,) int32 — exact distance where mask, BIG off
    overflow: int        # total dropped frontier entries of the dispatch
    degraded: Optional[str] = None   # ladder stage that degraded this
    #                      answer ("cheap_tau"), or None for a full answer


class TopKResponse(NamedTuple):
    ids: np.ndarray      # (k,) int32 global ids, ascending (distance, id);
    #                      rerank= requests order by (score desc, id asc)
    dists: np.ndarray    # (k,) int32 exact distances; BIG on pad
    tau: int             # final ladder rung of the dispatch (batch-shared)
    overflow: int
    scores: Optional[np.ndarray] = None   # (k,) f32 exact re-rank scores
    #                      (rerank= requests only); -1.0 on pad
    degraded: Optional[str] = None   # deepest ladder stage that degraded
    #                      this answer ("rerank_off" | "shrink_k" |
    #                      "cheap_tau"), or None for a full answer


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Batching and admission-control knobs.

    Attributes:
      max_batch:   most queries coalesced into one read dispatch (the
                   largest shape bucket is ``bucket_m(max_batch)``).
      max_queue:   per-collection bound on queued requests; beyond it
                   ``submit_*`` raises ``OverloadError``.
      max_wait_ms: longest a partially filled read batch waits for more
                   arrivals before flushing (threaded mode; ``pump()``
                   always flushes immediately).
      slow_ms:     slow-query threshold (end-to-end, milliseconds); a
                   request at or above it dumps its span tree into the
                   scheduler's ``SlowQueryLog``.  None (default)
                   disables the slow log — and, with no ``tracer``
                   either, disables span recording entirely (requests
                   carry no spans and the query path's instrumentation
                   points are shared no-ops).
      admission:   per-collection adaptive admission control
                   (``overload.AdmissionConfig``): cost-budget admission
                   over the τ-ladder cost model + CoDel queue-delay
                   pressure levels.  None (default) keeps only the hard
                   ``max_queue`` cliff — pre-§12 behavior.
      degrade:     graceful-degradation ladder (``overload.DegradePolicy``)
                   applied per batch at the current pressure level;
                   requires ``admission``.  None = never degrade.
      breaker:     per-collection circuit breaker
                   (``overload.BreakerConfig``) over deadline outcomes.
                   None = never trip.
      default_deadline_ms: deadline applied to requests that pass
                   ``deadline_ms=None`` (per-collection
                   ``CollectionConfig.default_deadline_ms`` wins over
                   this scheduler-wide default).  None = no deadline.
      join_timeout_s: how long ``stop()`` waits for each worker thread
                   before declaring the shutdown dirty.
    """

    max_batch: int = 64
    max_queue: int = 1024
    max_wait_ms: float = 2.0
    slow_ms: Optional[float] = None
    admission: Optional[AdmissionConfig] = None
    degrade: Optional[DegradePolicy] = None
    breaker: Optional[BreakerConfig] = None
    default_deadline_ms: Optional[float] = None
    join_timeout_s: float = 60.0


@dataclasses.dataclass(eq=False)      # identity equality: requests are
class _Request:                       # queue entries, never value-compared
    op: str                       # "search" | "topk" | "insert" | "delete"
    key: tuple                    # reads: batch key; writes: (op,)
    payload: dict
    future: Future
    t_enq: float
    span: Optional[Span] = None   # request root (tracing enabled only)
    deadline: Optional[float] = None   # absolute perf_counter() budget
    priority: int = 0             # > 0 bypasses cost-budget admission
    units: float = 1.0            # estimated cost (reference top-k = 1)


class _CollState:
    """Per-collection queue + condition variable (+ the collection's
    admission controller and circuit breaker, when configured)."""

    def __init__(self, ctrl: Optional[AdmissionController] = None,
                 breaker: Optional[CircuitBreaker] = None):
        self.queue: Deque[_Request] = deque()
        self.cond = threading.Condition()
        self.ctrl = ctrl
        self.breaker = breaker


class Scheduler:
    """Micro-batching front end over a ``CollectionRegistry``.

    Threaded mode: ``start()`` spawns one worker per collection;
    ``stop()`` drains every queue and joins.  Synchronous mode: skip
    ``start()`` and call ``pump()`` to drain queues deterministically on
    the caller's thread (batching behaves identically, minus the
    max-wait timer).
    """

    def __init__(self, registry: Optional[CollectionRegistry] = None,
                 config: Optional[SchedulerConfig] = None,
                 metrics: Optional[ServingMetrics] = None,
                 tracer: Optional[Tracer] = None,
                 slowlog: Optional[SlowQueryLog] = None,
                 faults=None):
        self.registry = registry if registry is not None \
            else CollectionRegistry()
        self.config = config if config is not None else SchedulerConfig()
        self.metrics = metrics if metrics is not None else ServingMetrics()
        self.tracer = tracer
        if slowlog is None and self.config.slow_ms is not None:
            slowlog = SlowQueryLog()        # slow_ms implies a log to fill
        self.slowlog = slowlog
        # fault-injection hook (chaos harness): any object with
        # ``hit(label)`` — called once per batch as
        # ``execute:<collection>:<op>`` before the batch runs, matching
        # the store.faults protocol (overload.SlowDispatchInjector)
        self.faults = faults
        self._states: Dict[str, _CollState] = {}
        self._states_lock = threading.Lock()
        self._workers: Dict[str, threading.Thread] = {}
        self._started = False
        self._stopping = False
        self.stopped_dirty = False          # a stop() failed to join
        self._dirty: set = set()            # collections with stuck workers
        # adopt collections already in the registry (a recovered
        # CollectionRegistry.open(data_dir)): queue state + metrics tap,
        # exactly as create_collection would have wired them
        for name in self.registry.names():
            coll = self.registry.get(name)
            for idx in getattr(coll.index, "shards", [coll.index]):
                idx.event_hook = self._maintenance_hook
            self._ensure_state(name)

    # -- collection management -------------------------------------------

    def create_collection(self, name: str,
                          config: CollectionConfig) -> Collection:
        """Register a collection and tap its index's write events into
        the metrics (``maintenance_total:flush|merge|compact`` ...)."""
        coll = self.registry.create(name, config)
        for idx in getattr(coll.index, "shards", [coll.index]):
            idx.event_hook = self._maintenance_hook
        self._ensure_state(name)
        return coll

    def _maintenance_hook(self, event: str, info: dict) -> None:
        self.metrics.inc(f"maintenance_total:{event}")

    def _ensure_state(self, name: str) -> _CollState:
        with self._states_lock:
            state = self._states.get(name)
            if state is None:
                cfg = self.config
                ctrl = AdmissionController(cfg.admission) \
                    if cfg.admission is not None else None
                breaker = CircuitBreaker(cfg.breaker) \
                    if cfg.breaker is not None else None
                state = self._states[name] = _CollState(ctrl, breaker)
                if self._started and not self._stopping:
                    self._spawn_worker(name)
            return state

    # -- submission ------------------------------------------------------

    def _shed(self, name: str, op: str, reason: str,
              retry_after_ms: float, depth: int) -> None:
        """Reject one request at submit time with full context."""
        self.metrics.inc("rejected_total")
        self.metrics.inc(f"rejected_total:{op}")
        self.metrics.inc(f"shed_total:{reason}")
        raise OverloadError(
            f"collection {name!r} shed {op} ({reason}, "
            f"queue_depth={depth}, retry_after_ms={retry_after_ms:.0f})",
            collection=name, op=op, queue_depth=depth,
            retry_after_ms=retry_after_ms, reason=reason)

    def _submit(self, name: str, op: str, key: tuple, payload: dict,
                deadline_ms: Optional[float] = None,
                priority: Optional[int] = None) -> Future:
        coll = self.registry.get(name)     # raises KeyError if unknown
        state = self._ensure_state(name)
        if deadline_ms is None:
            deadline_ms = getattr(coll.config, "default_deadline_ms", None)
        if deadline_ms is None:
            deadline_ms = self.config.default_deadline_ms
        if priority is None:
            priority = int(getattr(coll.config, "priority", 0) or 0)
        fut: Future = Future()
        t_enq = time.perf_counter()
        req = _Request(
            op=op, key=key, payload=payload, future=fut, t_enq=t_enq,
            deadline=(None if deadline_ms is None
                      else t_enq + float(deadline_ms) / 1e3),
            priority=int(priority))
        ctrl, breaker = state.ctrl, state.breaker
        if ctrl is not None:
            req.units = estimate_units(coll.index, op, key, payload)
        probed = False
        if breaker is not None:
            ok, retry = breaker.allow()
            if not ok:
                self._shed(name, op, "breaker_open", retry,
                           len(state.queue))
            probed = True        # admitted through a possibly-probing
        try:                     # breaker: cancel the slot on any reject
            with state.cond:
                if self._stopping:
                    raise RuntimeError("scheduler is stopped")
                depth = len(state.queue)
                if depth >= self.config.max_queue:
                    retry = ctrl.retry_after_ms() if ctrl is not None \
                        else 0.0
                    self._shed(name, op, "queue_full", retry, depth)
                if ctrl is not None and req.priority <= 0 \
                        and depth >= ctrl.config.min_queue:
                    # past the ladder there is no cheaper answer left:
                    # shed new best-effort work at submit time
                    reject_level = (self.config.degrade.reject_level
                                    if self.config.degrade is not None
                                    else 2)
                    if ctrl.pressure() >= reject_level:
                        self._shed(name, op, "pressure",
                                   ctrl.retry_after_ms(), depth)
                if ctrl is not None:
                    retry = ctrl.admit(req.units, depth, req.priority)
                    if retry is not None:
                        self._shed(name, op, "cost_budget", retry, depth)
                if self.tracer is not None or self.slowlog is not None:
                    req.span = Span("request", cat="request", ts=req.t_enq,
                                    args={"op": op, "collection": name})
                state.queue.append(req)
                if ctrl is not None:
                    ctrl.on_admit(req.units)
                state.cond.notify_all()
        except BaseException:
            if probed:
                breaker.cancel()           # don't leak a half-open probe
            raise
        self.metrics.inc(f"requests_total:{op}")
        return fut

    def submit_search(self, collection: str, q: np.ndarray, tau: int,
                      deadline_ms: Optional[float] = None,
                      priority: Optional[int] = None) -> Future:
        """One range query -> Future[SearchResponse].  Coalesces with
        other queued ``(collection, τ)`` searches.  ``deadline_ms`` is
        the request's end-to-end latency budget (expired-in-queue
        requests fail with ``DeadlineExceeded`` before any dispatch);
        ``priority > 0`` bypasses cost-budget admission."""
        q = np.asarray(q, dtype=np.uint8)
        return self._submit(collection, "search", ("search", int(tau)),
                            {"q": q}, deadline_ms=deadline_ms,
                            priority=priority)

    def submit_topk(self, collection: str, q: np.ndarray, k: int,
                    tau0: Optional[int] = None,
                    rerank: Optional[str] = None,
                    q_payload: Optional[np.ndarray] = None,
                    deadline_ms: Optional[float] = None,
                    priority: Optional[int] = None) -> Future:
        """One kNN query -> Future[TopKResponse].  Coalesces with other
        queued ``(collection, k, τ0, metric)`` lookups — a two-stage
        ``rerank=`` request never coalesces with a plain one (the batch
        key carries the metric), and ``q_payload`` is the query's (Wp,)
        uint32 set bitmap.  ``deadline_ms``/``priority`` as
        ``submit_search``."""
        q = np.asarray(q, dtype=np.uint8)
        payload = {"q": q}
        if q_payload is not None:
            payload["q_payload"] = np.asarray(q_payload,
                                              np.uint32).reshape(-1)
        return self._submit(collection, "topk",
                            ("topk", int(k),
                             None if tau0 is None else int(tau0), rerank),
                            payload, deadline_ms=deadline_ms,
                            priority=priority)

    def submit_insert(self, collection: str, sketches: np.ndarray,
                      payloads: Optional[np.ndarray] = None,
                      deadline_ms: Optional[float] = None,
                      priority: Optional[int] = None) -> Future:
        """Insert -> Future[(k,) int64 global ids].  ``payloads`` carries
        the rows' (k, Wp) uint32 re-rank set bitmaps for collections
        configured with ``payload_words``."""
        payload = {"sketches": np.asarray(sketches, dtype=np.uint8),
                   "payloads": (None if payloads is None
                                else np.asarray(payloads, np.uint32))}
        return self._submit(collection, "insert", ("insert",), payload,
                            deadline_ms=deadline_ms, priority=priority)

    def submit_delete(self, collection: str, ids,
                      deadline_ms: Optional[float] = None,
                      priority: Optional[int] = None) -> Future:
        """Delete -> Future[int newly-removed count]."""
        return self._submit(collection, "delete", ("delete",),
                            {"ids": np.atleast_1d(np.asarray(ids,
                                                             np.int64))},
                            deadline_ms=deadline_ms, priority=priority)

    # -- batch formation -------------------------------------------------

    def _peek_read_group(self, state: _CollState) \
            -> Tuple[List[_Request], bool]:
        """The coalescible read prefix: requests matching the head's
        batch key, stopping the scan at the first write (a fence).
        Returns (group, fence_seen)."""
        head = state.queue[0]
        group: List[_Request] = []
        for req in state.queue:
            if req.op in _WRITES:
                return group, True
            if req.key == head.key:
                group.append(req)
                if len(group) >= self.config.max_batch:
                    break            # a full group flushes regardless
        return group, False

    def _fail_deadline(self, name: str, state: _CollState,
                       req: _Request) -> None:
        """Cancel one expired request: ``DeadlineExceeded`` to the
        client (with the controller's backoff hint), outcome fed to the
        breaker, span closed.  The request never reaches a dispatch."""
        retry = state.ctrl.retry_after_ms() if state.ctrl is not None \
            else 0.0
        budget_ms = (req.deadline - req.t_enq) * 1e3
        self.metrics.inc("deadline_exceeded_total")
        self.metrics.inc(f"deadline_exceeded_total:{req.op}")
        if state.breaker is not None:
            state.breaker.record(False)
        if req.span is not None:
            req.span.args["deadline_exceeded"] = True
            req.span.dur = time.perf_counter() - req.t_enq
            if self.tracer is not None:
                self.tracer.add(req.span)
        if not req.future.done():
            req.future.set_exception(DeadlineExceeded(
                f"{req.op} on {name!r} expired in queue "
                f"(budget {budget_ms:.0f} ms, cancelled before dispatch)",
                collection=name, op=req.op, deadline_ms=budget_ms,
                retry_after_ms=retry))

    def _purge_expired(self, name: str, state: _CollState) -> None:
        """``state.cond`` held: drop queued requests whose deadline has
        already passed — they can only waste a device dispatch."""
        now = time.perf_counter()
        expired = [r for r in state.queue
                   if r.deadline is not None and now >= r.deadline]
        if not expired:
            return
        dead = set(map(id, expired))
        state.queue = deque(r for r in state.queue if id(r) not in dead)
        for r in expired:
            if state.ctrl is not None:
                state.ctrl.on_pop(r.units)
            self._fail_deadline(name, state, r)

    def _next_batch(self, name: str, state: _CollState,
                    block: bool) -> Optional[List[_Request]]:
        """Pop the next executable batch (one write, or a coalesced read
        group).  ``block=True`` (worker threads) waits for work and holds
        partially filled read batches up to max_wait; ``block=False``
        (``pump``) flushes whatever is queued and returns None on empty."""
        max_wait = self.config.max_wait_ms / 1e3
        with state.cond:
            while True:
                self._purge_expired(name, state)
                if not state.queue:
                    if state.ctrl is not None:
                        state.ctrl.note_empty()
                    if not block or self._stopping:
                        return None
                    state.cond.wait(timeout=0.1)
                    continue
                head = state.queue[0]
                if head.op in _WRITES:
                    state.queue.popleft()
                    return [head]
                group, fence = self._peek_read_group(state)
                deadline = head.t_enq + max_wait
                if (not block or fence or self._stopping
                        or len(group) >= self.config.max_batch
                        or time.perf_counter() >= deadline):
                    picked = set(map(id, group))   # one O(queue) rebuild
                    state.queue = deque(
                        r for r in state.queue if id(r) not in picked)
                    return group
                state.cond.wait(
                    timeout=max(deadline - time.perf_counter(), 0.0))

    # -- execution -------------------------------------------------------

    def _execute(self, name: str, batch: List[_Request]) -> None:
        """Run one batch; any exception fails the batch's futures (the
        clients see it) and never escapes to the worker loop — a failed
        batch must not kill a queue's only worker or skip the latency
        accounting of its requests.

        Tracing (enabled per request at submit): each traced request
        root gets a ``queue_wait`` child covering enqueue -> here, then
        links the ONE shared ``batch`` span (the work was genuinely
        shared by the coalesced group; the Chrome export de-duplicates
        it).  The batch span is attached to this thread for the
        execution, so the query path's instrumentation points
        (``rung_dispatch``, ``tier_stage``, ``rerank``, ...) nest under
        it with no signature threading."""
        op = batch[0].op
        state = self._ensure_state(name)
        ctrl, breaker = state.ctrl, state.breaker
        t_pop = time.perf_counter()
        for req in batch:
            self.metrics.record_queue(op, t_pop - req.t_enq)
            if ctrl is not None:
                ctrl.on_pop(req.units)
                ctrl.note_delay(t_pop - req.t_enq, now=t_pop)
        if self.faults is not None:
            # chaos-harness hook: an armed SlowDispatchInjector sleeps
            # here — the "device got slow for this tenant" fault
            self.faults.hit(f"execute:{name}:{op}")
        # last-gasp deadline check (the fault may have slept): an
        # expired request must never reach the dispatch below
        now = time.perf_counter()
        expired = [r for r in batch
                   if r.deadline is not None and now >= r.deadline]
        if expired:
            for req in expired:
                self._fail_deadline(name, state, req)
            dead = set(map(id, expired))
            batch = [r for r in batch if id(r) not in dead]
            if not batch:
                return
        level = ctrl.pressure() if ctrl is not None else 0
        batch_span: Optional[Span] = None
        traced = [r for r in batch if r.span is not None]
        if traced:
            batch_span = Span(
                "batch", cat="batch", ts=t_pop,
                track=threading.current_thread().name,
                args={"op": op, "collection": name, "size": len(batch),
                      "key": repr(batch[0].key)})
            for req in traced:
                wait = req.span.child("queue_wait", cat="sched")
                wait.ts, wait.dur = req.t_enq, t_pop - req.t_enq
                req.span.children.append(batch_span)
        try:
            coll = self.registry.get(name)
            if batch_span is not None:
                with attach(batch_span):
                    self._run_batch(coll, op, batch, level, batch_span)
            else:
                self._run_batch(coll, op, batch, level, batch_span)
        except Exception as e:                     # noqa: BLE001
            self.metrics.inc("executor_errors_total")
            for req in batch:
                if not req.future.done():
                    req.future.set_exception(e)
        finally:
            t_done = time.perf_counter()
            if ctrl is not None:
                ctrl.note_exec(sum(r.units for r in batch),
                               t_done - t_pop)
            if batch_span is not None:
                batch_span.dur = t_done - batch_span.ts
            for req in batch:
                e2e = t_done - req.t_enq
                self.metrics.record_latency(op, e2e)
                if breaker is not None:
                    exc = req.future.exception() if req.future.done() \
                        else None
                    ok = exc is None and (req.deadline is None
                                          or t_done <= req.deadline)
                    breaker.record(ok)
                if req.span is None:
                    continue
                req.span.dur = e2e
                if self.tracer is not None:
                    self.tracer.add(req.span)
                if (self.slowlog is not None
                        and self.config.slow_ms is not None
                        and e2e * 1e3 >= self.config.slow_ms):
                    self.slowlog.record(
                        req.span, op=op, collection=name,
                        slow_ms=self.config.slow_ms)

    def _run_batch(self, coll: Collection, op: str, batch: List[_Request],
                   level: int = 0,
                   batch_span: Optional[Span] = None) -> None:
        if op in _WRITES:
            self._execute_write(coll, batch[0])
        else:
            self._execute_reads(coll, batch, level, batch_span)

    def _execute_reads(self, coll: Collection, batch: List[_Request],
                       level: int = 0,
                       batch_span: Optional[Span] = None) -> None:
        op, key = batch[0].op, batch[0].key
        g = len(batch)
        policy = self.config.degrade
        degraded: Optional[str] = None
        with _obs_span("batch_assembly", cat="sched", size=g,
                       bucket=bucket_m(g)):
            qs = pad_to_bucket(np.stack([r.payload["q"] for r in batch]))
        t0 = time.perf_counter()
        if op == "search":
            tau = key[1]
            if policy is not None and level > 0:
                tau, degraded = policy.apply_search(level, tau)
            with _obs_span("execute", cat="exec", op=op, tau=tau):
                res = coll.index.search_batch(qs, tau)
            self.metrics.record_exec(op, time.perf_counter() - t0)
            overflow = int(res.overflow)
            with _obs_span("respond", cat="sched"):
                for i, req in enumerate(batch):
                    req.future.set_result(SearchResponse(
                        mask=np.asarray(res.mask[i]),
                        dist=np.asarray(res.dist[i]), overflow=overflow,
                        degraded=degraded))
        else:
            k, tau0, metric = key[1], key[2], key[3]
            if policy is not None and level > 0:
                # degradation changes *parameters*, never kernels: the
                # degraded answer is bit-identical to an undegraded run
                # at the same effective (k, τ0, rerank) settings
                k, tau0, metric, degraded = policy.apply_topk(
                    level, k, tau0, metric)
            with _obs_span("execute", cat="exec", op=op, k=k):
                if metric is not None:
                    pays = pad_to_bucket(np.stack(
                        [r.payload["q_payload"] for r in batch]))
                    res: TopKResult = coll.index.topk_batch(
                        qs, k, tau0=tau0, rerank=metric, q_payloads=pays)
                else:
                    res = coll.index.topk_batch(qs, k, tau0=tau0)
            self.metrics.record_exec(op, time.perf_counter() - t0)
            with _obs_span("respond", cat="sched"):
                ids, dists = np.asarray(res.ids), np.asarray(res.dists)
                scores = (None if res.scores is None
                          else np.asarray(res.scores))
                for i, req in enumerate(batch):
                    req.future.set_result(TopKResponse(
                        ids=ids[i], dists=dists[i], tau=int(res.tau),
                        overflow=int(res.overflow),
                        scores=None if scores is None else scores[i],
                        degraded=degraded))
        if degraded is not None:
            self.metrics.inc("degraded_total", g)
            self.metrics.inc(f"degraded_total:{degraded}", g)
            if batch_span is not None:
                batch_span.args["degrade"] = degraded
                batch_span.args["pressure_level"] = level
        self.metrics.record_batch(op, g, bucket_m(g))

    def _execute_write(self, coll: Collection, req: _Request) -> None:
        t0 = time.perf_counter()
        with _obs_span("execute", cat="exec", op=req.op):
            if req.op == "insert":
                result = coll.index.insert(
                    req.payload["sketches"],
                    payloads=req.payload.get("payloads"))
            else:
                result = coll.index.delete(req.payload["ids"])
                frac = coll.config.compact_dead_frac
                if frac is not None:
                    coll.index.compact(min_dead_frac=frac)
        self.metrics.record_exec(req.op, time.perf_counter() - t0)
        self.metrics.inc("write_ops_total")
        req.future.set_result(result)

    # -- drive -----------------------------------------------------------

    def start(self) -> "Scheduler":
        """Spawn one worker thread per registered collection."""
        # _started flips under _states_lock so a concurrent
        # create_collection() cannot race us into spawning a second
        # worker on one queue (which would let a read pass a write fence)
        with self._states_lock:
            if self._started:
                return self
            self._started = True
            self._stopping = False
            for name in self._states:
                self._spawn_worker(name)
        return self

    def _spawn_worker(self, name: str) -> None:
        prev = self._workers.get(name)
        if prev is not None and prev.is_alive():
            return                          # one worker per queue, ever
        t = threading.Thread(target=self._worker, args=(name,),
                             name=f"serving-{name}", daemon=True)
        self._workers[name] = t
        t.start()

    def _worker(self, name: str) -> None:
        state = self._ensure_state(name)
        while True:
            batch = self._next_batch(name, state, block=True)
            if batch is None:
                return                      # stopping and drained
            if batch:
                try:
                    self._execute(name, batch)
                except Exception:           # noqa: BLE001 — paranoia:
                    # _execute already routes failures into the batch's
                    # futures; whatever still escapes (metrics bugs, OOM
                    # cleanup) must not silently kill the queue's worker
                    self.metrics.inc("executor_errors_total")

    def stop(self) -> None:
        """Drain every queue (outstanding futures complete) and join the
        workers.  Subsequent submits raise.

        A worker that fails to join within ``config.join_timeout_s`` is
        a loud event, never a silent one: it is logged at ERROR,
        ``stopped_dirty`` flips (surfaced in ``stats()`` and as the
        ``serving_stopped_dirty`` gauge), and ``pump()`` permanently
        skips the stuck collection — its queue may still be owned by
        the wedged thread, and a second driver would break the
        one-executor-per-queue invariant (a read could pass a write
        fence)."""
        self._stopping = True
        with self._states_lock:
            states = list(self._states.items())
        for _, state in states:
            with state.cond:
                state.cond.notify_all()
        for name, t in list(self._workers.items()):
            t.join(timeout=self.config.join_timeout_s)
            if t.is_alive():
                self.stopped_dirty = True
                self._dirty.add(name)
                self.metrics.inc("stopped_dirty_total")
                self.metrics.set_gauge("serving_stopped_dirty", 1)
                _LOG.error(
                    "stop(): worker %r failed to join within %.1f s — "
                    "DIRTY shutdown; collection %r is quarantined from "
                    "pump() (its queue may still be owned by the wedged "
                    "thread)", t.name, self.config.join_timeout_s, name)
        self._workers.clear()
        self._started = False
        self.pump()                         # finish anything left behind

    def pump(self) -> int:
        """Synchronous drive: drain every collection queue on the calling
        thread (deterministic — no timers).  Returns batches executed.
        Collections quarantined by a dirty ``stop()`` are skipped."""
        executed = 0
        progressed = True
        while progressed:
            progressed = False
            with self._states_lock:
                items = list(self._states.items())
            for name, state in items:
                if name in self._dirty:
                    continue
                while True:
                    batch = self._next_batch(name, state, block=False)
                    if not batch:
                        break
                    self._execute(name, batch)
                    executed += 1
                    progressed = True
        return executed

    def warmup(self, collection: Optional[str] = None,
               ks: Tuple[int, ...] = (8,),
               taus: Tuple[int, ...] = ()) -> Dict[str, int]:
        """Pre-jit every power-of-two shape bucket up to ``max_batch``
        so first-request compile time never pollutes serving p99 (the
        multi-second smoke tail in BENCH_serving.json was dominated by
        one trace per (bucket, k/τ) on the first live request).

        Drives ``topk_batch`` for each k in ``ks`` and ``search_batch``
        for each τ in ``taus`` over zero-sketch queries at every bucket
        size, for ``collection`` (default: all).  Empty collections are
        skipped (their searchers re-specialize on first insert anyway).
        Returns ``{"buckets", "calls", "traces"}`` — ``traces`` is the
        number of fresh compiles the warmup absorbed."""
        from ..core.search import searcher_cache_info
        names = [collection] if collection is not None \
            else self.registry.names()
        buckets = bucket_table(self.config.max_batch)
        traces0 = searcher_cache_info().get("traces", 0)
        calls = 0
        for name in names:
            coll = self.registry.get(name)
            if getattr(coll.index, "n_live", 0) == 0:
                continue
            for bkt in buckets:
                qs = np.zeros((bkt, coll.config.L), dtype=np.uint8)
                for k in ks:
                    coll.index.topk_batch(qs, int(k))
                    calls += 1
                for tau in taus:
                    coll.index.search_batch(qs, int(tau))
                    calls += 1
        self.metrics.inc("warmup_calls_total", calls)
        return {"buckets": len(buckets), "calls": calls,
                "traces": searcher_cache_info().get("traces", 0) - traces0}

    # -- introspection ---------------------------------------------------

    def queue_depth(self, collection: Optional[str] = None) -> int:
        with self._states_lock:
            states = [self._states[collection]] if collection is not None \
                else list(self._states.values())
        return sum(len(s.queue) for s in states)

    def stats(self) -> Dict[str, object]:
        """One dict: metrics snapshot + queue depths + per-collection
        index occupancy (segments, tombstones, live counts) + the
        overload control plane's state (pressure level, queued cost
        units, breaker state/trips) when configured."""
        with self._states_lock:
            depths = {name: len(state.queue)
                      for name, state in self._states.items()}
            overload: Dict[str, Dict[str, object]] = {}
            for name, state in self._states.items():
                d: Dict[str, object] = {}
                if state.ctrl is not None:
                    d["pressure_level"] = state.ctrl.pressure()
                    d["queued_units"] = state.ctrl.queued_units()
                    d["retry_after_ms"] = state.ctrl.retry_after_ms()
                    d["cost_sheds"] = state.ctrl.sheds
                if state.breaker is not None:
                    d["breaker"] = state.breaker.state()
                    d["breaker_trips"] = state.breaker.trips_total
                if d:
                    overload[name] = d
        out = {**self.metrics.snapshot(), "queue_depth": depths,
               "collections": self.registry.stats(),
               "stopped_dirty": self.stopped_dirty}
        if overload:
            out["overload"] = overload
        return out

    def render_stats(self) -> str:
        """``/stats``-style text dump of everything ``stats()`` reports."""
        extra: Dict[str, object] = {}
        with self._states_lock:
            for name, state in self._states.items():
                extra[f'serving_queue_depth{{collection="{name}"}}'] = \
                    len(state.queue)
                if state.breaker is not None:
                    extra[f'serving_breaker_state{{collection="{name}"}}'] \
                        = state.breaker.state_code()
                if state.ctrl is not None:
                    extra[f'serving_pressure_level{{collection="{name}"}}'] \
                        = state.ctrl.pressure()
                    extra[f'serving_queued_cost_units'
                          f'{{collection="{name}"}}'] = \
                        state.ctrl.queued_units()
        for name, st in self.registry.stats().items():
            for gauge in ("n_live", "tombstones", "n_segments", "n_ids",
                          "arena_bytes", "device_bytes", "host_bytes"):
                if gauge in st:
                    extra[f'index_{gauge}{{collection="{name}"}}'] = st[gauge]
            for gauge in ("wal_bytes", "snapshot_bytes", "wal_truncations",
                          "replayed_records", "recovered_segments"):
                if "store" in st and gauge in st["store"]:
                    extra[f'store_{gauge}{{collection="{name}"}}'] = \
                        st["store"][gauge]
        return self.metrics.render_text(extra=extra)
