"""Async micro-batching request scheduler over the segmented index
(DESIGN.md §5).

One ``Scheduler`` fronts a ``CollectionRegistry``: clients submit
single-request ``search`` / ``topk`` / ``insert`` / ``delete`` ops and
get a ``concurrent.futures.Future`` back.  Requests queue **per
collection** (tenant isolation: one collection's merge or burst never
blocks another's queue) and are executed by one worker per collection
(threaded mode) or by an explicit ``pump()`` (synchronous mode — used by
the deterministic property tests and single-threaded drivers).

Execution model, per collection queue:

  * **Reads coalesce, writes fence.**  The worker takes the longest
    prefix of queued reads that share the head request's batch key
    (``("search", τ)`` or ``("topk", k, τ0)``), up to
    ``SchedulerConfig.max_batch`` queries; a queued write is a barrier —
    reads behind it must observe it, so they stay queued.  Reads commute
    with reads, which makes any coalescing order bit-identical to
    sequential execution (the batched searchers are bit-identical per
    row; this is the scheduler's core correctness property, held by
    ``tests/test_serving.py``).
  * **Shape buckets.**  A group of g queries is padded to the
    power-of-two ``bucket_m(g)`` rows and results are sliced back, so
    every dispatch hits an already-compiled ``(index, τ/k, block_m,
    bucket)`` searcher after one warmup per bucket — a varying-size
    request stream causes zero steady-state re-jits.
  * **Max-wait flush.**  A partially filled read batch waits at most
    ``max_wait_ms`` (measured from its oldest request) for more
    arrivals; a write landing behind the read prefix flushes it
    immediately (nothing can join the prefix anymore).
  * **Admission control.**  Queues are bounded (``max_queue``); a full
    queue rejects new work with ``OverloadError`` at submit time instead
    of queueing unboundedly — overload is explicit, not silent latency.
  * **Writes interleave re-jit-free.**  ``insert`` lands in the delta
    buffer, ``delete`` flips traced tombstone bits; neither invalidates
    a compiled searcher, so read batches stream on between writes.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Deque, Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from ..core.search import TopKResult
from ..obs.slowlog import SlowQueryLog
from ..obs.trace import Span, Tracer, attach
from ..obs.trace import span as _obs_span
from .batching import bucket_m, pad_to_bucket
from .collections import Collection, CollectionConfig, CollectionRegistry
from .metrics import ServingMetrics

__all__ = ["OverloadError", "SchedulerConfig", "Scheduler",
           "SearchResponse", "TopKResponse"]

_WRITES = ("insert", "delete")


class OverloadError(RuntimeError):
    """Raised at submit time when a collection's queue is full.  Carries
    the shed request's context so callers (and logs) can see *what* was
    rejected: ``collection``, ``op``, and the ``queue_depth`` observed at
    rejection."""

    def __init__(self, message: str, *, collection: Optional[str] = None,
                 op: Optional[str] = None,
                 queue_depth: Optional[int] = None):
        super().__init__(message)
        self.collection = collection
        self.op = op
        self.queue_depth = queue_depth


class SearchResponse(NamedTuple):
    mask: np.ndarray     # (n_ids,) bool — live ids within τ
    dist: np.ndarray     # (n_ids,) int32 — exact distance where mask, BIG off
    overflow: int        # total dropped frontier entries of the dispatch


class TopKResponse(NamedTuple):
    ids: np.ndarray      # (k,) int32 global ids, ascending (distance, id);
    #                      rerank= requests order by (score desc, id asc)
    dists: np.ndarray    # (k,) int32 exact distances; BIG on pad
    tau: int             # final ladder rung of the dispatch (batch-shared)
    overflow: int
    scores: Optional[np.ndarray] = None   # (k,) f32 exact re-rank scores
    #                      (rerank= requests only); -1.0 on pad


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Batching and admission-control knobs.

    Attributes:
      max_batch:   most queries coalesced into one read dispatch (the
                   largest shape bucket is ``bucket_m(max_batch)``).
      max_queue:   per-collection bound on queued requests; beyond it
                   ``submit_*`` raises ``OverloadError``.
      max_wait_ms: longest a partially filled read batch waits for more
                   arrivals before flushing (threaded mode; ``pump()``
                   always flushes immediately).
      slow_ms:     slow-query threshold (end-to-end, milliseconds); a
                   request at or above it dumps its span tree into the
                   scheduler's ``SlowQueryLog``.  None (default)
                   disables the slow log — and, with no ``tracer``
                   either, disables span recording entirely (requests
                   carry no spans and the query path's instrumentation
                   points are shared no-ops).
    """

    max_batch: int = 64
    max_queue: int = 1024
    max_wait_ms: float = 2.0
    slow_ms: Optional[float] = None


@dataclasses.dataclass(eq=False)      # identity equality: requests are
class _Request:                       # queue entries, never value-compared
    op: str                       # "search" | "topk" | "insert" | "delete"
    key: tuple                    # reads: batch key; writes: (op,)
    payload: dict
    future: Future
    t_enq: float
    span: Optional[Span] = None   # request root (tracing enabled only)


class _CollState:
    """Per-collection queue + condition variable."""

    def __init__(self):
        self.queue: Deque[_Request] = deque()
        self.cond = threading.Condition()


class Scheduler:
    """Micro-batching front end over a ``CollectionRegistry``.

    Threaded mode: ``start()`` spawns one worker per collection;
    ``stop()`` drains every queue and joins.  Synchronous mode: skip
    ``start()`` and call ``pump()`` to drain queues deterministically on
    the caller's thread (batching behaves identically, minus the
    max-wait timer).
    """

    def __init__(self, registry: Optional[CollectionRegistry] = None,
                 config: Optional[SchedulerConfig] = None,
                 metrics: Optional[ServingMetrics] = None,
                 tracer: Optional[Tracer] = None,
                 slowlog: Optional[SlowQueryLog] = None):
        self.registry = registry if registry is not None \
            else CollectionRegistry()
        self.config = config if config is not None else SchedulerConfig()
        self.metrics = metrics if metrics is not None else ServingMetrics()
        self.tracer = tracer
        if slowlog is None and self.config.slow_ms is not None:
            slowlog = SlowQueryLog()        # slow_ms implies a log to fill
        self.slowlog = slowlog
        self._states: Dict[str, _CollState] = {}
        self._states_lock = threading.Lock()
        self._workers: Dict[str, threading.Thread] = {}
        self._started = False
        self._stopping = False
        # adopt collections already in the registry (a recovered
        # CollectionRegistry.open(data_dir)): queue state + metrics tap,
        # exactly as create_collection would have wired them
        for name in self.registry.names():
            coll = self.registry.get(name)
            for idx in getattr(coll.index, "shards", [coll.index]):
                idx.event_hook = self._maintenance_hook
            self._ensure_state(name)

    # -- collection management -------------------------------------------

    def create_collection(self, name: str,
                          config: CollectionConfig) -> Collection:
        """Register a collection and tap its index's write events into
        the metrics (``maintenance_total:flush|merge|compact`` ...)."""
        coll = self.registry.create(name, config)
        for idx in getattr(coll.index, "shards", [coll.index]):
            idx.event_hook = self._maintenance_hook
        self._ensure_state(name)
        return coll

    def _maintenance_hook(self, event: str, info: dict) -> None:
        self.metrics.inc(f"maintenance_total:{event}")

    def _ensure_state(self, name: str) -> _CollState:
        with self._states_lock:
            state = self._states.get(name)
            if state is None:
                state = self._states[name] = _CollState()
                if self._started and not self._stopping:
                    self._spawn_worker(name)
            return state

    # -- submission ------------------------------------------------------

    def _submit(self, name: str, op: str, key: tuple,
                payload: dict) -> Future:
        self.registry.get(name)            # raises KeyError if unknown
        state = self._ensure_state(name)
        fut: Future = Future()
        req = _Request(op=op, key=key, payload=payload, future=fut,
                       t_enq=time.perf_counter())
        with state.cond:
            if self._stopping:
                raise RuntimeError("scheduler is stopped")
            if len(state.queue) >= self.config.max_queue:
                depth = len(state.queue)
                self.metrics.inc("rejected_total")
                self.metrics.inc(f"rejected_total:{op}")
                raise OverloadError(
                    f"collection {name!r} queue full "
                    f"({self.config.max_queue} requests, op={op})",
                    collection=name, op=op, queue_depth=depth)
            if self.tracer is not None or self.slowlog is not None:
                req.span = Span("request", cat="request", ts=req.t_enq,
                                args={"op": op, "collection": name})
            state.queue.append(req)
            state.cond.notify_all()
        self.metrics.inc(f"requests_total:{op}")
        return fut

    def submit_search(self, collection: str, q: np.ndarray,
                      tau: int) -> Future:
        """One range query -> Future[SearchResponse].  Coalesces with
        other queued ``(collection, τ)`` searches."""
        q = np.asarray(q, dtype=np.uint8)
        return self._submit(collection, "search", ("search", int(tau)),
                            {"q": q})

    def submit_topk(self, collection: str, q: np.ndarray, k: int,
                    tau0: Optional[int] = None,
                    rerank: Optional[str] = None,
                    q_payload: Optional[np.ndarray] = None) -> Future:
        """One kNN query -> Future[TopKResponse].  Coalesces with other
        queued ``(collection, k, τ0, metric)`` lookups — a two-stage
        ``rerank=`` request never coalesces with a plain one (the batch
        key carries the metric), and ``q_payload`` is the query's (Wp,)
        uint32 set bitmap."""
        q = np.asarray(q, dtype=np.uint8)
        payload = {"q": q}
        if q_payload is not None:
            payload["q_payload"] = np.asarray(q_payload,
                                              np.uint32).reshape(-1)
        return self._submit(collection, "topk",
                            ("topk", int(k),
                             None if tau0 is None else int(tau0), rerank),
                            payload)

    def submit_insert(self, collection: str, sketches: np.ndarray,
                      payloads: Optional[np.ndarray] = None) -> Future:
        """Insert -> Future[(k,) int64 global ids].  ``payloads`` carries
        the rows' (k, Wp) uint32 re-rank set bitmaps for collections
        configured with ``payload_words``."""
        payload = {"sketches": np.asarray(sketches, dtype=np.uint8),
                   "payloads": (None if payloads is None
                                else np.asarray(payloads, np.uint32))}
        return self._submit(collection, "insert", ("insert",), payload)

    def submit_delete(self, collection: str, ids) -> Future:
        """Delete -> Future[int newly-removed count]."""
        return self._submit(collection, "delete", ("delete",),
                            {"ids": np.atleast_1d(np.asarray(ids,
                                                             np.int64))})

    # -- batch formation -------------------------------------------------

    def _peek_read_group(self, state: _CollState) \
            -> Tuple[List[_Request], bool]:
        """The coalescible read prefix: requests matching the head's
        batch key, stopping the scan at the first write (a fence).
        Returns (group, fence_seen)."""
        head = state.queue[0]
        group: List[_Request] = []
        for req in state.queue:
            if req.op in _WRITES:
                return group, True
            if req.key == head.key:
                group.append(req)
                if len(group) >= self.config.max_batch:
                    break            # a full group flushes regardless
        return group, False

    def _next_batch(self, state: _CollState,
                    block: bool) -> Optional[List[_Request]]:
        """Pop the next executable batch (one write, or a coalesced read
        group).  ``block=True`` (worker threads) waits for work and holds
        partially filled read batches up to max_wait; ``block=False``
        (``pump``) flushes whatever is queued and returns None on empty."""
        max_wait = self.config.max_wait_ms / 1e3
        with state.cond:
            while True:
                if not state.queue:
                    if not block or self._stopping:
                        return None
                    state.cond.wait(timeout=0.1)
                    continue
                head = state.queue[0]
                if head.op in _WRITES:
                    state.queue.popleft()
                    return [head]
                group, fence = self._peek_read_group(state)
                deadline = head.t_enq + max_wait
                if (not block or fence or self._stopping
                        or len(group) >= self.config.max_batch
                        or time.perf_counter() >= deadline):
                    picked = set(map(id, group))   # one O(queue) rebuild
                    state.queue = deque(
                        r for r in state.queue if id(r) not in picked)
                    return group
                state.cond.wait(
                    timeout=max(deadline - time.perf_counter(), 0.0))

    # -- execution -------------------------------------------------------

    def _execute(self, name: str, batch: List[_Request]) -> None:
        """Run one batch; any exception fails the batch's futures (the
        clients see it) and never escapes to the worker loop — a failed
        batch must not kill a queue's only worker or skip the latency
        accounting of its requests.

        Tracing (enabled per request at submit): each traced request
        root gets a ``queue_wait`` child covering enqueue -> here, then
        links the ONE shared ``batch`` span (the work was genuinely
        shared by the coalesced group; the Chrome export de-duplicates
        it).  The batch span is attached to this thread for the
        execution, so the query path's instrumentation points
        (``rung_dispatch``, ``tier_stage``, ``rerank``, ...) nest under
        it with no signature threading."""
        op = batch[0].op
        t_pop = time.perf_counter()
        for req in batch:
            self.metrics.record_queue(op, t_pop - req.t_enq)
        batch_span: Optional[Span] = None
        traced = [r for r in batch if r.span is not None]
        if traced:
            batch_span = Span(
                "batch", cat="batch", ts=t_pop,
                track=threading.current_thread().name,
                args={"op": op, "collection": name, "size": len(batch),
                      "key": repr(batch[0].key)})
            for req in traced:
                wait = req.span.child("queue_wait", cat="sched")
                wait.ts, wait.dur = req.t_enq, t_pop - req.t_enq
                req.span.children.append(batch_span)
        try:
            coll = self.registry.get(name)
            if batch_span is not None:
                with attach(batch_span):
                    self._run_batch(coll, op, batch)
            else:
                self._run_batch(coll, op, batch)
        except Exception as e:                     # noqa: BLE001
            self.metrics.inc("executor_errors_total")
            for req in batch:
                if not req.future.done():
                    req.future.set_exception(e)
        finally:
            t_done = time.perf_counter()
            if batch_span is not None:
                batch_span.dur = t_done - batch_span.ts
            for req in batch:
                e2e = t_done - req.t_enq
                self.metrics.record_latency(op, e2e)
                if req.span is None:
                    continue
                req.span.dur = e2e
                if self.tracer is not None:
                    self.tracer.add(req.span)
                if (self.slowlog is not None
                        and self.config.slow_ms is not None
                        and e2e * 1e3 >= self.config.slow_ms):
                    self.slowlog.record(
                        req.span, op=op, collection=name,
                        slow_ms=self.config.slow_ms)

    def _run_batch(self, coll: Collection, op: str,
                   batch: List[_Request]) -> None:
        if op in _WRITES:
            self._execute_write(coll, batch[0])
        else:
            self._execute_reads(coll, batch)

    def _execute_reads(self, coll: Collection,
                       batch: List[_Request]) -> None:
        op, key = batch[0].op, batch[0].key
        g = len(batch)
        with _obs_span("batch_assembly", cat="sched", size=g,
                       bucket=bucket_m(g)):
            qs = pad_to_bucket(np.stack([r.payload["q"] for r in batch]))
        t0 = time.perf_counter()
        if op == "search":
            tau = key[1]
            with _obs_span("execute", cat="exec", op=op, tau=tau):
                res = coll.index.search_batch(qs, tau)
            self.metrics.record_exec(op, time.perf_counter() - t0)
            overflow = int(res.overflow)
            with _obs_span("respond", cat="sched"):
                for i, req in enumerate(batch):
                    req.future.set_result(SearchResponse(
                        mask=np.asarray(res.mask[i]),
                        dist=np.asarray(res.dist[i]), overflow=overflow))
        else:
            k, tau0, metric = key[1], key[2], key[3]
            with _obs_span("execute", cat="exec", op=op, k=k):
                if metric is not None:
                    pays = pad_to_bucket(np.stack(
                        [r.payload["q_payload"] for r in batch]))
                    res: TopKResult = coll.index.topk_batch(
                        qs, k, tau0=tau0, rerank=metric, q_payloads=pays)
                else:
                    res = coll.index.topk_batch(qs, k, tau0=tau0)
            self.metrics.record_exec(op, time.perf_counter() - t0)
            with _obs_span("respond", cat="sched"):
                ids, dists = np.asarray(res.ids), np.asarray(res.dists)
                scores = (None if res.scores is None
                          else np.asarray(res.scores))
                for i, req in enumerate(batch):
                    req.future.set_result(TopKResponse(
                        ids=ids[i], dists=dists[i], tau=int(res.tau),
                        overflow=int(res.overflow),
                        scores=None if scores is None else scores[i]))
        self.metrics.record_batch(op, g, bucket_m(g))

    def _execute_write(self, coll: Collection, req: _Request) -> None:
        t0 = time.perf_counter()
        with _obs_span("execute", cat="exec", op=req.op):
            if req.op == "insert":
                result = coll.index.insert(
                    req.payload["sketches"],
                    payloads=req.payload.get("payloads"))
            else:
                result = coll.index.delete(req.payload["ids"])
                frac = coll.config.compact_dead_frac
                if frac is not None:
                    coll.index.compact(min_dead_frac=frac)
        self.metrics.record_exec(req.op, time.perf_counter() - t0)
        self.metrics.inc("write_ops_total")
        req.future.set_result(result)

    # -- drive -----------------------------------------------------------

    def start(self) -> "Scheduler":
        """Spawn one worker thread per registered collection."""
        # _started flips under _states_lock so a concurrent
        # create_collection() cannot race us into spawning a second
        # worker on one queue (which would let a read pass a write fence)
        with self._states_lock:
            if self._started:
                return self
            self._started = True
            self._stopping = False
            for name in self._states:
                self._spawn_worker(name)
        return self

    def _spawn_worker(self, name: str) -> None:
        prev = self._workers.get(name)
        if prev is not None and prev.is_alive():
            return                          # one worker per queue, ever
        t = threading.Thread(target=self._worker, args=(name,),
                             name=f"serving-{name}", daemon=True)
        self._workers[name] = t
        t.start()

    def _worker(self, name: str) -> None:
        state = self._ensure_state(name)
        while True:
            batch = self._next_batch(state, block=True)
            if batch is None:
                return                      # stopping and drained
            if batch:
                try:
                    self._execute(name, batch)
                except Exception:           # noqa: BLE001 — paranoia:
                    # _execute already routes failures into the batch's
                    # futures; whatever still escapes (metrics bugs, OOM
                    # cleanup) must not silently kill the queue's worker
                    self.metrics.inc("executor_errors_total")

    def stop(self) -> None:
        """Drain every queue (outstanding futures complete) and join the
        workers.  Subsequent submits raise."""
        self._stopping = True
        with self._states_lock:
            states = list(self._states.values())
        for state in states:
            with state.cond:
                state.cond.notify_all()
        for t in self._workers.values():
            t.join(timeout=60.0)
        self._workers.clear()
        self._started = False
        self.pump()                         # finish anything left behind

    def pump(self) -> int:
        """Synchronous drive: drain every collection queue on the calling
        thread (deterministic — no timers).  Returns batches executed."""
        executed = 0
        progressed = True
        while progressed:
            progressed = False
            with self._states_lock:
                items = list(self._states.items())
            for name, state in items:
                while True:
                    batch = self._next_batch(state, block=False)
                    if not batch:
                        break
                    self._execute(name, batch)
                    executed += 1
                    progressed = True
        return executed

    # -- introspection ---------------------------------------------------

    def queue_depth(self, collection: Optional[str] = None) -> int:
        with self._states_lock:
            states = [self._states[collection]] if collection is not None \
                else list(self._states.values())
        return sum(len(s.queue) for s in states)

    def stats(self) -> Dict[str, object]:
        """One dict: metrics snapshot + queue depths + per-collection
        index occupancy (segments, tombstones, live counts)."""
        with self._states_lock:
            depths = {name: len(state.queue)
                      for name, state in self._states.items()}
        return {**self.metrics.snapshot(), "queue_depth": depths,
                "collections": self.registry.stats()}

    def render_stats(self) -> str:
        """``/stats``-style text dump of everything ``stats()`` reports."""
        extra: Dict[str, object] = {}
        with self._states_lock:
            for name, state in self._states.items():
                extra[f'serving_queue_depth{{collection="{name}"}}'] = \
                    len(state.queue)
        for name, st in self.registry.stats().items():
            for gauge in ("n_live", "tombstones", "n_segments", "n_ids",
                          "arena_bytes", "device_bytes", "host_bytes"):
                if gauge in st:
                    extra[f'index_{gauge}{{collection="{name}"}}'] = st[gauge]
            for gauge in ("wal_bytes", "snapshot_bytes", "wal_truncations",
                          "replayed_records", "recovered_segments"):
                if "store" in st and gauge in st["store"]:
                    extra[f'store_{gauge}{{collection="{name}"}}'] = \
                        st["store"][gauge]
        return self.metrics.render_text(extra=extra)
