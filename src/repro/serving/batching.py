"""Shape-bucket micro-batching helpers (DESIGN.md §5).

Every jitted searcher specializes on the query-batch size m, so a
serving frontend that dispatched raw client batches would pay one fresh
trace per distinct m it has ever seen.  The scheduler instead coalesces
queued single-query requests into **power-of-two shape buckets**: a
group of g queries is padded up to ``bucket_m(g)`` rows (repeating the
last real query — a real sketch can never overflow a frontier harder
than the rows already present) and the result planes are sliced back to
g rows.  After one warmup per bucket, every dispatch hits an
already-compiled ``(index, τ/k, block_m, bucket)`` searcher.

``bucket_m`` itself lives in ``repro.core.search`` (the core batched
searchers apply the same bucketing internally); this module adds the
host-side padding/slicing used by the scheduler and the bucket table
used for capacity planning.
"""

from __future__ import annotations

from typing import List

import numpy as np

from ..core.search import bucket_m

__all__ = ["bucket_m", "bucket_table", "pad_to_bucket", "slice_rows"]


def bucket_table(max_batch: int) -> List[int]:
    """The ascending power-of-two buckets a scheduler with this
    ``max_batch`` can dispatch: 1, 2, 4, ..., bucket_m(max_batch).

    >>> bucket_table(6)
    [1, 2, 4, 8]
    """
    out, b = [], 1
    top = bucket_m(max_batch)
    while b <= top:
        out.append(b)
        b *= 2
    return out


def pad_to_bucket(qs: np.ndarray) -> np.ndarray:
    """(g, L) queries -> (bucket_m(g), L): pad rows repeat the last real
    query so pad traffic behaves like real traffic (no pathological
    frontier blow-up, no extra ladder rungs)."""
    qs = np.asarray(qs)
    g = qs.shape[0]
    bucket = bucket_m(g)
    if bucket == g:
        return qs
    pad = np.broadcast_to(qs[-1:], (bucket - g,) + qs.shape[1:])
    return np.concatenate([qs, pad], axis=0)


def slice_rows(arr, g: int):
    """Mask padded results back out: keep the first g rows."""
    return arr[:g]
