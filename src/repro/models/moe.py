"""Mixture-of-Experts block: shared + routed experts, top-k routing with
capacity-based dispatch (GShard/Switch formulation, GSPMD-friendly).

Dispatch is *grouped*: the token axis is reshaped to (G, T/G) where G is
the number of data shards (pod x data).  Routing decisions, the
position-in-expert cumsum, and capacity drops are then computed per group
with no cross-shard scan; the expert einsums contract over the expert axis
(sharded over "model"), which is exactly the all-to-all exchange pattern
of expert parallelism when lowered by GSPMD.  Smoke tests run G=1 and a
capacity factor large enough for zero drops, validated against the dense
all-experts reference ``moe_apply_dense``.

Weights: routed ``w_*`` are stacked (E, d, ff); shared experts are a plain
fused MLP of width ``n_shared * moe_d_ff`` (mathematically identical to
summing ``n_shared`` always-on experts).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import constrain
from .layers import act_fn


def moe_init(key, d_model: int, n_experts: int, moe_d_ff: int,
             n_shared: int, dtype) -> dict:
    ks = jax.random.split(key, 7)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(moe_d_ff)
    params = {
        "router": (jax.random.normal(ks[0], (d_model, n_experts)) * s_in
                   ).astype(jnp.float32),  # router math stays f32
        "w_gate": (jax.random.normal(ks[1], (n_experts, d_model, moe_d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (n_experts, d_model, moe_d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (n_experts, moe_d_ff, d_model)) * s_out).astype(dtype),
    }
    if n_shared:
        ff_sh = n_shared * moe_d_ff
        params["shared"] = {
            "w_gate": (jax.random.normal(ks[4], (d_model, ff_sh)) * s_in).astype(dtype),
            "w_up": (jax.random.normal(ks[5], (d_model, ff_sh)) * s_in).astype(dtype),
            "w_down": (jax.random.normal(ks[6], (ff_sh, d_model)) * s_out).astype(dtype),
        }
    return params


def _route(router_w: jnp.ndarray, x: jnp.ndarray, top_k: int):
    """x: (..., d) -> gates (..., k) f32 (normalized over top-k), idx (..., k)."""
    logits = x.astype(jnp.float32) @ router_w            # (..., E)
    gate_all = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(gate_all, top_k)          # (..., k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx


def moe_apply(params: dict, x: jnp.ndarray, *, top_k: int, act: str,
              num_groups: int = 1, capacity_factor: float = 1.25) -> jnp.ndarray:
    """Capacity-based top-k MoE.  x: (B, S, d) -> (B, S, d).

    ``num_groups`` must divide B·S; set it to the data-shard count so each
    group's dispatch is shard-local (see module docstring).
    """
    B, S, d = x.shape
    E = params["router"].shape[-1]
    T = B * S
    assert T % num_groups == 0, (T, num_groups)
    tg = T // num_groups
    xg = x.reshape(num_groups, tg, d)                     # (G, tg, d)

    gates, idx = _route(params["router"], xg, top_k)      # (G, tg, k)

    cap = int(np.ceil(tg * top_k / E * capacity_factor))
    cap = max(cap, top_k)

    # position of each (token, slot) within its expert, per group
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)      # (G, tg, k, E)
    flat = onehot.reshape(num_groups, tg * top_k, E)
    pos = jnp.cumsum(flat, axis=1) - 1                    # (G, tg*k, E)
    pos_own = (pos * flat).sum(-1).reshape(num_groups, tg, top_k)
    keep = pos_own < cap                                  # capacity drop mask

    # Dispatch: scatter tokens into the (G, E, cap+1, d) buffer; slot
    # ``cap`` is the scratch row for capacity-dropped tokens.  Sharding
    # choreography (the GSPMD expert-parallel exchange):
    #   1. the scatter runs shard-LOCAL — buf group axis over (pod, data),
    #      experts replicated (data-dependent indices never cross shards);
    #   2. a constraint then re-shards E over "model" — a local slice
    #      plus the all-to-all-equivalent exchange GSPMD picks;
    #   3. expert einsums run with E and the expert weights co-sharded;
    #   4. the inverse constraint (all-gather over "model") precedes the
    #      data-dependent gather back to token order.
    pos_clip = jnp.where(keep, pos_own, cap)              # (G, tg, k)
    buf = jnp.zeros((num_groups, E, cap + 1, d), x.dtype)
    src = jnp.broadcast_to(xg[:, :, None, :], (num_groups, tg, top_k, d))
    g_idx = jnp.arange(num_groups)[:, None, None]
    buf = buf.at[g_idx, idx, pos_clip, :].set(src, mode="drop")
    buf = constrain(buf, ("batch", None, None, None))     # local scatter
    buf = constrain(buf[:, :, :cap], ("batch", "expert", None, None))

    # expert computation (E sharded over "model" shards under GSPMD)
    h = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    h = constrain(act_fn(act)(h) * u, ("batch", "expert", None, None))
    out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    out = constrain(out, ("batch", "expert", None, None))
    out = constrain(out, ("batch", None, None, None))     # gather back E
    out = jnp.concatenate(
        [out, jnp.zeros((num_groups, E, 1, d), out.dtype)], axis=2)

    # combine with gates in token order (shard-local gather)
    picked = out[g_idx, idx, pos_clip, :]                 # (G, tg, k, d)
    w = (gates * keep).astype(x.dtype)
    y = (picked * w[..., None]).sum(axis=2)               # (G, tg, d)
    y = y.reshape(B, S, d)

    if "shared" in params:
        sh = params["shared"]
        hs = act_fn(act)(x @ sh["w_gate"]) * (x @ sh["w_up"])
        y = y + hs @ sh["w_down"]
    return y


def moe_apply_dense(params: dict, x: jnp.ndarray, *, top_k: int,
                    act: str) -> jnp.ndarray:
    """Dense all-experts reference (oracle for the dispatch path): every
    expert runs on every token; outputs combined by top-k gates."""
    B, S, d = x.shape
    gates, idx = _route(params["router"], x, top_k)       # (B, S, k)
    h = jnp.einsum("bsd,edf->besf", x, params["w_gate"])
    u = jnp.einsum("bsd,edf->besf", x, params["w_up"])
    h = act_fn(act)(h) * u
    out = jnp.einsum("besf,efd->besd", h, params["w_down"])  # (B, E, S, d)
    E = out.shape[1]
    comb = jnp.zeros((B, S, E), jnp.float32)
    comb = comb.at[jnp.arange(B)[:, None, None],
                   jnp.arange(S)[None, :, None], idx].set(gates)
    y = jnp.einsum("bse,besd->bsd", comb.astype(x.dtype), out)
    if "shared" in params:
        sh = params["shared"]
        hs = act_fn(act)(x @ sh["w_gate"]) * (x @ sh["w_up"])
        y = y + hs @ sh["w_down"]
    return y


def aux_load_balance_loss(params: dict, x: jnp.ndarray, *, top_k: int) -> jnp.ndarray:
    """Switch-style load-balance auxiliary: E * sum_e f_e * p_e."""
    logits = x.astype(jnp.float32) @ params["router"]
    E = logits.shape[-1]
    p = jax.nn.softmax(logits, axis=-1)                   # (B, S, E)
    _, idx = jax.lax.top_k(p, top_k)
    f = jax.nn.one_hot(idx, E).sum(axis=-2)               # (B, S, E) counts
    return E * jnp.mean(f.mean(axis=(0, 1)) * p.mean(axis=(0, 1)))
