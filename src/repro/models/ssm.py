"""Mamba2 SSD (state-space duality) block — chunked parallel scan form.

Follows the reference minimal SSD algorithm [Dao & Gu, arXiv:2405.21060]:
the sequence is split into chunks; within a chunk the recurrence is
evaluated as a (masked, decay-weighted) attention-like einsum on the MXU;
across chunks a small (c+1 x c+1) decay matrix propagates states.

TPU adaptation (recorded in DESIGN.md): the reference implementation
fuses z/x/B/C/dt into ONE in_proj and runs ONE grouped conv over the
concatenated xBC channels — a CUDA-kernel-launch optimization.  Under
GSPMD that fused output dimension mixes tensor-parallel segments
(d_inner, sharded over "model") with replicated segments (B, C, dt), and
the downstream ``split`` of a sharded dimension forces resharding
collectives.  We therefore keep *separate* projections and convs per
stream — mathematically identical (a concat of matmuls), and each factor
gets a clean PartitionSpec.

Decode is the O(1) recurrent step on a (B, H, P, N) state plus rolling
depthwise-conv windows — this is what makes the ``long_500k`` cell
sub-quadratic (state size is independent of context length).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import constrain
from .layers import rms_norm


class SSMConfig(NamedTuple):
    d_model: int
    d_inner: int
    d_state: int
    head_dim: int
    d_conv: int
    chunk: int

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def ssm_init(key, cfg: SSMConfig, dtype) -> dict:
    ks = jax.random.split(key, 8)
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    s_in = 1.0 / np.sqrt(d)
    s_out = 1.0 / np.sqrt(di)
    # A in [1, 16) as in the reference init; dt bias ~ softplus^-1 of U(1e-3, 0.1)
    a = jax.random.uniform(ks[0], (H,), minval=1.0, maxval=16.0)
    dt = jnp.exp(jax.random.uniform(ks[1], (H,),
                                    minval=np.log(1e-3), maxval=np.log(0.1)))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    K = cfg.d_conv
    return {
        "wz": (jax.random.normal(ks[2], (d, di)) * s_in).astype(dtype),
        "wx": (jax.random.normal(ks[3], (d, di)) * s_in).astype(dtype),
        "wB": (jax.random.normal(ks[4], (d, N)) * s_in).astype(dtype),
        "wC": (jax.random.normal(ks[5], (d, N)) * s_in).astype(dtype),
        "wdt": (jax.random.normal(ks[6], (d, H)) * s_in).astype(dtype),
        "out_proj": (jax.random.normal(ks[7], (di, d)) * s_out).astype(dtype),
        "conv_x": jnp.zeros((K, di), dtype).at[-1].set(1.0),
        "conv_bx": jnp.zeros((di,), dtype),
        "conv_B": jnp.zeros((K, N), dtype).at[-1].set(1.0),
        "conv_bB": jnp.zeros((N,), dtype),
        "conv_C": jnp.zeros((K, N), dtype).at[-1].set(1.0),
        "conv_bC": jnp.zeros((N,), dtype),
        "A_log": jnp.log(a).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": jnp.zeros((di,), dtype),
    }


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., l) -> (..., l, l); out[i, j] = sum_{k in (j, i]} x[k],
    -inf above the diagonal (diagonal itself is 0)."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, seg, -jnp.inf)


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d.  x: (B, T, C); w: (K, C).
    ``state``: (B, K-1, C) left context (decode); returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    T = x.shape[1]
    y = sum(xp[:, i:i + T] * w[i] for i in range(K)) + b
    return y, xp[:, -(K - 1):]


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None):
    """Chunked SSD scan.

    x:  (B, T, H, P) f32 head inputs;  dt: (B, T, H) f32 (post-softplus);
    A:  (H,) f32 negative decay rates;  Bm, Cm: (B, T, N) f32 (ngroups=1).
    Returns (y: (B, T, H, P), final_state: (B, H, P, N)).
    """
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-T) % chunk
    if pad:
        # dt = 0 padding is an identity step: decay exp(0·A) = 1 and the
        # injected input dt·B·x = 0, so the final state is unaffected and
        # the padded outputs are sliced off below.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    T_pad = T + pad
    c = T_pad // chunk

    xd = x * dt[..., None]                                  # dt-scaled input
    dA = dt * A[None, None, :]                              # (B, T, H)

    # chunked views
    xc = xd.reshape(Bsz, c, chunk, H, P)
    Bc = Bm.reshape(Bsz, c, chunk, N)
    Cc = Cm.reshape(Bsz, c, chunk, N)
    dAc = dA.reshape(Bsz, c, chunk, H).transpose(0, 3, 1, 2)  # (B, H, c, l)
    dA_cs = jnp.cumsum(dAc, axis=-1)                          # (B, H, c, l)

    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(dAc))                                 # (B, H, c, l, l)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xc)

    # 2. per-chunk end states
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)           # (B, H, c, l)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xc)

    # 3. inter-chunk recurrence on the (c+1)-long chunk-state chain
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), x.dtype)
    states = jnp.concatenate([init_state[:, None], states], axis=1)  # (B, c+1, H, P, N)
    chain = jnp.pad(dA_cs[..., -1], ((0, 0), (0, 0), (1, 0)))        # (B, H, c+1)
    decay_chunk = jnp.exp(_segsum(chain))                            # (B, H, c+1, c+1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    prev_states, final_state = new_states[:, :-1], new_states[:, -1]

    # 4. state -> output contribution
    state_decay = jnp.exp(dA_cs)                                     # (B, H, c, l)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(Bsz, T_pad, H, P)
    return y[:, :T], final_state


def _streams(params: dict, x: jnp.ndarray,
             conv_state: Optional[Tuple] = None):
    """Project + causal-conv + silu the x/B/C streams; project z and dt.
    Returns (z, xs, Bm, Cm, dt_raw, new_conv_state)."""
    z = x @ params["wz"]
    xs = x @ params["wx"]
    Bm = x @ params["wB"]
    Cm = x @ params["wC"]
    dt_raw = x @ params["wdt"]
    cs = conv_state or (None, None, None)
    xs, c_x = _causal_conv(xs, params["conv_x"], params["conv_bx"], cs[0])
    Bm, c_B = _causal_conv(Bm, params["conv_B"], params["conv_bB"], cs[1])
    Cm, c_C = _causal_conv(Cm, params["conv_C"], params["conv_bC"], cs[2])
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)
    xs = constrain(xs, ("batch", None, "model"))
    return z, xs, Bm, Cm, dt_raw, (c_x, c_B, c_C)


def ssm_apply(params: dict, x: jnp.ndarray, cfg: SSMConfig, *,
              norm_eps: float = 1e-6,
              init_state: Optional[jnp.ndarray] = None,
              return_state: bool = False):
    """Full Mamba2 block (train/prefill).  x: (B, T, d_model)."""
    Bsz, T, _ = x.shape
    H, P, N = cfg.n_heads, cfg.head_dim, cfg.d_state
    z, xs, Bm, Cm, dt_raw, _ = _streams(params, x)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(Bsz, T, H, P).astype(jnp.float32)
    y, final_state = ssd_chunked(xh, dt, A, Bm.astype(jnp.float32),
                                 Cm.astype(jnp.float32), cfg.chunk,
                                 init_state=init_state)
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(Bsz, T, cfg.d_inner).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), params["norm"], norm_eps)
    out = y @ params["out_proj"]
    if return_state:
        return out, final_state
    return out


class SSMCache(NamedTuple):
    conv_x: jnp.ndarray   # (B, K-1, d_inner)
    conv_B: jnp.ndarray   # (B, K-1, N)
    conv_C: jnp.ndarray   # (B, K-1, N)
    state: jnp.ndarray    # (B, H, P, N) f32


def ssm_cache_init(batch: int, cfg: SSMConfig, dtype=jnp.bfloat16) -> SSMCache:
    K = cfg.d_conv
    return SSMCache(
        conv_x=jnp.zeros((batch, K - 1, cfg.d_inner), dtype),
        conv_B=jnp.zeros((batch, K - 1, cfg.d_state), dtype),
        conv_C=jnp.zeros((batch, K - 1, cfg.d_state), dtype),
        state=jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                        jnp.float32))


def ssm_prefill_cache(params: dict, x_pre: jnp.ndarray, state: jnp.ndarray,
                      cfg: SSMConfig, dtype=jnp.bfloat16) -> SSMCache:
    """Cache from a prefill: trailing conv windows of the *pre-conv*
    streams + the final SSD state.  x_pre: (B, T, d_model) block input
    (post-ln)."""
    K = cfg.d_conv
    tail = x_pre[:, -(K - 1):]
    pad = (K - 1) - tail.shape[1]
    if pad > 0:
        tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
    return SSMCache(
        conv_x=(tail @ params["wx"]).astype(dtype),
        conv_B=(tail @ params["wB"]).astype(dtype),
        conv_C=(tail @ params["wC"]).astype(dtype),
        state=state)


def ssm_decode_step(params: dict, x: jnp.ndarray, cache: SSMCache,
                    cfg: SSMConfig, *, norm_eps: float = 1e-6):
    """One-token recurrent step.  x: (B, 1, d_model) -> (y, new_cache)."""
    Bsz = x.shape[0]
    H, P, N = cfg.n_heads, cfg.head_dim, cfg.d_state
    z, xs, Bm, Cm, dt_raw, (c_x, c_B, c_C) = _streams(
        params, x, conv_state=(cache.conv_x, cache.conv_B, cache.conv_C))
    xs, Bm, Cm = xs[:, 0], Bm[:, 0], Cm[:, 0]

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B, H)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A[None, :])                       # (B, H)
    xh = xs.reshape(Bsz, H, P).astype(jnp.float32)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm.astype(jnp.float32), xh)
    state = cache.state * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), state)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(Bsz, 1, cfg.d_inner).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), params["norm"], norm_eps)
    out = y @ params["out_proj"]
    return out, SSMCache(conv_x=c_x.astype(cache.conv_x.dtype),
                         conv_B=c_B.astype(cache.conv_B.dtype),
                         conv_C=c_C.astype(cache.conv_C.dtype),
                         state=state)
