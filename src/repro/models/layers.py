"""Core NN layers: RMSNorm, RoPE, GQA attention (global / sliding-window,
softcap, blockwise-streaming), gated MLP.  Pure JAX, pytree params.

Attention is *blockwise with online softmax* (flash-attention schedule in
lax.scan form): the (S, S) score matrix is never materialized, which is
what keeps the 32k-prefill dry-run cells inside per-chip HBM.  Logical
sharding constraints are annotated at the model level (model.py) — these
layers are sharding-agnostic.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True),
            "relu": jax.nn.relu}[name]


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return (jnp.tanh(x / cap) * cap).astype(x.dtype) if cap else x


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    freqs = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                          # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise attention (training / prefill)
# ---------------------------------------------------------------------------

def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool, window: int = 0, cap: float = 0.0,
                        q_block: int = 1024, kv_block: int = 1024,
                        q_offset: int = 0) -> jnp.ndarray:
    """Online-softmax attention.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    ``window`` > 0 restricts to a sliding window (gemma2 local layers).
    ``q_offset``: absolute position of q[0] (decode with cache).
    Returns (B, Sq, Hq, D).
    """
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    n_rep = Hq // Hkv
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)

    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    # pad to block multiples
    pq = (-Sq) % qb
    pk = (-Skv) % kb
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = (Sq + pq) // qb, (Skv + pk) // kb

    scale = 1.0 / np.sqrt(D)
    q = (q * scale).astype(q.dtype)

    # (nq, B, qb, H, D)
    qs = q.reshape(B, nq, qb, Hq, D).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(B, nk, kb, Hq, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kb, Hq, D).transpose(1, 0, 2, 3, 4)

    q_pos_base = jnp.arange(qb)
    k_pos_base = jnp.arange(kb)

    def q_step(_, qi):
        qblk, qidx = qi
        q_pos = q_offset + qidx * qb + q_pos_base           # (qb,)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kidx = ki
            k_pos = kidx * kb + k_pos_base                  # (kb,)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk,
                           preferred_element_type=jnp.float32)
            s = softcap(s, cap) if cap else s
            mask = jnp.ones((qb, kb), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask[None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, None], p, 0.0)
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
            corr = jnp.where(jnp.isfinite(m), corr, 0.0)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hq, qb), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hq, qb), jnp.float32)
        a0 = jnp.zeros((B, Hq, qb, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (ks, vs, jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 2, 1, 3)              # (B, qb, H, D)

    _, outs = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * qb, Hq, D)
    return out[:, :Sq].astype(v.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     cache_len: jnp.ndarray, *, cap: float = 0.0,
                     window: int = 0) -> jnp.ndarray:
    """Single-step attention against a (B, S_max, Hkv, D) cache.

    q: (B, 1, Hq, D); ``cache_len``: scalar or (B,) valid prefix length
    (the new token is already written at position cache_len-1).
    ``window`` > 0 restricts to the trailing sliding window.
    """
    B, _, Hq, D = q.shape
    Hkv = k_cache.shape[2]
    n_rep = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    qh = (q[:, 0] * scale).reshape(B, Hkv, n_rep, D)
    s = jnp.einsum("bgrd,bsgd->bgrs", qh.astype(jnp.float32),
                   k_cache.astype(jnp.float32))
    s = softcap(s, cap) if cap else s
    pos = jnp.arange(k_cache.shape[1])
    clen = jnp.reshape(cache_len, (-1, 1))
    valid = pos[None, :] < clen
    if window:
        valid &= pos[None, :] >= (clen - window)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------

def mlp_apply(params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    h = act_fn(act)(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]


def mlp_init(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * s_out).astype(dtype),
    }
