"""Input stand-ins: ShapeDtypeStruct specs for every model entry point.

The dry-run lowers against these — weak-type-correct, shardable, zero
allocation.  The same functions double as *generators* of synthetic
concrete batches for smoke tests and the end-to-end examples (seeded,
deterministic in (arch, shape, step) — the straggler-mitigation story
depends on any worker being able to regenerate any step's batch).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import SHAPES, ModelConfig, ShapeConfig
from .model import init_cache

PyTree = Any


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs_for(cfg: ModelConfig, batch: int, seq: int,
                    with_targets: bool) -> Dict[str, jax.ShapeDtypeStruct]:
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.inputs_embeds:
        specs["embeds"] = _sds((batch, seq, cfg.d_model), jnp.float32)
    else:
        specs["tokens"] = _sds((batch, seq), jnp.int32)
    if with_targets:
        specs["targets"] = _sds((batch, seq), jnp.int32)
    return specs


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                cache_dtype=jnp.bfloat16) -> Dict[str, PyTree]:
    """Stand-ins for one (arch x shape) cell, keyed by the step function's
    kwargs:  train -> {batch};  prefill -> {batch};
    decode -> {tokens, cache, cache_len}."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": batch_specs_for(cfg, B, S, with_targets=True)}
    if shape.kind == "prefill":
        return {"batch": batch_specs_for(cfg, B, S, with_targets=False)}
    assert shape.kind == "decode", shape.kind
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, S, dtype=cache_dtype))
    tok = (_sds((B, 1, cfg.d_model), jnp.float32) if cfg.inputs_embeds
           else _sds((B, 1), jnp.int32))
    return {"tokens": tok, "cache": cache,
            "cache_len": _sds((), jnp.int32)}


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, step: int,
                    with_targets: bool = True) -> Dict[str, jnp.ndarray]:
    """Deterministic synthetic batch for (cfg, step) — see module doc."""
    rng = np.random.default_rng((hash(cfg.arch_id) & 0xFFFF, step))
    out: Dict[str, jnp.ndarray] = {}
    if cfg.inputs_embeds:
        out["embeds"] = jnp.asarray(
            rng.standard_normal((batch, seq, cfg.d_model), dtype=np.float32))
    else:
        toks = rng.integers(0, cfg.vocab, size=(batch, seq + 1), dtype=np.int64)
        out["tokens"] = jnp.asarray(toks[:, :-1], jnp.int32)
        if with_targets:
            out["targets"] = jnp.asarray(toks[:, 1:], jnp.int32)
        return out
    if with_targets:
        out["targets"] = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(batch, seq), dtype=np.int64),
            jnp.int32)
    return out
