"""Unified model configuration covering all assigned architecture families.

One ``ModelConfig`` describes a stack of ``num_layers`` blocks built from a
repeating *unit* of ``period`` consecutive layers (gemma2's local/global
alternation is period=2; most archs are period=1).  Mixer per position in
the unit: global attention, sliding-window attention, or — when ``ssm`` is
set — a Mamba2 SSD block (optionally interleaved with a *shared* attention
block every ``shared_attn_every`` layers, the Zamba2 scheme).  The MLP is
dense or MoE (shared + routed experts, top-k).

Mesh-divisibility padding: dimensions sharded over the 16-wide "model"
axis must divide it.  ``padded()`` records the published (logical) values
and pads heads / experts / vocab upward; the roofline report exposes the
resulting useful-FLOPs ratio so the padding cost is visible rather than
hidden.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                      # dense | moe | audio | vlm | hybrid | ssm
    num_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv: int = 0
    head_dim: int = 0
    d_ff: int = 0
    period: int = 1
    attn_kinds: Tuple[str, ...] = ("global",)   # per unit position
    attn_impl: str = "flash"     # "flash" (custom-vjp bwd) | "ref" (naive bwd)
    decode_kv_shard: str = "heads"  # "seq": seq-parallel decode cache (P9)
    window: int = 4096
    softcap_attn: float = 0.0
    softcap_final: float = 0.0
    causal: bool = True
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25   # E/top_k => lossless (no token drops)
    # SSM (Mamba2 SSD)
    ssm: bool = False
    d_state: int = 0
    ssm_head_dim: int = 64
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256
    shared_attn_every: int = 0       # zamba2: shared attn block cadence
    # io / misc
    tie_embeddings: bool = True
    inputs_embeds: bool = False      # hubert-style: frontend supplies embeds
    norm_eps: float = 1e-6
    post_norms: bool = False         # gemma2: extra post-sublayer norms
    act: str = "silu"
    embed_scale: bool = False        # gemma2 scales embeddings by sqrt(d)
    # dtypes
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # bookkeeping: published values that were padded for the mesh
    logical: Tuple[Tuple[str, int], ...] = ()

    # ------------------------------------------------------------------
    @property
    def n_units(self) -> int:
        assert self.num_layers % self.period == 0, (self.num_layers, self.period)
        return self.num_layers // self.period

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def padded(self, model_axis: int = 16) -> "ModelConfig":
        """Pad mesh-sharded dims to divisibility; record originals."""
        changes: Dict[str, int] = {}
        upd: Dict[str, object] = {}
        if self.n_heads and self.n_heads % model_axis:
            changes["n_heads"] = self.n_heads
            upd["n_heads"] = _ceil_to(self.n_heads, model_axis)
        if (self.n_kv and self.n_kv % model_axis
                and self.decode_kv_shard != "seq"):
            # KV heads must divide the TP axis for head-sharded caches; the
            # padding waste (e.g. yi-9b kv 4 -> 16) is visible in the
            # useful-FLOPs ratio.  §Perf P9 removes the need: archs with
            # decode_kv_shard="seq" keep their true KV count and shard the
            # decode cache over the sequence axis instead.
            changes["n_kv"] = self.n_kv
            upd["n_kv"] = _ceil_to(self.n_kv, model_axis)
        if self.vocab % 128:
            changes["vocab"] = self.vocab
            upd["vocab"] = _ceil_to(self.vocab, 128)
        if self.n_experts and self.n_experts % model_axis:
            changes["n_experts"] = self.n_experts
            upd["n_experts"] = _ceil_to(self.n_experts, model_axis)
        if not changes:
            return self
        upd["logical"] = tuple(changes.items())
        return dataclasses.replace(self, **upd)

    # parameter counts (for 6·N·D roofline bookkeeping) ----------------
    def param_count(self, active_only: bool = False) -> int:
        d, L = self.d_model, self.num_layers
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.ssm:
            di, ns = self.d_inner, self.d_state
            nh = self.n_ssm_heads
            # in_proj: d -> 2*di + 2*groups*ns + nh (z, x, B, C, dt)
            per_layer += d * (2 * di + 2 * ns + nh)
            per_layer += di * d                      # out_proj
            per_layer += self.d_conv * (di + 2 * ns)  # conv
            per_layer += 3 * nh                      # A_log, D, dt_bias
            per_layer += d                           # norm
            if self.shared_attn_every:
                # shared attn block params counted once below
                pass
        else:
            hd = self.head_dim
            per_layer += d * (self.n_heads + 2 * self.n_kv) * hd  # qkv
            per_layer += self.n_heads * hd * d                    # o
            per_layer += 2 * d                                    # norms
            if self.post_norms:
                per_layer += 2 * d
        if self.n_experts:
            e_ff = self.moe_d_ff
            routed = self.n_experts * 3 * d * e_ff
            shared = self.n_shared * 3 * d * e_ff
            router = d * self.n_experts
            if active_only:
                routed = self.top_k * 3 * d * e_ff
            per_layer += routed + shared + router
        elif self.d_ff and not self.ssm:
            per_layer += 3 * d * self.d_ff
        total += per_layer * L
        if self.ssm and self.shared_attn_every:
            hd = self.head_dim or (d // max(self.n_heads, 1))
            total += d * (self.n_heads + 2 * self.n_kv) * hd + self.n_heads * hd * d
            total += 3 * d * (self.d_ff or 4 * d)
        total += d  # final norm
        return int(total)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One cell of the assigned input-shape grid."""
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
