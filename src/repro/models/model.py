"""Unified model: every assigned architecture is one ``ModelConfig``
interpreted by the same apply functions.

Structure: an embedding (or stub-frontend embeds), ``n_units`` repeating
*units* executed under one ``lax.scan`` (compact HLO, bounded compile
time at 512 devices), a final norm, and a (tied) LM head.  A unit is
``period`` consecutive layers — attention (global or sliding-window) or
Mamba2 SSD — each followed by a dense-MLP or MoE mixer; Zamba2-style
hybrids additionally run a *shared* attention block (same params every
invocation, captured as a scan constant) at the end of each unit.

Three entry points per the assigned shape grid:
  * ``loss_fn``        — train_* shapes (next-token CE, full sequence);
  * ``prefill``        — prefill_* shapes (forward + emit KV/SSM caches);
  * ``decode_step``    — decode_* / long_* shapes (1 token, cache update).

Sharding: activations carry ``constrain`` annotations against the global
mesh (no-ops on CPU smoke tests); parameters get their PartitionSpecs
from ``repro.distributed.sharding`` at jit boundary — these functions are
mesh-agnostic.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import constrain, get_global_mesh
from .config import ModelConfig
from .flash import flash_attention
from .layers import (apply_rope, blockwise_attention, decode_attention,
                     mlp_apply, mlp_init, rms_norm, softcap)
from .moe import moe_apply, moe_init
from .ssm import (SSMCache, SSMConfig, ssm_apply, ssm_cache_init,
                  ssm_decode_step, ssm_init, ssm_prefill_cache)

PyTree = Any


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def ssm_cfg(cfg: ModelConfig) -> SSMConfig:
    return SSMConfig(d_model=cfg.d_model, d_inner=cfg.d_inner,
                     d_state=cfg.d_state, head_dim=cfg.ssm_head_dim,
                     d_conv=cfg.d_conv, chunk=cfg.chunk)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _attn_layer_init(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 6)
    d, H, Kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    s = 1.0 / np.sqrt(d)
    so = 1.0 / np.sqrt(H * hd)
    p = {
        "ln1": jnp.zeros((d,), dtype),
        "wq": (jax.random.normal(ks[0], (d, H, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, Kv, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, Kv, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (H, hd, d)) * so).astype(dtype),
        "ln2": jnp.zeros((d,), dtype),
    }
    if cfg.n_experts:
        p["moe"] = moe_init(ks[4], d, cfg.n_experts, cfg.moe_d_ff,
                            cfg.n_shared, dtype)
    else:
        p["mlp"] = mlp_init(ks[4], d, cfg.d_ff, dtype)
    if cfg.post_norms:
        p["post_ln1"] = jnp.zeros((d,), dtype)
        p["post_ln2"] = jnp.zeros((d,), dtype)
    return p


def _ssm_layer_init(key, cfg: ModelConfig, dtype) -> dict:
    p = {"ln1": jnp.zeros((cfg.d_model,), dtype),
         "ssm": ssm_init(key, ssm_cfg(cfg), dtype)}
    return p


def _unit_init(key, cfg: ModelConfig, dtype) -> dict:
    keys = jax.random.split(key, cfg.period)
    unit = {}
    for pos in range(cfg.period):
        if cfg.ssm:
            unit[f"l{pos}"] = _ssm_layer_init(keys[pos], cfg, dtype)
        else:
            unit[f"l{pos}"] = _attn_layer_init(keys[pos], cfg, dtype)
    return unit


def init_params(key, cfg: ModelConfig) -> PyTree:
    dtype = _dtype(cfg.param_dtype)
    k_embed, k_units, k_shared, k_head = jax.random.split(key, 4)
    params: Dict[str, PyTree] = {}
    if not cfg.inputs_embeds:
        params["embed"] = (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model))
                           * 1.0).astype(dtype)
    unit_keys = jax.random.split(k_units, cfg.n_units)
    params["units"] = jax.vmap(
        lambda k: _unit_init(k, cfg, dtype))(unit_keys)
    if cfg.ssm and cfg.shared_attn_every:
        params["shared"] = _attn_layer_init(k_shared, cfg, dtype)
    params["final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if not cfg.tie_embeddings or cfg.inputs_embeds:
        params["lm_head"] = (jax.random.normal(k_head, (cfg.d_model, cfg.vocab))
                             / np.sqrt(cfg.d_model)).astype(dtype)
    return params


def abstract_params(cfg: ModelConfig) -> PyTree:
    """ShapeDtypeStruct pytree — what the dry-run lowers against (no
    allocation; the full configs are never materialized on this host)."""
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _project_qkv(p: dict, h: jnp.ndarray):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    return (constrain(q, ("batch", None, "model", None)),
            constrain(k, ("batch", None, "model", None)),
            constrain(v, ("batch", None, "model", None)))


def _moe_dispatch(moe_params: dict, h: jnp.ndarray, cfg: ModelConfig,
                  moe_groups: int) -> jnp.ndarray:
    """Pick the MoE path: explicit shard_map expert parallelism when a
    "model" mesh axis exists (production), GSPMD-local dispatch otherwise
    (single host / smoke tests)."""
    mesh = get_global_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        from .moe_sharded import moe_apply_sharded
        return moe_apply_sharded(moe_params, h, mesh, top_k=cfg.top_k,
                                 act=cfg.act,
                                 capacity_factor=cfg.capacity_factor)
    return moe_apply(moe_params, h, top_k=cfg.top_k, act=cfg.act,
                     num_groups=moe_groups,
                     capacity_factor=cfg.capacity_factor)


def _attn_layer(p: dict, x: jnp.ndarray, cfg: ModelConfig, kind: str, *,
                positions: jnp.ndarray, moe_groups: int,
                emit_cache: bool = False):
    window = cfg.window if kind == "local" else 0
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(p, h)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    attn_fn = (flash_attention if cfg.attn_impl == "flash"
               else blockwise_attention)
    attn = attn_fn(q, k, v, causal=cfg.causal, window=window,
                   cap=cfg.softcap_attn)
    out = jnp.einsum("bshk,hkd->bsd", attn, p["wo"])
    if cfg.post_norms:
        out = rms_norm(out, p["post_ln1"], cfg.norm_eps)
    x = x + out
    x = constrain(x, ("batch", None, None))

    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        m = _moe_dispatch(p["moe"], h2, cfg, moe_groups)
    else:
        m = mlp_apply(p["mlp"], h2, cfg.act)
    if cfg.post_norms:
        m = rms_norm(m, p["post_ln2"], cfg.norm_eps)
    x = x + m
    x = constrain(x, ("batch", None, None))
    cache = (k, v) if emit_cache else None
    return x, cache


def _attn_layer_decode(p: dict, x: jnp.ndarray, cfg: ModelConfig, kind: str, *,
                       cache: Tuple[jnp.ndarray, jnp.ndarray],
                       cache_len: jnp.ndarray, moe_groups: int):
    """One-token attention layer against a (B, S_cache, Kv, hd) cache pair.

    Sliding-window ("local") layers use a ROLLING cache of width
    ``min(window, s_max)``: key at absolute position p lives in slot
    p % W, so the buffer always holds exactly the attention window —
    §Perf P4 (halves gemma2's decode_32k cache bytes).  Softmax is
    permutation-invariant over keys, so slot order is irrelevant; RoPE
    is applied at absolute positions before caching.
    """
    k_cache, v_cache = cache
    W = k_cache.shape[1]
    rolling = kind == "local" and W <= cfg.window
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(p, h)
    pos = jnp.reshape(cache_len, (1,))              # position of the new token
    q = apply_rope(q, pos[None, :], cfg.rope_theta)
    k = apply_rope(k, pos[None, :], cfg.rope_theta)
    mesh = get_global_mesh()
    if (not rolling and cfg.decode_kv_shard == "seq" and mesh is not None
            and "model" in mesh.axis_names):
        from .decode_sp import decode_attention_seq_sharded
        attn, k_cache, v_cache = decode_attention_seq_sharded(
            q, k, v, k_cache, v_cache, cache_len, mesh,
            cap=cfg.softcap_attn)
        return _attn_decode_tail(p, x, cfg, attn, moe_groups), (k_cache,
                                                                v_cache)
    slot = cache_len % W if rolling else cache_len
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), slot, axis=1)
    if rolling:
        attn = decode_attention(q, k_cache, v_cache,
                                jnp.minimum(cache_len + 1, W),
                                cap=cfg.softcap_attn)
    else:
        attn = decode_attention(q, k_cache, v_cache, cache_len + 1,
                                cap=cfg.softcap_attn,
                                window=cfg.window if kind == "local" else 0)
    return _attn_decode_tail(p, x, cfg, attn, moe_groups), (k_cache, v_cache)


def _attn_decode_tail(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                      attn: jnp.ndarray, moe_groups: int) -> jnp.ndarray:
    out = jnp.einsum("bshk,hkd->bsd", attn, p["wo"])
    if cfg.post_norms:
        out = rms_norm(out, p["post_ln1"], cfg.norm_eps)
    x = x + out
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        m = _moe_dispatch(p["moe"], h2, cfg, moe_groups)
    else:
        m = mlp_apply(p["mlp"], h2, cfg.act)
    if cfg.post_norms:
        m = rms_norm(m, p["post_ln2"], cfg.norm_eps)
    return x + m


def _ssm_layer(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
               emit_cache: bool = False):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if emit_cache:
        out, state = ssm_apply(p["ssm"], h, ssm_cfg(cfg),
                               norm_eps=cfg.norm_eps, return_state=True)
        x = x + out
        return constrain(x, ("batch", None, None)), state
    out = ssm_apply(p["ssm"], h, ssm_cfg(cfg), norm_eps=cfg.norm_eps)
    x = x + out
    return constrain(x, ("batch", None, None)), None


def _ssm_layer_decode(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                      cache: SSMCache):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    out, new_cache = ssm_decode_step(p["ssm"], h, cache, ssm_cfg(cfg),
                                     norm_eps=cfg.norm_eps)
    return x + out, new_cache


def _layer_kind(cfg: ModelConfig, pos: int) -> str:
    if cfg.ssm:
        return "ssm"
    return cfg.attn_kinds[pos % len(cfg.attn_kinds)]


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _compute_dtype_of(params: PyTree):
    """The residual-stream dtype follows the (possibly bf16-cast) params —
    callers control precision via ``train.steps.cast_for_compute``."""
    ref = params["embed"] if "embed" in params else params["lm_head"]
    return ref.dtype


def embed_inputs(params: PyTree, cfg: ModelConfig, batch: Dict) -> jnp.ndarray:
    dtype = _compute_dtype_of(params)
    if cfg.inputs_embeds:
        x = batch["embeds"].astype(dtype)
    else:
        x = params["embed"][batch["tokens"]].astype(dtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return constrain(x, ("batch", None, None))


def _lm_logits(params: PyTree, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if "lm_head" in params:
        logits = x @ params["lm_head"]
    else:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    logits = softcap(logits.astype(jnp.float32), cfg.softcap_final)
    return constrain(logits, ("batch", None, "model"))


def forward(params: PyTree, cfg: ModelConfig, batch: Dict, *,
            moe_groups: int = 1, remat: bool = False) -> jnp.ndarray:
    """Full-sequence forward -> (B, S, vocab) f32 logits."""
    x = embed_inputs(params, cfg, batch)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def unit_fn(h, unit):
        for pos in range(cfg.period):
            p = unit[f"l{pos}"]
            kind = _layer_kind(cfg, pos)
            if kind == "ssm":
                h, _ = _ssm_layer(p, h, cfg)
            else:
                h, _ = _attn_layer(p, h, cfg, kind, positions=positions,
                                   moe_groups=moe_groups)
        if cfg.ssm and cfg.shared_attn_every:
            h, _ = _attn_layer(params["shared"], h, cfg, "global",
                               positions=positions, moe_groups=moe_groups)
        return h, None

    if remat:
        unit_fn = jax.checkpoint(unit_fn, prevent_cse=False)
    x, _ = jax.lax.scan(unit_fn, x, params["units"])
    return _lm_logits(params, cfg, x)


def loss_fn(params: PyTree, cfg: ModelConfig, batch: Dict, *,
            moe_groups: int = 1, remat: bool = False) -> jnp.ndarray:
    """Mean next-token (or frame-label) cross entropy.

    LM batches: {"tokens" (B,S), "targets" (B,S)} — targets are the
    pipeline-shifted next tokens; positions with target < 0 are masked.
    Frontend-stub batches: {"embeds" (B,S,d), "targets" (B,S)}.
    """
    logits = forward(params, cfg, batch, moe_groups=moe_groups, remat=remat)
    targets = batch["targets"]
    mask = (targets >= 0).astype(jnp.float32)
    t_safe = jnp.maximum(targets, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, t_safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16) -> PyTree:
    """Empty per-unit cache pytree, stacked (n_units, ...) for the scan."""
    def one_unit(_):
        unit = {}
        for pos in range(cfg.period):
            if cfg.ssm:
                unit[f"l{pos}"] = ssm_cache_init(batch, ssm_cfg(cfg), dtype)
            else:
                # local layers: rolling window cache (§Perf P4)
                s_c = (min(cfg.window, s_max)
                       if _layer_kind(cfg, pos) == "local" else s_max)
                kv = jnp.zeros((batch, s_c, cfg.n_kv, cfg.head_dim), dtype)
                unit[f"l{pos}"] = (kv, kv)
        if cfg.ssm and cfg.shared_attn_every:
            kv = jnp.zeros((batch, s_max, cfg.n_kv, cfg.head_dim), dtype)
            unit["shared"] = (kv, kv)
        return unit
    return jax.vmap(one_unit)(jnp.arange(cfg.n_units))


def prefill(params: PyTree, cfg: ModelConfig, batch: Dict, *,
            s_max: Optional[int] = None, moe_groups: int = 1,
            cache_dtype=jnp.bfloat16):
    """Forward + emit caches.  Returns (last-position logits, cache,
    cache_len)."""
    x = embed_inputs(params, cfg, batch)
    B, S = x.shape[:2]
    s_max = s_max or S
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def pad_kv(kv, kind="global"):
        k, v = kv
        if kind == "local" and min(cfg.window, s_max) < s_max:
            # rolling cache: keep the last W keys, each at slot p % W
            W = min(cfg.window, s_max)
            lo = max(S - W, 0)
            p = jnp.arange(lo, S)
            buf_k = jnp.zeros((k.shape[0], W) + k.shape[2:], cache_dtype)
            buf_v = jnp.zeros_like(buf_k)
            buf_k = buf_k.at[:, p % W].set(k[:, lo:S].astype(cache_dtype))
            buf_v = buf_v.at[:, p % W].set(v[:, lo:S].astype(cache_dtype))
            return (buf_k, buf_v)
        if s_max > S:
            pad = [(0, 0), (0, s_max - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return (k.astype(cache_dtype), v.astype(cache_dtype))

    def unit_fn(h, unit):
        caches = {}
        for pos in range(cfg.period):
            p = unit[f"l{pos}"]
            kind = _layer_kind(cfg, pos)
            if kind == "ssm":
                hp = rms_norm(h, p["ln1"], cfg.norm_eps)
                out, state = ssm_apply(p["ssm"], hp, ssm_cfg(cfg),
                                       norm_eps=cfg.norm_eps, return_state=True)
                h = h + out
                caches[f"l{pos}"] = ssm_prefill_cache(
                    p["ssm"], hp, state, ssm_cfg(cfg), dtype=cache_dtype)
            else:
                h, kv = _attn_layer(p, h, cfg, kind, positions=positions,
                                    moe_groups=moe_groups, emit_cache=True)
                caches[f"l{pos}"] = pad_kv(kv, kind)
        if cfg.ssm and cfg.shared_attn_every:
            h, kv = _attn_layer(params["shared"], h, cfg, "global",
                                positions=positions, moe_groups=moe_groups,
                                emit_cache=True)
            caches["shared"] = pad_kv(kv)
        return h, caches

    x, cache = jax.lax.scan(unit_fn, x, params["units"])
    logits = _lm_logits(params, cfg, x[:, -1:])
    return logits[:, 0], cache, jnp.int32(S)


def decode_step(params: PyTree, cfg: ModelConfig, tokens: jnp.ndarray,
                cache: PyTree, cache_len: jnp.ndarray, *,
                moe_groups: int = 1):
    """One decode step.  tokens: (B, 1) int32 (or embeds (B, 1, d)).
    Returns (logits (B, vocab) f32, new_cache)."""
    batch = {"tokens": tokens} if not cfg.inputs_embeds else {"embeds": tokens}
    x = embed_inputs(params, cfg, batch)

    def unit_fn(h, xs):
        unit, ucache = xs
        new_cache = {}
        for pos in range(cfg.period):
            p = unit[f"l{pos}"]
            kind = _layer_kind(cfg, pos)
            if kind == "ssm":
                h, nc = _ssm_layer_decode(p, h, cfg, cache=ucache[f"l{pos}"])
            else:
                h, nc = _attn_layer_decode(p, h, cfg, kind,
                                           cache=ucache[f"l{pos}"],
                                           cache_len=cache_len,
                                           moe_groups=moe_groups)
            new_cache[f"l{pos}"] = nc
        if cfg.ssm and cfg.shared_attn_every:
            h, nc = _attn_layer_decode(params["shared"], h, cfg, "global",
                                       cache=ucache["shared"],
                                       cache_len=cache_len,
                                       moe_groups=moe_groups)
            new_cache["shared"] = nc
        return h, new_cache

    x, new_cache = jax.lax.scan(unit_fn, x, (params["units"], cache))
    logits = _lm_logits(params, cfg, x)
    return logits[:, 0], new_cache
