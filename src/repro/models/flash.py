"""Flash-style attention with a custom-VJP backward (pure JAX).

The reference ``blockwise_attention`` has a flash *forward* (online
softmax, no S x S materialization) but a naive *backward*: jax's autodiff
of the kv-scan stashes every (q-block x kv-block) probability tile as a
scan residual — for a 4k-sequence layer that is gigabytes of f32 traffic
per layer (measured in the dry-run HLO; see EXPERIMENTS.md §Perf).

This module implements the FlashAttention-2 backward: save only
(q, k, v, out, lse); recompute probability tiles blockwise in two O(S)
-memory passes (dq pass: scan q blocks; dk/dv pass: scan kv blocks).
Logit softcap (gemma2) is differentiated through exactly:
d tanh = 1 - tanh^2 recomputed per tile.

Numerics: tiles and accumulators are f32; inputs/outputs keep the model
compute dtype.  Equality with the reference path is asserted to ~1e-5 in
tests/test_flash.py (values AND grads, causal x window x cap x GQA).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _repeat_kv


def _mask_tile(q_pos, k_pos, causal: bool, window: int):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


# probability tiles: f32 = exact (tests assert 5e-4 grad equality);
# bf16 halves the tile HBM traffic that XLA spills between the two
# attention matmuls — §Perf P3 measures the delta.  The running max /
# lse statistics stay f32 in either mode.
TILE_DTYPE = jnp.float32


def set_tile_dtype(dtype) -> None:
    global TILE_DTYPE
    TILE_DTYPE = dtype


def _fwd_blocks(q, k, v, *, causal, window, cap, qb, kb, q_offset):
    """Padded-shape flash forward.  q: (B, Sq, H, D) (pre-scaled);
    returns (out (B, Sq, H, D) f32, lse (B, H, Sq) f32)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    nq, nk = Sq // qb, Skv // kb
    qs = q.reshape(B, nq, qb, H, D).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(B, nk, kb, H, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kb, H, D).transpose(1, 0, 2, 3, 4)
    q_pos_base = jnp.arange(qb)
    k_pos_base = jnp.arange(kb)

    def q_step(_, qi):
        qblk, qidx = qi
        q_pos = q_offset + qidx * qb + q_pos_base

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kidx = ki
            k_pos = kidx * kb + k_pos_base
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk,
                           preferred_element_type=jnp.float32)
            if cap:
                s = jnp.tanh(s / cap) * cap
            mask = _mask_tile(q_pos, k_pos, causal, window)
            s = jnp.where(mask[None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.where(mask[None, None], jnp.exp(s - m_safe[..., None]), 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(TILE_DTYPE),
                vblk.astype(TILE_DTYPE),
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, qb), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, qb), jnp.float32)
        a0 = jnp.zeros((B, H, qb, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (ks, vs, jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = jnp.where(jnp.isfinite(m), m + jnp.log(jnp.maximum(l, 1e-30)),
                        -jnp.inf)
        return None, (out.transpose(0, 2, 1, 3), lse)   # (B, qb, H, D)

    _, (outs, lses) = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * qb, H, D)
    lse = lses.transpose(1, 2, 0, 3).reshape(B, H, nq * qb)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, cap, qb, kb, q_offset):
    out, _ = _fwd_blocks(q, k, v, causal=causal, window=window, cap=cap,
                         qb=qb, kb=kb, q_offset=q_offset)
    return out


def _flash_fwd(q, k, v, causal, window, cap, qb, kb, q_offset):
    out, lse = _fwd_blocks(q, k, v, causal=causal, window=window, cap=cap,
                           qb=qb, kb=kb, q_offset=q_offset)
    return out, (q, k, v, out, lse)


def _tile(s_raw, mask, lse_blk, cap):
    """Recompute (p, dtanh) for one tile from raw scores + row lse."""
    if cap:
        t = jnp.tanh(s_raw / cap)
        s_c = t * cap
        dt = 1.0 - t * t
    else:
        s_c = s_raw
        dt = None
    lse_safe = jnp.where(jnp.isfinite(lse_blk), lse_blk, 0.0)
    p = jnp.where(mask[None, None], jnp.exp(s_c - lse_safe[..., None]), 0.0)
    p = jnp.where(jnp.isfinite(lse_blk)[..., None], p, 0.0)
    return p, dt


def _flash_bwd(causal, window, cap, qb, kb, q_offset, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    nq, nk = Sq // qb, Skv // kb
    in_dtype = q.dtype

    qs = q.reshape(B, nq, qb, H, D).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(B, nk, kb, H, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kb, H, D).transpose(1, 0, 2, 3, 4)
    dos = dout.reshape(B, nq, qb, H, D).transpose(1, 0, 2, 3, 4)
    outs = out.reshape(B, nq, qb, H, D).transpose(1, 0, 2, 3, 4)
    lses = lse.reshape(B, H, nq, qb).transpose(2, 0, 1, 3)   # (nq, B, H, qb)
    # Delta_i = rowsum(dout * out)   (nq, B, H, qb)
    deltas = jnp.einsum("nbqhd,nbqhd->nbhq", dos.astype(jnp.float32),
                        outs.astype(jnp.float32))
    q_pos_base = jnp.arange(qb)
    k_pos_base = jnp.arange(kb)

    # ---- pass 1: dq (outer scan over q blocks) -------------------------
    def dq_step(_, qi):
        qblk, doblk, delta, lse_blk, qidx = qi
        q_pos = q_offset + qidx * qb + q_pos_base

        def kv_step(dq_acc, ki):
            kblk, vblk, kidx = ki
            k_pos = kidx * kb + k_pos_base
            s_raw = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk,
                               preferred_element_type=jnp.float32)
            mask = _mask_tile(q_pos, k_pos, causal, window)
            p, dt = _tile(s_raw, mask, lse_blk, cap)
            dp = jnp.einsum("bqhd,bkhd->bhqk", doblk.astype(jnp.float32),
                            vblk.astype(jnp.float32))
            ds = p * (dp - delta[..., None])
            if dt is not None:
                ds = ds * dt
            dq_acc = dq_acc + jnp.einsum(
                "bhqk,bkhd->bqhd", ds.astype(TILE_DTYPE),
                kblk.astype(TILE_DTYPE),
                preferred_element_type=jnp.float32)
            return dq_acc, None

        dq0 = jnp.zeros((B, qb, H, D), jnp.float32)
        dq_blk, _ = jax.lax.scan(kv_step, dq0, (ks, vs, jnp.arange(nk)))
        return None, dq_blk

    _, dqs = jax.lax.scan(dq_step, None, (qs, dos, deltas, lses,
                                          jnp.arange(nq)))
    dq = dqs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D)

    # ---- pass 2: dk, dv (outer scan over kv blocks) ---------------------
    def dkv_step(_, ki):
        kblk, vblk, kidx = ki
        k_pos = kidx * kb + k_pos_base

        def q_step(carry, qi):
            dk_acc, dv_acc = carry
            qblk, doblk, delta, lse_blk, qidx = qi
            q_pos = q_offset + qidx * qb + q_pos_base
            s_raw = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk,
                               preferred_element_type=jnp.float32)
            mask = _mask_tile(q_pos, k_pos, causal, window)
            p, dt = _tile(s_raw, mask, lse_blk, cap)
            dv_acc = dv_acc + jnp.einsum(
                "bhqk,bqhd->bkhd", p.astype(TILE_DTYPE),
                doblk.astype(TILE_DTYPE),
                preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqhd,bkhd->bhqk", doblk.astype(jnp.float32),
                            vblk.astype(jnp.float32))
            ds = p * (dp - delta[..., None])
            if dt is not None:
                ds = ds * dt
            dk_acc = dk_acc + jnp.einsum(
                "bhqk,bqhd->bkhd", ds.astype(TILE_DTYPE),
                qblk.astype(TILE_DTYPE),
                preferred_element_type=jnp.float32)
            return (dk_acc, dv_acc), None

        z = jnp.zeros((B, kb, H, D), jnp.float32)
        (dk_blk, dv_blk), _ = jax.lax.scan(
            q_step, (z, z), (qs, dos, deltas, lses, jnp.arange(nq)))
        return None, (dk_blk, dv_blk)

    _, (dks, dvs) = jax.lax.scan(dkv_step, None, (ks, vs, jnp.arange(nk)))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Skv, H, D)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Skv, H, D)
    return dq.astype(in_dtype), dk.astype(in_dtype), dv.astype(in_dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool, window: int = 0, cap: float = 0.0,
                    q_block: int = 1024, kv_block: int = 1024,
                    q_offset: int = 0) -> jnp.ndarray:
    """Drop-in replacement for ``layers.blockwise_attention`` with an
    O(S)-memory custom backward.  Same signature and semantics."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    k = _repeat_kv(k, Hq // Hkv)
    v = _repeat_kv(v, Hq // Hkv)
    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    pq, pk = (-Sq) % qb, (-Skv) % kb
    scale = jnp.asarray(1.0 / np.sqrt(D), q.dtype)
    q = q * scale
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        # pad keys *out of the causal window* so padded kv never attends:
        # causal masking handles it because padded q_pos >= Skv region is
        # sliced off and padded k_pos > any real q_pos when causal; for
        # non-causal we mask via window... simplest: pad then rely on the
        # -inf masking of out-of-range positions below.
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    if pk and not causal:
        raise ValueError("non-causal flash path requires kv length to be a "
                         "multiple of kv_block")
    out = _flash(q, k, v, causal, window, cap, qb, kb, q_offset)
    return out[:, :Sq].astype(v.dtype)
