"""Sequence-parallel decode attention (§Perf P9).

GQA models with few KV heads (yi-9b kv=4, command-r/chameleon kv=8)
cannot head-shard their KV caches across a 16-wide "model" axis; the
baseline pads KV heads to 16, inflating the decode_32k cache 2–4x past
v5e HBM (20–24 GB/device measured).  This module shards the cache over
the SEQUENCE axis instead: each model rank holds an S/16 slice at its
true KV-head count, computes partial attention over its slice, and the
ranks combine with the standard distributed softmax
(global-max correction + psum of numerator/denominator) — one tiny
collective pair per layer, O(B·H·D).

The new token's K/V is written by whichever rank owns slot
``cache_len`` (the others blend-through), so the cache stays consistent
without any shuffle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .layers import softcap as _softcap


def _batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def decode_attention_seq_sharded(q, k_new, v_new, k_cache, v_cache,
                                 cache_len, mesh: Mesh, *,
                                 cap: float = 0.0):
    """q: (B, 1, Hq, D); k_new/v_new: (B, 1, Kv, D); caches
    (B, S, Kv, D) sharded (batch, 'model', None, None).  Returns
    (attn (B, 1, Hq, D), new_k_cache, new_v_cache)."""
    batch = _batch_axes(mesh)

    def body(q_loc, kn, vn, kc, vc, clen):
        B, S_loc, Kv, D = kc.shape
        Hq = q_loc.shape[2]
        rep = Hq // Kv
        rank = jax.lax.axis_index("model")
        offset = rank * S_loc

        # write the new key/value if this rank owns slot `clen`
        slot = clen - offset
        in_range = (slot >= 0) & (slot < S_loc)
        slot_c = jnp.clip(slot, 0, S_loc - 1)
        cur_k = jax.lax.dynamic_slice_in_dim(kc, slot_c, 1, axis=1)
        cur_v = jax.lax.dynamic_slice_in_dim(vc, slot_c, 1, axis=1)
        blend_k = jnp.where(in_range, kn.astype(kc.dtype), cur_k)
        blend_v = jnp.where(in_range, vn.astype(vc.dtype), cur_v)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, blend_k, slot_c, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, blend_v, slot_c, axis=1)

        # partial attention over the local slice
        scale = 1.0 / np.sqrt(D)
        qh = (q_loc[:, 0] * scale).reshape(B, Kv, rep, D)
        s = jnp.einsum("bgrd,bsgd->bgrs", qh.astype(jnp.float32),
                       kc.astype(jnp.float32))
        s = _softcap(s, cap) if cap else s
        pos = offset + jnp.arange(S_loc)
        valid = pos[None, :] <= jnp.reshape(clen, (-1, 1))
        s = jnp.where(valid[:, None, None, :], s, -jnp.inf)

        m_loc = s.max(axis=-1)
        m_glob = jax.lax.pmax(m_loc, "model")
        m_safe = jnp.where(jnp.isfinite(m_glob), m_glob, 0.0)
        p = jnp.where(valid[:, None, None, :],
                      jnp.exp(s - m_safe[..., None]), 0.0)
        num = jnp.einsum("bgrs,bsgd->bgrd", p, vc.astype(jnp.float32))
        den = p.sum(axis=-1)
        num = jax.lax.psum(num, "model")
        den = jax.lax.psum(den, "model")
        out = num / jnp.maximum(den, 1e-30)[..., None]
        return out.reshape(B, 1, Hq, D).astype(q_loc.dtype), kc, vc

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(batch, None, None, None), P(batch, None, None, None),
                  P(batch, None, None, None),
                  P(batch, "model", None, None),
                  P(batch, "model", None, None), P()),
        out_specs=(P(batch, None, None, None),
                   P(batch, "model", None, None),
                   P(batch, "model", None, None)),
        check_rep=False)
    return fn(q, k_new, v_new, k_cache, v_cache, cache_len)
