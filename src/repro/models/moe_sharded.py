"""Expert-parallel MoE via shard_map — the production dispatch path.

GSPMD partitions einsums beautifully but falls back to
replicate+all-reduce for the data-dependent scatter/gather of MoE
dispatch (measured: ~2.4 GB of collectives per layer on the
granite-moe train cell).  This module sidesteps auto-sharding entirely
for the MoE block with an explicit SPMD formulation:

  * activations are REPLICATED over the "model" axis (Megatron
    convention) and sharded over (pod, data) — so every model rank
    already holds all tokens of its data shard;
  * each model rank owns E/m contiguous experts (weights sharded over
    "model" on E, FSDP over "data" on d — manually all-gathered, whose
    transpose is the ZeRO reduce-scatter);
  * dispatch = LOCAL scatter of the rank's own tokens to its own
    experts — no collective at all;
  * combine = local gather + gate-weighted sum, then ONE psum over
    "model" (25 MB/layer on granite, vs 2.4 GB under auto-sharding) —
    identical in shape and cost to a Megatron MLP's output reduction.

Numerically identical to ``moe.moe_apply`` (same routing, same
capacity-drop policy) — asserted in tests/test_moe_sharded.py.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .layers import act_fn
from .moe import _route


def _batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def moe_apply_sharded(params: dict, x: jnp.ndarray, mesh: Mesh, *,
                      top_k: int, act: str,
                      capacity_factor: float = 1.25) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d) under explicit expert parallelism."""
    E = params["router"].shape[-1]
    m_size = mesh.shape["model"]
    assert E % m_size == 0, (E, m_size)
    e_loc = E // m_size
    has_shared = "shared" in params
    batch = _batch_axes(mesh)

    in_specs = [
        P(batch, None, None),          # x  (replicated over model)
        P("data", None),               # router (d, E)
        P("model", "data", None),      # w_gate (E, d, ff)
        P("model", "data", None),      # w_up
        P("model", None, "data"),      # w_down (E, ff, d)
    ]
    args = [x, params["router"], params["w_gate"], params["w_up"],
            params["w_down"]]
    if has_shared:
        in_specs += [P("data", "model"), P("data", "model"),
                     P("model", "data")]
        args += [params["shared"]["w_gate"], params["shared"]["w_up"],
                 params["shared"]["w_down"]]

    def body(x_loc, router_w, wg, wu, wd, *shared_w):
        # undo FSDP: gather the d-dim shards (transpose = reduce-scatter)
        router_full = jax.lax.all_gather(router_w, "data", axis=0, tiled=True)
        wg_full = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
        wu_full = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
        wd_full = jax.lax.all_gather(wd, "data", axis=2, tiled=True)

        b_loc, s, d = x_loc.shape
        t = b_loc * s
        xt = x_loc.reshape(t, d)
        gates, idx = _route(router_full, xt, top_k)      # (t, k) f32/int

        rank = jax.lax.axis_index("model")
        lo = rank * e_loc
        rel = idx - lo                                   # (t, k)
        sel = (rel >= 0) & (rel < e_loc)
        rel_c = jnp.clip(rel, 0, e_loc - 1).reshape(-1)  # (t*k,)
        sel_f = sel.reshape(-1)

        onehot = (jax.nn.one_hot(rel_c, e_loc, dtype=jnp.int32)
                  * sel_f[:, None].astype(jnp.int32))
        pos = jnp.cumsum(onehot, axis=0) - 1
        pos_own = (pos * onehot).sum(-1)                 # (t*k,)
        cap = max(int(np.ceil(t * top_k / E * capacity_factor)), top_k)
        keep = sel_f & (pos_own < cap)
        dest = jnp.where(keep, rel_c * cap + pos_own, e_loc * cap)

        src = jnp.broadcast_to(xt[:, None, :], (t, top_k, d)).reshape(-1, d)
        buf = jnp.zeros((e_loc * cap + 1, d), x_loc.dtype)
        buf = buf.at[dest].set(src, mode="drop")
        be = buf[:-1].reshape(e_loc, cap, d)

        h = jnp.einsum("ecd,edf->ecf", be, wg_full)
        u = jnp.einsum("ecd,edf->ecf", be, wu_full)
        h = act_fn(act)(h) * u
        o = jnp.einsum("ecf,efd->ecd", h, wd_full).reshape(e_loc * cap, d)
        o = jnp.concatenate([o, jnp.zeros((1, d), o.dtype)], axis=0)

        picked = o[dest]                                  # (t*k, d) local
        w = (gates.reshape(-1) * keep).astype(x_loc.dtype)
        y = (picked * w[:, None]).reshape(t, top_k, d).sum(axis=1)

        if shared_w:
            sg, su, sd = shared_w
            sg_full = jax.lax.all_gather(sg, "data", axis=0, tiled=True)
            su_full = jax.lax.all_gather(su, "data", axis=0, tiled=True)
            sd_full = jax.lax.all_gather(sd, "data", axis=1, tiled=True)
            hs = act_fn(act)(xt @ sg_full) * (xt @ su_full)
            y = y + hs @ sd_full                          # partial over ff

        y = jax.lax.psum(y, "model")
        return y.reshape(b_loc, s, d)

    fn = shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                   out_specs=P(batch, None, None), check_rep=False)
    return fn(*args)
