"""Train / serve step factories — the functions the launcher jits.

``make_train_step``: microbatched gradient accumulation under
``lax.scan`` (donated carry), per-unit remat inside the model scan,
bf16 compute with f32 master params and f32 gradient accumulation.

Mixed-precision / gradient-compression contract (verified in the dry-run
HLO, see EXPERIMENTS.md §Dry-run): parameters are cast to bf16 *inside*
the differentiated function, so the FSDP all-gather (fwd) and its
transpose reduce-scatter (bwd), plus the cross-pod gradient all-reduce,
all carry bf16 — half the collective bytes of an f32 scheme — while the
local accumulation and the AdamW update stay f32.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from ..models import model as M
from ..models.config import ModelConfig
from ..optim.adamw import AdamWState, Hyper, adamw_update

PyTree = Any


def cast_for_compute(params: PyTree, dtype=jnp.bfloat16) -> PyTree:
    """f32 master -> bf16 compute copies (matrices only; norms/scalars and
    integer buffers keep their dtype)."""
    def cast(p):
        if p.dtype == jnp.float32 and p.ndim >= 2:
            return p.astype(dtype)
        return p
    return jax.tree_util.tree_map(cast, params)


def _split_microbatches(batch: Dict, num: int) -> Dict:
    def split(x):
        assert x.shape[0] % num == 0, (x.shape, num)
        return x.reshape((num, x.shape[0] // num) + x.shape[1:])
    return jax.tree_util.tree_map(split, batch)


def make_train_step(cfg: ModelConfig, hyper: Hyper, *,
                    num_microbatches: int = 1, moe_groups: int = 1,
                    remat: bool = True,
                    compute_dtype=jnp.bfloat16) -> Callable:
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)."""

    def loss_of(params_f32, mb):
        params_c = cast_for_compute(params_f32, compute_dtype)
        return M.loss_fn(params_c, cfg, mb, moe_groups=moe_groups,
                         remat=remat)

    def train_step(params: PyTree, opt_state: AdamWState, batch: Dict):
        if num_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            mbs = _split_microbatches(batch, num_microbatches)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc_fn(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_of)(params, mb)
                grad_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            (loss, grads), _ = jax.lax.scan(
                acc_fn, (jnp.float32(0.0), zero), mbs)
            inv = 1.0 / num_microbatches
            loss = loss * inv
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)

        new_params, new_state, metrics = adamw_update(
            grads, opt_state, params, hyper)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, *, moe_groups: int = 1,
                   compute_dtype=jnp.bfloat16) -> Callable:
    def eval_step(params, batch):
        params_c = cast_for_compute(params, compute_dtype)
        return M.loss_fn(params_c, cfg, batch, moe_groups=moe_groups)
    return eval_step


def make_prefill_step(cfg: ModelConfig, *, moe_groups: int = 1,
                      s_max: Optional[int] = None,
                      compute_dtype=jnp.bfloat16) -> Callable:
    def prefill_step(params, batch):
        params_c = cast_for_compute(params, compute_dtype)
        return M.prefill(params_c, cfg, batch, s_max=s_max,
                         moe_groups=moe_groups)
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, moe_groups: int = 1,
                     compute_dtype=jnp.bfloat16) -> Callable:
    """serve_step for the decode_* / long_* cells: one new token against
    a seq_len-deep cache."""
    def decode_step(params, tokens, cache, cache_len):
        params_c = cast_for_compute(params, compute_dtype)
        return M.decode_step(params_c, cfg, tokens, cache, cache_len,
                             moe_groups=moe_groups)
    return decode_step
