"""AdamW + cosine schedule + global-norm clipping, pure JAX pytrees.

Optimizer moments are f32 and live in the same PartitionSpecs as their
parameters (``distributed.sharding.param_specs``), i.e. ZeRO-sharded over
(data, model) and replicated over pod; the update is elementwise so it
adds zero collectives.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray   # () int32
    mu: PyTree          # f32, like params
    nu: PyTree          # f32, like params


class Hyper(NamedTuple):
    base_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params: PyTree) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree_util.tree_map(jnp.copy, zeros))


def abstract_opt_state(params: PyTree) -> AdamWState:
    return jax.eval_shape(adamw_init, params)


def cosine_lr(step: jnp.ndarray, h: Hyper) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / max(h.warmup_steps, 1)
    t = jnp.clip((step - h.warmup_steps)
                 / max(h.total_steps - h.warmup_steps, 1), 0.0, 1.0)
    cos = h.min_lr_frac + (1 - h.min_lr_frac) * 0.5 * (1 + jnp.cos(np.pi * t))
    return h.base_lr * jnp.where(step < h.warmup_steps, warm, cos)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adamw_update(grads: PyTree, state: AdamWState, params: PyTree,
                 h: Hyper) -> Tuple[PyTree, AdamWState, dict]:
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, h.clip_norm)
    step = state.step + 1
    lr = cosine_lr(step, h)
    b1c = 1 - h.b1 ** step.astype(jnp.float32)
    b2c = 1 - h.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = h.b1 * m + (1 - h.b1) * g
        v = h.b2 * v + (1 - h.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + h.eps)
        if p.ndim >= 2:  # decay matrices only (norms/scalars exempt)
            delta = delta + h.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), metrics
