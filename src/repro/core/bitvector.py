"""Succinct bit vector with rank/select — the substrate of every bST layer.

The paper uses Jacobson-style rank/select directories (o(N) auxiliary bits,
O(1) scalar queries) from the SDSL.  Those directory layouts are scalar-ISA
artifacts; on TPU the same role is played by

  * ``rank``   : a gather from a per-word *cumulative popcount* table plus a
                 native ``lax.population_count`` on the residual word, and
  * ``select`` : a vectorized binary search (``searchsorted``) over the same
                 table plus an in-word select done with a 32-lane compare.

Both are O(1)-gather / O(log W)-search per query and fully batched — the
trie traversal issues them for a whole frontier at once.

Space accounting (reported by ``nbits``): N bits of payload + 32·(W+1) bits
of cumulative table = N + N + o(N) for word size 32.  A production TPU
deployment would widen the table blocks to trade the o(N); we keep per-word
cumsums because the dry-run shows the traversal is gather-latency bound,
not capacity bound.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32
_WORD_SHIFT = 5
_WORD_MASK = 31


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BitVector:
    """Packed bit array with rank/select support.

    Attributes:
      words: uint32[W]   — packed payload, LSB-first within each word.
      cum:   int32[W+1]  — exclusive cumulative popcount; ``cum[w]`` is the
             number of set bits strictly before word ``w``.
      length: python int — logical number of bits (static; not traced).
    """

    words: jnp.ndarray
    cum: jnp.ndarray
    length: int

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.words, self.cum), self.length

    @classmethod
    def tree_unflatten(cls, aux, children):
        words, cum = children
        return cls(words=words, cum=cum, length=aux)

    # -- constructors ----------------------------------------------------
    @staticmethod
    def from_bits(bits: np.ndarray) -> "BitVector":
        """Build from a host-side 0/1 array.  Construction is preprocessing
        (index build), so it runs in numpy; queries run in JAX."""
        bits = np.asarray(bits, dtype=np.uint8)
        n = int(bits.shape[0])
        n_words = max(1, (n + WORD_BITS - 1) // WORD_BITS)
        padded = np.zeros(n_words * WORD_BITS, dtype=np.uint8)
        padded[:n] = bits
        lanes = padded.reshape(n_words, WORD_BITS)
        weights = (1 << np.arange(WORD_BITS, dtype=np.uint64)).astype(np.uint64)
        words = (lanes.astype(np.uint64) * weights).sum(axis=1).astype(np.uint32)
        pops = lanes.sum(axis=1).astype(np.int64)
        cum = np.zeros(n_words + 1, dtype=np.int32)
        np.cumsum(pops, out=cum[1:])
        return BitVector(words=jnp.asarray(words), cum=jnp.asarray(cum), length=n)

    # -- metadata ----------------------------------------------------------
    @property
    def total_ones(self) -> jnp.ndarray:
        return self.cum[-1]

    def nbits(self) -> int:
        """Storage cost in bits (payload + rank directory)."""
        return int(self.words.shape[0]) * 32 + int(self.cum.shape[0]) * 32

    # -- queries (all traceable + batched) -------------------------------
    def rank(self, i: jnp.ndarray) -> jnp.ndarray:
        """Number of set bits in positions [0, i) — i.e. exclusive rank.

        ``i`` may be any int array; values are clipped to [0, length].
        """
        i = jnp.clip(jnp.asarray(i, jnp.int32), 0, self.length)
        w = i >> _WORD_SHIFT
        r = i & _WORD_MASK
        base = self.cum[w]
        word = self.words[jnp.minimum(w, self.words.shape[0] - 1)]
        mask = jnp.where(r > 0, (jnp.uint32(1) << r.astype(jnp.uint32)) - 1, jnp.uint32(0))
        partial = jax.lax.population_count(word & mask).astype(jnp.int32)
        # when i lands exactly on length with a partial final word, the clip
        # plus mask arithmetic above already excludes padding bits (they are 0)
        return base + jnp.where(r > 0, partial, 0)

    def select(self, k: jnp.ndarray) -> jnp.ndarray:
        """Position (0-indexed) of the k-th set bit, k being 1-indexed as in
        the paper.  Out-of-range k returns ``length`` (paper: "returns N+1").
        """
        k = jnp.asarray(k, jnp.int32)
        total = self.cum[-1]
        valid = (k >= 1) & (k <= total)
        k_safe = jnp.clip(k, 1, jnp.maximum(total, 1))
        # word containing the k-th one: last w with cum[w] < k
        w = jnp.searchsorted(self.cum, k_safe, side="left") - 1
        w = jnp.clip(w, 0, self.words.shape[0] - 1)
        residual = k_safe - self.cum[w]  # 1-indexed within the word
        word = self.words[w]
        lane = jnp.arange(WORD_BITS, dtype=jnp.uint32)
        if word.ndim > 0:
            lane = lane.reshape((1,) * word.ndim + (WORD_BITS,))
            word_b = word[..., None]
            residual_b = residual[..., None]
        else:
            word_b = word
            residual_b = residual
        bits = (word_b >> lane) & jnp.uint32(1)
        cs = jnp.cumsum(bits.astype(jnp.int32), axis=-1)
        # first lane where the cumulative count reaches the residual
        hit = (cs >= residual_b) & (bits == 1)
        inword = jnp.argmax(hit, axis=-1).astype(jnp.int32)
        pos = (w << _WORD_SHIFT) + inword
        return jnp.where(valid, pos, self.length)

    def select0(self, k: jnp.ndarray) -> jnp.ndarray:
        """Position of the k-th *zero* bit (k 1-indexed); ``length`` if out
        of range.  Used by the LOUDS baseline's unary degree sequences.
        Implemented over the complement cumsum ``32·w − cum[w]``."""
        k = jnp.asarray(k, jnp.int32)
        n_words_ = self.words.shape[0]
        word_idx = jnp.arange(n_words_ + 1, dtype=jnp.int32)
        cum0 = (word_idx << _WORD_SHIFT) - self.cum  # zeros before word w (incl. padding)
        # total zeros within logical length:
        total0 = jnp.int32(self.length) - self.cum[-1]
        valid = (k >= 1) & (k <= total0)
        k_safe = jnp.clip(k, 1, jnp.maximum(total0, 1))
        w = jnp.searchsorted(cum0, k_safe, side="left") - 1
        w = jnp.clip(w, 0, n_words_ - 1)
        residual = k_safe - cum0[w]
        word = ~self.words[w]  # complement: zeros become ones
        lane = jnp.arange(WORD_BITS, dtype=jnp.uint32)
        if word.ndim > 0:
            lane = lane.reshape((1,) * word.ndim + (WORD_BITS,))
            word_b = word[..., None]
            residual_b = residual[..., None]
        else:
            word_b = word
            residual_b = residual
        bits = (word_b >> lane) & jnp.uint32(1)
        cs = jnp.cumsum(bits.astype(jnp.int32), axis=-1)
        hit = (cs >= residual_b) & (bits == 1)
        inword = jnp.argmax(hit, axis=-1).astype(jnp.int32)
        pos = (w << _WORD_SHIFT) + inword
        return jnp.where(valid, pos, self.length)

    def get(self, i: jnp.ndarray) -> jnp.ndarray:
        """Bit at position i (0 for out-of-range)."""
        i = jnp.asarray(i, jnp.int32)
        ok = (i >= 0) & (i < self.length)
        i_safe = jnp.clip(i, 0, self.length - 1 if self.length else 0)
        w = i_safe >> _WORD_SHIFT
        r = (i_safe & _WORD_MASK).astype(jnp.uint32)
        bit = (self.words[w] >> r) & jnp.uint32(1)
        return jnp.where(ok, bit.astype(jnp.int32), 0)


def pack_bits_matrix(bits: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Pack a (n, L) 0/1 matrix row-wise into (n, ceil(L/32)) uint32 words
    plus per-row popcounts.  Host-side helper for the vertical format."""
    bits = np.asarray(bits, dtype=np.uint8)
    n, L = bits.shape
    n_words = (L + WORD_BITS - 1) // WORD_BITS
    padded = np.zeros((n, n_words * WORD_BITS), dtype=np.uint8)
    padded[:, :L] = bits
    lanes = padded.reshape(n, n_words, WORD_BITS)
    weights = (1 << np.arange(WORD_BITS, dtype=np.uint64)).astype(np.uint64)
    words = (lanes.astype(np.uint64) * weights).sum(axis=2).astype(np.uint32)
    return words, lanes.sum(axis=(1, 2)).astype(np.int32)
