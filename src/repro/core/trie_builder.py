"""Host-side trie construction over a b-bit sketch database.

Index build is preprocessing (run once per DB shard, embarrassingly
parallel across the (pod, data) mesh axes), so it runs in numpy; the
queryable encodings it feeds (``bst.py``) are JAX pytrees.

The construction never materializes a pointer trie.  Because sketches are
*fixed-length* strings (the paper's "favorable property"), sorting the
database lexicographically makes every trie level recoverable by prefix
change-detection over the sorted unique rows — an O(n·L) scan, no pointer
chasing, no allocation per node.  Level ``ℓ`` facts derived per scan:

  * ``t[ℓ]``        — number of nodes (distinct length-ℓ prefixes),
  * ``labels[ℓ]``   — edge label from each node to its parent (char ℓ-1),
  * ``parents[ℓ]``  — parent node id at level ℓ-1 (lexicographic ranks),
  * ``node_of_leaf``— each leaf's ancestor id at ℓ (kept only where needed).
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass
class TrieLevels:
    """Raw per-level facts (numpy, host-side)."""

    L: int
    b: int
    n: int                      # database size (with duplicates)
    uniq: np.ndarray            # (t_L, L) unique sketches, lex-sorted
    t: List[int]                # node count per level, t[0] == 1 (root)
    labels: List[np.ndarray]    # labels[ℓ] : (t[ℓ],) uint8, ℓ in 1..L
    parents: List[np.ndarray]   # parents[ℓ]: (t[ℓ],) int64 ids at ℓ-1
    leaf_offsets: np.ndarray    # (t_L+1,) CSR into ids_sorted
    ids_sorted: np.ndarray      # (n,) original ids grouped by leaf
    id_leaf: np.ndarray         # (n,) original id -> leaf index
    node_of_leaf: List[np.ndarray]  # per level ℓ: (t_L,) leaf -> ancestor id

    def first_leaf_of_node(self, level: int) -> np.ndarray:
        """(t[level],) index of the leftmost leaf under each node."""
        nol = self.node_of_leaf[level]
        first = np.zeros(self.t[level], dtype=np.int64)
        # nodes appear in nondecreasing order over leaves; mark boundaries
        boundary = np.concatenate([[True], nol[1:] != nol[:-1]])
        first[nol[boundary]] = np.flatnonzero(boundary)
        return first


def build_trie_levels(sketches: np.ndarray, b: int) -> TrieLevels:
    """Scan a sketch database into per-level trie facts.

    sketches: (n, L) uint8 over Σ=[0, 2^b) (duplicates allowed — they
    share a leaf); returns a host-side ``TrieLevels`` with node counts,
    labels, parents, and leaf maps per level (shapes in the dataclass).
    O(n·L) after the lexicographic sort; no pointer trie is built."""
    sketches = np.ascontiguousarray(np.asarray(sketches, dtype=np.uint8))
    n, L = sketches.shape
    assert sketches.max(initial=0) < (1 << b), "character exceeds alphabet"

    # lexicographic sort of rows (np.lexsort keys: last key is primary)
    order = np.lexsort(tuple(sketches[:, c] for c in range(L - 1, -1, -1)))
    srt = sketches[order]

    # unique rows -> leaves
    if n > 1:
        row_new = np.concatenate([[True], np.any(srt[1:] != srt[:-1], axis=1)])
    else:
        row_new = np.ones(1, dtype=bool)
    leaf_of_row = np.cumsum(row_new) - 1          # (n,)
    uniq = srt[row_new]                            # (t_L, L)
    t_L = uniq.shape[0]

    counts = np.bincount(leaf_of_row, minlength=t_L)
    leaf_offsets = np.zeros(t_L + 1, dtype=np.int64)
    np.cumsum(counts, out=leaf_offsets[1:])
    ids_sorted = order.astype(np.int64)
    id_leaf = np.empty(n, dtype=np.int64)
    id_leaf[order] = leaf_of_row

    # per-level prefix boundaries over unique rows
    t = [1]
    labels: List[np.ndarray] = [np.zeros(0, dtype=np.uint8)]   # pad index 0
    parents: List[np.ndarray] = [np.zeros(0, dtype=np.int64)]
    node_of_leaf: List[np.ndarray] = [np.zeros(t_L, dtype=np.int64)]  # root
    boundary = np.zeros(t_L, dtype=bool)
    boundary[0] = True  # level-0 "prefix" (empty) boundary bookkeeping
    prev_nodes = np.zeros(t_L, dtype=np.int64)    # node id at ℓ-1 per leaf

    for lev in range(1, L + 1):
        col = uniq[:, lev - 1]
        if t_L > 1:
            boundary = boundary | np.concatenate([[True], col[1:] != col[:-1]])
            boundary[0] = True
        # int64: a billion-scale level can exceed 2^31 nodes and the
        # cumsum must not wrap; the queryable encodings downcast to int32
        # at encoding time, after any per-shard split has bounded t.
        nodes = np.cumsum(boundary, dtype=np.int64) - 1  # leaf -> node id at lev
        t_lev = int(nodes[-1]) + 1
        first = np.flatnonzero(boundary)           # first leaf per node
        labels.append(col[first].astype(np.uint8))
        parents.append(prev_nodes[first])
        node_of_leaf.append(nodes.copy())
        t.append(t_lev)
        prev_nodes = nodes

    return TrieLevels(L=L, b=b, n=n, uniq=uniq, t=t, labels=labels,
                      parents=parents, leaf_offsets=leaf_offsets,
                      ids_sorted=ids_sorted, id_leaf=id_leaf,
                      node_of_leaf=node_of_leaf)


def pick_layers(trie: TrieLevels, lam: float = 0.5):
    """Layer boundaries (ℓ_m, ℓ_s) per paper §V.

    * dense:  largest ℓ_m with t[ℓ_m] == 2^(b·ℓ_m) (complete 2^b-ary trie).
    * sparse: smallest ℓ_s >= ℓ_m with t[ℓ_s] >= λ·t[L].
      (The paper prints the condition as D(ℓ_s, L) < λ with
      D(ℓ1,ℓ2)=t_{ℓ2}/t_{ℓ1}, which is unsatisfiable since t is
      non-decreasing; the intended reading — consistent with the reported
      (ℓ_m, ℓ_s) pairs and λ=0.5 — is t[ℓ_s]/t[L] >= λ, i.e. the level
      from which at least a λ fraction of root-to-leaf paths have become
      non-branching.  Recorded as a paper typo in DESIGN.md.)
    """
    b, L = trie.b, trie.L
    lm = 0
    for lev in range(1, L + 1):
        if b * lev < 63 and trie.t[lev] == (1 << (b * lev)):
            lm = lev
        else:
            break
    ls = L
    for lev in range(lm, L + 1):
        if trie.t[lev] >= lam * trie.t[L]:
            ls = lev
            break
    return lm, ls


def table_or_list(trie: TrieLevels, lev: int) -> str:
    """Adaptive middle-layer encoding (paper §V-B): TABLE iff the level's
    node density exceeds 2^b/(b+1)."""
    density = trie.t[lev] / max(trie.t[lev - 1], 1)
    return "table" if density > (1 << trie.b) / (trie.b + 1) else "list"
