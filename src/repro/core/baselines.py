"""Comparison methods from the paper's experiments (§VI-C): SIH, MIH,
HmSearch-style, and exhaustive linear scan.

These are the *baselines the paper beats*; we implement them faithfully
enough to reproduce the relative behaviour (SIH blowing up exponentially in
τ and b, MIH winning at large τ, HmSearch trading memory for filter time).

TPU adaptation note: hash tables do not exist on TPU; the idiomatic
equivalent of an inverted index is a **lexicographically sorted key array
queried with vectorized binary search** — identical asymptotics for batched
lookups.  Keys are the raw sketch bytes viewed as numpy ``void`` scalars
(memcmp ordering).  Signature *enumeration* (the very thing the paper
shows to be the bottleneck) is inherently combinatorial and data-dependent
— it stays host-side, which matches how SIH/MIH drive their index.
Verification always goes through the shared Pallas hamming kernel.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from . import cost_model
from .hamming import pack_vertical
from ..kernels import ops


def _as_void(rows: np.ndarray) -> np.ndarray:
    rows = np.ascontiguousarray(rows, dtype=np.uint8)
    return rows.view(np.dtype((np.void, rows.shape[1]))).reshape(-1)


# ---------------------------------------------------------------------------
# linear scan
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LinearScan:
    """Exhaustive vertical-format scan — the no-index floor."""

    full_vert: jnp.ndarray   # (b, W, n)
    b: int
    L: int
    n: int

    @staticmethod
    def build(sketches: np.ndarray, b: int) -> "LinearScan":
        n, L = sketches.shape
        planes = pack_vertical(sketches, b)
        return LinearScan(full_vert=jnp.asarray(np.transpose(planes, (1, 2, 0)).copy()),
                          b=b, L=L, n=n)

    def search(self, q: np.ndarray, tau: int) -> np.ndarray:
        qv = jnp.asarray(np.transpose(pack_vertical(np.asarray(q)[None], self.b), (1, 2, 0)))
        dist = ops.hamming_distances(self.full_vert, qv)[0]
        return np.asarray(dist <= tau)

    def array_bytes(self) -> int:
        return int(self.full_vert.nbytes)


# ---------------------------------------------------------------------------
# signature enumeration (shared by SIH / MIH)
# ---------------------------------------------------------------------------

def enumerate_signatures(q: np.ndarray, b: int, tau: int,
                         limit: Optional[int] = None) -> Tuple[np.ndarray, bool]:
    """All strings within Hamming distance τ of q (Eq. 3 enumeration).

    Returns (signatures, truncated).  ``limit`` emulates the paper's 10 s
    SIH timeout: enumeration stops once ``limit`` signatures exist.
    """
    L = len(q)
    A = 1 << b
    out = [q[None, :].copy()]
    count = 1
    truncated = False
    deltas = np.arange(1, A, dtype=np.uint8)
    for k in range(1, min(tau, L) + 1):
        for pos in itertools.combinations(range(L), k):
            # all (A-1)^k character-replacement combos, vectorized
            grids = np.meshgrid(*([deltas] * k), indexing="ij")
            combo = np.stack([g.reshape(-1) for g in grids], axis=1)  # ((A-1)^k, k)
            sig = np.repeat(q[None, :], combo.shape[0], axis=0)
            for j, p in enumerate(pos):
                sig[:, p] = (q[p] + combo[:, j]) % A
            out.append(sig)
            count += combo.shape[0]
            if limit is not None and count > limit:
                truncated = True
                return np.concatenate(out, axis=0)[:limit], truncated
    return np.concatenate(out, axis=0), truncated


class _SortedInvertedIndex:
    """Sorted-key inverted index: key -> contiguous id range (CSR)."""

    def __init__(self, keys: np.ndarray, ids: Optional[np.ndarray] = None):
        n = keys.shape[0]
        ids = ids if ids is not None else np.arange(n, dtype=np.int64)
        void = _as_void(keys)
        order = np.argsort(void, kind="stable")
        self.sorted_void = void[order]
        self.ids_sorted = ids[order]
        uniq_mask = np.concatenate([[True], self.sorted_void[1:] != self.sorted_void[:-1]]) \
            if n > 1 else np.ones(n, bool)
        self.uniq = self.sorted_void[uniq_mask]
        starts = np.flatnonzero(uniq_mask)
        self.offsets = np.concatenate([starts, [n]]).astype(np.int64)
        self.key_bytes = keys.shape[1]

    def lookup_many(self, queries: np.ndarray) -> np.ndarray:
        """(m, key_len) query rows -> concatenated candidate ids."""
        qv = _as_void(queries)
        pos = np.searchsorted(self.uniq, qv)
        pos_c = np.minimum(pos, len(self.uniq) - 1) if len(self.uniq) else pos
        hit = np.zeros(len(qv), dtype=bool)
        if len(self.uniq):
            hit = self.uniq[pos_c] == qv
        out = []
        for p in pos_c[hit]:
            out.append(self.ids_sorted[self.offsets[p]:self.offsets[p + 1]])
        return np.concatenate(out) if out else np.zeros(0, dtype=np.int64)

    def nbytes(self) -> int:
        return (self.uniq.size * self.key_bytes + self.ids_sorted.nbytes
                + self.offsets.nbytes)


# ---------------------------------------------------------------------------
# SIH — single-index hashing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SIH:
    index: _SortedInvertedIndex
    b: int
    L: int
    n: int

    @staticmethod
    def build(sketches: np.ndarray, b: int) -> "SIH":
        n, L = np.asarray(sketches).shape
        return SIH(index=_SortedInvertedIndex(np.asarray(sketches, np.uint8)),
                   b=b, L=L, n=n)

    def search(self, q: np.ndarray, tau: int,
               limit: Optional[int] = 2_000_000) -> Tuple[np.ndarray, bool]:
        """Returns (mask, truncated). truncated=True ~ the paper's timeout."""
        sigs, truncated = enumerate_signatures(np.asarray(q, np.uint8), self.b, tau, limit)
        ids = self.index.lookup_many(sigs)
        mask = np.zeros(self.n, dtype=bool)
        mask[ids] = True
        return mask, truncated

    def array_bytes(self) -> int:
        return self.index.nbytes()


# ---------------------------------------------------------------------------
# MIH — multi-index hashing (Norouzi et al., adapted to b-bit sketches)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MIH:
    indexes: List[_SortedInvertedIndex]
    bounds: List[Tuple[int, int]]
    full_vert: jnp.ndarray
    b: int
    L: int
    n: int
    m: int

    @staticmethod
    def build(sketches: np.ndarray, b: int, m: int) -> "MIH":
        sketches = np.asarray(sketches, np.uint8)
        n, L = sketches.shape
        lens = cost_model._block_lengths(L, m)
        bounds, indexes, lo = [], [], 0
        for Lj in lens:
            hi = lo + Lj
            indexes.append(_SortedInvertedIndex(sketches[:, lo:hi]))
            bounds.append((lo, hi))
            lo = hi
        planes = pack_vertical(sketches, b)
        return MIH(indexes=indexes, bounds=bounds,
                   full_vert=jnp.asarray(np.transpose(planes, (1, 2, 0)).copy()),
                   b=b, L=L, n=n, m=m)

    def search(self, q: np.ndarray, tau: int,
               limit: Optional[int] = 2_000_000) -> Tuple[np.ndarray, bool, int]:
        """Filter blocks with MIH thresholds, verify with the kernel.
        Returns (mask, truncated, n_candidates)."""
        q = np.asarray(q, np.uint8)
        taus = cost_model.block_thresholds(tau, self.m, mih_style=True)
        cand: List[np.ndarray] = []
        truncated = False
        for idx, (lo, hi), tj in zip(self.indexes, self.bounds, taus):
            sigs, tr = enumerate_signatures(q[lo:hi], self.b, tj, limit)
            truncated |= tr
            cand.append(idx.lookup_many(sigs))
        ids = np.unique(np.concatenate(cand)) if cand else np.zeros(0, np.int64)
        if ids.size == 0:
            return np.zeros(self.n, bool), truncated, 0
        cand_vert = self.full_vert[:, :, jnp.asarray(ids)]
        qv = jnp.asarray(np.transpose(pack_vertical(q[None], self.b), (1, 2, 0)))
        dist = np.asarray(ops.hamming_distances(cand_vert, qv)[0])
        mask = np.zeros(self.n, dtype=bool)
        mask[ids[dist <= tau]] = True
        return mask, truncated, int(ids.size)

    def array_bytes(self) -> int:
        return sum(ix.nbytes() for ix in self.indexes) + int(self.full_vert.nbytes)


# ---------------------------------------------------------------------------
# HmSearch-style (Zhang et al.): τ^j ∈ {0,1} blocks, 1-wildcard variants
# registered at **index** time — fast filter, heavy memory (paper §III-B)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HmSearch:
    indexes: List[_SortedInvertedIndex]
    bounds: List[Tuple[int, int]]
    full_vert: jnp.ndarray
    b: int
    L: int
    n: int
    m: int

    @staticmethod
    def _variant_keys(block: np.ndarray) -> np.ndarray:
        """Key scheme: [block with position p zeroed | p+1] for each wildcard
        position p, plus [block | 0] for the exact entry.  The trailing
        position byte keeps variants from colliding with real characters
        (a plain 255-wildcard byte would collide at b=8)."""
        n, Lj = block.shape
        keys = [np.concatenate([block, np.zeros((n, 1), np.uint8)], axis=1)]
        for p in range(Lj):
            v = block.copy()
            v[:, p] = 0
            keys.append(np.concatenate([v, np.full((n, 1), p + 1, np.uint8)], axis=1))
        return np.concatenate(keys, axis=0)

    @staticmethod
    def build(sketches: np.ndarray, b: int, tau: int) -> "HmSearch":
        """m = ⌊τ/2⌋ + 1 blocks ⇒ pigeonhole guarantees some block has ≤ 1
        mismatch; register every 1-wildcard variant of every block string."""
        sketches = np.asarray(sketches, np.uint8)
        n, L = sketches.shape
        m = tau // 2 + 1
        lens = cost_model._block_lengths(L, m)
        bounds, indexes, lo = [], [], 0
        for Lj in lens:
            hi = lo + Lj
            keys = HmSearch._variant_keys(sketches[:, lo:hi])
            ids = np.tile(np.arange(n, dtype=np.int64), Lj + 1)
            indexes.append(_SortedInvertedIndex(keys, ids))
            bounds.append((lo, hi))
            lo = hi
        planes = pack_vertical(sketches, b)
        return HmSearch(indexes=indexes, bounds=bounds,
                        full_vert=jnp.asarray(np.transpose(planes, (1, 2, 0)).copy()),
                        b=b, L=L, n=n, m=m)

    def search(self, q: np.ndarray, tau: int) -> Tuple[np.ndarray, int]:
        q = np.asarray(q, np.uint8)
        cand: List[np.ndarray] = []
        for idx, (lo, hi) in zip(self.indexes, self.bounds):
            cand.append(idx.lookup_many(HmSearch._variant_keys(q[lo:hi][None, :])))
        ids = np.unique(np.concatenate(cand)) if cand else np.zeros(0, np.int64)
        if ids.size == 0:
            return np.zeros(self.n, bool), 0
        cand_vert = self.full_vert[:, :, jnp.asarray(ids)]
        qv = jnp.asarray(np.transpose(pack_vertical(q[None], self.b), (1, 2, 0)))
        dist = np.asarray(ops.hamming_distances(cand_vert, qv)[0])
        mask = np.zeros(self.n, dtype=bool)
        mask[ids[dist <= tau]] = True
        return mask, int(ids.size)

    def array_bytes(self) -> int:
        return sum(ix.nbytes() for ix in self.indexes) + int(self.full_vert.nbytes)
