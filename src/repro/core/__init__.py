"""The paper's contribution: b-bit sketch trie similarity search."""

from .bitvector import BitVector
from .bst import SketchIndex, build_bst, build_fst_style, build_louds
from .column_store import (ColumnStore, SuffixGeometry, geometry_for,
                           reset_tier_stats, tier_stats)
from .cost_model import cost_multi, cost_single, frontier_capacities, sigs
from .hamming import pack_suffix_words, pack_vertical, unpack_vertical
from .multi_index import (MultiIndex, build_multi_index, choose_plan,
                          clear_mi_searcher_cache, make_mi_searcher,
                          mi_search, mi_search_batch)
from .search import (SearchResult, TopKResult, bucket_m,
                     clear_searcher_cache, get_searcher, make_batch_searcher,
                     make_searcher, search, searcher_cache_info, topk,
                     topk_batch)
from .segments import (ColumnSearchResult, Segment, SegmentedIndex,
                       SegmentedSearchResult, ShardedSegmentedIndex,
                       clear_fused_cache, dispatch_stats,
                       reset_dispatch_stats, tombstone_bits)

__all__ = [
    "BitVector", "SketchIndex", "build_bst", "build_louds", "build_fst_style",
    "SearchResult", "make_searcher", "make_batch_searcher", "search",
    "TopKResult", "topk", "topk_batch", "get_searcher", "bucket_m",
    "searcher_cache_info", "clear_searcher_cache",
    "MultiIndex", "build_multi_index", "mi_search", "mi_search_batch",
    "make_mi_searcher", "clear_mi_searcher_cache",
    "choose_plan", "sigs", "cost_single", "cost_multi", "frontier_capacities",
    "Segment", "SegmentedIndex", "SegmentedSearchResult",
    "ColumnSearchResult", "ShardedSegmentedIndex", "tombstone_bits",
    "dispatch_stats", "reset_dispatch_stats", "clear_fused_cache",
    "ColumnStore", "SuffixGeometry", "geometry_for", "tier_stats",
    "reset_tier_stats", "pack_vertical", "unpack_vertical",
    "pack_suffix_words",
]
