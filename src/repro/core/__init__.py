"""The paper's contribution: b-bit sketch trie similarity search."""

from .bitvector import BitVector
from .bst import SketchIndex, build_bst, build_fst_style, build_louds
from .cost_model import cost_multi, cost_single, frontier_capacities, sigs
from .multi_index import (MultiIndex, build_multi_index, choose_plan,
                          make_mi_searcher, mi_search)
from .search import SearchResult, make_batch_searcher, make_searcher, search

__all__ = [
    "BitVector", "SketchIndex", "build_bst", "build_louds", "build_fst_style",
    "SearchResult", "make_searcher", "make_batch_searcher", "search",
    "MultiIndex", "build_multi_index", "mi_search", "make_mi_searcher",
    "choose_plan", "sigs", "cost_single", "cost_multi", "frontier_capacities",
]
