"""Vertical-format bit-parallel Hamming distance (paper §V-C).

A b-bit sketch of length L over Σ=[0, 2^b) is transposed into *b bit
planes*: plane ``i`` holds the i-th significant bit of every character,
packed LSB-first into ``ceil(L/32)`` uint32 words.  Two sketches differ at a
position iff *any* plane differs there, so

    bits  = OR_{i<b} ( s'[i] XOR q'[i] )
    ham   = popcount(bits)

which costs O(b·ceil(L/32)) word ops instead of O(L) character compares.
The paper measured >10x over the naive loop on CPU; on TPU the same layout
is the difference between an int8 gather-compare per character and a dense
uint32 VPU stream — the Pallas kernel in ``repro.kernels`` consumes exactly
this layout.

Conventions: characters are 0-indexed (``[0, 2^b)``) internally; the paper
writes Σ=[1, 2^b].  This is a pure relabeling and keeps arrays compact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32


def n_words(L: int) -> int:
    return (L + WORD_BITS - 1) // WORD_BITS


def pack_vertical(sketches: np.ndarray, b: int) -> np.ndarray:
    """(n, L) uint8/int sketches -> (n, b, W) uint32 bit planes (host-side).

    Index order (n, b, W) keeps a single sketch's planes contiguous, which
    is the layout the verification kernel streams.
    """
    sketches = np.asarray(sketches)
    if sketches.ndim == 1:
        sketches = sketches[None, :]
    n, L = sketches.shape
    W = n_words(L)
    assert sketches.max(initial=0) < (1 << b), "character out of alphabet range"
    planes = np.zeros((n, b, W), dtype=np.uint32)
    pos = np.arange(L)
    word_idx = pos // WORD_BITS
    bit_idx = (pos % WORD_BITS).astype(np.uint32)
    for i in range(b):
        plane_bits = ((sketches >> i) & 1).astype(np.uint32)  # (n, L)
        # scatter-add each bit into its word
        contrib = plane_bits << bit_idx  # (n, L)
        for w in range(W):
            sel = word_idx == w
            if sel.any():
                planes[:, i, w] = contrib[:, sel].sum(axis=1, dtype=np.uint64).astype(np.uint32)
    return planes


def unpack_vertical(planes: np.ndarray, b: int, L: int) -> np.ndarray:
    """Inverse of :func:`pack_vertical`: (n, b, W) uint32 bit planes ->
    (n, L) uint8 sketches (host-side).

    The segment stack stores sealed sketches packed (b bits per symbol
    instead of 8) and unpacks only when a merge/compact needs the raw
    characters back (DESIGN.md §7).

    >>> sk = np.array([[3, 0, 1, 2]], np.uint8)
    >>> bool((unpack_vertical(pack_vertical(sk, 2), 2, 4) == sk).all())
    True
    """
    planes = np.asarray(planes, dtype=np.uint32)
    n = planes.shape[0]
    pos = np.arange(L)
    word_idx = pos // WORD_BITS
    bit_idx = (pos % WORD_BITS).astype(np.uint32)
    out = np.zeros((n, L), np.uint8)
    for i in range(b):
        bits = (planes[:, i, word_idx] >> bit_idx) & np.uint32(1)  # (n, L)
        out |= (bits.astype(np.uint8) << i)
    return out


def pack_suffix_words(sketches: np.ndarray, b: int) -> np.ndarray:
    """(n, S) uint8 suffixes with b·S <= 32 -> (n,) uint32, all b bit
    planes of one row packed into a single word (host-side).

    Plane ``i``'s S bits occupy bit offsets [i·S, (i+1)·S) LSB-first —
    the layout of the packed suffix column store (DESIGN.md §7):
    XOR-ing two words and OR-folding the b S-bit fields reproduces the
    vertical-format Hamming distance of the suffixes.
    """
    sketches = np.asarray(sketches)
    if sketches.ndim == 1:
        sketches = sketches[None, :]
    n, S = sketches.shape
    if b * S > WORD_BITS:
        raise ValueError(f"b*S = {b * S} exceeds one {WORD_BITS}-bit word")
    out = np.zeros((n,), np.uint64)
    for i in range(b):
        bits = ((sketches >> i) & 1).astype(np.uint64)        # (n, S)
        shifts = (np.arange(S) + i * S).astype(np.uint64)
        out |= (bits << shifts).sum(axis=1, dtype=np.uint64)
    return out.astype(np.uint32)


def pack_suffix_words_jax(sketches: jnp.ndarray, b: int) -> jnp.ndarray:
    """Traceable :func:`pack_suffix_words` — packs the (m, S) query
    suffixes inside the fused program so the packed-suffix verify kernel
    sees queries in the exact column layout."""
    if sketches.ndim == 1:
        sketches = sketches[None, :]
    m, S = sketches.shape
    if b * S > WORD_BITS:
        raise ValueError(f"b*S = {b * S} exceeds one {WORD_BITS}-bit word")
    if S == 0:
        return jnp.zeros((m,), jnp.uint32)
    s = sketches.astype(jnp.uint32)
    out = jnp.zeros((m,), jnp.uint32)
    for i in range(b):
        bits = (s >> jnp.uint32(i)) & jnp.uint32(1)           # (m, S)
        shifts = (jnp.arange(S, dtype=jnp.uint32)
                  + jnp.uint32(i * S))
        # disjoint bit positions: the sum is an exact OR
        out = out | (bits << shifts[None, :]).sum(axis=1, dtype=jnp.uint32)
    return out


def pack_vertical_jax(sketches: jnp.ndarray, b: int) -> jnp.ndarray:
    """Traceable version of :func:`pack_vertical` — used when sketches are
    produced on-device (e.g. dedup inside the data pipeline)."""
    if sketches.ndim == 1:
        sketches = sketches[None, :]
    n, L = sketches.shape
    W = n_words(L)
    pad = W * WORD_BITS - L
    s = jnp.pad(sketches.astype(jnp.uint32), ((0, 0), (0, pad)))
    s = s.reshape(n, W, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)

    def plane(i):
        bits = (s >> jnp.uint32(i)) & jnp.uint32(1)
        return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)  # (n, W)

    planes = jnp.stack([plane(i) for i in range(b)], axis=1)  # (n, b, W)
    return planes


@jax.jit
def hamming_vertical(db_planes: jnp.ndarray, q_planes: jnp.ndarray) -> jnp.ndarray:
    """Hamming distances between every DB sketch and one query.

    db_planes: (n, b, W) uint32;  q_planes: (b, W) uint32  ->  (n,) int32.
    """
    diff = db_planes ^ q_planes[None, :, :]  # (n, b, W)
    acc = diff[:, 0, :]
    for i in range(1, diff.shape[1]):  # b is static under jit
        acc = acc | diff[:, i, :]
    pops = jax.lax.population_count(acc).astype(jnp.int32)
    return pops.sum(axis=-1)


def hamming_vertical_many(db_planes: jnp.ndarray, q_planes: jnp.ndarray) -> jnp.ndarray:
    """(n, b, W) x (m, b, W) -> (m, n) distances, vmapped over queries."""
    return jax.vmap(lambda q: hamming_vertical(db_planes, q))(q_planes)


def hamming_naive(db: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Character-by-character O(L) reference (paper's 'naive approach').
    db: (n, L) uint8; q: (L,) uint8 -> (n,) int32."""
    return (db != q[None, :]).sum(axis=-1).astype(jnp.int32)


def hamming_pairwise_naive(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(m, L) x (n, L) -> (m, n) distances, the brute-force oracle."""
    return (a[:, None, :] != b[None, :, :]).sum(axis=-1).astype(jnp.int32)


def pack_sets(sets, vocab: int) -> np.ndarray:
    """Token-id sets -> (n, Wp) uint32 LSB-first membership bitmaps.

    ``sets`` is a sequence of integer token-id arrays (each over
    ``[0, vocab)``) or an already-multihot (n, vocab) 0/1 array.  The
    bitmaps are the exact re-rank payload format (DESIGN.md §10): word
    ``w`` bit ``j`` holds membership of token ``32*w + j``, so one
    AND+popcount pass recovers exact set intersections.
    """
    if vocab <= 0:
        raise ValueError("vocab must be positive")
    Wp = n_words(vocab)
    if isinstance(sets, np.ndarray) and sets.ndim == 2 \
            and sets.shape[1] == vocab:
        multihot = sets.astype(bool)
    else:
        multihot = np.zeros((len(sets), vocab), bool)
        for r, toks in enumerate(sets):
            toks = np.asarray(toks, np.int64).ravel()
            if toks.size and (toks.min() < 0 or toks.max() >= vocab):
                raise ValueError(f"token ids of row {r} outside [0, {vocab})")
            multihot[r, toks] = True
    n = multihot.shape[0]
    padded = np.zeros((n, Wp * WORD_BITS), bool)
    padded[:, :vocab] = multihot
    bits = padded.reshape(n, Wp, WORD_BITS).astype(np.uint32)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    return (bits << shifts).sum(axis=2, dtype=np.uint32)
