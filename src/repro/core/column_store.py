"""Tiered suffix column store: the layout + placement layers of the
segment data plane (DESIGN.md §7).

The PR-5 arena (`segments._ColumnArena`) keeps one *full-length*
(b, W, R) verify column per sealed row device-resident.  That is
redundant: the fused program's traversal already computes the exact
prefix distance down to every segment's collapse depth ℓ_s, and the
verify kernel receives it through the gathered root base plane — so the
columns only need the **suffix** below ℓ_s.  This module owns that
observation end to end, split into two layers:

**Layout** — per-segment packed suffix columns.  Each sealed segment
gets a `_Block` whose geometry depends on its own ℓ_s: when the b bit
planes of the S = L - ℓ_s suffix symbols fit one 32-bit word
(b·S <= 32 — every paper dataset with b <= 2), the whole row packs into
a single uint32 (`hamming.pack_suffix_words`, kernel
`sparse_verify_arena_packed`); otherwise the block falls back to
plane-packed (b, ceil(S/32), n) columns consumed by the unchanged
full-length arena kernel with W = ceil(S/32).  Blocks with equal
geometry share one kernel call inside the ONE jitted program per rung —
the dispatch contract (`_DISPATCH_STATS`) counts program launches, not
kernel bodies, so heterogeneous ℓ_s still costs one fused dispatch.

**Placement** — per-block tier policy.  Hot blocks keep their columns
device-resident (closed over by the compiled program, exactly like the
PR-5 arena).  Cold blocks keep them host-packed only; before a rung
executes, `stage()` copies every cold block's columns ahead into a
device staging slab (one async `jax.device_put` per geometry group,
bounded by the cold bytes of the current plan) that the program takes
as a *traced* argument.  Demotion is LRU under the `hot_bytes` budget
(`None` = unlimited: everything stays hot, byte-for-byte the PR-5
behavior); freed budget promotes the most recently used cold block
back.  Tier flips bump `gen`, which keys the fused-program cache — a
stale program can never read a moved block.

The store keeps the arena's maintenance surface (`serials`, `live`,
`col_off`, `col_ids`, `array_bytes`) so `SegmentedIndex.delete` flips
device liveness lanes in place and incremental flush appends work
unchanged; `segments._ColumnArena` survives as the bit-identical
full-length reference (`layout="full"`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hamming import n_words, pack_suffix_words, pack_vertical
from ..obs.trace import span as _obs_span

WORD_BYTES = 4
TIER_HOT = "hot"
TIER_COLD = "cold"

# Process-wide placement counters (mirrors segments._DISPATCH_STATS):
# promotions/demotions count tier flips, prefetches the cold blocks
# staged to device, staged_bytes the bytes those copies moved
# (staged_payload_bytes the payload-bitmap share, DESIGN.md §10).
_TIER_STATS = {"promotions": 0, "demotions": 0, "prefetches": 0,
               "staged_bytes": 0, "staged_payload_bytes": 0}


def tier_stats() -> Dict[str, int]:
    """Placement counters of the tiered column store: ``promotions`` /
    ``demotions`` (tier flips under the ``hot_bytes`` budget),
    ``prefetches`` (cold blocks copied ahead to the device staging slab)
    and ``staged_bytes`` (bytes those copies moved)."""
    return dict(_TIER_STATS)


def reset_tier_stats() -> None:
    for k in _TIER_STATS:
        _TIER_STATS[k] = 0


class SuffixGeometry(NamedTuple):
    """Column geometry of one segment's suffix block: ``suffix_len`` =
    L - ℓ_s symbols below the collapse depth; ``packed`` when all b bit
    planes fit one uint32 word per row (b·suffix_len <= 32);
    ``row_words`` the uint32 words per column (1 packed, b·ceil(S/32)
    plane-packed)."""

    suffix_len: int
    packed: bool
    row_words: int


def geometry_for(L: int, b: int, ls: int) -> SuffixGeometry:
    """Pick the layout for a segment collapsing at depth ``ls``."""
    S = int(L) - int(ls)
    if b * S <= 32:
        return SuffixGeometry(S, True, 1)
    return SuffixGeometry(S, False, b * n_words(S))


@dataclasses.dataclass
class _Block:
    """One sealed segment's suffix columns + placement state.

    ``cols_hot`` (device) and ``cols_cold`` (host) are mutually
    exclusive — exactly one is set, per the block's ``tier``.  Packed
    geometry stores (n,) uint32 words, plane geometry (b, W_sfx, n)
    uint32.  ``base_idx`` (host, immutable once appended) is the
    segment-offset lane into the global root base plane."""

    serial: int
    n: int
    geom: SuffixGeometry
    base_idx: np.ndarray
    cols_hot: Optional[jnp.ndarray] = None
    cols_cold: Optional[np.ndarray] = None
    last_used: int = 0
    # exact re-rank payload bitmaps (DESIGN.md §10): (Wp, n) uint32,
    # same tier as the sketch columns — a tier flip moves both, so the
    # re-rank program's closure/staged split always matches the verify's
    pays_hot: Optional[jnp.ndarray] = None
    pays_cold: Optional[np.ndarray] = None
    pay_words: int = 0

    @property
    def tier(self) -> str:
        return TIER_HOT if self.cols_hot is not None else TIER_COLD

    @property
    def col_bytes(self) -> int:
        return self.n * self.geom.row_words * WORD_BYTES

    @property
    def pay_bytes(self) -> int:
        return self.n * self.pay_words * WORD_BYTES

    @property
    def block_bytes(self) -> int:
        """Placement-budget charge: sketch columns + payload bitmaps
        (both move together on a tier flip)."""
        return self.col_bytes + self.pay_bytes


class _Group(NamedTuple):
    """One geometry group of the current plan: the per-rung program runs
    one verify kernel per group (inside the single fused dispatch).
    ``perm`` maps the group's column order (hot blocks in stack order,
    then cold blocks in stack order) back to global stack positions."""

    geom: SuffixGeometry
    cols_hot: Optional[jnp.ndarray]   # concatenated hot columns (device)
    base_idx: jnp.ndarray             # (n_group,) int32 device constant
    perm: np.ndarray                  # (n_group,) int64 stack positions
    cold_blocks: Tuple[int, ...]      # indexes into store.blocks
    cold_bytes: int
    pays_hot: Optional[jnp.ndarray] = None  # (Wp, n_hot) payload bitmaps
    pay_cold_bytes: int = 0


class ColumnStore:
    """Tiered suffix column store for one segment stack (bst backend).

    Maintenance mirrors ``_ColumnArena``: a flush *appends* a block (and
    its liveness/gid/id lanes) without touching existing ones; a merge
    or compact changes the serial fingerprint non-monotonically and the
    owner rebuilds from scratch.  ``delete`` flips the shared ``live``
    lanes in place through ``col_off`` — liveness is a traced program
    argument, so tier state never changes on delete.
    """

    def __init__(self, L: int, b: int, hot_bytes: Optional[int] = None,
                 payload_words: Optional[int] = None):
        self.L, self.b = int(L), int(b)
        self.hot_bytes = hot_bytes
        # uint32 words per re-rank payload bitmap (None = no payloads)
        self.payload_words = payload_words
        self.serials: Tuple[int, ...] = ()
        self.blocks: List[_Block] = []
        self.live: jnp.ndarray = jnp.zeros((0,), bool)
        self.gids: jnp.ndarray = jnp.zeros((0,), jnp.int32)
        self.col_ids = np.zeros((0,), np.int64)
        self.col_off: Dict[int, int] = {}
        self.root_off: Dict[int, int] = {}
        self.t_root_total = 0
        self.gen = 0                   # bumped on every tier flip
        self._tick = 0                 # LRU clock
        self._plan: Optional[Tuple[_Group, ...]] = None

    @property
    def n_cols(self) -> int:
        return int(self.col_ids.shape[0])

    # -- maintenance -----------------------------------------------------

    def append_segment(self, seg) -> None:
        """Append one sealed segment's block: suffix columns sliced below
        its own ℓ_s, packed per :func:`geometry_for`, plus the shared
        base-offset/gid/liveness/id lanes.  New blocks start hot; the
        budget is enforced at :meth:`seal`."""
        ls = int(seg.index.ls)
        geom = geometry_for(self.L, self.b, ls)
        sfx = seg.sketches[:, ls:]
        if geom.packed:
            cols = pack_suffix_words(sfx, self.b)            # (n,)
        else:
            cols = np.ascontiguousarray(
                np.transpose(pack_vertical(sfx, self.b), (1, 2, 0)))
        root0 = 1 + self.t_root_total        # slot 0: delta's trivial base
        leaf_root = np.asarray(seg.index.tail.leaf_root)
        id_leaf = np.asarray(seg.index.id_leaf)
        base_idx = (root0 + leaf_root[id_leaf]).astype(np.int32)
        pays_hot = None
        pay_words = 0
        if self.payload_words is not None:
            if getattr(seg, "payloads", None) is None:
                raise ValueError(
                    "payload_words is set but the segment holds no payloads")
            pay_words = int(self.payload_words)
            pays_hot = jnp.asarray(np.ascontiguousarray(
                seg.payloads.T.astype(np.uint32)))       # (Wp, n)
        self._tick += 1
        self.blocks.append(_Block(
            serial=seg.serial, n=seg.n, geom=geom, base_idx=base_idx,
            cols_hot=jnp.asarray(cols), last_used=self._tick,
            pays_hot=pays_hot, pay_words=pay_words))
        self.col_off[seg.serial] = self.n_cols
        self.root_off[seg.serial] = root0
        self.t_root_total += int(seg.index.tail.t_root)
        self.live = jnp.concatenate([self.live, jnp.asarray(seg.live)])
        self.gids = jnp.concatenate(
            [self.gids, jnp.asarray(seg.ids.astype(np.int32))])
        self.col_ids = np.concatenate([self.col_ids, seg.ids])
        self._plan = None

    def seal(self, serials: Tuple[int, ...]) -> None:
        """Stamp the stack fingerprint and enforce the placement budget
        (LRU demotion under pressure, promotion into freed room)."""
        self.serials = serials
        self._enforce_budget()

    def _demote(self, blk: _Block) -> None:
        blk.cols_cold = np.asarray(blk.cols_hot)
        blk.cols_hot = None
        if blk.pays_hot is not None:
            blk.pays_cold = np.asarray(blk.pays_hot)
            blk.pays_hot = None
        _TIER_STATS["demotions"] += 1
        self.gen += 1
        self._plan = None

    def _promote(self, blk: _Block) -> None:
        blk.cols_hot = jnp.asarray(blk.cols_cold)
        blk.cols_cold = None
        if blk.pays_cold is not None:
            blk.pays_hot = jnp.asarray(blk.pays_cold)
            blk.pays_cold = None
        self._tick += 1
        blk.last_used = self._tick
        _TIER_STATS["promotions"] += 1
        self.gen += 1
        self._plan = None

    def _enforce_budget(self) -> None:
        if self.hot_bytes is None:
            return
        budget = int(self.hot_bytes)
        hot = lambda: [blk for blk in self.blocks if blk.tier == TIER_HOT]
        used = sum(blk.block_bytes for blk in hot())
        while used > budget:
            victims = hot()
            if not victims:
                break
            lru = min(victims, key=lambda blk: blk.last_used)
            self._demote(lru)
            used -= lru.block_bytes
        # freed room (a merge shrank R, or the budget grew): pull the
        # most recently used cold blocks back while they fit
        cold = sorted((blk for blk in self.blocks if blk.tier == TIER_COLD),
                      key=lambda blk: -blk.last_used)
        for blk in cold:
            if used + blk.block_bytes > budget:
                continue
            self._promote(blk)
            used += blk.block_bytes

    # -- plan / staging --------------------------------------------------

    def plan(self) -> Tuple[_Group, ...]:
        """Group blocks by geometry (one kernel call per group inside the
        fused program): hot columns pre-concatenated device-side, cold
        blocks listed for :meth:`stage`, base-offset lanes as one device
        constant, and the stack-position permutation that restores the
        global column order.  Cached until the stack or a tier changes."""
        if self._plan is not None:
            return self._plan
        order: Dict[SuffixGeometry, List[int]] = {}
        for bi, blk in enumerate(self.blocks):
            order.setdefault(blk.geom, []).append(bi)
        groups: List[_Group] = []
        for geom, idxs in order.items():
            hot = [i for i in idxs if self.blocks[i].tier == TIER_HOT]
            cold = [i for i in idxs if self.blocks[i].tier == TIER_COLD]
            perm = np.concatenate([
                self.col_off[self.blocks[i].serial]
                + np.arange(self.blocks[i].n)
                for i in hot + cold]).astype(np.int64)
            base_idx = np.concatenate(
                [self.blocks[i].base_idx for i in hot + cold])
            axis = 0 if geom.packed else -1
            cols_hot = (jnp.concatenate(
                [self.blocks[i].cols_hot for i in hot], axis=axis)
                if hot else None)
            pays_hot = None
            if self.payload_words is not None and hot:
                pays_hot = jnp.concatenate(
                    [self.blocks[i].pays_hot for i in hot], axis=-1)
            groups.append(_Group(
                geom=geom, cols_hot=cols_hot,
                base_idx=jnp.asarray(base_idx), perm=perm,
                cold_blocks=tuple(cold),
                cold_bytes=sum(self.blocks[i].col_bytes for i in cold),
                pays_hot=pays_hot,
                pay_cold_bytes=sum(self.blocks[i].pay_bytes
                                   for i in cold)))
        self._plan = tuple(groups)
        return self._plan

    def stage(self) -> Tuple[Optional[jnp.ndarray], ...]:
        """Copy-ahead: upload every cold block's columns into one device
        staging slab per geometry group (async ``jax.device_put`` — the
        transfers overlap the traversal that runs before the verify
        consumes them).  Returns one traced-arg slab per plan group
        (None where the group is fully hot); call once per fused query,
        before the rung loop."""
        slabs: List[Optional[jnp.ndarray]] = []
        for g in self.plan():
            if not g.cold_blocks:
                slabs.append(None)
                continue
            axis = 0 if g.geom.packed else -1
            cols = np.concatenate(
                [self.blocks[i].cols_cold for i in g.cold_blocks], axis=axis)
            with _obs_span("tier_stage", cat="device",
                           blocks=len(g.cold_blocks), bytes=int(cols.nbytes)):
                slabs.append(jax.device_put(cols))
            _TIER_STATS["prefetches"] += len(g.cold_blocks)
            _TIER_STATS["staged_bytes"] += int(cols.nbytes)
        return tuple(slabs)

    def stage_payloads(self) -> Tuple[Optional[jnp.ndarray], ...]:
        """Copy-ahead for the re-rank pass: upload every cold block's
        payload bitmaps into one (Wp, n_cold) device slab per plan group
        (None where the group is fully hot, or when the store holds no
        payloads).  Same async ``jax.device_put`` discipline as
        :meth:`stage`; counted under ``staged_bytes`` plus the dedicated
        ``staged_payload_bytes`` ledger."""
        slabs: List[Optional[jnp.ndarray]] = []
        for g in self.plan():
            if self.payload_words is None or not g.cold_blocks:
                slabs.append(None)
                continue
            pays = np.concatenate(
                [self.blocks[i].pays_cold for i in g.cold_blocks], axis=-1)
            with _obs_span("tier_stage_payloads", cat="device",
                           blocks=len(g.cold_blocks), bytes=int(pays.nbytes)):
                slabs.append(jax.device_put(pays))
            _TIER_STATS["staged_bytes"] += int(pays.nbytes)
            _TIER_STATS["staged_payload_bytes"] += int(pays.nbytes)
        return tuple(slabs)

    # -- accounting ------------------------------------------------------

    def array_bytes(self) -> int:
        """Resident device bytes: hot columns + the shared gid/liveness
        lanes + the per-group base-offset lanes (the staging slab is
        transient and accounted by ``tier_stats()['staged_bytes']``)."""
        by = int(self.live.nbytes + self.gids.nbytes)
        by += sum(blk.block_bytes for blk in self.blocks
                  if blk.tier == TIER_HOT)
        by += sum(blk.base_idx.nbytes for blk in self.blocks)
        return by

    def host_bytes(self) -> int:
        """Resident host bytes: cold columns and cold payload bitmaps
        (the host master copies)."""
        return sum(blk.block_bytes for blk in self.blocks
                   if blk.tier == TIER_COLD)

    def col_bytes(self, tier: Optional[str] = None) -> int:
        """Sketch-column bytes, optionally restricted to one tier —
        the bytes-per-row numerator of the capacity benchmarks
        (payload bitmaps are ledgered separately, :meth:`pay_bytes`)."""
        return sum(blk.col_bytes for blk in self.blocks
                   if tier is None or blk.tier == tier)

    def pay_bytes(self, tier: Optional[str] = None) -> int:
        """Re-rank payload-bitmap bytes, optionally per tier."""
        return sum(blk.pay_bytes for blk in self.blocks
                   if tier is None or blk.tier == tier)

    def tier_summary(self) -> Dict[str, int]:
        """Per-store placement snapshot for ``SegmentedIndex.stats()``."""
        hot = [blk for blk in self.blocks if blk.tier == TIER_HOT]
        cold = [blk for blk in self.blocks if blk.tier == TIER_COLD]
        return {"hot_blocks": len(hot), "cold_blocks": len(cold),
                "hot_bytes": sum(blk.col_bytes for blk in hot),
                "cold_bytes": sum(blk.col_bytes for blk in cold)}
