"""MI-bST: the multi-index approach with bST as each block's inverted index
(paper §III-B, §VI-C).

The sketch is split into ``m`` disjoint blocks; block j gets its own bST
built over the block *substrings* (deduplication within a block is what
makes the per-block tries small), searched with the pigeonhole threshold
τ^j = ⌊τ/m⌋.  A candidate is any id surviving in ≥ 1 block; verification
re-checks the full-length Hamming distance with the Pallas kernel over the
compacted candidate set (fixed capacity from the cost model + overflow
ladder — same static-shape discipline as the frontier).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import cost_model
from .bst import BIG, SketchIndex, build_bst
from .hamming import pack_vertical, pack_vertical_jax
from .search import (_compact, _compact_batch, _pin_cache_get, _search_trace,
                     _search_trace_batch)
from ..kernels import ops
from ..kernels.hamming_kernel import DEFAULT_BLOCK_M


class MultiSearchResult(NamedTuple):
    mask: jnp.ndarray        # (n,) bool final solutions
    dist: jnp.ndarray        # (n,) int32 — exact distance where mask, BIG off
    candidates: jnp.ndarray  # int32 — |∪ C^j| before verification
    overflow: jnp.ndarray    # int32 — frontier + candidate-capacity drops


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class MultiIndex:
    blocks: Tuple[SketchIndex, ...]
    full_vert: jnp.ndarray          # (b, W, n) — verification layout
    bounds: Tuple[Tuple[int, int], ...]
    L: int
    b: int
    n: int

    def tree_flatten(self):
        return (self.blocks, self.full_vert), (self.bounds, self.L, self.b, self.n)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    def model_bits(self) -> int:
        return sum(blk.model_bits() for blk in self.blocks) \
            + int(self.full_vert.size) * 32

    def array_bytes(self) -> int:
        return sum(blk.array_bytes() for blk in self.blocks) \
            + int(self.full_vert.nbytes)


def build_multi_index(sketches: np.ndarray, b: int, m: int,
                      lam: float = 0.5) -> MultiIndex:
    """MI-bST over ``m`` disjoint sketch blocks (paper §III-B).

    sketches: (n, L) uint8 over Σ=[0, 2^b); each of the m blocks gets its
    own bST over the block substrings, plus one (b, W, n) vertical copy
    of the full sketches for kernel verification."""
    sketches = np.asarray(sketches, dtype=np.uint8)
    n, L = sketches.shape
    lens = cost_model._block_lengths(L, m)
    bounds = []
    lo = 0
    blocks = []
    for Lj in lens:
        hi = lo + Lj
        blocks.append(build_bst(sketches[:, lo:hi], b, lam))
        bounds.append((lo, hi))
        lo = hi
    planes = pack_vertical(sketches, b)                 # (n, b, W)
    full_vert = jnp.asarray(np.transpose(planes, (1, 2, 0)).copy())
    return MultiIndex(blocks=tuple(blocks), full_vert=full_vert,
                      bounds=tuple(bounds), L=L, b=b, n=n)


def candidate_capacity(mi: MultiIndex, tau: int, safety: int = 8,
                       cap_max: int = 1 << 20) -> int:
    """Static capacity for the verification gather, from the Appendix-A
    candidate estimate |C^j| = sigs(b, L^j, τ^j)·n/(2^b)^{L^j}."""
    est = 1.0
    taus = cost_model.block_thresholds(tau, len(mi.blocks))
    for (lo, hi), tj in zip(mi.bounds, taus):
        Lj = hi - lo
        est += min(cost_model.sigs(mi.b, Lj, tj) * mi.n / float(1 << mi.b) ** Lj, mi.n)
    return int(min(max(est * safety, 1024), min(cap_max, mi.n)))


def _mi_search_trace(mi: MultiIndex, q: jnp.ndarray, *, tau: int,
                     caps_per_block, cand_cap: int) -> MultiSearchResult:
    q = q.astype(jnp.int32)
    taus = cost_model.block_thresholds(tau, len(mi.blocks))
    cand_mask = jnp.zeros((mi.n,), bool)
    overflow = jnp.int32(0)
    for blk, (lo, hi), tj, caps in zip(mi.blocks, mi.bounds, taus, caps_per_block):
        res = _search_trace(blk, q[lo:hi], tau=tj, caps=caps)
        cand_mask = cand_mask | res.mask
        overflow = overflow + res.overflow

    n_cand = cand_mask.sum(dtype=jnp.int32)
    ids, _, cvalid, ov = _compact(jnp.arange(mi.n, dtype=jnp.int32),
                                  jnp.zeros((mi.n,), jnp.int32),
                                  cand_mask, cand_cap)
    overflow = overflow + ov
    safe_ids = jnp.where(cvalid, ids, 0)
    cand_vert = mi.full_vert[:, :, safe_ids]                     # (b, W, C)
    q_vert = pack_vertical_jax(q[None], mi.b)[0]                 # (b, W)
    dist = ops.hamming_distances(cand_vert, q_vert[..., None])[0]  # (C,)
    ok = cvalid & (dist <= tau)
    mask = jnp.zeros((mi.n,), bool).at[safe_ids].max(ok, mode="drop")
    dvec = jnp.full((mi.n,), BIG, jnp.int32).at[safe_ids].min(
        jnp.where(ok, dist, BIG), mode="drop")
    return MultiSearchResult(mask=mask, dist=dvec, candidates=n_cand,
                             overflow=overflow)


def _mi_search_trace_batch(mi: MultiIndex, qs: jnp.ndarray, *, tau: int,
                           caps_per_block, cand_cap: int,
                           block_m: int = DEFAULT_BLOCK_M,
                           id_live: jnp.ndarray | None = None) -> MultiSearchResult:
    """Natively batched MI search: every block runs the 2D-frontier batch
    trace, candidate sets compact per query, and verification XOR/
    popcounts each query against its own gathered candidates.

    ``id_live``: optional (n,) bool tombstone mask (dynamic segmented
    index, DESIGN.md §4) — dead ids are dropped from the candidate union
    *before* compaction, so they consume neither candidate-buffer
    capacity nor verification bandwidth."""
    qs = qs.astype(jnp.int32)
    m = qs.shape[0]
    taus = cost_model.block_thresholds(tau, len(mi.blocks))
    cand_mask = jnp.zeros((m, mi.n), bool)
    overflow = jnp.zeros((m,), jnp.int32)
    for blk, (lo, hi), tj, caps in zip(mi.blocks, mi.bounds, taus,
                                       caps_per_block):
        res = _search_trace_batch(blk, qs[:, lo:hi], tau=tj, caps=caps,
                                  block_m=block_m)
        cand_mask = cand_mask | res.mask
        overflow = overflow + res.overflow
    if id_live is not None:
        cand_mask = cand_mask & id_live[None, :]

    n_cand = cand_mask.sum(axis=1, dtype=jnp.int32)
    all_ids = jnp.broadcast_to(jnp.arange(mi.n, dtype=jnp.int32)[None, :],
                               (m, mi.n))
    ids, _, cvalid, ov = _compact_batch(all_ids,
                                        jnp.zeros((m, mi.n), jnp.int32),
                                        cand_mask, cand_cap)
    overflow = overflow + ov
    safe_ids = jnp.where(cvalid, ids, 0)                    # (m, C)
    cand_vert = mi.full_vert[:, :, safe_ids]                # (b, W, m, C)
    q_vert = jnp.transpose(pack_vertical_jax(qs, mi.b), (1, 2, 0))  # (b, W, m)
    # per-query candidate sets: vmap the shared scan over the query axis
    # (backend auto-selects — pallas_call batches under vmap, same as the
    # sharded scan path; the oracle handles tiny candidate buffers)
    dist = jax.vmap(
        lambda cv, qv: ops.hamming_distances(cv, qv[..., None])[0],
        in_axes=(2, 2))(cand_vert, q_vert)                  # (m, C)
    ok = cvalid & (dist <= tau)
    row = jnp.arange(m, dtype=jnp.int32)[:, None]
    mask = jnp.zeros((m, mi.n), bool).at[row, safe_ids].max(ok, mode="drop")
    dvec = jnp.full((m, mi.n), BIG, jnp.int32).at[row, safe_ids].min(
        jnp.where(ok, dist, BIG), mode="drop")
    return MultiSearchResult(mask=mask, dist=dvec, candidates=n_cand,
                             overflow=overflow)


def mi_trace_params(mi: MultiIndex, tau: int, cap_max: int = 1 << 17,
                    cand_cap: int | None = None):
    """The static parameters of one MI search trace: per-block frontier
    capacities + the candidate-buffer capacity (Appendix-A estimate by
    default).  Shared by ``make_mi_searcher`` and the dynamic segmented
    index's fused one-dispatch program, which inlines
    ``_mi_search_trace_batch`` per MI segment (DESIGN.md §6)."""
    taus = cost_model.block_thresholds(tau, len(mi.blocks))
    caps_per_block = tuple(
        cost_model.frontier_capacities(blk.t, blk.b, tj, cap_max)
        for blk, tj in zip(mi.blocks, taus))
    cc = cand_cap if cand_cap is not None else candidate_capacity(mi, tau)
    return caps_per_block, cc


def mi_column_dists(mi: MultiIndex, qs: jnp.ndarray, tau: int,
                    caps_per_block, cand_cap: int,
                    block_m: int = DEFAULT_BLOCK_M,
                    id_live: jnp.ndarray | None = None):
    """Traced MI search reduced to the column contract: (m, L) queries ->
    ((m, n) int32 exact distances — BIG off-mask/dead, (m,) int32
    overflow).  A thin adapter over ``_mi_search_trace_batch`` so an
    MI-backed segment drops into the fused arena program as a
    sub-trace."""
    res = _mi_search_trace_batch(mi, qs, tau=tau,
                                 caps_per_block=caps_per_block,
                                 cand_cap=cand_cap, block_m=block_m,
                                 id_live=id_live)
    return res.dist, res.overflow    # dist is already BIG off-mask


# same discipline as search._SEARCHER_CACHE: the MultiIndex is pinned in
# the value so the id key can never be recycled while the entry lives;
# FIFO-bounded against benchmark sweeps.
_MI_SEARCHER_CACHE: dict = {}
_MI_SEARCHER_CACHE_CAP = 128


def clear_mi_searcher_cache() -> None:
    """Drop every cached MI searcher (and the MultiIndex pins with them)."""
    _MI_SEARCHER_CACHE.clear()


def make_mi_searcher(mi: MultiIndex, tau: int, cap_max: int = 1 << 17,
                     cand_cap: int | None = None, *, batch: bool = False,
                     block_m: int = DEFAULT_BLOCK_M, with_live: bool = False):
    """Cached compiled MI searcher.  ``batch=False``: f(q (L,));
    ``batch=True``: f(qs (m, L)) through the natively batched per-block
    traces (leading query axis on every result field).  ``with_live=True``
    (batch only) compiles the tombstone-aware ``f(qs, id_live (n,) bool)``
    variant — the liveness bitmap is traced, so deletes never re-jit."""
    taus = cost_model.block_thresholds(tau, len(mi.blocks))
    caps_per_block = tuple(
        cost_model.frontier_capacities(blk.t, blk.b, tj, cap_max)
        for blk, tj in zip(mi.blocks, taus))
    cc = cand_cap if cand_cap is not None else candidate_capacity(mi, tau)

    key = (id(mi), tau, caps_per_block, cc, block_m if batch else None,
           with_live)

    def build():
        if batch and with_live:
            @jax.jit
            def run(qs, id_live):
                return _mi_search_trace_batch(mi, qs, tau=tau,
                                              caps_per_block=caps_per_block,
                                              cand_cap=cc, block_m=block_m,
                                              id_live=id_live)
        elif batch:
            @jax.jit
            def run(qs):
                return _mi_search_trace_batch(mi, qs, tau=tau,
                                              caps_per_block=caps_per_block,
                                              cand_cap=cc, block_m=block_m)
        else:
            @jax.jit
            def run(q):
                return _mi_search_trace(mi, q, tau=tau,
                                        caps_per_block=caps_per_block,
                                        cand_cap=cc)
        return run

    fn, _ = _pin_cache_get(_MI_SEARCHER_CACHE, _MI_SEARCHER_CACHE_CAP, key,
                           mi, build)
    return fn


def mi_search(mi: MultiIndex, q: np.ndarray, tau: int) -> MultiSearchResult:
    """Host wrapper with the doubled overflow ladder: the m=1 row of
    ``mi_search_batch`` (same pattern as ``topk``/``topk_batch``).
    ``q``: (L,) uint8 -> ``MultiSearchResult`` over the index's n ids."""
    res = mi_search_batch(mi, jnp.asarray(q)[None], tau)
    return MultiSearchResult(mask=res.mask[0], dist=res.dist[0],
                             candidates=res.candidates[0],
                             overflow=res.overflow[0])


def mi_search_batch(mi: MultiIndex, qs: np.ndarray, tau: int,
                    block_m: int = DEFAULT_BLOCK_M,
                    id_live: np.ndarray | None = None) -> MultiSearchResult:
    """Batched ``mi_search``: (m, L) queries with one shared overflow
    ladder (escalates until every query is exact).  ``id_live``: optional
    (n,) bool tombstone mask — dead ids are excluded from candidates and
    results (segmented-index fan-out, DESIGN.md §4)."""
    qs = jnp.asarray(qs)
    live = jnp.asarray(id_live) if id_live is not None else None
    cap_max, cand_cap = 1 << 15, candidate_capacity(mi, tau)
    while True:
        fn = make_mi_searcher(mi, tau, cap_max, cand_cap, batch=True,
                              block_m=block_m, with_live=live is not None)
        res = fn(qs, live) if live is not None else fn(qs)
        if int(res.overflow.sum()) == 0 or (cap_max >= 1 << 22
                                            and cand_cap >= mi.n):
            return res
        cap_max *= 2
        cand_cap = min(cand_cap * 2, mi.n)


def choose_plan(b: int, L: int, tau: int, n: int,
                ms: Tuple[int, ...] = (2, 3, 4)) -> Tuple[str, int]:
    """Cost-model auto-tuner: single- vs multi-index and the block count.
    Mirrors the paper's finding (SI fastest for τ<=4, MI competitive at 5)."""
    best = ("single", 1)
    best_cost = cost_model.cost_single(b, L, tau, n)
    for m in ms:
        c = cost_model.cost_multi(b, L, tau, n, m)
        if c < best_cost:
            best, best_cost = ("multi", m), c
    return best
