"""Sharded bST similarity search — the paper's technique at pod scale.

The database of n sketches is split over the mesh's data axes; every
device owns a *local* bST over its n/D shard and answers every query
against it (classic sharded-retrieval: queries replicated, index
sharded, result masks concatenated).  Index build stays embarrassingly
parallel — a lost shard re-sketches and rebuilds 1/D of the database
(the fault-tolerance story for the retrieval plane).

SPMD constraint and the adaptation it forces (DESIGN.md §2): one program
must serve every shard, so per-shard tries must share
  * a COMMON static layer plan (dense span, TABLE/LIST choice per level,
    collapse level ℓ_s) — computed from aggregate statistics; because
    b-bit sketches are uniformly random (the paper's own observation,
    §V), per-shard density profiles are nearly identical and the common
    plan is near-optimal for every shard; and
  * COMMON array shapes — per-shard encodings are zero-padded to the
    max across shards and stacked on a leading shard axis; true sizes
    travel as int32 *data* (t_prev per level, t_L, n_local), and every
    children() variant takes them as traced scalars.

``make_sharded_searcher`` returns a jit-able f(db_arrays, queries) whose
in_shardings place the shard axis on the mesh data axes — under GSPMD
each device computes exactly its local trie traversal, and the only
collective is the final result all-gather.
"""

from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..kernels.hamming_kernel import DEFAULT_BLOCK_M
from .bitvector import BitVector
from .bst import BIG
from .cost_model import frontier_capacities
from .hamming import pack_vertical, pack_vertical_jax
from .search import _compact, _compact_batch
from .trie_builder import TrieLevels, build_trie_levels, pick_layers, table_or_list

WORD_SHIFT = 5
WORD_MASK = 31


# ---------------------------------------------------------------------------
# dynamic-size rank/select on padded (words, cum) pairs
# ---------------------------------------------------------------------------

def _rank(words: jnp.ndarray, cum: jnp.ndarray, i: jnp.ndarray,
          length: jnp.ndarray) -> jnp.ndarray:
    i = jnp.clip(i.astype(jnp.int32), 0, length)
    w = i >> WORD_SHIFT
    r = i & WORD_MASK
    base = cum[w]
    word = words[jnp.minimum(w, words.shape[0] - 1)]
    mask = jnp.where(r > 0, (jnp.uint32(1) << r.astype(jnp.uint32))
                     - 1, jnp.uint32(0))
    partial = jax.lax.population_count(word & mask).astype(jnp.int32)
    return base + jnp.where(r > 0, partial, 0)


def _select(words: jnp.ndarray, cum: jnp.ndarray, k: jnp.ndarray,
            length: jnp.ndarray) -> jnp.ndarray:
    """Position of the k-th one (1-indexed); ``length`` when out of range
    (note: *dynamic* length, not the padded array length)."""
    k = k.astype(jnp.int32)
    total = _rank(words, cum, length, length)
    valid = (k >= 1) & (k <= total)
    k_safe = jnp.clip(k, 1, jnp.maximum(total, 1))
    w = jnp.searchsorted(cum, k_safe, side="left") - 1
    w = jnp.clip(w, 0, words.shape[0] - 1)
    residual = k_safe - cum[w]
    word = words[w]
    lane = jnp.arange(32, dtype=jnp.uint32)
    lane = lane.reshape((1,) * word.ndim + (32,))
    bits = (word[..., None] >> lane) & jnp.uint32(1)
    cs = jnp.cumsum(bits.astype(jnp.int32), axis=-1)
    hit = (cs >= residual[..., None]) & (bits == 1)
    inword = jnp.argmax(hit, axis=-1).astype(jnp.int32)
    pos = (w << WORD_SHIFT) + inword
    return jnp.where(valid, pos, length)


# ---------------------------------------------------------------------------
# stacked, padded index container
# ---------------------------------------------------------------------------

class ShardedLevel(NamedTuple):
    kind: str                      # static: "dense" | "table" | "list"
    words: Optional[jnp.ndarray]   # (S, Wmax) uint32 (table: H; list: B)
    cum: Optional[jnp.ndarray]     # (S, Wmax+1) int32
    labels: Optional[jnp.ndarray]  # (S, Tmax) uint8 (list only)


class ShardedBST(NamedTuple):
    levels: Tuple[ShardedLevel, ...]
    t: jnp.ndarray            # (S, L+1) int32 true node counts per level
    paths_vert: jnp.ndarray   # (S, b, Wsfx, tLmax) uint32
    d_words: jnp.ndarray      # (S, WD) uint32  — leftmost-leaf bitvector
    d_cum: jnp.ndarray        # (S, WD+1) int32
    leaf_root: jnp.ndarray    # (S, tLmax) int32 (t_root sentinel on pads)
    id_leaf: jnp.ndarray      # (S, n_max) int32 (leaf idx per local id)
    n_local: jnp.ndarray      # (S,) int32
    shard_of: np.ndarray      # (n,) host-side: global id -> shard
    pos_of: np.ndarray        # (n,) host-side: global id -> local position
    # static metadata (identical across shards)
    L: int
    b: int
    lm: int
    ls: int
    kinds: Tuple[str, ...]
    n_max: int
    max_leaves_per_root: int

    def array_bytes(self, include_ids: bool = True) -> int:
        """Resident device bytes of the SPMD pytree (the per-shard
        padded arrays every shard's program closes over) — the sharded
        entry of ``SegmentedIndex.space_ledger()``'s device column.
        ``include_ids=False`` drops the id_leaf map, mirroring
        ``SketchIndex.array_bytes``."""
        by = 0
        for lv in self.levels:
            for arr in (lv.words, lv.cum, lv.labels):
                if arr is not None:
                    by += int(arr.nbytes)
        for arr in (self.t, self.paths_vert, self.d_words, self.d_cum,
                    self.leaf_root, self.n_local):
            by += int(arr.nbytes)
        if include_ids:
            by += int(self.id_leaf.nbytes)
        return by

    def model_bits(self) -> int:
        """Model-space accounting of the sharded layout: in this padded
        SPMD form the device arrays ARE the model (shard-uniform shapes
        are the price of the single vmapped program), so the bit count
        is the padded-array payload minus the host-side routing maps."""
        return 8 * self.array_bytes(include_ids=False)


def _pad_to(arr: np.ndarray, n: int, fill=0) -> np.ndarray:
    pad = n - arr.shape[0]
    if pad <= 0:
        return arr
    return np.concatenate(
        [arr, np.full((pad,) + arr.shape[1:], fill, arr.dtype)])


def build_sharded_bst(sketches: np.ndarray, b: int, n_shards: int,
                      lam: float = 0.5) -> ShardedBST:
    """One SPMD-servable index over ``n_shards`` padded per-shard bSTs.

    sketches: (n, L) uint8 over Σ=[0, 2^b); global id i lands on shard
    ``i % n_shards``.  All shards share one static layer plan (computed
    from aggregate stats) and common padded array shapes; true sizes
    travel as int32 data (see module docstring)."""
    n, L = sketches.shape
    shard_of = (np.arange(n) % n_shards).astype(np.int64)
    tries: List[TrieLevels] = []
    locals_: List[np.ndarray] = []
    pos_of = np.zeros(n, np.int64)
    for s in range(n_shards):
        ids = np.flatnonzero(shard_of == s)
        pos_of[ids] = np.arange(len(ids))
        locals_.append(ids)
        tries.append(build_trie_levels(sketches[ids], b))

    # common layer plan from aggregate stats
    agg_t = [sum(tr.t[lev] for tr in tries) for lev in range(L + 1)]
    lm = 0
    A = 1 << b
    while lm + 1 <= L and agg_t[lm + 1] == n_shards * (A ** (lm + 1)):
        lm += 1
    ls = L
    while ls - 1 >= lm and agg_t[L] / max(agg_t[ls - 1], 1) < 1.0 / lam:
        ls -= 1
    ls = max(ls, lm)
    kinds: List[str] = []
    for lev in range(1, ls + 1):
        if lev <= lm:
            kinds.append("dense")
        elif agg_t[lev] * (b + 1) < agg_t[lev - 1] * A:
            kinds.append("list")
        else:
            kinds.append("table")

    levels: List[ShardedLevel] = []
    for lev in range(1, ls + 1):
        kind = kinds[lev - 1]
        if kind == "dense":
            levels.append(ShardedLevel("dense", None, None, None))
            continue
        words_l, cum_l, labels_l = [], [], []
        for tr in tries:
            if kind == "table":
                bits = np.zeros(A * tr.t[lev - 1], dtype=np.uint8)
                pos = tr.parents[lev] * A + tr.labels[lev].astype(np.int64)
                bits[pos] = 1
                bv = BitVector.from_bits(bits)
                labels_l.append(np.zeros(1, np.uint8))
            else:
                par = tr.parents[lev]
                first = (np.concatenate([[True], par[1:] != par[:-1]])
                         if len(par) > 1 else np.ones(len(par), bool))
                bv = BitVector.from_bits(first.astype(np.uint8))
                labels_l.append(np.asarray(tr.labels[lev]))
            words_l.append(np.asarray(bv.words))
            cum_l.append(np.asarray(bv.cum))
        wmax = max(w.shape[0] for w in words_l)
        tmax = max(l.shape[0] for l in labels_l)
        words = np.stack([_pad_to(w, wmax) for w in words_l])
        cum = np.stack([_pad_to(c, wmax + 1, fill=c[-1]) for c in cum_l])
        labels = np.stack([_pad_to(l, tmax) for l in labels_l])
        levels.append(ShardedLevel(
            kind, jnp.asarray(words), jnp.asarray(cum),
            jnp.asarray(labels) if kind == "list" else None))

    # sparse tail
    sfx = L - ls
    tl_max = max(tr.t[L] for tr in tries)
    n_max = max(len(ids) for ids in locals_)
    paths, dwords, dcums, leafroots, idleafs = [], [], [], [], []
    for tr in tries:
        t_L = tr.t[L]
        if sfx > 0:
            planes = pack_vertical(tr.uniq[:, ls:], b)      # (t_L, b, W)
            pv = np.transpose(planes, (1, 2, 0))            # (b, W, t_L)
        else:
            pv = np.zeros((b, 1, t_L), np.uint32)
        pv = np.concatenate(
            [pv, np.zeros(pv.shape[:2] + (tl_max - t_L,), np.uint32)], -1)
        paths.append(pv)
        lr = tr.node_of_leaf[ls]
        d_bits = (np.concatenate([[1], (lr[1:] != lr[:-1]).astype(np.uint8)])
                  if t_L > 1 else np.ones(t_L, np.uint8))
        bv = BitVector.from_bits(d_bits)
        dwords.append(np.asarray(bv.words))
        dcums.append(np.asarray(bv.cum))
        leafroots.append(_pad_to(np.asarray(lr, np.int32), tl_max,
                                 fill=tr.t[ls]))
        idleafs.append(_pad_to(np.asarray(tr.id_leaf, np.int32), n_max))
    wd = max(w.shape[0] for w in dwords)
    t_mat = np.stack([np.asarray(tr.t, np.int32) for tr in tries])
    max_lpr = 1
    for tr in tries:
        lr = tr.node_of_leaf[ls]
        if len(lr):
            max_lpr = max(max_lpr, int(np.bincount(lr).max()))

    return ShardedBST(
        levels=tuple(levels),
        t=jnp.asarray(t_mat),
        paths_vert=jnp.asarray(np.stack(paths)),
        d_words=jnp.asarray(np.stack([_pad_to(w, wd) for w in dwords])),
        d_cum=jnp.asarray(np.stack([_pad_to(c, wd + 1, fill=c[-1])
                                    for c in dcums])),
        leaf_root=jnp.asarray(np.stack(leafroots)),
        id_leaf=jnp.asarray(np.stack(idleafs)),
        n_local=jnp.asarray([len(ids) for ids in locals_], jnp.int32),
        shard_of=shard_of, pos_of=pos_of,
        L=L, b=b, lm=lm, ls=ls, kinds=tuple(kinds), n_max=n_max,
        max_leaves_per_root=max_lpr)


# ---------------------------------------------------------------------------
# single-shard traced search with dynamic sizes
# ---------------------------------------------------------------------------

def _children_dense(u, b):
    A = 1 << b
    c = jnp.arange(A, dtype=jnp.int32)[None, :]
    ids = u[:, None] * A + c
    return ids, jnp.broadcast_to(c, ids.shape), jnp.ones(ids.shape, bool)


def _children_table(words, cum, u, t_prev, b):
    A = 1 << b
    c = jnp.arange(A, dtype=jnp.int32)[None, :]
    u_safe = jnp.clip(u, 0, jnp.maximum(t_prev - 1, 0))
    pos = u_safe[:, None] * A + c
    length = t_prev * A
    w = pos >> WORD_SHIFT
    r = (pos & WORD_MASK).astype(jnp.uint32)
    bit = (words[jnp.minimum(w, words.shape[0] - 1)] >> r) & jnp.uint32(1)
    exists = (bit == 1) & (pos < length)
    ids = _rank(words, cum, pos, length)
    return ids, jnp.broadcast_to(c, ids.shape), exists


def _children_list(words, cum, labels, u, t_prev, t_cur, b):
    A = 1 << b
    u_safe = jnp.clip(u, 0, jnp.maximum(t_prev - 1, 0))
    length = jnp.int32(words.shape[0] * 32)
    start = _select(words, cum, u_safe + 1, length)
    end = jnp.minimum(_select(words, cum, u_safe + 2, length), t_cur)
    j = jnp.arange(A, dtype=jnp.int32)[None, :]
    ids = start[:, None] + j
    exists = ids < end[:, None]
    lab = labels[jnp.clip(ids, 0, labels.shape[0] - 1)].astype(jnp.int32)
    return ids, lab, exists


def _shard_search(index: ShardedBST, shard_levels, shard_t, paths_vert,
                  d_words, d_cum, leaf_root, id_leaf, n_local,
                  q: jnp.ndarray, tau: int, caps,
                  verify: str = "scan"):
    """One shard, one query -> ((n_max,) bool local mask, (n_max,) int32
    exact local distances — BIG off-mask and on pad lanes, overflow).

    ``verify``: "scan" streams EVERY collapsed suffix path past the query
    (pruning = masking — the original TPU adaptation);  "gather" (§Perf
    P7) restores the paper's pruning to the verification stage: only the
    leaves under *surviving* ℓ_s roots are gathered into a fixed-capacity
    candidate buffer and verified — the dominant bytes term drops by the
    pruned fraction.
    """
    q = q.astype(jnp.int32)
    ids = jnp.zeros((1,), jnp.int32)
    dists = jnp.zeros((1,), jnp.int32)
    valid = jnp.ones((1,), bool)
    overflow = jnp.int32(0)
    b = index.b
    for lev in range(1, index.ls + 1):
        kind = index.kinds[lev - 1]
        lv = shard_levels[lev - 1]
        t_prev = shard_t[lev - 1]
        t_cur = shard_t[lev]
        if kind == "dense":
            c_ids, c_lab, c_ex = _children_dense(ids, b)
        elif kind == "table":
            c_ids, c_lab, c_ex = _children_table(
                lv[0], lv[1], ids, t_prev, b)
        else:
            c_ids, c_lab, c_ex = _children_list(
                lv[0], lv[1], lv[2], ids, t_prev, t_cur, b)
        c_d = dists[:, None] + (c_lab != q[lev - 1]).astype(jnp.int32)
        c_v = valid[:, None] & c_ex & (c_d <= tau)
        ids, dists, valid, ov = _compact(
            c_ids.reshape(-1), c_d.reshape(-1), c_v.reshape(-1), caps[lev])
        overflow = overflow + ov

    t_L = shard_t[index.L]
    t_Lmax = index.paths_vert.shape[-1]
    sfx = index.L - index.ls
    q_sfx = (pack_vertical_jax(q[index.ls:][None], b)[0] if sfx > 0 else None)

    if verify == "gather":
        # leaf range per surviving root from the leftmost-leaf bitvector
        safe = jnp.where(valid, ids, 0)
        start = _select(d_words, d_cum, safe + 1, t_L)      # (F,)
        end = jnp.minimum(_select(d_words, d_cum, safe + 2, t_L), t_L)
        counts = jnp.where(valid, jnp.maximum(end - start, 0), 0)
        prefix = jnp.cumsum(counts)                          # inclusive
        total = prefix[-1]
        cap_v = min(t_Lmax, caps[index.ls] * index.max_leaves_per_root)
        slots = jnp.arange(cap_v, dtype=jnp.int32)
        root_idx = jnp.searchsorted(prefix, slots, side="right")
        root_idx = jnp.clip(root_idx, 0, start.shape[0] - 1)
        excl = prefix[root_idx] - counts[root_idx]
        leaf = start[root_idx] + (slots - excl)
        ok = slots < jnp.minimum(total, cap_v)
        leaf_safe = jnp.clip(leaf, 0, t_Lmax - 1)
        overflow = overflow + jnp.maximum(total - cap_v, 0)
        base = jnp.where(ok, dists[root_idx], BIG)
        if sfx > 0:
            cand = paths_vert[:, :, leaf_safe]               # (b, W, cap_v)
            hm, cand_dist = ops.sparse_verify(cand, q_sfx, base, tau=tau,
                                              use_kernel=False)
            hit = hm > 0
        else:
            hit = base <= tau
            cand_dist = base
        slot = jnp.where(ok, leaf_safe, t_Lmax)
        survive = jnp.zeros((t_Lmax,), bool)
        survive = survive.at[slot].max(hit & ok, mode="drop")
        leaf_dist = jnp.full((t_Lmax,), BIG, jnp.int32).at[slot].min(
            jnp.where(hit & ok, cand_dist, BIG), mode="drop")
    else:
        base_root = jnp.full((t_Lmax + 1,), BIG, jnp.int32)
        safe = jnp.where(valid, ids, 0)
        base_root = base_root.at[safe].min(jnp.where(valid, dists, BIG),
                                           mode="drop")
        base_leaf = base_root[jnp.clip(leaf_root, 0, base_root.shape[0] - 1)]
        lanes = jnp.arange(t_Lmax)
        base_leaf = jnp.where(lanes < t_L, base_leaf, BIG)
        if sfx > 0:
            hm, leaf_dist = ops.sparse_verify(paths_vert, q_sfx, base_leaf,
                                              tau=tau, use_kernel=False)
            survive = hm > 0
        else:
            survive = base_leaf <= tau
            leaf_dist = base_leaf
    leaf_of_id = jnp.clip(id_leaf, 0, survive.shape[0] - 1)
    mask = survive[leaf_of_id] & (jnp.arange(index.n_max) < n_local)
    dist = jnp.where(mask, leaf_dist[leaf_of_id], BIG)
    return mask, dist, overflow


def _shard_search_batch(index: ShardedBST, shard_levels, shard_t, paths_vert,
                        d_words, d_cum, leaf_root, id_leaf, n_local,
                        qs: jnp.ndarray, tau: int, caps,
                        block_m: int = DEFAULT_BLOCK_M):
    """One shard, the WHOLE query batch -> ((m, n_max) bool local masks,
    (m, n_max) int32 exact local distances, (m,) int32 overflow).

    The natively batched analogue of ``_shard_search`` for the "scan"
    verify mode (DESIGN.md §3): a (m, cap) 2D frontier with one shared
    children() lookup per level, a batched scatter-min onto per-query
    ℓ_s-root planes, and the query-tiled batch verify over the padded
    collapsed-path array — so under SPMD each device streams its local
    path array once per ⌈m/block_m⌉ query tile rather than once per
    query.  The verify backend auto-selects (this function is vmapped
    over the shard axis; pallas_call batches the shard dim onto the
    grid): the kernel for production-sized shards, the jnp oracle when
    the padded shard is smaller than one block.  ``d_words``/``d_cum``
    ride along unused to keep the vmapped signature identical to the
    gather-verify path."""
    del d_words, d_cum
    qs = qs.astype(jnp.int32)
    m = qs.shape[0]
    ids = jnp.zeros((m, 1), jnp.int32)
    dists = jnp.zeros((m, 1), jnp.int32)
    valid = jnp.ones((m, 1), bool)
    overflow = jnp.zeros((m,), jnp.int32)
    b = index.b
    for lev in range(1, index.ls + 1):
        kind = index.kinds[lev - 1]
        lv = shard_levels[lev - 1]
        t_prev = shard_t[lev - 1]
        t_cur = shard_t[lev]
        cap = ids.shape[1]
        flat = ids.reshape(-1)
        if kind == "dense":
            c_ids, c_lab, c_ex = _children_dense(flat, b)
        elif kind == "table":
            c_ids, c_lab, c_ex = _children_table(lv[0], lv[1], flat, t_prev, b)
        else:
            c_ids, c_lab, c_ex = _children_list(
                lv[0], lv[1], lv[2], flat, t_prev, t_cur, b)
        A = c_ids.shape[-1]
        c_ids = c_ids.reshape(m, cap, A)
        c_lab = c_lab.reshape(m, cap, A)
        c_ex = c_ex.reshape(m, cap, A)
        q_char = qs[:, lev - 1][:, None, None]
        c_d = dists[:, :, None] + (c_lab != q_char).astype(jnp.int32)
        c_v = valid[:, :, None] & c_ex & (c_d <= tau)
        ids, dists, valid, ov = _compact_batch(
            c_ids.reshape(m, -1), c_d.reshape(m, -1), c_v.reshape(m, -1),
            caps[lev])
        overflow = overflow + ov

    t_L = shard_t[index.L]
    t_Lmax = index.paths_vert.shape[-1]
    sfx = index.L - index.ls
    row = jnp.arange(m, dtype=jnp.int32)[:, None]
    safe = jnp.where(valid, ids, 0)
    base_root = jnp.full((m, t_Lmax + 1), BIG, jnp.int32).at[row, safe].min(
        jnp.where(valid, dists, BIG), mode="drop")
    lr_safe = jnp.clip(leaf_root, 0, t_Lmax)
    base_leaf = base_root[:, lr_safe]                        # (m, t_Lmax)
    lanes = jnp.arange(t_Lmax)
    base_leaf = jnp.where(lanes[None, :] < t_L, base_leaf, BIG)
    if sfx > 0:
        q_sfx = jnp.transpose(pack_vertical_jax(qs[:, index.ls:], b),
                              (1, 2, 0))                     # (b, W, m)
        hm, leaf_dist = ops.sparse_verify_batch(paths_vert, q_sfx, base_leaf,
                                                tau=tau, block_m=block_m)
        survive = hm > 0
    else:
        survive = base_leaf <= tau
        leaf_dist = base_leaf
    leaf_of_id = jnp.clip(id_leaf, 0, t_Lmax - 1)
    local = (jnp.arange(index.n_max) < n_local)[None, :]
    mask = survive[:, leaf_of_id] & local
    dist = jnp.where(mask, leaf_dist[:, leaf_of_id], BIG)
    return mask, dist, overflow


def expected_caps(t: Tuple[int, ...], b: int, tau: int,
                  safety: int = 16, floor: int = 64) -> Tuple[int, ...]:
    """Expected-case frontier capacities (§Perf P8): for uniform sketches
    the expected level-ℓ frontier is t_ℓ · sigs(b, ℓ, τ) / A^ℓ — orders of
    magnitude below the worst-case sigs bound that ``frontier_capacities``
    allocates.  Exactness is preserved by the overflow counter + host
    retry ladder (the same discipline as core.search)."""
    import math
    A = 1 << b
    caps = [1]
    for lev in range(1, len(t)):
        exp = t[lev] * min(
            sum(math.comb(lev, k) * (A - 1) ** k for k in range(tau + 1))
            / float(A) ** lev, 1.0)
        caps.append(int(min(t[lev], max(floor, safety * math.ceil(exp)))))
    return tuple(caps)


def _shard_args(index: ShardedBST):
    """The vmappable per-shard array stack of a ShardedBST (shared by
    ``make_sharded_searcher`` and ``sharded_column_dists``)."""
    level_arrays = tuple(
        (lv.words, lv.cum, lv.labels) if lv.kind == "list"
        else (lv.words, lv.cum) if lv.kind == "table" else ()
        for lv in index.levels)
    return (level_arrays, index.t, index.paths_vert, index.d_words,
            index.d_cum, index.leaf_root, index.id_leaf, index.n_local)


def sharded_column_dists(index: ShardedBST, queries: jnp.ndarray, tau: int,
                         caps, block_m: int = DEFAULT_BLOCK_M,
                         live: jnp.ndarray | None = None):
    """Traced sharded search merged onto global columns — the sharded
    backend's contribution to the one-dispatch segment arena
    (DESIGN.md §6).

    queries: (m, L) int/uint8 -> ((m, n) int32 exact global column
    distances — BIG off-mask and on dead columns, int32 total overflow).
    Runs the vmapped per-shard batched traversal+verify
    (``_shard_search_batch``) and performs the shard→global merge **on
    device** via the static ``shard_of``/``pos_of`` gathers (the host
    path materializes the same merge in numpy per segment per rung —
    this helper lets the dynamic segmented index inline a whole sharded
    segment as a sub-trace of its single fused program).  ``live``:
    optional (n,) bool tombstone lane over global rows."""
    def per_shard(levels, t_row, pv, dw, dc, lr, il, nl):
        return _shard_search_batch(index, levels, t_row, pv, dw, dc, lr,
                                   il, nl, queries, tau, caps,
                                   block_m=block_m)
    _, dists, overflows = jax.vmap(per_shard)(*_shard_args(index))
    dists = jnp.transpose(dists, (1, 0, 2))            # (m, S, n_max)
    merged = dists[:, index.shard_of, index.pos_of]    # (m, n)
    if live is not None:
        merged = jnp.where(live[None, :], merged, BIG)
    return merged, overflows.sum()


def make_sharded_searcher(index: ShardedBST, tau: int,
                          cap_max: int = 1 << 14, verify: str = "scan",
                          caps_mode: str = "worst",
                          block_m: int = DEFAULT_BLOCK_M):
    """Returns jitted f(queries (m, L)) -> ((m, S, n_max) bool masks,
    (m, S, n_max) int32 exact distances, int32 overflow).  The shard axis
    vmaps — under jit-with-shardings it partitions over the mesh data
    axes (each device runs only its own shard's trie).

    For ``verify="scan"`` (the default) the query axis is natively
    batched inside each shard (``_shard_search_batch``): one 2D-frontier
    traversal and one query-tiled verify per shard for the whole batch.
    ``verify="gather"`` keeps the per-query trace (candidate gathering is
    query-dependent) and vmaps over queries as before."""
    t_max = tuple(int(x) for x in np.asarray(index.t).max(axis=0))
    if caps_mode == "expected":
        caps = expected_caps(t_max, index.b, tau)
    else:
        caps = frontier_capacities(t_max, index.b, tau, cap_max)
    shard_args = _shard_args(index)

    if verify == "scan":
        def search(queries):
            def per_shard(levels, t_row, pv, dw, dc, lr, il, nl):
                return _shard_search_batch(
                    index, levels, t_row, pv, dw, dc, lr, il, nl,
                    queries, tau, caps, block_m=block_m)
            masks, dists, overflows = jax.vmap(per_shard)(*shard_args)
            # (S, m, ...) -> (m, S, ...): keep the public result contract
            return (jnp.transpose(masks, (1, 0, 2)),
                    jnp.transpose(dists, (1, 0, 2)), overflows.sum())
    else:
        def one_shard(levels, t_row, pv, dw, dc, lr, il, nl, q):
            return _shard_search(index, levels, t_row, pv, dw, dc, lr, il,
                                 nl, q, tau, caps, verify=verify)

        def search(queries):
            def per_query(q):
                return jax.vmap(
                    lambda levels, t_row, pv, dw, dc, lr, il, nl: one_shard(
                        levels, t_row, pv, dw, dc, lr, il, nl, q)
                )(*shard_args)
            masks, dists, overflows = jax.vmap(per_query)(queries)
            return masks, dists, overflows.sum()

    return jax.jit(search)


def gather_ids(index: ShardedBST, masks: np.ndarray) -> List[np.ndarray]:
    """(m, S, n_max) masks -> per-query arrays of global ids."""
    out = []
    n = index.shard_of.shape[0]
    # global id -> (shard, pos) lookup is host-side metadata
    for qmask in masks:
        hit = qmask[index.shard_of, index.pos_of]
        out.append(np.flatnonzero(hit))
    return out


def topk_from_dists(dists: np.ndarray, k: int,
                    ids: Optional[np.ndarray] = None
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Select per-query top-k from merged distance planes.

    dists: (m, n) int32 — one distance per (query, column), BIG on
    non-results; ids: optional (n,) int global labels per column
    (default: the column index itself).  Returns ((m, k) int32 ids,
    (m, k) int32 dists), each row sorted ascending by (distance, label);
    slots beyond a query's real survivors are (-1, BIG) pads.  This is
    the shared shard-merge selection: ``gather_topk`` feeds it the
    all-gathered shard planes (columns == global ids) and the dynamic
    segmented index (``core.segments``) feeds it column-compressed
    fan-out planes labeled by stable global ids.
    """
    m, n = dists.shape
    kk = min(k, n)
    labels = np.arange(n, dtype=np.int64) if ids is None \
        else np.asarray(ids, dtype=np.int64)
    out_ids = np.full((m, k), -1, np.int32)
    out_d = np.full((m, k), int(BIG), np.int32)
    for qi in range(m):
        d = np.asarray(dists[qi])
        # partial selection, then a full (distance, label) sort over
        # every candidate at or below the k-th distance — a bare
        # argpartition would pick arbitrarily among ties at the boundary
        if kk < n:
            thresh = d[np.argpartition(d, kk - 1)[:kk]].max()
            cand = np.flatnonzero(d <= thresh)
        else:
            cand = np.arange(n)
        order = cand[np.lexsort((labels[cand], d[cand]))][:kk]
        real = d[order] < int(BIG)
        out_ids[qi, :kk] = np.where(real, labels[order], -1)
        out_d[qi, :kk] = d[order]
    return out_ids, out_d


def gather_topk(index: ShardedBST, dists: np.ndarray,
                k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Merge per-shard distance planes into global per-query top-k.

    dists: (m, S, n_max) int32 from the sharded searcher (BIG off-mask).
    Returns ((m, k) ids, (m, k) dists), each row sorted ascending by
    (distance, id): the sharded analogue of ``core.topk``'s final
    selection, run host-side after the result all-gather
    (``topk_from_dists``).  Slots beyond a query's within-τ survivors are
    (-1, BIG) pads — unlike ``core.topk`` there is no τ-escalation here,
    so fewer than k real neighbors can come back; re-search at a larger τ
    to fill them.
    """
    merged = np.asarray(dists)[:, index.shard_of, index.pos_of]  # (m, n)
    return topk_from_dists(merged, k)
