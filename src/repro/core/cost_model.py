"""Query cost model (paper Appendix A, Eq. 2-4).

Used three ways:
  * benchmark ``bench_fig8`` reproduces Figure 8's curves;
  * the auto-tuner (``multi_index.choose_plan``) picks single- vs
    multi-index and the block count ``m`` per (b, L, τ, n) — mirroring the
    paper's empirical "MI-bST with m=2 was fastest / SI best for τ<=4";
  * the searcher derives static frontier capacities from ``sigs`` (the
    level-ℓ frontier is a subset of both the t_ℓ trie nodes and the
    sigs(b, ℓ, τ) strings within distance τ).
"""

from __future__ import annotations

import math
from typing import List, Tuple

_CAP = float(2**62)


def sigs(b: int, L: int, tau: int) -> float:
    """Number of signatures |{q' : ham(q, q') <= tau}| (Eq. 3); float with
    saturation (the exact value overflows int64 for large b, L, τ)."""
    total = 0.0
    for k in range(min(tau, L) + 1):
        total += math.comb(L, k) * float((1 << b) - 1) ** k
        if total > _CAP:
            return _CAP
    return total


def tau_for_k(b: int, L: int, n: float, k: int) -> int:
    """Smallest τ whose expected candidate count over a uniform DB of n
    sketches reaches k: |I(τ)| ≈ n·sigs(b, L, τ)/(2^b)^L (Appendix A).
    Seeds the τ-escalation ladders of ``search.topk*`` and the dynamic
    segmented index — one estimator, every ladder."""
    denom = float(1 << b) ** min(L, 64)
    n = max(float(n), 1.0)
    for tau in range(L + 1):
        if sigs(b, L, tau) * n / denom >= k:
            return tau
    return L


def cost_single(b: int, L: int, tau: int, n: float) -> float:
    """cost_S = sigs(b,L,τ)·L + |I|  (Eq. 2), with |I| estimated under the
    uniform-distribution assumption of Appendix A."""
    s = sigs(b, L, tau)
    expected_I = min(s * n / float(1 << b) ** min(L, 64), n)
    return s * L + expected_I


def _block_lengths(L: int, m: int) -> List[int]:
    base = L // m
    rem = L - base * m
    return [base + 1] * rem + [base] * (m - rem)


def block_thresholds(tau: int, m: int, mih_style: bool = False) -> List[int]:
    """Pigeonhole thresholds.  Traditional rule: τ^j = ⌊τ/m⌋ (no false
    negatives).  MIH rule: the first τ − m·⌊τ/m⌋ + 1 blocks get ⌊τ/m⌋ − 1
    [Norouzi et al.], valid because a candidate must beat the *strict*
    bound in at least one block."""
    base = tau // m
    if not mih_style:
        return [base] * m
    k = tau - m * base + 1
    out = [max(base - 1, 0)] * k + [base] * (m - k)
    return out


def cost_multi(b: int, L: int, tau: int, n: float, m: int,
               mih_style: bool = False) -> float:
    """cost_M (Eq. 4): filtering + verification, uniform-DB candidate
    estimate |C^j| = sigs(b, L^j, τ^j)·n/(2^b)^{L^j}."""
    lens = _block_lengths(L, m)
    taus = block_thresholds(tau, m, mih_style)
    total = 0.0
    for Lj, tj in zip(lens, taus):
        s = sigs(b, Lj, tj)
        cand = min(s * n / float(1 << b) ** Lj, n)
        total += s * Lj + L * cand
    return total


def frontier_capacities(t: Tuple[int, ...], b: int, tau: int,
                        cap_max: int = 1 << 17) -> Tuple[int, ...]:
    """Static frontier capacity per level: min(t_ℓ, sigs(b, ℓ, τ), cap_max).
    ``cap_max`` bounds memory; the searcher detects overflow and the host
    wrapper retries on the next rung of the ladder."""
    caps = []
    for lev in range(len(t)):
        s = sigs(b, lev, tau)
        caps.append(int(min(float(t[lev]), s, float(cap_max))))
    return tuple(max(c, 1) for c in caps)
