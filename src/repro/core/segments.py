"""Dynamic segmented bST index: streaming insert/delete with background
merge, never blocking search (DESIGN.md §4).

The paper's bST is static — ``build_trie_levels`` consumes the whole
sketch matrix up front — but the trie family supports incremental
maintenance (Kanda & Tabei's follow-up, arXiv 2009.11559).  This module
adds the LSM-style construction on top of the *unchanged* static
machinery:

  * a small mutable **delta buffer** absorbs inserts and answers queries
    by brute-force Hamming scan (the batch verify kernel's
    ``ops.hamming_distances`` — exact distances at any τ for free);
  * sealed **segments** are immutable bSTs (or MI-bST / sharded-bST
    stacks) with a per-segment **tombstone bitmap**: ``delete`` flips a
    bit, and the liveness bitmap is a *traced* argument of the cached
    compiled searcher (``get_searcher(..., with_live=True)``), so
    deletes never re-jit and dead leaves are pruned inside the verify
    stage (``ops.sparse_verify*(..., live=...)``);
  * a size-tiered ``merge()`` rebuilds two segments into one via
    ``build_trie_levels`` (dropping tombstones as it goes) and
    ``compact()`` rebuilds a single segment to reclaim tombstoned rows;
  * ``search``/``topk``/``topk_batch`` fan out over the delta buffer and
    every segment, merge the per-segment distance planes onto the global
    id space, and reuse the shard-merge selection
    (``distributed_search.topk_from_dists``) — results are bit-identical
    to a static bST built from the surviving sketches (ties by id, and
    global ids are assigned monotonically, so the tie order matches the
    static build's insertion order).

Ids are **stable**: ``insert`` assigns monotonically increasing global
ids that survive merges and compactions.  Internally everything is
column-compressed — fan-out planes are (m, R) over the *physical* rows
currently held, labeled by global id, so churn cost tracks the live
corpus (R is reclaimed by merge/compact).  Only the range-search result
contract (``search_batch``'s (m, n_ids) mask/dist planes) materializes
the full ever-assigned id axis; ``topk*`` never does.

Shapes and dtypes: sketches are (n, L) uint8 over Σ=[0, 2^b); result
masks are (m, n_ids) bool, distances (m, n_ids) int32 with BIG
(= 1 << 20) on non-results; ids returned by ``insert``/``topk`` are
int64 / int32 global ids.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..kernels.hamming_kernel import DEFAULT_BLOCK_M
from .bst import BIG, build_bst
from .cost_model import tau_for_k
from .distributed_search import (build_sharded_bst, make_sharded_searcher,
                                 topk_from_dists)
from .hamming import pack_vertical
from .multi_index import build_multi_index, mi_search_batch
from .search import (CAP_MAX_DEFAULT, LADDER_CAP_MAX, TopKResult,
                     _pin_cache_get, get_searcher)

BIG_I = int(BIG)

BACKENDS = ("bst", "multi", "sharded")


def tombstone_bits(n: int) -> int:
    """Storage cost in bits of one tombstone bitmap over ``n`` ids,
    accounted exactly like ``BitVector.nbits`` (payload words + the
    32-bit-per-word rank directory a succinct liveness bitmap carries):
    word-padded payload + cumulative-popcount table.

    >>> tombstone_bits(64)      # 2 payload words + 3 table entries
    160
    """
    n_words = max(1, (int(n) + 31) // 32)
    return n_words * 32 + (n_words + 1) * 32


@dataclasses.dataclass
class Segment:
    """One immutable sealed segment: a static index + host-side metadata.

    Attributes:
      index:    the queryable structure (``SketchIndex``, ``MultiIndex``,
                or ``ShardedBST`` depending on the stack's backend).
      sketches: (n_seg, L) uint8 — retained host-side so merges/compacts
                can rebuild without touching the encodings.
      ids:      (n_seg,) int64 global ids, sorted ascending.
      live:     (n_seg,) bool tombstone bitmap (False = deleted).
    """

    index: object
    sketches: np.ndarray
    ids: np.ndarray
    live: np.ndarray

    @property
    def n(self) -> int:
        return int(self.ids.shape[0])

    @property
    def n_live(self) -> int:
        return int(self.live.sum())


class SegmentedSearchResult(NamedTuple):
    mask: np.ndarray      # (m, n_ids) bool — live ids within τ per query
    dist: np.ndarray      # (m, n_ids) int32 — exact distance where mask, BIG off
    overflow: int         # total dropped frontier entries (0 = exact)


# make_sharded_searcher has no process-level cache of its own (the static
# pipeline jits once per program); segment stacks re-enter it per search,
# so pin compiled sharded searchers here with the same discipline as
# search._SEARCHER_CACHE.
_SHARDED_SEARCHER_CACHE: Dict[tuple, tuple] = {}
_SHARDED_SEARCHER_CACHE_CAP = 64


def _ladder_topk(columns_fn, n_live: int, b: int, L: int, qs: np.ndarray,
                 k: int, tau0: Optional[int]) -> TopKResult:
    """The shared kNN ladder over column-compressed fan-out results.

    ``columns_fn(qs, tau)`` -> ((m, R) int32 distances over the physical
    columns — BIG on non-results, (R,) int64 global id per column,
    overflow).  Escalates τ (seeded by ``cost_model.tau_for_k`` over the
    live count) until every query has ≥ min(k, n_live) survivors, then
    runs the shard-merge selection with the global-id labels.  Working
    memory is O(m·R) where R is the *physical* row count (reclaimed by
    merge/compact), not the ever-assigned global id space."""
    m = qs.shape[0]
    if n_live == 0:
        return TopKResult(ids=jnp.full((m, k), -1, jnp.int32),
                          dists=jnp.full((m, k), BIG_I, jnp.int32),
                          tau=0, overflow=0)
    kk = min(int(k), n_live)
    tau = tau0 if tau0 is not None else tau_for_k(b, L, n_live, kk)
    tau = min(max(int(tau), 0), L)
    while True:
        dist, col_ids, overflow = columns_fn(qs, tau)
        if int((dist < BIG_I).sum(axis=1).min()) >= kk or tau >= L:
            break
        tau = min(L, max(tau + 1, 2 * tau))
    ids, dists = topk_from_dists(dist, int(k), ids=col_ids)
    return TopKResult(ids=jnp.asarray(ids), dists=jnp.asarray(dists),
                      tau=tau, overflow=overflow)


class SegmentedIndex:
    """A dynamic, incrementally maintained index over b-bit sketches.

    Parameters:
      L, b:       sketch length / bits per character (Σ = [0, 2^b)).
      delta_cap:  delta-buffer rows that trigger an automatic ``flush``.
      backend:    "bst" (default) — each segment is one bST;
                  "multi"   — each segment is an MI-bST (``mi_blocks``);
                  "sharded" — each segment is a padded S-shard ShardedBST
                  searched through ``make_sharded_searcher`` (each shard
                  of the SPMD program serves its slice of the segment).
      mi_blocks:  block count for backend="multi".
      n_shards:   shard count for backend="sharded" (clamped to the
                  segment size).
      lam:        the paper's λ collapse parameter, forwarded to builds.
      auto_merge: run the size-tiered merge policy after every automatic
                  flush (manual ``flush()`` never merges implicitly).
      block_m:    query-tile size forwarded to the batched verify kernel.

    >>> import numpy as np
    >>> idx = SegmentedIndex(L=8, b=2, delta_cap=4)
    >>> ids = idx.insert(np.zeros((5, 8), np.uint8))   # auto-flush at 4
    >>> (len(ids), idx.n_live, len(idx.segments))
    (5, 5, 1)
    >>> int(idx.delete(ids[:2]))
    2
    >>> idx.n_live
    3
    """

    def __init__(self, L: int, b: int, *, delta_cap: int = 4096,
                 backend: str = "bst", mi_blocks: int = 2, n_shards: int = 4,
                 lam: float = 0.5, auto_merge: bool = True,
                 block_m: int = DEFAULT_BLOCK_M):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        self.L = int(L)
        self.b = int(b)
        self.delta_cap = int(delta_cap)
        self.backend = backend
        self.mi_blocks = int(mi_blocks)
        self.n_shards = int(n_shards)
        self.lam = float(lam)
        self.auto_merge = bool(auto_merge)
        self.block_m = int(block_m)

        self.segments: List[Segment] = []
        self.n_ids = 0                      # global ids ever assigned
        self._delta_sk = np.zeros((0, self.L), np.uint8)
        self._delta_ids = np.zeros((0,), np.int64)
        self._delta_live = np.zeros((0,), bool)
        self._delta_vert: Optional[jnp.ndarray] = None  # cached (b, W, nd)
        self.counters = {"flushes": 0, "merges": 0, "compactions": 0,
                         "inserted": 0, "deleted": 0}
        # write hook: fn(event: str, info: dict) fired after every
        # lifecycle write ("insert" / "delete" / "flush" / "merge" /
        # "compact") — the serving layer's metrics tap (DESIGN.md §5).
        # Exceptions are the caller's problem; keep hooks cheap.
        self.event_hook: Optional[object] = None

    # -- mutation --------------------------------------------------------

    def _emit(self, event: str, **info) -> None:
        if self.event_hook is not None:
            self.event_hook(event, info)

    def insert(self, sketches: np.ndarray) -> np.ndarray:
        """Append sketches to the delta buffer; returns their (k,) int64
        global ids.  ``sketches``: (k, L) or (L,) uint8 over [0, 2^b).
        Triggers ``flush`` (and, if ``auto_merge``, the size-tiered merge
        policy) once the delta buffer reaches ``delta_cap`` rows —
        search stays available throughout."""
        sk = np.asarray(sketches, dtype=np.uint8)
        if sk.ndim == 1:
            sk = sk[None, :]
        if sk.shape[1] != self.L:
            raise ValueError(f"sketch length {sk.shape[1]} != L={self.L}")
        if sk.size and int(sk.max()) >= (1 << self.b):
            raise ValueError("character exceeds alphabet [0, 2^b)")
        k = sk.shape[0]
        new_ids = np.arange(self.n_ids, self.n_ids + k, dtype=np.int64)
        self.n_ids += k
        self._delta_sk = np.concatenate([self._delta_sk, sk])
        self._delta_ids = np.concatenate([self._delta_ids, new_ids])
        self._delta_live = np.concatenate(
            [self._delta_live, np.ones(k, bool)])
        self._delta_vert = None
        self.counters["inserted"] += k
        self._emit("insert", rows=k)
        if len(self._delta_ids) >= self.delta_cap:
            self.flush()
            if self.auto_merge:
                self.maybe_merge()
        return new_ids

    def delete(self, ids) -> int:
        """Tombstone global ids (scalar or (k,) array-like); returns the
        number of ids newly deleted (already-dead or unknown ids are
        ignored).  O(k log n) searchsorted per container — no index is
        rebuilt and compiled searchers stay valid (liveness is traced)."""
        ids = np.unique(np.atleast_1d(np.asarray(ids, dtype=np.int64)))
        newly = 0
        containers: List[Tuple[np.ndarray, np.ndarray]] = [
            (self._delta_ids, self._delta_live)]
        containers += [(seg.ids, seg.live) for seg in self.segments]
        for id_arr, live_arr in containers:
            if id_arr.size == 0:
                continue
            pos = np.searchsorted(id_arr, ids)
            ok = (pos < id_arr.size) & (
                id_arr[np.minimum(pos, id_arr.size - 1)] == ids)
            sel = pos[ok]
            newly += int(live_arr[sel].sum())
            live_arr[sel] = False
        self.counters["deleted"] += newly
        self._emit("delete", rows=newly)
        return newly

    def flush(self) -> Optional[Segment]:
        """Seal the delta buffer's live rows into a new immutable segment
        (dead delta rows are dropped for free).  Returns the new Segment,
        or None when nothing was live."""
        live = self._delta_live
        seg = None
        if live.any():
            sk = self._delta_sk[live]
            ids = self._delta_ids[live]
            seg = Segment(index=self._build(sk), sketches=sk, ids=ids,
                          live=np.ones(len(ids), bool))
            self.segments.append(seg)
            self.counters["flushes"] += 1
            self._emit("flush", rows=seg.n)
        self._delta_sk = np.zeros((0, self.L), np.uint8)
        self._delta_ids = np.zeros((0,), np.int64)
        self._delta_live = np.zeros((0,), bool)
        self._delta_vert = None
        return seg

    def merge(self, i: Optional[int] = None,
              j: Optional[int] = None) -> bool:
        """Rebuild two segments into one via ``build_trie_levels`` (inside
        the backend's builder), dropping tombstoned rows as it goes.
        Defaults to the two smallest segments (size-tiered choice);
        returns False when fewer than two segments exist."""
        if len(self.segments) < 2:
            return False
        if i is None or j is None:
            order = np.argsort([seg.n for seg in self.segments],
                               kind="stable")
            i, j = int(order[0]), int(order[1])
        if i == j:
            raise ValueError("cannot merge a segment with itself")
        a, b_ = self.segments[i], self.segments[j]
        sk = np.concatenate([a.sketches[a.live], b_.sketches[b_.live]])
        ids = np.concatenate([a.ids[a.live], b_.ids[b_.live]])
        order = np.argsort(ids, kind="stable")   # keep ids sorted for delete
        sk, ids = sk[order], ids[order]
        lo, hi = min(i, j), max(i, j)
        del self.segments[hi], self.segments[lo]
        if len(ids):
            self.segments.insert(lo, Segment(
                index=self._build(sk), sketches=sk, ids=ids,
                live=np.ones(len(ids), bool)))
        self.counters["merges"] += 1
        self._emit("merge", rows=int(len(ids)))
        return True

    def maybe_merge(self) -> int:
        """Size-tiered merge policy: while two segments share a size tier
        (⌊log2 n⌋ bucket), merge the two smallest of that tier.  Returns
        the number of merges performed.  Amortized O(log n) rebuilds per
        inserted row, and search never blocks (the old segments answer
        queries until the swap)."""
        merges = 0
        while True:
            tiers: Dict[int, List[int]] = {}
            for si, seg in enumerate(self.segments):
                tiers.setdefault(max(seg.n, 1).bit_length(), []).append(si)
            crowded = [idxs for idxs in tiers.values() if len(idxs) >= 2]
            if not crowded:
                return merges
            idxs = min(crowded, key=lambda g: min(self.segments[s].n
                                                  for s in g))
            pair = sorted(idxs, key=lambda s: self.segments[s].n)[:2]
            self.merge(pair[0], pair[1])
            merges += 1

    def compact(self, i: Optional[int] = None,
                min_dead_frac: float = 0.0) -> int:
        """Rebuild segment ``i`` (or every segment when None) without its
        tombstoned leaves; fully-dead segments are removed outright.
        ``min_dead_frac`` skips segments whose dead fraction is at or
        below the threshold.  Returns the number of segments rebuilt or
        removed."""
        targets = range(len(self.segments)) if i is None else [i]
        out: List[Optional[Segment]] = list(self.segments)
        done = 0
        for si in targets:
            seg = self.segments[si]
            dead = seg.n - seg.n_live
            if dead == 0 or (seg.n and dead / seg.n <= min_dead_frac):
                continue
            if seg.n_live == 0:
                out[si] = None
            else:
                sk, ids = seg.sketches[seg.live], seg.ids[seg.live]
                out[si] = Segment(index=self._build(sk), sketches=sk,
                                  ids=ids, live=np.ones(len(ids), bool))
            done += 1
        self.segments = [s for s in out if s is not None]
        self.counters["compactions"] += done
        if done:
            self._emit("compact", segments=done)
        return done

    # -- queries ---------------------------------------------------------

    def search_batch(self, qs: np.ndarray, tau: int) -> SegmentedSearchResult:
        """Range search, fanned out over the delta buffer and every
        segment.  ``qs``: (m, L) uint8 queries -> (m, n_ids) global mask
        and exact-distance planes (BIG off-mask / on dead ids)."""
        qs = np.asarray(qs, dtype=np.uint8)
        if qs.ndim == 1:
            qs = qs[None, :]
        plane, overflow = self._search_planes(qs, int(tau))
        return SegmentedSearchResult(mask=plane <= tau, dist=plane,
                                     overflow=overflow)

    def search(self, q: np.ndarray, tau: int) -> SegmentedSearchResult:
        """Single-query ``search_batch`` (m=1 planes squeezed)."""
        res = self.search_batch(np.asarray(q)[None], tau)
        return SegmentedSearchResult(mask=res.mask[0], dist=res.dist[0],
                                     overflow=res.overflow)

    def topk_batch(self, qs: np.ndarray, k: int,
                   tau0: Optional[int] = None) -> TopKResult:
        """Exact k-nearest-neighbors over the live ids: the fan-out
        planes of ``search_batch`` on a shared τ-escalation ladder, then
        the shard-merge selection (``topk_from_dists``).  ``qs``: (m, L)
        uint8 -> (m, k) int32 global ids / int32 exact distances,
        ascending by (distance, id); (-1, BIG) pads past the live count.
        Bit-identical to ``core.search.topk_batch`` on a static bST of
        the surviving sketches (after the monotone global-id mapping).
        Works over column-compressed planes — O(m · physical rows), not
        O(m · ids-ever-assigned)."""
        qs = np.asarray(qs, dtype=np.uint8)
        if qs.ndim == 1:
            qs = qs[None, :]
        return _ladder_topk(self._search_columns, self.n_live, self.b,
                            self.L, qs, k, tau0)

    def topk(self, q: np.ndarray, k: int,
             tau0: Optional[int] = None) -> TopKResult:
        """Single-query ``topk_batch`` (row 0)."""
        res = self.topk_batch(np.asarray(q)[None], k, tau0=tau0)
        return TopKResult(ids=res.ids[0], dists=res.dists[0], tau=res.tau,
                          overflow=res.overflow)

    # -- accounting ------------------------------------------------------

    @property
    def n_live(self) -> int:
        """Live (inserted minus deleted) ids across delta + segments."""
        return int(self._delta_live.sum()) + sum(
            seg.n_live for seg in self.segments)

    @property
    def tombstones(self) -> int:
        """Dead rows still physically held (reclaimable by merge/compact)
        across the delta buffer and every segment."""
        dead_delta = int((~self._delta_live).sum())
        return dead_delta + sum(seg.n - seg.n_live for seg in self.segments)

    def __len__(self) -> int:
        return self.n_live

    def space_bits(self) -> int:
        """Model-space accounting: per-segment index bits + one tombstone
        bitmap per segment and one for the delta buffer (DESIGN.md §4 —
        the dynamic overhead next to ``BitVector.nbits``'s static
        accounting), + raw delta rows at b bits per character."""
        bits = 0
        for seg in self.segments:
            bits += int(seg.index.model_bits()) + tombstone_bits(seg.n)
        nd = len(self._delta_ids)
        if nd:
            bits += nd * self.L * self.b + tombstone_bits(nd)
        return bits

    def stats(self) -> Dict[str, object]:
        """Lifecycle counters and per-segment occupancy (for dashboards
        and the ingest benchmark)."""
        return {
            "n_ids": self.n_ids, "n_live": self.n_live,
            "tombstones": self.tombstones,
            "delta_rows": int(len(self._delta_ids)),
            "delta_live": int(self._delta_live.sum()),
            "n_segments": len(self.segments),
            "segments": [(seg.n, seg.n_live) for seg in self.segments],
            "space_bits": self.space_bits(), **self.counters,
        }

    # -- internals -------------------------------------------------------

    def _build(self, sk: np.ndarray):
        if self.backend == "multi":
            return build_multi_index(sk, self.b, self.mi_blocks, self.lam)
        if self.backend == "sharded":
            return build_sharded_bst(sk, self.b,
                                     max(1, min(self.n_shards, len(sk))),
                                     self.lam)
        return build_bst(sk, self.b, self.lam)

    def _delta_planes(self) -> jnp.ndarray:
        if self._delta_vert is None:
            planes = pack_vertical(self._delta_sk, self.b)   # (nd, b, W)
            self._delta_vert = jnp.asarray(
                np.transpose(planes, (1, 2, 0)).copy())       # (b, W, nd)
        return self._delta_vert

    def _search_columns(self, qs: np.ndarray,
                        tau: int) -> Tuple[np.ndarray, np.ndarray, int]:
        """(m, L) queries -> ((m, R) int32 distances over the physical
        columns — BIG on non-results, (R,) int64 global id per column,
        total overflow), where R = rows currently held (every segment's
        rows, then the delta buffer's) — R shrinks with merge/compact,
        unlike the ever-assigned global id space.  Every segment
        contributes exact distances within τ; the delta buffer
        contributes a brute-force scan clamped to the same τ so the
        ladder logic sees one consistent contract."""
        m = qs.shape[0]
        dists: List[np.ndarray] = []
        col_ids: List[np.ndarray] = []
        overflow = 0
        qs_j = jnp.asarray(qs)
        for seg in self.segments:
            if seg.live.any():
                dist, ov = self._search_segment(seg, qs_j, tau)
                overflow += ov
            else:
                dist = np.full((m, seg.n), BIG_I, np.int32)
            dists.append(dist)
            col_ids.append(seg.ids)
        if len(self._delta_ids):
            planes = pack_vertical(qs, self.b)                # (m, b, W)
            q_vert = jnp.asarray(np.transpose(planes, (1, 2, 0)).copy())
            d = np.asarray(ops.hamming_distances(self._delta_planes(),
                                                 q_vert))     # (m, nd)
            d = np.where(self._delta_live[None, :] & (d <= tau), d, BIG_I)
            dists.append(d.astype(np.int32))
            col_ids.append(self._delta_ids)
        if not dists:
            return (np.zeros((m, 0), np.int32), np.zeros((0,), np.int64),
                    0)
        return (np.concatenate(dists, axis=1),
                np.concatenate(col_ids), overflow)

    def _search_planes(self, qs: np.ndarray,
                       tau: int) -> Tuple[np.ndarray, int]:
        """(m, L) queries -> ((m, n_ids) int32 global distance plane with
        BIG on non-results, total overflow): the column-compressed
        fan-out scattered onto the full global-id axis (the range-search
        result contract)."""
        m = qs.shape[0]
        dist, col_ids, overflow = self._search_columns(qs, tau)
        plane = np.full((m, self.n_ids), BIG_I, np.int32)
        plane[:, col_ids] = dist
        return plane, overflow

    def _search_segment(self, seg: Segment, qs_j: jnp.ndarray,
                        tau: int) -> Tuple[np.ndarray, int]:
        """One segment, the whole batch -> ((m, n_seg) int32 exact local
        distances — BIG off-mask and on tombstones, overflow).  Runs the
        backend's cached compiled searcher with the liveness bitmap as a
        traced argument, on the doubled capacity ladder until exact."""
        if self.backend == "multi":
            res = mi_search_batch(seg.index, qs_j, tau,
                                  block_m=self.block_m, id_live=seg.live)
            return (np.asarray(res.dist, dtype=np.int32),
                    int(np.asarray(res.overflow).sum()))
        if self.backend == "sharded":
            idx = seg.index
            cap = 1 << 14
            while True:
                key = (id(idx), tau, cap)

                def build():
                    return make_sharded_searcher(idx, tau, cap_max=cap)
                fn, _ = _pin_cache_get(_SHARDED_SEARCHER_CACHE,
                                       _SHARDED_SEARCHER_CACHE_CAP,
                                       key, idx, build)
                _, dists, ov = fn(qs_j)
                if int(ov) == 0 or cap >= LADDER_CAP_MAX:
                    break
                cap *= 2
            merged = np.asarray(dists)[:, idx.shard_of, idx.pos_of]
            merged = np.where(seg.live[None, :], merged, BIG_I)
            return merged.astype(np.int32), int(ov)
        live_j = jnp.asarray(seg.live)
        cap = CAP_MAX_DEFAULT
        while True:
            fn = get_searcher(seg.index, tau, cap, batch=True,
                              block_m=self.block_m, with_live=True)
            res = fn(qs_j, live_j)
            ov = int(np.asarray(res.overflow).sum())
            if ov == 0 or cap >= LADDER_CAP_MAX:
                break
            cap *= 2
        return np.asarray(res.dist, dtype=np.int32), ov


class ShardedSegmentedIndex:
    """S independent segment stacks, one per shard — the dynamic analogue
    of ``build_sharded_bst``'s layout: inserts round-robin across shards
    (matching the static builder's ``id % S`` placement), deletes route
    by id, and queries fan out over every shard's stack before the
    shared shard-merge selection.  Per-shard stacks keep every segment
    rebuild bounded by its shard's slice — a merge touches 1/S of the
    data, the same fault/rebuild granularity as the static sharded
    index.

    Same result contract as ``SegmentedIndex`` (global-id planes,
    ``TopKResult`` with global ids).
    """

    def __init__(self, L: int, b: int, n_shards: int = 4, *,
                 delta_cap: int = 4096, backend: str = "bst",
                 lam: float = 0.5, auto_merge: bool = True,
                 block_m: int = DEFAULT_BLOCK_M):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.L, self.b = int(L), int(b)
        self.n_shards = int(n_shards)
        self.shards = [
            SegmentedIndex(L, b, delta_cap=delta_cap, backend=backend,
                           lam=lam, auto_merge=auto_merge, block_m=block_m)
            for _ in range(self.n_shards)]
        self.n_ids = 0
        # global id -> shard is `id % S`; per-shard local ids are dense,
        # so global id maps to local position `id // S`.

    def insert(self, sketches: np.ndarray) -> np.ndarray:
        """Round-robin insert; returns (k,) int64 global ids."""
        sk = np.asarray(sketches, dtype=np.uint8)
        if sk.ndim == 1:
            sk = sk[None, :]
        k = sk.shape[0]
        new_ids = np.arange(self.n_ids, self.n_ids + k, dtype=np.int64)
        for s in range(self.n_shards):
            rows = np.flatnonzero(new_ids % self.n_shards == s)
            if rows.size:
                self.shards[s].insert(sk[rows])
        self.n_ids += k
        return new_ids

    def delete(self, ids) -> int:
        """Tombstone global ids; returns the number newly deleted."""
        ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
        ids = ids[(ids >= 0) & (ids < self.n_ids)]
        newly = 0
        for s in range(self.n_shards):
            mine = ids[ids % self.n_shards == s]
            if mine.size:
                newly += self.shards[s].delete(mine // self.n_shards)
        return newly

    def flush(self) -> None:
        for shard in self.shards:
            shard.flush()

    def merge(self) -> int:
        """Size-tiered merge inside every shard's stack; returns total
        merges performed."""
        return sum(shard.maybe_merge() for shard in self.shards)

    def compact(self, min_dead_frac: float = 0.0) -> int:
        return sum(shard.compact(min_dead_frac=min_dead_frac)
                   for shard in self.shards)

    @property
    def n_live(self) -> int:
        return sum(shard.n_live for shard in self.shards)

    def __len__(self) -> int:
        return self.n_live

    def space_bits(self) -> int:
        return sum(shard.space_bits() for shard in self.shards)

    @property
    def tombstones(self) -> int:
        return sum(shard.tombstones for shard in self.shards)

    def stats(self) -> Dict[str, object]:
        return {"n_ids": self.n_ids, "n_live": self.n_live,
                "tombstones": self.tombstones,
                "n_segments": sum(len(s.segments) for s in self.shards),
                "shards": [shard.stats() for shard in self.shards]}

    def _search_columns(self, qs: np.ndarray,
                        tau: int) -> Tuple[np.ndarray, np.ndarray, int]:
        """Column-compressed fan-out over every shard's stack: local
        column ids relabel to global via ``gid = local * S + s``."""
        m = qs.shape[0]
        dists: List[np.ndarray] = []
        col_ids: List[np.ndarray] = []
        overflow = 0
        for s, shard in enumerate(self.shards):
            dist, local_ids, ov = shard._search_columns(qs, tau)
            dists.append(dist)
            col_ids.append(local_ids * self.n_shards + s)
            overflow += ov
        if not dists:
            return (np.zeros((m, 0), np.int32), np.zeros((0,), np.int64),
                    0)
        return (np.concatenate(dists, axis=1),
                np.concatenate(col_ids), overflow)

    def _global_plane(self, qs: np.ndarray,
                      tau: int) -> Tuple[np.ndarray, int]:
        m = qs.shape[0]
        dist, col_ids, overflow = self._search_columns(qs, tau)
        plane = np.full((m, self.n_ids), BIG_I, np.int32)
        plane[:, col_ids] = dist
        return plane, overflow

    def search_batch(self, qs: np.ndarray, tau: int) -> SegmentedSearchResult:
        """(m, L) uint8 queries -> global (m, n_ids) mask/dist planes."""
        qs = np.asarray(qs, dtype=np.uint8)
        if qs.ndim == 1:
            qs = qs[None, :]
        plane, overflow = self._global_plane(qs, int(tau))
        return SegmentedSearchResult(mask=plane <= tau, dist=plane,
                                     overflow=overflow)

    def search(self, q: np.ndarray, tau: int) -> SegmentedSearchResult:
        res = self.search_batch(np.asarray(q)[None], tau)
        return SegmentedSearchResult(mask=res.mask[0], dist=res.dist[0],
                                     overflow=res.overflow)

    def topk_batch(self, qs: np.ndarray, k: int,
                   tau0: Optional[int] = None) -> TopKResult:
        """Exact global kNN: per-shard column-compressed fan-out on one
        shared τ ladder (same contract as ``SegmentedIndex.topk_batch``)."""
        qs = np.asarray(qs, dtype=np.uint8)
        if qs.ndim == 1:
            qs = qs[None, :]
        return _ladder_topk(self._search_columns, self.n_live, self.b,
                            self.L, qs, k, tau0)

    def topk(self, q: np.ndarray, k: int,
             tau0: Optional[int] = None) -> TopKResult:
        res = self.topk_batch(np.asarray(q)[None], k, tau0=tau0)
        return TopKResult(ids=res.ids[0], dists=res.dists[0], tau=res.tau,
                          overflow=res.overflow)
