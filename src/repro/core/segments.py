"""Dynamic segmented bST index: streaming insert/delete with background
merge, never blocking search (DESIGN.md §4).

The paper's bST is static — ``build_trie_levels`` consumes the whole
sketch matrix up front — but the trie family supports incremental
maintenance (Kanda & Tabei's follow-up, arXiv 2009.11559).  This module
adds the LSM-style construction on top of the *unchanged* static
machinery:

  * a small mutable **delta buffer** absorbs inserts and answers queries
    by brute-force Hamming scan (the batch verify kernel's
    ``ops.hamming_distances`` — exact distances at any τ for free);
  * sealed **segments** are immutable bSTs (or MI-bST / sharded-bST
    stacks) with a per-segment **tombstone bitmap**: ``delete`` flips a
    bit, and the liveness bitmap is a *traced* argument of the cached
    compiled searcher (``get_searcher(..., with_live=True)``), so
    deletes never re-jit and dead leaves are pruned inside the verify
    stage (``ops.sparse_verify*(..., live=...)``);
  * a size-tiered ``merge()`` rebuilds two segments into one via
    ``build_trie_levels`` (dropping tombstones as it goes) and
    ``compact()`` rebuilds a single segment to reclaim tombstoned rows;
  * queries run through the **fused one-dispatch segment arena**
    (DESIGN.md §6): a device-resident column arena holds one verify
    column per sealed physical row (plus base-offset, global-id, and
    liveness lanes), and ONE jitted program per τ rung runs every
    segment's traversal, the delta scan, the arena verify kernel, and
    the on-device (distance, id) selection — serving latency is flat in
    segment count, and the only per-request transfer is the final
    (m, k) ids/dists (plus two ladder scalars per rung).  The
    per-segment fan-out survives as the reference path
    (``use_arena=False``); both are bit-identical to each other and to
    a static bST built from the surviving sketches (ties by id, and
    global ids are assigned monotonically, so the tie order matches the
    static build's insertion order).

Ids are **stable**: ``insert`` assigns monotonically increasing global
ids that survive merges and compactions.  Internally everything is
column-compressed — fan-out planes are (m, R) over the *physical* rows
currently held, labeled by global id, so churn cost tracks the live
corpus (R is reclaimed by merge/compact).  The primary range-search
contract is the column-compressed ``search_columns_batch``
(``ColumnSearchResult``); only the opt-in dense contract
(``search_batch``'s (m, n_ids) mask/dist planes) materializes the full
ever-assigned id axis, and ``topk*`` never does.

Shapes and dtypes: sketches are (n, L) uint8 over Σ=[0, 2^b); result
masks are (m, n_ids) bool, distances (m, n_ids) int32 with BIG
(= 1 << 20) on non-results; ids returned by ``insert``/``topk`` are
int64 / int32 global ids.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import threading
import time
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..kernels.hamming_kernel import DEFAULT_BLOCK_M
from ..kernels.ref import RERANK_METRICS
from .bst import BIG, build_bst
from .column_store import ColumnStore, tier_stats
from .cost_model import cost_single, frontier_capacities, tau_for_k
from .distributed_search import (build_sharded_bst, make_sharded_searcher,
                                 sharded_column_dists, topk_from_dists)
from .hamming import (n_words, pack_suffix_words_jax, pack_vertical,
                      pack_vertical_jax, unpack_vertical)
from .multi_index import (build_multi_index, mi_column_dists, mi_search_batch,
                          mi_trace_params)
from .search import (CAP_MAX_DEFAULT, LADDER_CAP_MAX, TopKResult,
                     _CACHE_STATS, _note_trace, _pad_rows, _pad_topk,
                     _pin_cache_get, _traverse_frontier_batch, bucket_m,
                     get_searcher, scatter_root_plane, searcher_cache_info,
                     select_topk_columns, select_topk_scores)
from ..obs.explain import QueryExplain, RungExplain
from ..obs.trace import span as _obs_span

BIG_I = int(BIG)

BACKENDS = ("bst", "multi", "sharded")

# Column-store layouts of the fused arena path (bst backend,
# DESIGN.md §7): "suffix" (default) stores per-segment packed suffix
# columns below each segment's ℓ_s in the tiered ``ColumnStore``;
# "full" keeps the PR-5 full-length ``_ColumnArena`` — the bit-identical
# always-hot reference.
LAYOUTS = ("suffix", "full")

# Monotonic segment serials: every sealed Segment gets the next value,
# and merged/compacted replacements get fresh ones.  Serials key every
# per-segment compiled-artifact cache (the sharded searcher pin, the
# fused arena programs) — unlike ``id()``, a serial is never reused, so
# a merged-away segment can never alias a live one's cached searcher.
_SEG_SERIALS = itertools.count()

# Host->device program launches issued by the segmented query path:
# "fanout" counts the per-segment reference path (one per segment
# searcher call, capacity-ladder retries included, plus one per
# delta-buffer scan), "fused" the single-dispatch arena path (one per
# τ-ladder rung), "rerank" the exact re-rank pass (one per
# ``topk(rerank=...)`` request, regardless of segment count —
# DESIGN.md §10).  The serving metrics snapshot exposes these —
# dispatch accounting replaces per-segment accounting (DESIGN.md §6).
_DISPATCH_STATS = {"total": 0, "fused": 0, "fanout": 0, "rerank": 0}
# the counters are bumped from every scheduler worker thread — guard the
# read-modify-write (plain ``+=`` on a dict slot is not atomic)
_DISPATCH_LOCK = threading.Lock()


def _dispatch(kind: str) -> None:
    with _DISPATCH_LOCK:
        _DISPATCH_STATS["total"] += 1
        _DISPATCH_STATS[kind] += 1


def dispatch_stats() -> Dict[str, int]:
    """Device-dispatch counters of the segmented query path: ``total``
    host->device program launches, split into ``fused`` (arena path —
    one per τ rung, independent of segment count), ``fanout``
    (per-segment reference path — one per segment per rung), and
    ``rerank`` (exact re-rank pass — one per ``topk(rerank=...)``
    request, never per segment)."""
    with _DISPATCH_LOCK:
        return dict(_DISPATCH_STATS)


def reset_dispatch_stats() -> None:
    with _DISPATCH_LOCK:
        for k in _DISPATCH_STATS:
            _DISPATCH_STATS[k] = 0


def ensure_serial_floor(floor: int) -> None:
    """Advance the global segment-serial counter to at least ``floor``.
    Recovery calls this with ``max(persisted serial) + 1`` so serials
    restored from disk can never collide with serials minted later in
    this process — the invariant every compiled-artifact cache key
    relies on (a serial is never reused)."""
    global _SEG_SERIALS
    with _DISPATCH_LOCK:
        cur = next(_SEG_SERIALS)
        _SEG_SERIALS = itertools.count(max(cur, int(floor)))


def tombstone_bits(n: int) -> int:
    """Storage cost in bits of one tombstone bitmap over ``n`` ids,
    accounted exactly like ``BitVector.nbits`` (payload words + the
    32-bit-per-word rank directory a succinct liveness bitmap carries):
    word-padded payload + cumulative-popcount table.

    >>> tombstone_bits(64)      # 2 payload words + 3 table entries
    160
    """
    n_words = max(1, (int(n) + 31) // 32)
    return n_words * 32 + (n_words + 1) * 32


@dataclasses.dataclass
class Segment:
    """One immutable sealed segment: a static index + host-side metadata.

    Attributes:
      index:    the queryable structure (``SketchIndex``, ``MultiIndex``,
                or ``ShardedBST`` depending on the stack's backend).
      packed:   (n_seg, b, W) uint32 — the sealed sketches retained
                host-side in ``pack_vertical`` bit-plane form (b bits per
                symbol instead of 8 — an 8/b× host-RAM saving,
                DESIGN.md §7); merges/compacts unpack on demand through
                :attr:`sketches`.
      ids:      (n_seg,) int64 global ids, sorted ascending.
      live:     (n_seg,) bool tombstone bitmap (False = deleted).
      L, b:     the sketch geometry ``packed`` was packed with.
      serial:   process-monotonic id (auto-assigned); keys every cached
                compiled artifact for this segment — never reused, unlike
                ``id()``.
      payloads: optional (n_seg, Wp) uint32 — the rows' original
                token-set bitmaps (``hamming.pack_sets``), retained
                host-side for the exact re-rank plane (DESIGN.md §10);
                row order matches ``ids``.
    """

    index: object
    packed: np.ndarray
    ids: np.ndarray
    live: np.ndarray
    L: int
    b: int
    serial: int = dataclasses.field(
        default_factory=lambda: next(_SEG_SERIALS))
    payloads: Optional[np.ndarray] = None

    @property
    def sketches(self) -> np.ndarray:
        """(n_seg, L) uint8 — unpacked on demand (merge/compact rebuilds
        and the suffix column slicing are the only consumers)."""
        return unpack_vertical(self.packed, self.b, self.L)

    @property
    def n(self) -> int:
        return int(self.ids.shape[0])

    @property
    def n_live(self) -> int:
        return int(self.live.sum())


class SegmentedSearchResult(NamedTuple):
    mask: np.ndarray      # (m, n_ids) bool — live ids within τ per query
    dist: np.ndarray      # (m, n_ids) int32 — exact distance where mask, BIG off
    overflow: int         # total dropped frontier entries (0 = exact)


class ColumnSearchResult(NamedTuple):
    """Column-compressed range-search result — the primary contract
    (DESIGN.md §6): one column per *physical* row currently held (every
    segment's rows in stack order, then the delta buffer's), labeled by
    stable global id.  O(m · R) where R shrinks with merge/compact — it
    never grows with ids-ever-assigned, unlike the opt-in dense plane of
    ``search_batch``."""

    mask: np.ndarray      # (m, R) bool — live columns within τ per query
    dist: np.ndarray      # (m, R) int32 — exact distance where mask, BIG off
    ids: np.ndarray       # (R,) int64 — global id per column
    overflow: int         # total dropped frontier entries (0 = exact)


class _ColumnArena:
    """Device-resident verify state for the sealed segment stack
    (DESIGN.md §6): everything the fused one-dispatch program streams,
    maintained across queries and updated incrementally on lifecycle
    writes instead of re-uploaded per query.

    Attributes (R = total sealed physical rows, T = 1 + Σ per-segment
    ℓ_s-root counts — slot 0 is the delta buffer's trivial base):
      cols:      (b, W, R) uint32 — full-length vertical verify columns,
                 segment blocks concatenated in stack order;
      base_idx:  (R,) int32 device — per-column index into the
                 concatenated root base plane (the segment-offset lane):
                 ``1 + root_offset[s] + leaf_root[id_leaf[row]]``;
      gids:      (R,) int32 device — global id per column (selection
                 labels);
      live:      (R,) bool device — liveness lanes; ``delete`` flips
                 lanes in place (one scatter), never rebuilding;
      col_ids:   (R,) int64 host — global id per column (result labels);
      col_off:   dict serial -> first column of that segment's block;
      root_off:  dict serial -> first root slot of that segment;
      t_root_total: Σ per-segment root counts (plane width minus 1);
      serials:   the segment-stack fingerprint this arena matches.
    """

    def __init__(self):
        self.serials: Tuple[int, ...] = ()
        self.cols: Optional[jnp.ndarray] = None
        self.base_idx: Optional[jnp.ndarray] = None
        self.gids: Optional[jnp.ndarray] = None
        self.live: Optional[jnp.ndarray] = None
        self.col_ids = np.zeros((0,), np.int64)
        self.col_off: Dict[int, int] = {}
        self.root_off: Dict[int, int] = {}
        self.t_root_total = 0

    @property
    def n_cols(self) -> int:
        """Columns currently held (the shared maintenance surface with
        ``column_store.ColumnStore``)."""
        return int(self.col_ids.shape[0])

    def array_bytes(self) -> int:
        """Device bytes held by the arena (space accounting, §6)."""
        if self.cols is None:
            return 0
        return int(self.cols.nbytes + self.base_idx.nbytes
                   + self.gids.nbytes + self.live.nbytes)

    def host_bytes(self) -> int:
        """The full-length arena keeps no host master copies (it is the
        always-hot reference layout)."""
        return 0

    def col_bytes(self, tier: Optional[str] = None) -> int:
        """Column payload bytes (all device-resident — the full-length
        baseline of the bytes-per-row benchmarks)."""
        if self.cols is None or tier == "cold":
            return 0
        return int(self.cols.nbytes)

    def tier_summary(self) -> Dict[str, int]:
        n_blocks = len(self.col_off)
        return {"hot_blocks": n_blocks, "cold_blocks": 0,
                "hot_bytes": self.col_bytes(), "cold_bytes": 0}


# make_sharded_searcher has no process-level cache of its own (the static
# pipeline jits once per program); segment stacks re-enter it per search,
# so pin compiled sharded searchers here with the same discipline as
# search._SEARCHER_CACHE.
_SHARDED_SEARCHER_CACHE: Dict[tuple, tuple] = {}
_SHARDED_SEARCHER_CACHE_CAP = 64

# Fused one-dispatch arena programs, keyed on (index instance,
# segment-serial fingerprint, kind, τ, capacity rung, k, block_m) —
# serials are monotonic, so a rebuilt stack can never alias a stale
# program; the closures pin the segment indexes and arena arrays they
# stream, and an index drops its own dead-generation entries the moment
# its fingerprint changes (``_fused_fn``).
_FUSED_CACHE: Dict[tuple, object] = {}
_FUSED_CACHE_CAP = 32


def clear_fused_cache() -> None:
    """Drop every compiled fused arena program (and its pinned arrays)."""
    _FUSED_CACHE.clear()


def _ladder_topk(columns_fn, n_live: int, b: int, L: int, qs: np.ndarray,
                 k: int, tau0: Optional[int]) -> TopKResult:
    """The shared kNN ladder over column-compressed fan-out results.

    ``columns_fn(qs, tau)`` -> ((m, R) int32 distances over the physical
    columns — BIG on non-results, (R,) int64 global id per column,
    overflow).  Escalates τ (seeded by ``cost_model.tau_for_k`` over the
    live count) until every query has ≥ min(k, n_live) survivors, then
    runs the shard-merge selection with the global-id labels.  Working
    memory is O(m·R) where R is the *physical* row count (reclaimed by
    merge/compact), not the ever-assigned global id space."""
    m = qs.shape[0]
    if n_live == 0:
        return TopKResult(ids=jnp.full((m, k), -1, jnp.int32),
                          dists=jnp.full((m, k), BIG_I, jnp.int32),
                          tau=0, overflow=0)
    kk = min(int(k), n_live)
    tau = tau0 if tau0 is not None else tau_for_k(b, L, n_live, kk)
    tau = min(max(int(tau), 0), L)
    while True:
        dist, col_ids, overflow = columns_fn(qs, tau)
        if int((dist < BIG_I).sum(axis=1).min()) >= kk or tau >= L:
            break
        tau = min(L, max(tau + 1, 2 * tau))
    with _obs_span("topk_readback", cat="device", k=int(k)):
        ids, dists = topk_from_dists(dist, int(k), ids=col_ids)
    return TopKResult(ids=jnp.asarray(ids), dists=jnp.asarray(dists),
                      tau=tau, overflow=overflow)


class _PayloadArena:
    """Device-resident payload plane for the non-suffix configurations
    (bst ``layout="full"``, multi, sharded): one (Wp, R) uint32 bitmap
    column per sealed physical row, stack order, maintained with the
    arena's incremental discipline — a flush appends one block, a
    merge/compact rebuilds.  (The suffix layout keeps payloads inside
    the tiered ``ColumnStore`` blocks instead, DESIGN.md §10.)"""

    def __init__(self, pay_words: int):
        self.pay_words = int(pay_words)
        self.serials: Tuple[int, ...] = ()
        self.pays: jnp.ndarray = jnp.zeros((self.pay_words, 0), jnp.uint32)

    def refresh(self, segments: List[Segment],
                serials: Tuple[int, ...]) -> "jnp.ndarray":
        if self.serials == serials:
            return self.pays
        if not (len(serials) > len(self.serials)
                and serials[:len(self.serials)] == self.serials):
            self.pays = jnp.zeros((self.pay_words, 0), jnp.uint32)
            self.serials = ()
        new_segs = segments[len(self.serials):]
        if new_segs:
            blocks = [np.ascontiguousarray(
                seg.payloads.T.astype(np.uint32)) for seg in new_segs]
            self.pays = jnp.concatenate(
                [self.pays, jnp.asarray(np.concatenate(blocks, axis=-1))],
                axis=-1)
        self.serials = serials
        return self.pays

    def array_bytes(self) -> int:
        return int(self.pays.nbytes)


@functools.partial(jax.jit,
                   static_argnames=("metric", "kk", "block_m"))
def _rerank_select(dist, pay_vert, q_pay, col_ids, *, metric: str, kk: int,
                   block_m: int):
    """One-launch exact re-rank + selection for the host-assembled
    (reference / sharded) paths: survivors of the final-τ dist plane are
    scored by ``ops.exact_rerank`` and selected by
    ``search.select_topk_scores`` — the same kernel and sort the fused
    arena's re-rank program runs, so every path is bit-identical."""
    _note_trace()
    surv = (dist < BIG).astype(jnp.int32)
    scores = ops.exact_rerank(pay_vert, q_pay, surv, metric=metric,
                              block_m=block_m)
    return select_topk_scores(scores, dist, col_ids, kk)


def _pad_topk_scores(ids: np.ndarray, dists: np.ndarray,
                     scores: np.ndarray, k: int):
    """Pad re-ranked (m, kk) planes out to (m, k): (-1, BIG, -1.0)."""
    kk = ids.shape[-1]
    if kk == k:
        return ids, dists, scores
    pad = [(0, 0)] * (ids.ndim - 1) + [(0, k - kk)]
    return (np.pad(ids, pad, constant_values=-1),
            np.pad(dists, pad, constant_values=BIG_I),
            np.pad(scores, pad, constant_values=np.float32(-1.0)))


def _empty_topk_rerank(m: int, k: int) -> TopKResult:
    return TopKResult(ids=jnp.full((m, k), -1, jnp.int32),
                      dists=jnp.full((m, k), BIG_I, jnp.int32),
                      tau=0, overflow=0,
                      scores=jnp.full((m, k), -1.0, jnp.float32))


def _ladder_topk_rerank(columns_fn, payload_rows_fn, n_live: int, b: int,
                        L: int, block_m: int, qs: np.ndarray, k: int,
                        tau0: Optional[int], metric: str,
                        q_pay: np.ndarray) -> TopKResult:
    """The shared reference two-stage ladder (the fan-out analogue of
    ``_ladder_topk``): escalate τ until every query has ≥ min(k, n_live)
    survivors, then ONE ``_rerank_select`` launch scores the final
    survivor plane against ``payload_rows_fn()``'s (R, Wp) host rows and
    selects the k best (score desc, id asc)."""
    m = qs.shape[0]
    if n_live == 0:
        return _empty_topk_rerank(m, int(k))
    kk = min(int(k), n_live)
    tau = tau0 if tau0 is not None else tau_for_k(b, L, n_live, kk)
    tau = min(max(int(tau), 0), L)
    while True:
        dist, col_ids, overflow = columns_fn(qs, tau)
        if int((dist < BIG_I).sum(axis=1).min()) >= kk or tau >= L:
            break
        tau = min(L, max(tau + 1, 2 * tau))
    pay_vert = jnp.asarray(np.ascontiguousarray(payload_rows_fn().T))
    _dispatch("rerank")
    with _obs_span("rerank", cat="device", metric=metric, kk=kk):
        ids, dists, scores = _rerank_select(
            jnp.asarray(dist), pay_vert,
            jnp.asarray(np.ascontiguousarray(q_pay.T)),
            jnp.asarray(col_ids.astype(np.int32)),
            metric=metric, kk=kk, block_m=block_m)
        ids, dists, scores = (np.asarray(ids), np.asarray(dists),
                              np.asarray(scores))
    ids, dists, scores = _pad_topk_scores(ids, dists, scores, int(k))
    return TopKResult(ids=jnp.asarray(ids), dists=jnp.asarray(dists),
                      tau=tau, overflow=int(overflow),
                      scores=jnp.asarray(scores))


class _ExplainRecorder:
    """Explain-mode bookkeeping (DESIGN.md §11): wraps a ``columns_fn``
    so every τ-ladder rung it serves is recorded as a ``RungExplain``
    (survivor/pruned counts off the rung's own distance plane, device-
    launch deltas, wall-clock), and snapshots the process-level cache /
    dispatch / tier counters at construction so ``finish`` can report
    the request's deltas.  The wrapped fn returns the *identical*
    planes — explain-on results are bit-identical to explain-off by
    construction (held by ``tests/test_obs.py``).

    Per-rung counter deltas read the process-global ledgers, so explain
    is a single-request diagnostic: concurrent queries on other threads
    would bleed into the deltas (the counts derived from the distance
    planes themselves are always exact)."""

    def __init__(self, frontier_index=None):
        self.t0 = time.perf_counter()
        self.cache0 = searcher_cache_info()
        self.disp0 = dispatch_stats()
        self.tier0 = tier_stats()
        self.rungs: List[RungExplain] = []
        self._frontier_index = frontier_index

    def wrap(self, columns_fn):
        def fn(qs, tau):
            t0 = time.perf_counter()
            d0 = dispatch_stats()
            dist, col_ids, overflow = columns_fn(qs, tau)
            d1 = dispatch_stats()
            dt = (time.perf_counter() - t0) * 1e3
            dist_np = np.asarray(dist)
            surv = (dist_np < BIG_I).sum(axis=1)
            frontier = None
            if self._frontier_index is not None:
                frontier = self._frontier_index._frontier_widths(qs, tau)
            self.rungs.append(RungExplain(
                tau=int(tau), candidates=int(dist_np.shape[1]),
                survivors=[int(s) for s in surv],
                pruned=[int(dist_np.shape[1] - s) for s in surv],
                overflow=int(overflow),
                dispatches={k: d1[k] - d0[k] for k in d1},
                duration_ms=dt, frontier=frontier))
            return dist_np, col_ids, overflow
        return fn

    def finish(self, *, op: str, backend: str, n_queries: int,
               n_live: int, k: Optional[int], tau0: Optional[int],
               tau_final: int, rerank: Optional[str]) -> QueryExplain:
        cache1 = searcher_cache_info()
        disp1 = dispatch_stats()
        tier1 = tier_stats()
        rerank_surv = None
        if rerank is not None and self.rungs:
            rerank_surv = list(self.rungs[-1].survivors)
        return QueryExplain(
            op=op, backend=backend, n_queries=int(n_queries),
            n_live=int(n_live), k=k, tau0=tau0, tau_final=int(tau_final),
            rungs=self.rungs, rerank=rerank,
            rerank_survivors=rerank_surv,
            cache={key: cache1[key] - self.cache0[key]
                   for key in ("hits", "misses", "traces")},
            dispatch={key: disp1[key] - self.disp0[key] for key in disp1},
            tier={key: tier1[key] - self.tier0[key] for key in tier1},
            duration_ms=(time.perf_counter() - self.t0) * 1e3)


class SegmentedIndex:
    """A dynamic, incrementally maintained index over b-bit sketches.

    Parameters:
      L, b:       sketch length / bits per character (Σ = [0, 2^b)).
      delta_cap:  delta-buffer rows that trigger an automatic ``flush``.
      backend:    "bst" (default) — each segment is one bST;
                  "multi"   — each segment is an MI-bST (``mi_blocks``);
                  "sharded" — each segment is a padded S-shard ShardedBST
                  searched through ``make_sharded_searcher`` (each shard
                  of the SPMD program serves its slice of the segment).
      mi_blocks:  block count for backend="multi".
      n_shards:   shard count for backend="sharded" (clamped to the
                  segment size).
      lam:        the paper's λ collapse parameter, forwarded to builds.
      auto_merge: run the size-tiered merge policy after every automatic
                  flush (manual ``flush()`` never merges implicitly).
      block_m:    query-tile size forwarded to the batched verify kernel.
      use_arena:  serve queries through the fused one-dispatch segment
                  arena (DESIGN.md §6) — one device launch per τ-ladder
                  rung regardless of segment count, bit-identical to the
                  per-segment reference fan-out (False restores it).
      layout:     column layout of the arena path (bst backend,
                  DESIGN.md §7): "suffix" (default) stores packed
                  per-segment suffix columns below each segment's ℓ_s in
                  the tiered ``ColumnStore``; "full" keeps the
                  full-length ``_ColumnArena`` — the bit-identical
                  always-hot reference.
      hot_bytes:  device budget (bytes) for hot suffix-column blocks;
                  cold blocks stay host-packed and are staged per query
                  (LRU demotion under pressure).  None = unlimited
                  (everything hot — the PR-5 placement).
      payload_words: uint32 words per row payload bitmap
                  (``ceil(vocab / 32)``, see ``hamming.pack_sets``).
                  When set, every ``insert`` must supply matching
                  ``payloads`` and ``topk*(rerank=metric)`` runs the
                  exact re-rank plane (DESIGN.md §10); None (default)
                  disables payload storage and re-ranking.

    >>> import numpy as np
    >>> idx = SegmentedIndex(L=8, b=2, delta_cap=4)
    >>> ids = idx.insert(np.zeros((5, 8), np.uint8))   # auto-flush at 4
    >>> (len(ids), idx.n_live, len(idx.segments))
    (5, 5, 1)
    >>> int(idx.delete(ids[:2]))
    2
    >>> idx.n_live
    3
    """

    def __init__(self, L: int, b: int, *, delta_cap: int = 4096,
                 backend: str = "bst", mi_blocks: int = 2, n_shards: int = 4,
                 lam: float = 0.5, auto_merge: bool = True,
                 block_m: int = DEFAULT_BLOCK_M, use_arena: bool = True,
                 layout: str = "suffix",
                 hot_bytes: Optional[int] = None,
                 payload_words: Optional[int] = None):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        if layout not in LAYOUTS:
            raise ValueError(f"layout must be one of {LAYOUTS}")
        self.L = int(L)
        self.b = int(b)
        self.delta_cap = int(delta_cap)
        self.backend = backend
        self.mi_blocks = int(mi_blocks)
        self.n_shards = int(n_shards)
        self.lam = float(lam)
        self.auto_merge = bool(auto_merge)
        self.block_m = int(block_m)
        self.use_arena = bool(use_arena)
        self.layout = layout
        self.hot_bytes = hot_bytes
        self.payload_words = (None if payload_words is None
                              else int(payload_words))

        self.segments: List[Segment] = []
        self.n_ids = 0                      # global ids ever assigned
        self._delta_sk = np.zeros((0, self.L), np.uint8)
        self._delta_ids = np.zeros((0,), np.int64)
        self._delta_live = np.zeros((0,), bool)
        self._delta_vert: Optional[jnp.ndarray] = None  # cached (b, W, ndb)
        # re-rank payloads (DESIGN.md §10): host delta rows + cached
        # device plane, plus the sealed payload arena of the non-suffix
        # configurations (suffix keeps payloads in the ColumnStore)
        self._delta_pay = (np.zeros((0, self.payload_words), np.uint32)
                           if self.payload_words is not None else None)
        self._delta_pay_vert: Optional[jnp.ndarray] = None  # (Wp, ndb)
        self._pay_arena: Optional[_PayloadArena] = None
        # bst backend only: the tiered suffix ColumnStore (layout
        # "suffix") or the full-length _ColumnArena reference ("full") —
        # both expose the same maintenance surface (serials / live /
        # col_off / col_ids / array_bytes)
        self._arena: Optional[object] = None
        self._fused_id = next(_SEG_SERIALS)             # per-index cache scope
        self._fused_stamp: Tuple = ()                   # (serials, gen)
        self.counters = {"flushes": 0, "merges": 0, "compactions": 0,
                         "inserted": 0, "deleted": 0}
        # write hook: fn(event: str, info: dict) fired after every
        # lifecycle write ("insert" / "delete" / "flush" / "merge" /
        # "compact") — the serving layer's metrics tap (DESIGN.md §5).
        # Exceptions are the caller's problem; keep hooks cheap.
        self.event_hook: Optional[object] = None
        # durability binding (repro.store.StackBinding): log-before-apply
        # for insert/delete, checkpoint after flush/merge/compact.  None
        # (default) = ephemeral index, zero overhead.
        self.store: Optional[object] = None

    # -- mutation --------------------------------------------------------

    def _emit(self, event: str, **info) -> None:
        if self.event_hook is not None:
            self.event_hook(event, info)

    def _check_payloads(self, payloads, k: int) -> Optional[np.ndarray]:
        """Validate insert-time payloads against ``payload_words``."""
        if self.payload_words is None:
            if payloads is not None:
                raise ValueError(
                    "payloads supplied but the index was built without "
                    "payload_words")
            return None
        if payloads is None:
            raise ValueError(
                "payload_words is set: insert requires (k, "
                f"{self.payload_words}) uint32 payload bitmaps")
        pay = np.asarray(payloads, dtype=np.uint32)
        if pay.ndim == 1:
            pay = pay[None, :]
        if pay.shape != (k, self.payload_words):
            raise ValueError(f"payloads shape {pay.shape} != "
                             f"({k}, {self.payload_words})")
        return pay

    def insert(self, sketches: np.ndarray,
               payloads: Optional[np.ndarray] = None) -> np.ndarray:
        """Append sketches to the delta buffer; returns their (k,) int64
        global ids.  ``sketches``: (k, L) or (L,) uint8 over [0, 2^b).
        When the index was built with ``payload_words``, ``payloads``
        must carry the rows' (k, Wp) uint32 set bitmaps
        (``hamming.pack_sets``) — the exact re-rank plane's source of
        truth.  Triggers ``flush`` (and, if ``auto_merge``, the
        size-tiered merge policy) once the delta buffer reaches
        ``delta_cap`` rows — search stays available throughout."""
        sk = np.asarray(sketches, dtype=np.uint8)
        if sk.ndim == 1:
            sk = sk[None, :]
        if sk.shape[1] != self.L:
            raise ValueError(f"sketch length {sk.shape[1]} != L={self.L}")
        if sk.size and int(sk.max()) >= (1 << self.b):
            raise ValueError("character exceeds alphabet [0, 2^b)")
        k = sk.shape[0]
        pay = self._check_payloads(payloads, k)
        new_ids = np.arange(self.n_ids, self.n_ids + k, dtype=np.int64)
        if self.store is not None:
            # write-ahead: log, then apply
            self.store.log_insert(new_ids, sk, payloads=pay)
        self.n_ids += k
        self._delta_sk = np.concatenate([self._delta_sk, sk])
        self._delta_ids = np.concatenate([self._delta_ids, new_ids])
        self._delta_live = np.concatenate(
            [self._delta_live, np.ones(k, bool)])
        self._delta_vert = None
        if pay is not None:
            self._delta_pay = np.concatenate([self._delta_pay, pay])
            self._delta_pay_vert = None
        self.counters["inserted"] += k
        self._emit("insert", rows=k)
        if len(self._delta_ids) >= self.delta_cap:
            self.flush()
            if self.auto_merge:
                self.maybe_merge()
        return new_ids

    def delete(self, ids) -> int:
        """Tombstone global ids (scalar or (k,) array-like); returns the
        number of ids newly deleted (already-dead or unknown ids are
        ignored).  O(k log n) searchsorted per container — no index is
        rebuilt and compiled searchers stay valid (liveness is traced).
        The arena's device liveness lanes are flipped in place with one
        scatter (DESIGN.md §6) — deletes never re-upload columns."""
        ids = np.unique(np.atleast_1d(np.asarray(ids, dtype=np.int64)))
        if self.store is not None and ids.size:
            self.store.log_delete(ids)           # write-ahead: log, then apply
        newly = 0
        arena = self._arena
        lanes: List[np.ndarray] = []     # arena columns going dead
        containers: List[Tuple[np.ndarray, np.ndarray, Optional[int]]] = [
            (self._delta_ids, self._delta_live, None)]
        containers += [
            (seg.ids, seg.live,
             arena.col_off.get(seg.serial) if arena is not None else None)
            for seg in self.segments]
        for id_arr, live_arr, col0 in containers:
            if id_arr.size == 0:
                continue
            pos = np.searchsorted(id_arr, ids)
            ok = (pos < id_arr.size) & (
                id_arr[np.minimum(pos, id_arr.size - 1)] == ids)
            sel = pos[ok]
            newly += int(live_arr[sel].sum())
            live_arr[sel] = False
            if col0 is not None and sel.size:
                lanes.append(col0 + sel)
        if lanes:
            arena.live = arena.live.at[np.concatenate(lanes)].set(False)
        self.counters["deleted"] += newly
        self._emit("delete", rows=newly)
        return newly

    def flush(self) -> Optional[Segment]:
        """Seal the delta buffer's live rows into a new immutable segment
        (dead delta rows are dropped for free).  Returns the new Segment,
        or None when nothing was live."""
        live = self._delta_live
        seg = None
        if live.any():
            sk = self._delta_sk[live]
            ids = self._delta_ids[live]
            pay = (self._delta_pay[live]
                   if self._delta_pay is not None else None)
            seg = Segment(index=self._build(sk),
                          packed=pack_vertical(sk, self.b), ids=ids,
                          live=np.ones(len(ids), bool), L=self.L, b=self.b,
                          payloads=pay)
            self.segments.append(seg)
            self.counters["flushes"] += 1
            self._emit("flush", rows=seg.n)
        self._delta_sk = np.zeros((0, self.L), np.uint8)
        self._delta_ids = np.zeros((0,), np.int64)
        self._delta_live = np.zeros((0,), bool)
        self._delta_vert = None
        if self._delta_pay is not None:
            self._delta_pay = np.zeros((0, self.payload_words), np.uint32)
            self._delta_pay_vert = None
        if self.store is not None:
            self.store.checkpoint(self)
        return seg

    def merge(self, i: Optional[int] = None,
              j: Optional[int] = None) -> bool:
        """Rebuild two segments into one via ``build_trie_levels`` (inside
        the backend's builder), dropping tombstoned rows as it goes.
        Defaults to the two smallest segments (size-tiered choice);
        returns False when fewer than two segments exist."""
        if len(self.segments) < 2:
            return False
        if i is None or j is None:
            order = np.argsort([seg.n for seg in self.segments],
                               kind="stable")
            i, j = int(order[0]), int(order[1])
        if i == j:
            raise ValueError("cannot merge a segment with itself")
        a, b_ = self.segments[i], self.segments[j]
        sk = np.concatenate([a.sketches[a.live], b_.sketches[b_.live]])
        ids = np.concatenate([a.ids[a.live], b_.ids[b_.live]])
        pay = None
        if self.payload_words is not None:
            pay = np.concatenate([a.payloads[a.live], b_.payloads[b_.live]])
        order = np.argsort(ids, kind="stable")   # keep ids sorted for delete
        sk, ids = sk[order], ids[order]
        if pay is not None:
            pay = pay[order]
        lo, hi = min(i, j), max(i, j)
        del self.segments[hi], self.segments[lo]
        if len(ids):
            self.segments.insert(lo, Segment(
                index=self._build(sk), packed=pack_vertical(sk, self.b),
                ids=ids, live=np.ones(len(ids), bool), L=self.L, b=self.b,
                payloads=pay))
        self.counters["merges"] += 1
        self._emit("merge", rows=int(len(ids)))
        if self.store is not None:
            self.store.checkpoint(self)
        return True

    def maybe_merge(self) -> int:
        """Size-tiered merge policy: while two segments share a size tier
        (⌊log2 n⌋ bucket), merge the two smallest of that tier.  Returns
        the number of merges performed.  Amortized O(log n) rebuilds per
        inserted row, and search never blocks (the old segments answer
        queries until the swap)."""
        merges = 0
        while True:
            tiers: Dict[int, List[int]] = {}
            for si, seg in enumerate(self.segments):
                tiers.setdefault(max(seg.n, 1).bit_length(), []).append(si)
            crowded = [idxs for idxs in tiers.values() if len(idxs) >= 2]
            if not crowded:
                return merges
            idxs = min(crowded, key=lambda g: min(self.segments[s].n
                                                  for s in g))
            pair = sorted(idxs, key=lambda s: self.segments[s].n)[:2]
            self.merge(pair[0], pair[1])
            merges += 1

    def compact(self, i: Optional[int] = None,
                min_dead_frac: float = 0.0) -> int:
        """Rebuild segment ``i`` (or every segment when None) without its
        tombstoned leaves; fully-dead segments are removed outright.
        ``min_dead_frac`` skips segments whose dead fraction is at or
        below the threshold.  Returns the number of segments rebuilt or
        removed."""
        targets = range(len(self.segments)) if i is None else [i]
        out: List[Optional[Segment]] = list(self.segments)
        done = 0
        for si in targets:
            seg = self.segments[si]
            dead = seg.n - seg.n_live
            if dead == 0 or (seg.n and dead / seg.n <= min_dead_frac):
                continue
            if seg.n_live == 0:
                out[si] = None
            else:
                sk, ids = seg.sketches[seg.live], seg.ids[seg.live]
                pay = (seg.payloads[seg.live]
                       if seg.payloads is not None else None)
                out[si] = Segment(index=self._build(sk),
                                  packed=pack_vertical(sk, self.b), ids=ids,
                                  live=np.ones(len(ids), bool), L=self.L,
                                  b=self.b, payloads=pay)
            done += 1
        self.segments = [s for s in out if s is not None]
        self.counters["compactions"] += done
        if done:
            self._emit("compact", segments=done)
            if self.store is not None:
                self.store.checkpoint(self)
        return done

    # -- queries ---------------------------------------------------------

    def search_columns_batch(self, qs: np.ndarray, tau: int,
                             explain: bool = False) -> ColumnSearchResult:
        """Range search, column-compressed — the **primary** result
        contract (DESIGN.md §6): ``qs`` (m, L) uint8 ->
        ``ColumnSearchResult`` with (m, R) mask/dist planes over the
        physical columns plus the (R,) global-id labels.  O(m · R)
        where R = rows currently held (reclaimed by merge/compact) —
        long-lived collections never pay O(ids-ever-assigned) per query;
        the dense global-id plane is the opt-in ``search_batch``.  One
        device dispatch end to end on the arena path.

        ``explain=True`` returns ``(ColumnSearchResult, QueryExplain)``
        — identical planes plus the per-rung pruning record
        (DESIGN.md §11)."""
        qs = np.asarray(qs, dtype=np.uint8)
        if qs.ndim == 1:
            qs = qs[None, :]
        if explain:
            rec = self._explain_recorder()
            dist, col_ids, overflow = rec.wrap(self._columns)(qs, int(tau))
            res = ColumnSearchResult(mask=dist <= tau, dist=dist,
                                     ids=col_ids, overflow=overflow)
            return res, rec.finish(
                op="search", backend=self.backend,
                n_queries=qs.shape[0], n_live=self.n_live, k=None,
                tau0=int(tau), tau_final=int(tau), rerank=None)
        dist, col_ids, overflow = self._columns(qs, int(tau))
        return ColumnSearchResult(mask=dist <= tau, dist=dist, ids=col_ids,
                                  overflow=overflow)

    def search_columns(self, q: np.ndarray, tau: int) -> ColumnSearchResult:
        """Single-query ``search_columns_batch`` (m=1 planes squeezed)."""
        res = self.search_columns_batch(np.asarray(q)[None], tau)
        return ColumnSearchResult(mask=res.mask[0], dist=res.dist[0],
                                  ids=res.ids, overflow=res.overflow)

    def search_batch(self, qs: np.ndarray, tau: int,
                     explain: bool = False) -> SegmentedSearchResult:
        """Range search on the **opt-in dense** contract: ``qs``: (m, L)
        uint8 queries -> (m, n_ids) global mask and exact-distance
        planes (BIG off-mask / on dead ids).  The scatter materializes
        the full ever-assigned id axis — O(m · n_ids) host memory; use
        ``search_columns_batch`` (the primary contract) when the corpus
        is long-lived and churny.

        ``explain=True`` returns ``(SegmentedSearchResult,
        QueryExplain)`` — identical planes plus the pruning record."""
        qs = np.asarray(qs, dtype=np.uint8)
        if qs.ndim == 1:
            qs = qs[None, :]
        if explain:
            rec = self._explain_recorder()
            plane, overflow = self._search_planes(
                qs, int(tau), columns_fn=rec.wrap(self._columns))
            res = SegmentedSearchResult(mask=plane <= tau, dist=plane,
                                        overflow=overflow)
            return res, rec.finish(
                op="search", backend=self.backend,
                n_queries=qs.shape[0], n_live=self.n_live, k=None,
                tau0=int(tau), tau_final=int(tau), rerank=None)
        plane, overflow = self._search_planes(qs, int(tau))
        return SegmentedSearchResult(mask=plane <= tau, dist=plane,
                                     overflow=overflow)

    def search(self, q: np.ndarray, tau: int,
               explain: bool = False) -> SegmentedSearchResult:
        """Single-query ``search_batch`` (m=1 planes squeezed);
        ``explain=True`` appends the ``QueryExplain`` record."""
        out = self.search_batch(np.asarray(q)[None], tau, explain=explain)
        res, ex = out if explain else (out, None)
        res = SegmentedSearchResult(mask=res.mask[0], dist=res.dist[0],
                                    overflow=res.overflow)
        return (res, ex) if explain else res

    def topk_batch(self, qs: np.ndarray, k: int,
                   tau0: Optional[int] = None, *,
                   rerank: Optional[str] = None,
                   q_payloads: Optional[np.ndarray] = None,
                   explain: bool = False) -> TopKResult:
        """Exact k-nearest-neighbors over the live ids: the fused
        one-dispatch arena program on a shared τ-escalation ladder —
        traversal, delta scan, verify, and (distance, id) selection all
        on device, so each rung costs one launch and transfers two
        scalars; the final (m, k) ids/dists are the only per-request
        result transfer (DESIGN.md §6).  ``qs``: (m, L) uint8 -> (m, k)
        int32 global ids / int32 exact distances, ascending by
        (distance, id); (-1, BIG) pads past the live count.
        Bit-identical to ``core.search.topk_batch`` on a static bST of
        the surviving sketches (after the monotone global-id mapping)
        and to the per-segment reference fan-out (``use_arena=False``).
        Works over column-compressed planes — O(m · physical rows), not
        O(m · ids-ever-assigned).

        ``rerank`` ("jaccard" / "cosine" / "containment") switches on
        the two-stage contract (DESIGN.md §10): the final-τ survivor
        plane stays on device and ONE additional fused dispatch gathers
        the survivors' payload bitmaps, scores them exactly against
        ``q_payloads`` ((m, Wp) uint32), and selects the k *largest*
        (score, -id) — ``TopKResult.scores`` carries the exact scores,
        ids/dists re-order to score order, pads are (-1, BIG, -1.0).
        Requires ``payload_words``.

        ``explain=True`` returns ``(TopKResult, QueryExplain)`` — a
        bit-identical result plus the per-rung pruning record
        (DESIGN.md §11); explain serves through the shared ladder over
        the same column planes, so the extra cost is the record itself
        (plus the bst frontier-width sampling launch)."""
        qs = np.asarray(qs, dtype=np.uint8)
        if qs.ndim == 1:
            qs = qs[None, :]
        if explain:
            return self._explain_topk(qs, int(k), tau0, rerank,
                                      q_payloads)
        if rerank is not None:
            q_pay = self._check_rerank(rerank, q_payloads, qs.shape[0])
            if self.use_arena:
                return self._fused_topk_rerank(qs, int(k), tau0, rerank,
                                               q_pay)
            return self._rerank_ladder(qs, int(k), tau0, rerank, q_pay)
        if q_payloads is not None:
            raise ValueError("q_payloads supplied without rerank=")
        if self.use_arena:
            return self._fused_topk(qs, int(k), tau0)
        return _ladder_topk(self._search_columns, self.n_live, self.b,
                            self.L, qs, k, tau0)

    def topk(self, q: np.ndarray, k: int,
             tau0: Optional[int] = None, *,
             rerank: Optional[str] = None,
             q_payloads: Optional[np.ndarray] = None,
             explain: bool = False) -> TopKResult:
        """Single-query ``topk_batch`` (row 0); ``explain=True`` appends
        the ``QueryExplain`` record."""
        qp = None
        if q_payloads is not None:
            qp = np.asarray(q_payloads, np.uint32)
            if qp.ndim == 1:
                qp = qp[None, :]
        out = self.topk_batch(np.asarray(q)[None], k, tau0=tau0,
                              rerank=rerank, q_payloads=qp,
                              explain=explain)
        res, ex = out if explain else (out, None)
        res = TopKResult(ids=res.ids[0], dists=res.dists[0], tau=res.tau,
                         overflow=res.overflow,
                         scores=(None if res.scores is None
                                 else res.scores[0]))
        return (res, ex) if explain else res

    # -- accounting ------------------------------------------------------

    @property
    def n_live(self) -> int:
        """Live (inserted minus deleted) ids across delta + segments."""
        return int(self._delta_live.sum()) + sum(
            seg.n_live for seg in self.segments)

    @property
    def tombstones(self) -> int:
        """Dead rows still physically held (reclaimable by merge/compact)
        across the delta buffer and every segment."""
        dead_delta = int((~self._delta_live).sum())
        return dead_delta + sum(seg.n - seg.n_live for seg in self.segments)

    def __len__(self) -> int:
        return self.n_live

    def space_ledger(self) -> Dict[str, int]:
        """The one consistent space ledger (DESIGN.md §7):

        ``model_bits``   — the succinct model: per-segment index bits +
          tombstone bitmaps, PLUS everything the dynamic machinery
          allocates per row that the old ``space_bits`` drifted away
          from: the arena's base_idx/gids/live lanes (9 bytes per sealed
          column) and the delta verify planes at the power-of-two bucket
          size ``_delta_planes()`` actually allocates (not the raw row
          count).  Deterministic in the lifecycle state — lazily built
          arrays are accounted at their steady-state size.
        ``device_bytes`` — resident device arrays: the column store /
          arena (hot columns + lanes), the materialized delta planes,
          and every segment's static index pytree.
        ``host_bytes``   — resident host arrays: packed sealed sketches,
          id/liveness lanes, raw delta rows, and cold column blocks.
        """
        model = 0
        r_sealed = 0
        for seg in self.segments:
            model += int(seg.index.model_bits()) + tombstone_bits(seg.n)
            r_sealed += seg.n
        nd = len(self._delta_ids)
        W = n_words(self.L)
        if nd:
            model += bucket_m(nd) * self.b * W * 32 + tombstone_bits(nd)
        if r_sealed and self.use_arena and self.backend == "bst":
            model += r_sealed * (4 + 4 + 1) * 8   # base_idx/gids/live lanes
        device = 0
        host = 0
        ar = self._arena
        if ar is not None:
            device += ar.array_bytes()
            host += ar.host_bytes()
        if self._delta_vert is not None:
            device += int(self._delta_vert.nbytes)
        # re-rank payload plane (DESIGN.md §10): the suffix store's
        # payload blocks are already inside ar.array_bytes()/host_bytes()
        # (block_bytes); the non-suffix arena and the delta plane are
        # ledgered here
        if self._delta_pay_vert is not None:
            device += int(self._delta_pay_vert.nbytes)
        if self._pay_arena is not None:
            device += self._pay_arena.array_bytes()
        for seg in self.segments:
            device += int(seg.index.array_bytes())
            host += int(seg.packed.nbytes + seg.ids.nbytes
                        + seg.live.nbytes)
            if seg.payloads is not None:
                host += int(seg.payloads.nbytes)
        host += int(self._delta_sk.nbytes + self._delta_ids.nbytes
                    + self._delta_live.nbytes)
        if self._delta_pay is not None:
            host += int(self._delta_pay.nbytes)
        return {"model_bits": model, "device_bytes": device,
                "host_bytes": host}

    def space_bits(self) -> int:
        """Model-space accounting — ``space_ledger()['model_bits']``:
        per-segment index bits + tombstone bitmaps (DESIGN.md §4) + the
        arena lanes and bucket-padded delta planes the dynamic path
        allocates per row."""
        return self.space_ledger()["model_bits"]

    def cost_hint(self, op: str, *, k: Optional[int] = None,
                  tau: Optional[int] = None, rows: int = 1) -> float:
        """Cost-model estimate of one request against the *current*
        corpus (paper Appendix A, Eq. 2; DESIGN.md §12) — the admission
        controller's currency.  ``op``:

          * ``"topk"``   — cost of the τ ladder seeded by
            ``tau_for_k(b, L, n, k)``;
          * ``"search"`` — cost at the fixed ``tau``;
          * ``"write"``  — ``rows`` delta appends / tombstone flips,
            priced as τ=0 probes (cheap relative to any query; their
            amortized seal/merge cost is the maintenance path's budget,
            not the admission controller's).

        Pure host arithmetic, monotone in k/τ/rows, never raises —
        callable on every submit."""
        n = max(float(self.n_live), 1.0)
        if op == "write":
            return max(float(rows), 1.0) \
                * max(cost_single(self.b, self.L, 0, n), 1e-6)
        if op == "search":
            t = min(max(int(tau) if tau is not None else 0, 0), self.L)
        else:
            t = tau_for_k(self.b, self.L, n,
                          max(int(k) if k is not None else 1, 1))
        return max(cost_single(self.b, self.L, t, n), 1e-6)

    def stats(self) -> Dict[str, object]:
        """Lifecycle counters, per-segment occupancy, and the space
        ledger (for dashboards and the ingest benchmark)."""
        led = self.space_ledger()
        ar = self._arena
        return {
            "n_ids": self.n_ids, "n_live": self.n_live,
            "tombstones": self.tombstones,
            "delta_rows": int(len(self._delta_ids)),
            "delta_live": int(self._delta_live.sum()),
            "n_segments": len(self.segments),
            "segments": [(seg.n, seg.n_live) for seg in self.segments],
            "space_bits": led["model_bits"],
            "device_bytes": led["device_bytes"],
            "host_bytes": led["host_bytes"],
            "arena_bytes": ar.array_bytes() if ar is not None else 0,
            "tier": (ar.tier_summary() if ar is not None else
                     {"hot_blocks": 0, "cold_blocks": 0, "hot_bytes": 0,
                      "cold_bytes": 0}),
            **self.counters,
        }

    # -- internals -------------------------------------------------------

    def _replay_insert(self, ids: np.ndarray, sk: np.ndarray,
                       payloads: Optional[np.ndarray] = None) -> None:
        """Recovery-only: append rows with *preassigned* ids to the delta
        buffer.  No WAL logging and no auto-flush — the store runs the
        maintenance fixpoint once replay completes, so the recovered
        partition matches a never-crashed index."""
        sk = np.asarray(sk, np.uint8)
        ids = np.asarray(ids, np.int64)
        self._delta_sk = np.concatenate([self._delta_sk, sk])
        self._delta_ids = np.concatenate([self._delta_ids, ids])
        self._delta_live = np.concatenate(
            [self._delta_live, np.ones(len(ids), bool)])
        self._delta_vert = None
        if self._delta_pay is not None:
            if payloads is None:
                raise ValueError("replay of a payload index requires the "
                                 "records' payload bitmaps")
            self._delta_pay = np.concatenate(
                [self._delta_pay, np.asarray(payloads, np.uint32)])
            self._delta_pay_vert = None
        if ids.size:
            self.n_ids = max(self.n_ids, int(ids.max()) + 1)

    def _build(self, sk: np.ndarray):
        if self.backend == "multi":
            return build_multi_index(sk, self.b, self.mi_blocks, self.lam)
        if self.backend == "sharded":
            return build_sharded_bst(sk, self.b,
                                     max(1, min(self.n_shards, len(sk))),
                                     self.lam)
        return build_bst(sk, self.b, self.lam)

    def _delta_planes(self) -> jnp.ndarray:
        """(b, W, ndb) uint32 delta-buffer verify planes, with the row
        axis padded up to the power-of-two bucket ``ndb = bucket_m(nd)``
        (zero columns past nd — masked dead by every caller).  Bucketing
        the brute-force scan's shape means a stream of single-row
        inserts touches O(log delta_cap) compiled scan shapes instead of
        re-jitting ``hamming_distances`` at every delta size."""
        if self._delta_vert is None:
            nd = len(self._delta_ids)
            ndb = bucket_m(nd)
            planes = pack_vertical(self._delta_sk, self.b)   # (nd, b, W)
            vert = np.transpose(planes, (1, 2, 0))            # (b, W, nd)
            if ndb != nd:
                vert = np.concatenate(
                    [vert, np.zeros(vert.shape[:2] + (ndb - nd,),
                                    np.uint32)], axis=-1)
            self._delta_vert = jnp.asarray(vert.copy())
        return self._delta_vert

    def _delta_pay_planes(self) -> jnp.ndarray:
        """(Wp, ndb) uint32 delta-buffer payload plane, bucketed to the
        same ``ndb = bucket_m(nd)`` shape as ``_delta_planes`` (zero
        columns past nd — the survivor mask already kills them), so the
        re-rank program shares the delta shape buckets of the verify
        scan."""
        if self._delta_pay_vert is None:
            nd = len(self._delta_ids)
            ndb = bucket_m(nd)
            vert = np.zeros((self.payload_words, ndb), np.uint32)
            if nd:
                vert[:, :nd] = self._delta_pay.T
            self._delta_pay_vert = jnp.asarray(vert)
        return self._delta_pay_vert

    def _search_columns(self, qs: np.ndarray,
                        tau: int) -> Tuple[np.ndarray, np.ndarray, int]:
        """Per-segment reference fan-out: (m, L) queries -> ((m, R) int32
        distances over the physical columns — BIG on non-results, (R,)
        int64 global id per column, total overflow), where R = rows
        currently held (every segment's rows, then the delta buffer's) —
        R shrinks with merge/compact, unlike the ever-assigned global id
        space.  Every segment contributes exact distances within τ; the
        delta buffer contributes a brute-force scan clamped to the same
        τ so the ladder logic sees one consistent contract.  Costs one
        device dispatch per segment plus one for the delta buffer; the
        fused arena path (``_fused_columns``) is the bit-identical
        single-dispatch replacement (DESIGN.md §6)."""
        m = qs.shape[0]
        dists: List[np.ndarray] = []
        col_ids: List[np.ndarray] = []
        overflow = 0
        qs_j = jnp.asarray(qs)
        for seg in self.segments:
            if seg.live.any():
                with _obs_span("segment_fanout", cat="device",
                               serial=seg.serial, tau=tau):
                    dist, ov = self._search_segment(seg, qs_j, tau)
                overflow += ov
            else:
                dist = np.full((m, seg.n), BIG_I, np.int32)
            dists.append(dist)
            col_ids.append(seg.ids)
        nd = len(self._delta_ids)
        if nd:
            planes = pack_vertical(qs, self.b)                # (m, b, W)
            q_vert = jnp.asarray(np.transpose(planes, (1, 2, 0)).copy())
            _dispatch("fanout")
            with _obs_span("delta_scan", cat="device", rows=nd):
                d = np.asarray(ops.hamming_distances(self._delta_planes(),
                                                     q_vert))[:, :nd]
            d = np.where(self._delta_live[None, :] & (d <= tau), d, BIG_I)
            dists.append(d.astype(np.int32))
            col_ids.append(self._delta_ids)
        if not dists:
            return (np.zeros((m, 0), np.int32), np.zeros((0,), np.int64),
                    0)
        return (np.concatenate(dists, axis=1),
                np.concatenate(col_ids), overflow)

    def _search_planes(self, qs: np.ndarray, tau: int,
                       columns_fn=None) -> Tuple[np.ndarray, int]:
        """(m, L) queries -> ((m, n_ids) int32 global distance plane with
        BIG on non-results, total overflow): the column-compressed
        fan-out scattered onto the full global-id axis (the opt-in dense
        range-search contract — O(m · ids-ever-assigned) memory).
        ``columns_fn`` overrides the column source (the explain path
        passes its recording wrapper)."""
        m = qs.shape[0]
        if columns_fn is None:
            columns_fn = self._columns
        dist, col_ids, overflow = columns_fn(qs, tau)
        plane = np.full((m, self.n_ids), BIG_I, np.int32)
        plane[:, col_ids] = dist
        return plane, overflow

    def _columns(self, qs: np.ndarray,
                 tau: int) -> Tuple[np.ndarray, np.ndarray, int]:
        """Route to the fused arena path or the per-segment reference
        fan-out (identical contracts, bit-identical results)."""
        if self.use_arena:
            return self._fused_columns(qs, tau)
        return self._search_columns(qs, tau)

    # -- query explain (DESIGN.md §11) -----------------------------------

    def _explain_recorder(self) -> _ExplainRecorder:
        """Frontier widths are sampled on the bst backend only (the
        multi/sharded traversals have no single per-level frontier)."""
        frontier_index = self if self.backend == "bst" else None
        return _ExplainRecorder(frontier_index=frontier_index)

    def _explain_topk(self, qs: np.ndarray, k: int, tau0: Optional[int],
                      rerank: Optional[str], q_payloads):
        """The explain-mode kNN: run the *shared* τ ladder over this
        index's column planes with a recording wrapper.  The ladder
        schedule, the column planes, and the (distance, id) / (score,
        -id) selections are the ones every serving path is already
        bit-identical to (``_ladder_topk`` vs ``_fused_topk``,
        ``_ladder_topk_rerank`` vs ``_fused_topk_rerank`` — held by the
        fused-vs-reference tests), so the result is bit-identical to
        ``explain=False``."""
        rec = self._explain_recorder()
        columns_fn = rec.wrap(self._columns)
        if rerank is not None:
            q_pay = self._check_rerank(rerank, q_payloads, qs.shape[0])
            res = _ladder_topk_rerank(
                columns_fn, self._payload_rows, self.n_live, self.b,
                self.L, self.block_m, qs, k, tau0, rerank, q_pay)
        else:
            if q_payloads is not None:
                raise ValueError("q_payloads supplied without rerank=")
            res = _ladder_topk(columns_fn, self.n_live, self.b, self.L,
                               qs, k, tau0)
        return res, rec.finish(
            op="topk", backend=self.backend, n_queries=qs.shape[0],
            n_live=self.n_live, k=int(k),
            tau0=None if tau0 is None else int(tau0),
            tau_final=int(res.tau), rerank=rerank)

    def _frontier_widths(self, qs: np.ndarray,
                         tau: int) -> Optional[List[List[int]]]:
        """Per-query, per-trie-level live frontier widths at this τ,
        summed across the segment stack ((m, L) — levels past a
        segment's collapse depth ℓ_s contribute nothing).  Explain-only:
        one extra cached program launch, deliberately outside the
        serving dispatch ledger."""
        if self.backend != "bst" or not self.segments:
            return None
        m = qs.shape[0]
        mb = bucket_m(m)
        qs_p = jnp.asarray(qs)
        if mb != m:
            qs_p = _pad_rows(qs_p, mb)
        widths = np.asarray(self._widths_fn(int(tau))(qs_p))[:m]
        return [[int(w) for w in row] for row in widths]

    def _widths_fn(self, tau: int):
        """Cache the frontier-width sampling program alongside the fused
        programs (same ``_fused_id`` scope, so the stale-generation
        purge in ``_fused_fn`` also drops it)."""
        serials = self._seg_serials()
        key = (self.backend, self.layout, self._fused_id, serials,
               "widths", tau, self.block_m)
        fn = _FUSED_CACHE.get(key)
        if fn is None:
            fn = self._build_widths(tau)
            while len(_FUSED_CACHE) >= _FUSED_CACHE_CAP:
                _FUSED_CACHE.pop(next(iter(_FUSED_CACHE)))
            _FUSED_CACHE[key] = fn
        return fn

    def _build_widths(self, tau: int):
        """One jitted program: every segment's frontier descent with the
        per-level width taps, summed into an (m, L) plane (same
        traversal arithmetic as the fused programs' first half)."""
        indexes = [seg.index for seg in self.segments]
        caps_list = [frontier_capacities(ix.t, self.b, tau,
                                         CAP_MAX_DEFAULT)
                     for ix in indexes]
        L = self.L

        @jax.jit
        def run(qs):
            _note_trace()
            qsi = qs.astype(jnp.int32)
            m = qsi.shape[0]
            per_level = jnp.zeros((m, L), jnp.int32)
            for ix, caps in zip(indexes, caps_list):
                widths: List[jnp.ndarray] = []
                _traverse_frontier_batch(ix, qsi, tau=tau, caps=caps,
                                         level_widths=widths)
                if widths:
                    w = jnp.stack(widths, axis=-1)        # (m, depth_s)
                    per_level = per_level.at[:, :w.shape[-1]].add(w)
            return per_level
        return run

    def _search_segment(self, seg: Segment, qs_j: jnp.ndarray,
                        tau: int) -> Tuple[np.ndarray, int]:
        """One segment, the whole batch -> ((m, n_seg) int32 exact local
        distances — BIG off-mask and on tombstones, overflow).  Runs the
        backend's cached compiled searcher with the liveness bitmap as a
        traced argument, on the doubled capacity ladder until exact."""
        if self.backend == "multi":
            _dispatch("fanout")
            res = mi_search_batch(seg.index, qs_j, tau,
                                  block_m=self.block_m, id_live=seg.live)
            return (np.asarray(res.dist, dtype=np.int32),
                    int(np.asarray(res.overflow).sum()))
        if self.backend == "sharded":
            idx = seg.index
            cap = 1 << 14
            while True:
                # keyed on the monotonic segment serial, never id(): a
                # serial is never reused, so a merged-away segment can
                # never alias a live one's cached searcher
                key = (seg.serial, tau, cap)

                def build():
                    return make_sharded_searcher(idx, tau, cap_max=cap)
                fn, _ = _pin_cache_get(_SHARDED_SEARCHER_CACHE,
                                       _SHARDED_SEARCHER_CACHE_CAP,
                                       key, idx, build)
                _dispatch("fanout")
                _, dists, ov = fn(qs_j)
                if int(ov) == 0 or cap >= LADDER_CAP_MAX:
                    break
                cap *= 2
            merged = np.asarray(dists)[:, idx.shard_of, idx.pos_of]
            merged = np.where(seg.live[None, :], merged, BIG_I)
            return merged.astype(np.int32), int(ov)
        live_j = jnp.asarray(seg.live)
        cap = CAP_MAX_DEFAULT
        while True:
            fn = get_searcher(seg.index, tau, cap, batch=True,
                              block_m=self.block_m, with_live=True)
            _dispatch("fanout")
            res = fn(qs_j, live_j)
            ov = int(np.asarray(res.overflow).sum())
            if ov == 0 or cap >= LADDER_CAP_MAX:
                break
            cap *= 2
        return np.asarray(res.dist, dtype=np.int32), ov

    # -- fused one-dispatch arena path (DESIGN.md §6) --------------------

    def _seg_serials(self) -> Tuple[int, ...]:
        return tuple(seg.serial for seg in self.segments)

    def _refresh_arena(self) -> _ColumnArena:
        """Bring the device-resident column arena (bst backend) up to
        date with the segment stack.  A flush *appends* the new
        segment's column block, base-offset lanes, id labels, and
        liveness lanes to the existing device arrays (one concat per
        flush, never per query); a merge or compact changes the stack's
        serial fingerprint non-monotonically and triggers a full
        rebuild — the same O(R) work as the index rebuild that caused
        it."""
        serials = self._seg_serials()
        ar = self._arena
        if ar is not None and ar.serials == serials:
            return ar
        incremental = (ar is not None and ar.cols is not None
                       and len(serials) > len(ar.serials)
                       and serials[:len(ar.serials)] == ar.serials)
        if not incremental:
            ar = _ColumnArena()
        new_segs = self.segments[len(ar.serials):]
        W = max(1, (self.L + 31) // 32)
        cols_np, idx_np, gid_np, live_np, cid_np = [], [], [], [], []
        col0 = int(ar.col_ids.shape[0])
        root0 = 1 + ar.t_root_total          # slot 0: delta's trivial base
        for seg in new_segs:
            cols_np.append(np.transpose(seg.packed, (1, 2, 0)))
            leaf_root = np.asarray(seg.index.tail.leaf_root)
            id_leaf = np.asarray(seg.index.id_leaf)
            idx_np.append((root0 + leaf_root[id_leaf]).astype(np.int32))
            gid_np.append(seg.ids.astype(np.int32))
            live_np.append(seg.live.copy())
            cid_np.append(seg.ids)
            ar.col_off[seg.serial] = col0
            ar.root_off[seg.serial] = root0
            col0 += seg.n
            root0 += int(seg.index.tail.t_root)
        empty_cols = jnp.zeros((self.b, W, 0), jnp.uint32)
        old = ((ar.cols, ar.base_idx, ar.gids, ar.live)
               if ar.cols is not None
               else (empty_cols, jnp.zeros((0,), jnp.int32),
                     jnp.zeros((0,), jnp.int32), jnp.zeros((0,), bool)))
        if new_segs:
            ar.cols = jnp.concatenate(
                [old[0], jnp.asarray(np.concatenate(cols_np, axis=-1))],
                axis=-1)
            ar.base_idx = jnp.concatenate(
                [old[1], jnp.asarray(np.concatenate(idx_np))])
            ar.gids = jnp.concatenate(
                [old[2], jnp.asarray(np.concatenate(gid_np))])
            ar.live = jnp.concatenate(
                [old[3], jnp.asarray(np.concatenate(live_np))])
            ar.col_ids = np.concatenate([ar.col_ids] + cid_np)
        else:
            ar.cols, ar.base_idx, ar.gids, ar.live = old
        ar.t_root_total = root0 - 1
        ar.serials = serials
        self._arena = ar
        return ar

    def _refresh_store(self) -> ColumnStore:
        """Bring the tiered suffix ``ColumnStore`` (bst backend,
        ``layout="suffix"``) up to date with the segment stack — the
        same incremental discipline as ``_refresh_arena``: a flush
        appends one block, a merge/compact triggers a rebuild.  Sealing
        enforces the ``hot_bytes`` placement budget (LRU demotion /
        promotion), so tier flips happen here, between queries — never
        inside a compiled program."""
        serials = self._seg_serials()
        st = self._arena
        if isinstance(st, ColumnStore) and st.serials == serials:
            return st
        incremental = (isinstance(st, ColumnStore)
                       and len(serials) > len(st.serials)
                       and serials[:len(st.serials)] == st.serials)
        if not incremental:
            st = ColumnStore(self.L, self.b, hot_bytes=self.hot_bytes,
                             payload_words=self.payload_words)
        for seg in self.segments[len(st.serials):]:
            st.append_segment(seg)
        st.seal(serials)
        self._arena = st
        return st

    def _fused_fn(self, kind: str, tau: int, rung: int, kk: Optional[int]):
        """Fetch (or build) the compiled fused program for this segment
        stack: ``kind="cols"`` -> f(...) = ((mb, R) int32 dist plane,
        overflow); ``kind="topk"`` -> ((mb, kk) ids, (mb, kk) dists,
        min-survivors, overflow) — selection on device.  jit
        re-specializes per (mb, ndb) shape bucket under one cache
        entry."""
        serials = self._seg_serials()
        suffix_store = self.backend == "bst" and self.layout == "suffix"
        # the placement generation joins the fingerprint: a tier flip
        # moves columns between device closure and staged slab, so a
        # pre-flip program must never be reused
        gen = self._refresh_store().gen if suffix_store else 0
        if (serials, gen) != self._fused_stamp:
            # the stack or placement changed generation: this index's
            # programs keyed on the old fingerprint are permanently
            # unreachable (serials/gen are monotonic) — drop them now so
            # dead generations don't pin full column-arena copies until
            # FIFO eviction
            for stale in [k for k in _FUSED_CACHE
                          if k[2] == self._fused_id]:
                del _FUSED_CACHE[stale]
            self._fused_stamp = (serials, gen)
        key = (self.backend, self.layout, self._fused_id, serials, gen,
               kind, tau, rung, kk, self.block_m)
        fn = _FUSED_CACHE.get(key)
        if fn is None:
            build = {"bst": (self._build_fused_bst_suffix if suffix_store
                             else self._build_fused_bst),
                     "multi": self._build_fused_multi,
                     "sharded": self._build_fused_sharded}[self.backend]
            fn = build(kind, tau, rung, kk)
            while len(_FUSED_CACHE) >= _FUSED_CACHE_CAP:
                _FUSED_CACHE.pop(next(iter(_FUSED_CACHE)))
            _FUSED_CACHE[key] = fn
            _CACHE_STATS["misses"] += 1   # same ledger as get_searcher
        else:
            _CACHE_STATS["hits"] += 1
        return fn

    def _build_fused_bst(self, kind: str, tau: int, rung: int,
                         kk: Optional[int]):
        """One jitted program for the whole bst stack: every segment's
        2D-frontier traversal to its ℓ_s roots, a 0/BIG reach scatter
        onto ONE concatenated root plane, the arena verify kernel over
        sealed + delta columns (full-length paths, so the reach plane is
        the only traversal output the verify needs), and the on-device
        (distance, id) selection."""
        arena = self._refresh_arena()
        cap = CAP_MAX_DEFAULT << rung
        indexes = [seg.index for seg in self.segments]
        caps_list = [frontier_capacities(ix.t, self.b, tau, cap)
                     for ix in indexes]
        t_roots = [int(ix.tail.t_root) for ix in indexes]
        cols0, idx0, gids0 = arena.cols, arena.base_idx, arena.gids
        b_, block_m = self.b, self.block_m

        @jax.jit
        def run(qs, live_sealed, delta_vert, delta_live, delta_gids):
            _note_trace()
            qsi = qs.astype(jnp.int32)
            m = qsi.shape[0]
            planes = [jnp.zeros((m, 1), jnp.int32)]  # slot 0: delta base
            overflow = jnp.zeros((m,), jnp.int32)
            for ix, caps, t_root in zip(indexes, caps_list, t_roots):
                ids, dists, valid, ov, _ = _traverse_frontier_batch(
                    ix, qsi, tau=tau, caps=caps)
                # full-length columns recompute the prefix in the XOR:
                # the plane only carries reached (0) / pruned (BIG)
                planes.append(scatter_root_plane(
                    ids, jnp.zeros_like(dists), valid, m, t_root))
                overflow = overflow + ov
            base_plane = jnp.concatenate(planes, axis=1)
            cols = jnp.concatenate([cols0, delta_vert], axis=-1)
            live = jnp.concatenate([live_sealed, delta_live])
            base_idx = jnp.concatenate(
                [idx0, jnp.zeros((delta_vert.shape[-1],), jnp.int32)])
            q_vert = jnp.transpose(pack_vertical_jax(qsi, b_), (1, 2, 0))
            hm, dist = ops.sparse_verify_arena(
                cols, q_vert, base_plane, base_idx, live, tau=tau,
                block_m=block_m)
            dist = jnp.where(hm > 0, dist, BIG)
            if kind == "cols":
                return dist, overflow.sum()
            if kind == "dist":
                # two-stage stage 1: the dist plane STAYS on device (the
                # re-rank program consumes it); only the ladder scalars
                # cross back (DESIGN.md §10)
                return (dist, (dist < BIG).sum(axis=1).min(),
                        overflow.sum())
            sel_ids, sel_d = select_topk_columns(
                dist, jnp.concatenate([gids0, delta_gids]), kk)
            min_surv = (dist < BIG).sum(axis=1).min()
            return sel_ids, sel_d, min_surv, overflow.sum()
        return run

    def _build_fused_bst_suffix(self, kind: str, tau: int, rung: int,
                                kk: Optional[int]):
        """The suffix-layout fused program (DESIGN.md §7): same shape as
        ``_build_fused_bst`` — every segment's traversal, ONE root
        plane, verify, selection, one jitted launch — but the scatter
        carries the traversal's exact *prefix distances* (not 0/BIG) and
        the verify runs over per-geometry suffix column groups, so
        prefix + suffix reproduces the full-length Hamming distance bit
        for bit.  Hot groups close over device columns; cold columns
        arrive through the staged slabs (traced args, uploaded by
        ``ColumnStore.stage`` before the rung loop).  Multiple geometry
        groups mean multiple verify kernel bodies INSIDE the one
        program — still one fused dispatch per rung."""
        store = self._refresh_store()
        plan = store.plan()
        cap = CAP_MAX_DEFAULT << rung
        indexes = [seg.index for seg in self.segments]
        caps_list = [frontier_capacities(ix.t, self.b, tau, cap)
                     for ix in indexes]
        t_roots = [int(ix.tail.t_root) for ix in indexes]
        gids0 = store.gids
        r_sealed = store.n_cols
        b_, L, block_m = self.b, self.L, self.block_m

        @jax.jit
        def run(qs, live_sealed, staged, delta_vert, delta_live,
                delta_gids):
            _note_trace()
            qsi = qs.astype(jnp.int32)
            m = qsi.shape[0]
            planes = [jnp.zeros((m, 1), jnp.int32)]  # slot 0: delta base
            overflow = jnp.zeros((m,), jnp.int32)
            for ix, caps, t_root in zip(indexes, caps_list, t_roots):
                ids, dists, valid, ov, _ = _traverse_frontier_batch(
                    ix, qsi, tau=tau, caps=caps)
                planes.append(scatter_root_plane(
                    ids, dists, valid, m, t_root))
                overflow = overflow + ov
            base_plane = jnp.concatenate(planes, axis=1)
            dist_parts: List[jnp.ndarray] = []
            order_parts: List[np.ndarray] = []
            for g, slab in zip(plan, staged):
                axis = 0 if g.geom.packed else -1
                parts = [p for p in (g.cols_hot, slab) if p is not None]
                cols_g = (parts[0] if len(parts) == 1
                          else jnp.concatenate(parts, axis=axis))
                live_g = live_sealed[g.perm]
                S = g.geom.suffix_len
                if g.geom.packed:
                    qw = pack_suffix_words_jax(qsi[:, L - S:], b_)
                    hm, d = ops.sparse_verify_arena_packed(
                        cols_g, qw, base_plane, g.base_idx, live_g, b=b_,
                        S=S, tau=tau, block_m=block_m)
                else:
                    qv = jnp.transpose(
                        pack_vertical_jax(qsi[:, L - S:], b_), (1, 2, 0))
                    hm, d = ops.sparse_verify_arena(
                        cols_g, qv, base_plane, g.base_idx, live_g,
                        tau=tau, block_m=block_m)
                dist_parts.append(jnp.where(hm > 0, d, BIG))
                order_parts.append(g.perm)
            # the delta buffer scans full-length (its rows have no trie,
            # hence no ℓ_s to slice at) — same arithmetic as the full
            # arena's trivial base slot 0
            q_vert = jnp.transpose(pack_vertical_jax(qsi, b_), (1, 2, 0))
            dd = ops.hamming_distances(delta_vert, q_vert)
            dd = jnp.where(delta_live[None, :] & (dd <= tau), dd, BIG)
            dist_parts.append(dd.astype(jnp.int32))
            ndb = delta_vert.shape[-1]
            order_parts.append(np.arange(r_sealed, r_sealed + ndb))
            # restore global stack order with a static inverse
            # permutation (ndb is trace-static), so the column contract
            # and tie order match the full-length arena exactly
            inv = np.argsort(np.concatenate(order_parts))
            dist = jnp.concatenate(dist_parts, axis=1)[:, inv]
            if kind == "cols":
                return dist, overflow.sum()
            if kind == "dist":
                return (dist, (dist < BIG).sum(axis=1).min(),
                        overflow.sum())
            sel_ids, sel_d = select_topk_columns(
                dist, jnp.concatenate([gids0, delta_gids]), kk)
            min_surv = (dist < BIG).sum(axis=1).min()
            return sel_ids, sel_d, min_surv, overflow.sum()
        return run

    def _build_fused_multi(self, kind: str, tau: int, rung: int,
                           kk: Optional[int]):
        """Fused stack program for MI segments: every segment's batched
        MI trace (per-block traversal + candidate verify) inlined as a
        sub-trace, delta scan and selection fused behind them."""
        segs = list(self.segments)
        cap_max = (1 << 15) << rung
        mis = [seg.index for seg in segs]
        params = []
        for mi in mis:
            caps_pb, cc = mi_trace_params(mi, tau, cap_max)
            params.append((caps_pb, min(cc << rung, mi.n)))
        gids_const = [jnp.asarray(seg.ids.astype(np.int32)) for seg in segs]
        b_, block_m = self.b, self.block_m

        @jax.jit
        def run(qs, seg_lives, delta_vert, delta_live, delta_gids):
            _note_trace()
            qsi = qs.astype(jnp.int32)
            dists: List[jnp.ndarray] = []
            ov = jnp.int32(0)
            for mi, (caps_pb, cc), live in zip(mis, params, seg_lives):
                d, o = mi_column_dists(mi, qsi, tau, caps_pb, cc,
                                       block_m=block_m, id_live=live)
                dists.append(d)
                ov = ov + o.sum()
            q_vert = jnp.transpose(pack_vertical_jax(qsi, b_), (1, 2, 0))
            dd = ops.hamming_distances(delta_vert, q_vert)
            dd = jnp.where(delta_live[None, :] & (dd <= tau), dd, BIG)
            dists.append(dd.astype(jnp.int32))
            dist = jnp.concatenate(dists, axis=1)
            if kind == "cols":
                return dist, ov
            if kind == "dist":
                return dist, (dist < BIG).sum(axis=1).min(), ov
            sel_ids, sel_d = select_topk_columns(
                dist, jnp.concatenate(gids_const + [delta_gids]), kk)
            min_surv = (dist < BIG).sum(axis=1).min()
            return sel_ids, sel_d, min_surv, ov
        return run

    def _build_fused_sharded(self, kind: str, tau: int, rung: int,
                             kk: Optional[int]):
        """Fused stack program for sharded-bST segments: each segment's
        vmapped per-shard traversal+verify runs as a sub-trace and the
        shard->global merge happens on device
        (``sharded_column_dists``), so S shards × n_segments collapse
        into the one launch."""
        segs = list(self.segments)
        cap = (1 << 14) << rung
        idxs = [seg.index for seg in segs]
        capss = []
        for idx in idxs:
            t_max = tuple(int(x) for x in np.asarray(idx.t).max(axis=0))
            capss.append(frontier_capacities(t_max, idx.b, tau, cap))
        gids_const = [jnp.asarray(seg.ids.astype(np.int32)) for seg in segs]
        b_, block_m = self.b, self.block_m

        @jax.jit
        def run(qs, seg_lives, delta_vert, delta_live, delta_gids):
            _note_trace()
            qsi = qs.astype(jnp.int32)
            dists: List[jnp.ndarray] = []
            ov = jnp.int32(0)
            for idx, caps, live in zip(idxs, capss, seg_lives):
                d, o = sharded_column_dists(idx, qsi, tau, caps,
                                            block_m=block_m, live=live)
                dists.append(d.astype(jnp.int32))
                ov = ov + o
            q_vert = jnp.transpose(pack_vertical_jax(qsi, b_), (1, 2, 0))
            dd = ops.hamming_distances(delta_vert, q_vert)
            dd = jnp.where(delta_live[None, :] & (dd <= tau), dd, BIG)
            dists.append(dd.astype(jnp.int32))
            dist = jnp.concatenate(dists, axis=1)
            if kind == "cols":
                return dist, ov
            if kind == "dist":
                return dist, (dist < BIG).sum(axis=1).min(), ov
            sel_ids, sel_d = select_topk_columns(
                dist, jnp.concatenate(gids_const + [delta_gids]), kk)
            min_surv = (dist < BIG).sum(axis=1).min()
            return sel_ids, sel_d, min_surv, ov
        return run

    def _fused_saturated(self, rung: int) -> bool:
        start = {"bst": CAP_MAX_DEFAULT, "multi": 1 << 15,
                 "sharded": 1 << 14}[self.backend]
        if (start << rung) < LADDER_CAP_MAX:
            return False
        if self.backend == "multi":
            # candidate caps floor at 1024 and double per rung alongside
            # the frontier caps (mi_search_batch's ladder discipline)
            return all((1024 << rung) >= seg.index.n
                       for seg in self.segments)
        return True

    def _fused_call(self, kind: str, qs: np.ndarray, tau: int,
                    kk: Optional[int] = None):
        """Dispatch ONE fused program per capacity rung: pads the query
        axis to its power-of-two bucket, assembles the (bucketed) delta
        args, and escalates the frontier-capacity rung until the
        traversal is exact — each retry is again a single launch."""
        m = qs.shape[0]
        mb = bucket_m(m)
        qs_p = jnp.asarray(qs)
        if mb != m:
            qs_p = _pad_rows(qs_p, mb)
        nd = len(self._delta_ids)
        if nd:
            delta_vert = self._delta_planes()
            ndb = delta_vert.shape[-1]
            delta_live = np.zeros(ndb, bool)
            delta_live[:nd] = self._delta_live
            delta_gids = np.zeros(ndb, np.int32)
            delta_gids[:nd] = self._delta_ids.astype(np.int32)
        else:
            W = max(1, (self.L + 31) // 32)
            delta_vert = jnp.zeros((self.b, W, 0), jnp.uint32)
            delta_live = np.zeros(0, bool)
            delta_gids = np.zeros(0, np.int32)
        staged = None
        if self.backend == "bst":
            if self.layout == "suffix":
                store = self._refresh_store()
                # copy-ahead: upload every cold block's staging slab
                # ONCE per query, before the rung loop — the async
                # device_put overlaps the first rung's traversal, and
                # ladder retries reuse the same slabs
                staged = store.stage()
                seg_arg = store.live
            else:
                seg_arg = self._refresh_arena().live
        else:
            seg_arg = tuple(jnp.asarray(seg.live) for seg in self.segments)
        rung = 0
        while True:
            # span covers build/fetch + dispatch + the steering-scalar
            # readback (the sync point where device time surfaces)
            with _obs_span("rung_dispatch", cat="device", kind=kind,
                           tau=tau, rung=rung):
                fn = self._fused_fn(kind, tau, rung, kk)
                _dispatch("fused")
                if staged is not None:
                    out = fn(jnp.asarray(qs_p), seg_arg, staged,
                             delta_vert, jnp.asarray(delta_live),
                             jnp.asarray(delta_gids))
                else:
                    out = fn(jnp.asarray(qs_p), seg_arg, delta_vert,
                             jnp.asarray(delta_live),
                             jnp.asarray(delta_gids))
                done = int(out[-1]) == 0 or self._fused_saturated(rung)
            if done:
                return out
            rung += 1

    def _fused_columns(self, qs: np.ndarray,
                       tau: int) -> Tuple[np.ndarray, np.ndarray, int]:
        """Arena-path ``_search_columns``: same ((m, R) dist, (R,) ids,
        overflow) contract, one device dispatch per capacity rung."""
        m = qs.shape[0]
        r_sealed = sum(seg.n for seg in self.segments)
        nd = len(self._delta_ids)
        if r_sealed + nd == 0:
            return (np.zeros((m, 0), np.int32), np.zeros((0,), np.int64),
                    0)
        dist, ov = self._fused_call("cols", qs, tau)
        dist = np.asarray(dist)[:m, :r_sealed + nd]
        col_ids = np.concatenate([seg.ids for seg in self.segments]
                                 + [self._delta_ids])
        return dist, col_ids, int(ov)

    def _fused_topk(self, qs: np.ndarray, k: int,
                    tau0: Optional[int]) -> TopKResult:
        """The on-device τ-escalation ladder: each rung is one fused
        launch whose selection already ran on device — the host reads
        back two scalars (min survivor count, overflow) to steer the
        ladder, and only the final (m, k) ids/dists when it stops."""
        m = qs.shape[0]
        n_live = self.n_live
        if n_live == 0:
            return TopKResult(ids=jnp.full((m, k), -1, jnp.int32),
                              dists=jnp.full((m, k), BIG_I, jnp.int32),
                              tau=0, overflow=0)
        kk = min(int(k), n_live)
        tau = tau0 if tau0 is not None else tau_for_k(self.b, self.L,
                                                      n_live, kk)
        tau = min(max(int(tau), 0), self.L)
        while True:
            ids, dists, min_surv, ov = self._fused_call("topk", qs, tau,
                                                        kk=kk)
            if int(min_surv) >= kk or tau >= self.L:
                break
            tau = min(self.L, max(tau + 1, 2 * tau))
        with _obs_span("topk_readback", cat="device", k=int(k)):
            dd, ids = _pad_topk(np.asarray(dists)[:m],
                                np.asarray(ids)[:m], int(k))
        return TopKResult(ids=jnp.asarray(ids), dists=jnp.asarray(dd),
                          tau=tau, overflow=int(ov))

    # -- exact re-rank plane (DESIGN.md §10) -----------------------------

    def _check_rerank(self, metric: str, q_payloads,
                      m: int) -> np.ndarray:
        """Validate the two-stage request: known metric, payload-bearing
        index, (m, Wp) uint32 query bitmaps."""
        if metric not in RERANK_METRICS:
            raise ValueError(f"rerank must be one of {RERANK_METRICS}")
        if self.payload_words is None:
            raise ValueError(
                "rerank requires an index built with payload_words")
        if q_payloads is None:
            raise ValueError("rerank requires q_payloads — the queries' "
                             "(m, Wp) uint32 set bitmaps")
        qp = np.asarray(q_payloads, np.uint32)
        if qp.ndim == 1:
            qp = qp[None, :]
        if qp.shape != (m, self.payload_words):
            raise ValueError(f"q_payloads shape {qp.shape} != "
                             f"({m}, {self.payload_words})")
        return qp

    def _payload_rows(self) -> np.ndarray:
        """(R, Wp) uint32 host payload rows in global column order (every
        segment's rows in stack order, then the delta buffer's) — the
        reference path's re-rank source."""
        parts = [seg.payloads for seg in self.segments]
        if len(self._delta_ids):
            parts.append(self._delta_pay)
        if not parts:
            return np.zeros((0, self.payload_words), np.uint32)
        return np.concatenate(parts, axis=0)

    def _rerank_ladder(self, qs: np.ndarray, k: int, tau0: Optional[int],
                       metric: str, q_pay: np.ndarray) -> TopKResult:
        """Reference two-stage path (``use_arena=False``): the
        per-segment fan-out ladder finds the final-τ survivor plane,
        then ONE ``_rerank_select`` launch scores and selects — same
        kernel, sort, and tie order as the fused path."""
        return _ladder_topk_rerank(
            self._search_columns, self._payload_rows, self.n_live, self.b,
            self.L, self.block_m, qs, k, tau0, metric, q_pay)

    def _rerank_fn(self, metric: str, kk: int):
        """Fetch (or build) the compiled stage-2 program for this stack —
        same cache, fingerprint, and dead-generation discipline as
        ``_fused_fn`` (the stamp purge there also drops stale re-rank
        programs: they share this index's ``_fused_id`` scope)."""
        serials = self._seg_serials()
        suffix_store = self.backend == "bst" and self.layout == "suffix"
        gen = self._refresh_store().gen if suffix_store else 0
        key = (self.backend, self.layout, self._fused_id, serials, gen,
               "rerank", metric, 0, kk, self.block_m)
        fn = _FUSED_CACHE.get(key)
        if fn is None:
            fn = self._build_rerank(metric, kk)
            while len(_FUSED_CACHE) >= _FUSED_CACHE_CAP:
                _FUSED_CACHE.pop(next(iter(_FUSED_CACHE)))
            _FUSED_CACHE[key] = fn
            _CACHE_STATS["misses"] += 1
        else:
            _CACHE_STATS["hits"] += 1
        return fn

    def _build_rerank(self, metric: str, kk: int):
        """ONE jitted stage-2 program: assemble the (Wp, R) payload plane
        in global column order (hot groups close over device bitmaps,
        cold arrive through the staged payload slabs, delta through its
        bucketed plane), score the stage-1 survivors with the exact
        re-rank kernel, and select the k best (score desc, id asc) on
        device — the dist plane never leaves the device between stages."""
        block_m = self.block_m
        if self.backend == "bst" and self.layout == "suffix":
            store = self._refresh_store()
            plan = store.plan()
            gids0 = store.gids
            r_sealed = store.n_cols

            @jax.jit
            def run(dist, q_pay, staged_pays, delta_pay, delta_gids):
                _note_trace()
                pay_parts: List[jnp.ndarray] = []
                order_parts: List[np.ndarray] = []
                for g, slab in zip(plan, staged_pays):
                    parts = [p for p in (g.pays_hot, slab) if p is not None]
                    pay_parts.append(parts[0] if len(parts) == 1
                                     else jnp.concatenate(parts, axis=-1))
                    order_parts.append(g.perm)
                ndb = delta_pay.shape[-1]
                pay_parts.append(delta_pay)
                order_parts.append(np.arange(r_sealed, r_sealed + ndb))
                # the same trace-static inverse permutation the dist
                # program applied — pay columns land in dist order
                inv = np.argsort(np.concatenate(order_parts))
                pays = jnp.concatenate(pay_parts, axis=-1)[:, inv]
                surv = (dist < BIG).astype(jnp.int32)
                scores = ops.exact_rerank(pays, q_pay, surv, metric=metric,
                                          block_m=block_m)
                col_ids = jnp.concatenate([gids0, delta_gids])
                return select_topk_scores(scores, dist, col_ids, kk)
            return run

        # non-suffix configurations: sealed payloads live in the
        # incremental device payload arena, already in stack order
        if self._pay_arena is None:
            self._pay_arena = _PayloadArena(self.payload_words)
        pays0 = self._pay_arena.refresh(self.segments, self._seg_serials())
        if self.backend == "bst":
            gids0 = self._refresh_arena().gids
        elif self.segments:
            gids0 = jnp.concatenate(
                [jnp.asarray(seg.ids.astype(np.int32))
                 for seg in self.segments])
        else:
            gids0 = jnp.zeros((0,), jnp.int32)

        @jax.jit
        def run(dist, q_pay, delta_pay, delta_gids):
            _note_trace()
            pays = jnp.concatenate([pays0, delta_pay], axis=-1)
            surv = (dist < BIG).astype(jnp.int32)
            scores = ops.exact_rerank(pays, q_pay, surv, metric=metric,
                                      block_m=block_m)
            col_ids = jnp.concatenate([gids0, delta_gids])
            return select_topk_scores(scores, dist, col_ids, kk)
        return run

    def _fused_topk_rerank(self, qs: np.ndarray, k: int,
                           tau0: Optional[int], metric: str,
                           q_pay: np.ndarray) -> TopKResult:
        """The fused two-stage ladder: stage 1 re-runs the kind="dist"
        fused program per τ rung (the survivor plane stays device-side;
        only the two ladder scalars transfer), then stage 2 is ONE
        additional re-rank dispatch for the whole request — regardless
        of segment count (DESIGN.md §10)."""
        m = qs.shape[0]
        n_live = self.n_live
        if n_live == 0:
            return _empty_topk_rerank(m, int(k))
        kk = min(int(k), n_live)
        tau = tau0 if tau0 is not None else tau_for_k(self.b, self.L,
                                                      n_live, kk)
        tau = min(max(int(tau), 0), self.L)
        while True:
            dist, min_surv, ov = self._fused_call("dist", qs, tau)
            if int(min_surv) >= kk or tau >= self.L:
                break
            tau = min(self.L, max(tau + 1, 2 * tau))
        mb = int(dist.shape[0])
        qp = np.zeros((mb, self.payload_words), np.uint32)
        qp[:m] = q_pay
        q_pay_vert = jnp.asarray(np.ascontiguousarray(qp.T))
        nd = len(self._delta_ids)
        if nd:
            delta_pay = self._delta_pay_planes()
            ndb = delta_pay.shape[-1]
            delta_gids = np.zeros(ndb, np.int32)
            delta_gids[:nd] = self._delta_ids.astype(np.int32)
        else:
            delta_pay = jnp.zeros((self.payload_words, 0), jnp.uint32)
            delta_gids = np.zeros(0, np.int32)
        fn = self._rerank_fn(metric, kk)
        _dispatch("rerank")
        with _obs_span("rerank", cat="device", metric=metric, kk=kk):
            if self.backend == "bst" and self.layout == "suffix":
                staged_pays = self._refresh_store().stage_payloads()
                ids, dists, scores = fn(dist, q_pay_vert, staged_pays,
                                        delta_pay,
                                        jnp.asarray(delta_gids))
            else:
                ids, dists, scores = fn(dist, q_pay_vert, delta_pay,
                                        jnp.asarray(delta_gids))
            ids, dists, scores = (np.asarray(ids)[:m],
                                  np.asarray(dists)[:m],
                                  np.asarray(scores)[:m])
        ids, dists, scores = _pad_topk_scores(ids, dists, scores, int(k))
        return TopKResult(ids=jnp.asarray(ids), dists=jnp.asarray(dists),
                          tau=tau, overflow=int(ov),
                          scores=jnp.asarray(scores))


class ShardedSegmentedIndex:
    """S independent segment stacks, one per shard — the dynamic analogue
    of ``build_sharded_bst``'s layout: inserts round-robin across shards
    (matching the static builder's ``id % S`` placement), deletes route
    by id, and queries fan out over every shard's stack before the
    shared shard-merge selection.  Per-shard stacks keep every segment
    rebuild bounded by its shard's slice — a merge touches 1/S of the
    data, the same fault/rebuild granularity as the static sharded
    index.

    Same result contract as ``SegmentedIndex`` (global-id planes,
    ``TopKResult`` with global ids).
    """

    def __init__(self, L: int, b: int, n_shards: int = 4, *,
                 delta_cap: int = 4096, backend: str = "bst",
                 lam: float = 0.5, auto_merge: bool = True,
                 block_m: int = DEFAULT_BLOCK_M, use_arena: bool = True,
                 layout: str = "suffix", hot_bytes: Optional[int] = None,
                 payload_words: Optional[int] = None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.L, self.b = int(L), int(b)
        self.n_shards = int(n_shards)
        self.block_m = int(block_m)
        self.payload_words = (None if payload_words is None
                              else int(payload_words))
        # a per-stack hot budget: the device budget splits evenly across
        # the independent stacks (each stack places its own blocks)
        per_stack = (None if hot_bytes is None
                     else max(0, int(hot_bytes) // self.n_shards))
        self.shards = [
            SegmentedIndex(L, b, delta_cap=delta_cap, backend=backend,
                           lam=lam, auto_merge=auto_merge, block_m=block_m,
                           use_arena=use_arena, layout=layout,
                           hot_bytes=per_stack,
                           payload_words=self.payload_words)
            for _ in range(self.n_shards)]
        self.n_ids = 0
        # global id -> shard is `id % S`; per-shard local ids are dense,
        # so global id maps to local position `id // S`.
        # durability binding: the top level journals one global-id record
        # per write (shard stacks bind with log_writes=False and only
        # snapshot their own segments).
        self.store: Optional[object] = None

    def insert(self, sketches: np.ndarray,
               payloads: Optional[np.ndarray] = None) -> np.ndarray:
        """Round-robin insert; returns (k,) int64 global ids.  With
        ``payload_words`` set, ``payloads`` carries the rows' (k, Wp)
        uint32 set bitmaps, routed to each shard alongside its rows."""
        sk = np.asarray(sketches, dtype=np.uint8)
        if sk.ndim == 1:
            sk = sk[None, :]
        k = sk.shape[0]
        pay = self.shards[0]._check_payloads(payloads, k)
        new_ids = np.arange(self.n_ids, self.n_ids + k, dtype=np.int64)
        if self.store is not None and k:
            # one global-id WAL record
            self.store.log_insert(new_ids, sk, payloads=pay)
            # scope the routing: a shard's auto-flush checkpoint mid-way
            # through must not let the store truncate the WAL (or seal
            # sibling stacks past this record) before every shard has
            # applied its rows
            self.store.begin_write()
        try:
            for s in range(self.n_shards):
                rows = np.flatnonzero(new_ids % self.n_shards == s)
                if rows.size:
                    self.shards[s].insert(
                        sk[rows],
                        payloads=pay[rows] if pay is not None else None)
        finally:
            if self.store is not None and k:
                self.store.end_write()
        self.n_ids += k
        return new_ids

    def delete(self, ids) -> int:
        """Tombstone global ids; returns the number newly deleted."""
        ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
        ids = ids[(ids >= 0) & (ids < self.n_ids)]
        if self.store is not None and ids.size:
            self.store.log_delete(ids)
        newly = 0
        for s in range(self.n_shards):
            mine = ids[ids % self.n_shards == s]
            if mine.size:
                newly += self.shards[s].delete(mine // self.n_shards)
        return newly

    def flush(self) -> None:
        for shard in self.shards:
            shard.flush()

    def merge(self) -> int:
        """Size-tiered merge inside every shard's stack; returns total
        merges performed."""
        return sum(shard.maybe_merge() for shard in self.shards)

    def compact(self, min_dead_frac: float = 0.0) -> int:
        return sum(shard.compact(min_dead_frac=min_dead_frac)
                   for shard in self.shards)

    @property
    def n_live(self) -> int:
        return sum(shard.n_live for shard in self.shards)

    def __len__(self) -> int:
        return self.n_live

    def space_bits(self) -> int:
        return sum(shard.space_bits() for shard in self.shards)

    def cost_hint(self, op: str, *, k: Optional[int] = None,
                  tau: Optional[int] = None, rows: int = 1) -> float:
        """Sum of the per-shard-stack cost hints (every stack answers
        every read; writes split their rows round-robin)."""
        per_rows = max(rows // len(self.shards), 1) if op == "write" \
            else rows
        return sum(s.cost_hint(op, k=k, tau=tau, rows=per_rows)
                   for s in self.shards)

    @property
    def tombstones(self) -> int:
        return sum(shard.tombstones for shard in self.shards)

    def space_ledger(self) -> Dict[str, int]:
        led = {"model_bits": 0, "device_bytes": 0, "host_bytes": 0}
        for shard in self.shards:
            for k, v in shard.space_ledger().items():
                led[k] += v
        return led

    def stats(self) -> Dict[str, object]:
        led = self.space_ledger()
        return {"n_ids": self.n_ids, "n_live": self.n_live,
                "tombstones": self.tombstones,
                "n_segments": sum(len(s.segments) for s in self.shards),
                "arena_bytes": sum(
                    s._arena.array_bytes() if s._arena is not None else 0
                    for s in self.shards),
                "device_bytes": led["device_bytes"],
                "host_bytes": led["host_bytes"],
                "shards": [shard.stats() for shard in self.shards]}

    def _search_columns(self, qs: np.ndarray,
                        tau: int) -> Tuple[np.ndarray, np.ndarray, int]:
        """Column-compressed fan-out over every shard's stack: local
        column ids relabel to global via ``gid = local * S + s``.  Each
        shard's stack answers through its own fused arena (one dispatch
        per shard, flat in its segment count — DESIGN.md §6); the
        per-shard merge stays on host like the static sharded path."""
        m = qs.shape[0]
        dists: List[np.ndarray] = []
        col_ids: List[np.ndarray] = []
        overflow = 0
        for s, shard in enumerate(self.shards):
            dist, local_ids, ov = shard._columns(qs, tau)
            dists.append(dist)
            col_ids.append(local_ids * self.n_shards + s)
            overflow += ov
        if not dists:
            return (np.zeros((m, 0), np.int32), np.zeros((0,), np.int64),
                    0)
        return (np.concatenate(dists, axis=1),
                np.concatenate(col_ids), overflow)

    def _global_plane(self, qs: np.ndarray,
                      tau: int) -> Tuple[np.ndarray, int]:
        m = qs.shape[0]
        dist, col_ids, overflow = self._search_columns(qs, tau)
        plane = np.full((m, self.n_ids), BIG_I, np.int32)
        plane[:, col_ids] = dist
        return plane, overflow

    def search_batch(self, qs: np.ndarray, tau: int,
                     explain: bool = False) -> SegmentedSearchResult:
        """(m, L) uint8 queries -> global (m, n_ids) mask/dist planes.
        ``explain=True`` appends the ``QueryExplain`` record."""
        qs = np.asarray(qs, dtype=np.uint8)
        if qs.ndim == 1:
            qs = qs[None, :]
        if explain:
            rec = _ExplainRecorder()
            dist, col_ids, overflow = rec.wrap(self._search_columns)(
                qs, int(tau))
            plane = np.full((qs.shape[0], self.n_ids), BIG_I, np.int32)
            plane[:, col_ids] = dist
            res = SegmentedSearchResult(mask=plane <= tau, dist=plane,
                                        overflow=overflow)
            return res, rec.finish(
                op="search", backend="sharded-stacks",
                n_queries=qs.shape[0], n_live=self.n_live, k=None,
                tau0=int(tau), tau_final=int(tau), rerank=None)
        plane, overflow = self._global_plane(qs, int(tau))
        return SegmentedSearchResult(mask=plane <= tau, dist=plane,
                                     overflow=overflow)

    def search(self, q: np.ndarray, tau: int,
               explain: bool = False) -> SegmentedSearchResult:
        out = self.search_batch(np.asarray(q)[None], tau, explain=explain)
        res, ex = out if explain else (out, None)
        res = SegmentedSearchResult(mask=res.mask[0], dist=res.dist[0],
                                    overflow=res.overflow)
        return (res, ex) if explain else res

    def _payload_rows(self) -> np.ndarray:
        """(R, Wp) uint32 payload rows in the global column order of
        ``_search_columns`` (shard 0's columns, then shard 1's, ...)."""
        parts = [shard._payload_rows() for shard in self.shards]
        return np.concatenate(parts, axis=0)

    def topk_batch(self, qs: np.ndarray, k: int,
                   tau0: Optional[int] = None, *,
                   rerank: Optional[str] = None,
                   q_payloads: Optional[np.ndarray] = None,
                   explain: bool = False) -> TopKResult:
        """Exact global kNN: per-shard column-compressed fan-out on one
        shared τ ladder (same contract as ``SegmentedIndex.topk_batch``,
        including the two-stage ``rerank=`` contract — stage 2 is still
        ONE re-rank dispatch over the merged survivor plane, never one
        per shard).  ``explain=True`` appends the ``QueryExplain``
        record (bit-identical result)."""
        qs = np.asarray(qs, dtype=np.uint8)
        if qs.ndim == 1:
            qs = qs[None, :]
        rec = _ExplainRecorder() if explain else None
        columns_fn = (rec.wrap(self._search_columns) if explain
                      else self._search_columns)
        if rerank is not None:
            q_pay = self.shards[0]._check_rerank(rerank, q_payloads,
                                                 qs.shape[0])
            res = _ladder_topk_rerank(
                columns_fn, self._payload_rows, self.n_live,
                self.b, self.L, self.block_m, qs, k, tau0, rerank, q_pay)
        else:
            if q_payloads is not None:
                raise ValueError("q_payloads supplied without rerank=")
            res = _ladder_topk(columns_fn, self.n_live, self.b,
                               self.L, qs, k, tau0)
        if not explain:
            return res
        return res, rec.finish(
            op="topk", backend="sharded-stacks", n_queries=qs.shape[0],
            n_live=self.n_live, k=int(k),
            tau0=None if tau0 is None else int(tau0),
            tau_final=int(res.tau), rerank=rerank)

    def topk(self, q: np.ndarray, k: int,
             tau0: Optional[int] = None, *,
             rerank: Optional[str] = None,
             q_payloads: Optional[np.ndarray] = None,
             explain: bool = False) -> TopKResult:
        qp = None
        if q_payloads is not None:
            qp = np.asarray(q_payloads, np.uint32)
            if qp.ndim == 1:
                qp = qp[None, :]
        out = self.topk_batch(np.asarray(q)[None], k, tau0=tau0,
                              rerank=rerank, q_payloads=qp,
                              explain=explain)
        res, ex = out if explain else (out, None)
        res = TopKResult(ids=res.ids[0], dists=res.dists[0], tau=res.tau,
                         overflow=res.overflow,
                         scores=(None if res.scores is None
                                 else res.scores[0]))
        return (res, ex) if explain else res
