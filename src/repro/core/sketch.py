"""Similarity-preserving hashing: b-bit minhash and 0-bit CWS (paper §I, §VI-A).

Both hashers map vectorial data to length-L strings over Σ=[0, 2^b) — the
*b-bit sketches* the index consumes.

* ``bbit_minhash`` [Li & König, WWW'10]: for binary vectors (sets), L
  independent min-wise hashes; keep the low b bits of each minimum.
  Collision probability per position ≈ J + (1-J)/2^b for Jaccard J.
* ``zbit_cws`` [Li, KDD'15]: 0-bit consistent weighted sampling for
  non-negative (weighted) vectors; per hash, the Ioffe-CWS argmin feature
  id i* is kept (the "0-bit" trick discards t*); low b bits of i* form the
  character.  Approximates the min-max kernel.

Everything is pure JAX (jit/vmap/pjit-able) so sketching runs *inside* the
sharded data pipeline: on a (pod, data, model) mesh each data shard
sketches its own documents — sketch generation is embarrassingly parallel
and needs no collectives.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

def _mix32(x: jnp.ndarray) -> jnp.ndarray:
    """Murmur3 finalizer on uint32 — a strong bijective mixer; uint32
    wraparound multiplies keep everything in 32-bit lanes (no x64 needed)."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _hash_params(key: jax.Array, L: int):
    ka, kb = jax.random.split(key)
    a = jax.random.randint(ka, (L,), 1, jnp.iinfo(jnp.int32).max, dtype=jnp.uint32) | jnp.uint32(1)
    b = jax.random.randint(kb, (L,), 0, jnp.iinfo(jnp.int32).max, dtype=jnp.uint32)
    return a, b


@functools.partial(jax.jit, static_argnames=("L", "b"))
def bbit_minhash(key: jax.Array, items: jnp.ndarray, mask: jnp.ndarray, *, L: int, b: int) -> jnp.ndarray:
    """b-bit minhash of a batch of sets.

    items: (batch, max_items) int32 feature ids (padded);
    mask:  (batch, max_items) bool validity;
    returns (batch, L) uint8 sketches over [0, 2^b).
    """
    a, c = _hash_params(key, L)
    x = items.astype(jnp.uint32)  # (batch, m)
    # h_j(x) = mix32(a_j * x + c_j)  — broadcast to (batch, m, L)
    hashed = _mix32(x[:, :, None] * a[None, None, :] + c[None, None, :])
    big = jnp.uint32(0xFFFFFFFF)
    hashed = jnp.where(mask[:, :, None], hashed, big)
    mins = hashed.min(axis=1)  # (batch, L)
    return (mins & jnp.uint32((1 << b) - 1)).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("L", "b"))
def zbit_cws(key: jax.Array, weights: jnp.ndarray, *, L: int, b: int) -> jnp.ndarray:
    """0-bit consistent weighted sampling of non-negative weighted vectors.

    weights: (batch, dim) float, >= 0; returns (batch, L) uint8 sketches.

    Ioffe-CWS per hash j and feature i:
      r, c ~ Gamma(2,1), beta ~ U(0,1)   (fixed per (j, i))
      t = floor(ln w_i / r + beta); ln y = r (t - beta); ln a = ln c - ln y - r
      k* = argmin_i ln a_i ;   0-bit: emit k* (low b bits)
    Features with w=0 are excluded via +inf.
    """
    batch, dim = weights.shape
    kr, kc, kb = jax.random.split(key, 3)
    # Gamma(2,1) = sum of two Exp(1); cheap and exact.
    r = (jax.random.exponential(kr, (2, L, dim)).sum(0)).astype(jnp.float32)
    cpar = (jax.random.exponential(kc, (2, L, dim)).sum(0)).astype(jnp.float32)
    beta = jax.random.uniform(kb, (L, dim), dtype=jnp.float32)

    logw = jnp.where(weights > 0, jnp.log(jnp.maximum(weights, 1e-30)), -jnp.inf)  # (batch, dim)
    t = jnp.floor(logw[:, None, :] / r[None] + beta[None])  # (batch, L, dim)
    lny = r[None] * (t - beta[None])
    lna = jnp.log(cpar)[None] - lny - r[None]
    lna = jnp.where(jnp.isfinite(logw)[:, None, :], lna, jnp.inf)
    kstar = jnp.argmin(lna, axis=-1)  # (batch, L)
    return (kstar & ((1 << b) - 1)).astype(jnp.uint8)


def jaccard(items_a, mask_a, items_b, mask_b) -> jnp.ndarray:
    """Exact Jaccard between two padded sets — oracle for minhash tests.
    items_*: (batch, max_items) int32 ids; mask_*: (batch, max_items)
    bool validity -> (batch,) float."""
    def one(ia, ma, ib, mb):
        ia = jnp.where(ma, ia, -1)
        ib = jnp.where(mb, ib, -2)
        inter = (ia[:, None] == ib[None, :]).any(axis=1) & ma
        ni = inter.sum()
        nu = ma.sum() + mb.sum() - ni
        return jnp.where(nu > 0, ni / nu, 0.0)
    return jax.vmap(one)(items_a, mask_a, items_b, mask_b)


def minmax_kernel(wa: jnp.ndarray, wb: jnp.ndarray) -> jnp.ndarray:
    """Exact min-max kernel — oracle for CWS tests.
    wa, wb: (..., dim) float, >= 0 -> (...,) float in [0, 1]."""
    num = jnp.minimum(wa, wb).sum(axis=-1)
    den = jnp.maximum(wa, wb).sum(axis=-1)
    return jnp.where(den > 0, num / den, 0.0)


def sketch_tokens(key: jax.Array, tokens: jnp.ndarray, *, L: int, b: int,
                  vocab_hash_dim: Optional[int] = None) -> jnp.ndarray:
    """Sketch token sequences (documents) for the dedup pipeline.

    tokens: (batch, seq) int32 — each document is treated as the *set* of
    its token ids (bag semantics collapse to set under minhash), matching
    the paper's Review preprocessing (presence/absence fingerprint).
    """
    mask = tokens >= 0
    items = jnp.maximum(tokens, 0)
    return bbit_minhash(key, items, mask, L=L, b=b)
