"""Level-synchronous similarity search over a SketchIndex (paper Alg. 1,
re-derived for TPU — see DESIGN.md §2).

The paper's recursive DFS visits one node at a time and prunes a subtree
when the accumulated Hamming distance exceeds τ.  Here the *whole frontier
at level ℓ* is a fixed-capacity array of (node id, distance) pairs; one
step expands every node's ≤ 2^b children with one vectorized ``children``
call, masks out children with dist > τ (the paper's pruning), and
compacts survivors with a cumsum-scatter.  The sparse tail is *not*
traversed: pruned ℓ_s-subtries get a +∞ base distance and the Pallas
verify kernel streams every collapsed suffix path in one masked scan —
pruning becomes masking, pointer work becomes bandwidth.

Static shapes: frontier capacities come from the cost model
(min(t_ℓ, sigs(b,ℓ,τ), cap_max)).  Exceeding ``cap_max`` is detected and
reported; the host wrapper retries on a doubled ladder (production: one
compiled searcher per (index, τ) pair, the common case never overflows).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .bst import BIG, SketchIndex
from .cost_model import frontier_capacities
from .hamming import pack_vertical_jax
from ..kernels import ops


class SearchResult(NamedTuple):
    mask: jnp.ndarray        # (n,) bool — ids within τ of the query
    overflow: jnp.ndarray    # int32 — dropped frontier entries (0 = exact)
    traversed: jnp.ndarray   # int32 — Σ frontier sizes (paper's t_tra)


def _compact(ids: jnp.ndarray, dists: jnp.ndarray, valid: jnp.ndarray,
             capacity: int):
    """Stable masked compaction into a fixed-size frontier."""
    pos = jnp.cumsum(valid) - 1
    total = jnp.where(valid.shape[0] > 0, pos[-1] + 1, 0).astype(jnp.int32)
    slot = jnp.where(valid & (pos < capacity), pos, capacity)
    out_ids = jnp.zeros((capacity + 1,), jnp.int32).at[slot].set(ids, mode="drop")
    out_dists = jnp.full((capacity + 1,), BIG, jnp.int32).at[slot].set(dists, mode="drop")
    kept = jnp.minimum(total, capacity)
    out_valid = jnp.arange(capacity + 1, dtype=jnp.int32) < kept
    overflow = jnp.maximum(total - capacity, 0)
    return out_ids[:capacity], out_dists[:capacity], out_valid[:capacity], overflow


def _search_trace(index: SketchIndex, q: jnp.ndarray, *, tau: int,
                  caps: Tuple[int, ...]) -> SearchResult:
    """Traced search body.  ``q``: (L,) uint8/int32 query sketch."""
    q = q.astype(jnp.int32)
    ids = jnp.zeros((1,), jnp.int32)
    dists = jnp.zeros((1,), jnp.int32)
    valid = jnp.ones((1,), bool)
    overflow = jnp.int32(0)
    traversed = jnp.int32(1)

    depth = len(index.levels)
    for lev in range(1, depth + 1):
        enc = index.levels[lev - 1]
        c_ids, c_labels, c_exists = enc.children(ids)            # (F, A)
        c_dists = dists[:, None] + (c_labels != q[lev - 1]).astype(jnp.int32)
        c_valid = valid[:, None] & c_exists & (c_dists <= tau)
        ids, dists, valid, ov = _compact(
            c_ids.reshape(-1), c_dists.reshape(-1), c_valid.reshape(-1),
            caps[lev])
        overflow = overflow + ov
        traversed = traversed + valid.sum(dtype=jnp.int32)

    if index.tail is not None:
        tail = index.tail
        # scatter frontier distances onto ℓ_s roots (+∞ = pruned subtrie)
        base_root = jnp.full((tail.t_root,), BIG, jnp.int32)
        safe_ids = jnp.where(valid, ids, 0)
        base_root = base_root.at[safe_ids].min(
            jnp.where(valid, dists, BIG), mode="drop")
        base_leaf = base_root[tail.leaf_root]                     # (t_L,)
        if tail.suffix_len > 0:
            q_sfx = pack_vertical_jax(q[index.ls:][None], index.b)[0]  # (b, W)
            survive = ops.sparse_verify(tail.paths_vert, q_sfx, base_leaf,
                                        tau=tau) > 0
        else:
            survive = base_leaf <= tau
    else:
        # no collapsed tail (LOUDS/FST baselines): frontier is at level L
        t_L = index.t[index.L]
        survive = jnp.zeros((t_L,), bool)
        safe_ids = jnp.where(valid, ids, 0)
        survive = survive.at[safe_ids].max(valid, mode="drop")

    mask = survive[index.id_leaf]
    return SearchResult(mask=mask, overflow=overflow, traversed=traversed)


def make_searcher(index: SketchIndex, tau: int, cap_max: int = 1 << 17):
    """Compile a single-query searcher for this (index, τ).  Returns
    ``fn(q) -> SearchResult`` (jitted, index closed over as constant)."""
    caps = frontier_capacities(index.t, index.b, tau, cap_max)

    @jax.jit
    def run(q):
        return _search_trace(index, q, tau=tau, caps=caps)

    return run


def make_batch_searcher(index: SketchIndex, tau: int, cap_max: int = 1 << 17):
    """vmapped searcher: (m, L) queries -> SearchResult with leading axis."""
    caps = frontier_capacities(index.t, index.b, tau, cap_max)

    @jax.jit
    def run(qs):
        return jax.vmap(lambda q: _search_trace(index, q, tau=tau, caps=caps))(qs)

    return run


def search(index: SketchIndex, q: np.ndarray, tau: int,
           cap_max: int = 1 << 15, max_cap: int = 1 << 22) -> SearchResult:
    """Host convenience wrapper with the overflow ladder: retries with a
    doubled capacity until the traversal is exact."""
    q = jnp.asarray(q)
    while True:
        res = make_searcher(index, tau, cap_max)(q)
        if int(res.overflow) == 0 or cap_max >= max_cap:
            return res
        cap_max *= 4
