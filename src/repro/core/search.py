"""Level-synchronous similarity search over a SketchIndex (paper Alg. 1,
re-derived for TPU — see DESIGN.md §2).

The paper's recursive DFS visits one node at a time and prunes a subtree
when the accumulated Hamming distance exceeds τ.  Here the *whole frontier
at level ℓ* is a fixed-capacity array of (node id, distance) pairs; one
step expands every node's ≤ 2^b children with one vectorized ``children``
call, masks out children with dist > τ (the paper's pruning), and
compacts survivors with a cumsum-scatter.  The sparse tail is *not*
traversed: pruned ℓ_s-subtries get a +∞ base distance and the Pallas
verify kernel streams every collapsed suffix path in one masked scan —
pruning becomes masking, pointer work becomes bandwidth.

Multi-query is the first-class fast path (DESIGN.md §3): the batched
searcher is NOT a vmap of the single-query trace but a natively batched
``_search_trace_batch`` over a (m, cap) 2D frontier — one shared
``children()`` gather per level for the whole batch, per-query
cumsum-scatter compaction, a batched scatter-min onto (m, t_root)
base-distance planes, and the query-tiled ``sparse_verify_batch`` Pallas
kernel, which streams the collapsed-path array from HBM ⌈m/BLOCK_M⌉
times instead of m.

Exact distances are first-class: the traversal accumulates per-node
Hamming distances anyway, and the verify kernel computes the exact total
before thresholding, so ``SearchResult.dist`` carries the exact distance
of every id inside the τ-ball (BIG elsewhere) at zero extra passes.
``topk`` builds k-nearest-neighbor search on top: a τ-escalation ladder
seeded from the cost model's expected-candidate estimate, followed by a
``jax.lax.top_k`` selection over the distance vector.

Static shapes: frontier capacities come from the cost model
(min(t_ℓ, sigs(b,ℓ,τ), cap_max)).  Exceeding ``cap_max`` is detected and
reported; the host wrapper retries on a doubled ladder.  Compiled
searchers live in a process-level cache keyed on (index, τ, caps) so the
ladder and repeated serving calls never re-jit the common case.
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .bst import BIG, SketchIndex
from .cost_model import frontier_capacities, tau_for_k
from .hamming import pack_vertical_jax
from ..kernels import ops
from ..kernels.hamming_kernel import DEFAULT_BLOCK_M

CAP_MAX_DEFAULT = 1 << 17
LADDER_CAP_MAX = 1 << 22


def bucket_m(m: int) -> int:
    """Power-of-two query-batch shape bucket: the smallest 2^j >= m.
    Batched searchers pad the query axis up to this bucket (and slice the
    results back), so a stream of arbitrary client batch sizes touches
    only O(log m_max) compiled traces instead of one per distinct m."""
    if m < 1:
        raise ValueError("batch must contain at least one query")
    return 1 << (m - 1).bit_length()


def _pad_rows(qs: jnp.ndarray, bucket: int) -> jnp.ndarray:
    """Pad the leading (query) axis up to ``bucket`` rows by repeating the
    last row — a real query, so pad rows can never overflow a frontier
    harder than the rows already present (zeros could)."""
    m = qs.shape[0]
    pad = jnp.broadcast_to(qs[-1:], (bucket - m,) + qs.shape[1:])
    return jnp.concatenate([qs, pad], axis=0)


class SearchResult(NamedTuple):
    mask: jnp.ndarray        # (n,) bool — ids within τ of the query
    dist: jnp.ndarray        # (n,) int32 — exact distance where mask, BIG off
    overflow: jnp.ndarray    # int32 — dropped frontier entries (0 = exact)
    traversed: jnp.ndarray   # int32 — Σ frontier sizes (paper's t_tra)


class TopKResult(NamedTuple):
    ids: jnp.ndarray         # (k,) int32 — ascending (distance, id); -1 pad
    dists: jnp.ndarray       # (k,) int32 — exact distances; BIG on pad
    tau: int                 # final rung of the τ-escalation ladder
    overflow: int            # dropped frontier entries (0 = provably exact)
    scores: jnp.ndarray | None = None  # (k,) f32 exact re-rank scores —
    #   descending (score, -id); -1.0 pad.  None on sketch-only requests;
    #   when set, ids/dists re-order to score order (DESIGN.md §10).


def _compact(ids: jnp.ndarray, dists: jnp.ndarray, valid: jnp.ndarray,
             capacity: int):
    """Stable masked compaction into a fixed-size frontier."""
    total = valid.sum(dtype=jnp.int32)      # 0 for an empty frontier
    pos = jnp.cumsum(valid) - 1
    slot = jnp.where(valid & (pos < capacity), pos, capacity)
    out_ids = jnp.zeros((capacity + 1,), jnp.int32).at[slot].set(ids, mode="drop")
    out_dists = jnp.full((capacity + 1,), BIG, jnp.int32).at[slot].set(dists, mode="drop")
    kept = jnp.minimum(total, capacity)
    out_valid = jnp.arange(capacity + 1, dtype=jnp.int32) < kept
    overflow = jnp.maximum(total - capacity, 0)
    return out_ids[:capacity], out_dists[:capacity], out_valid[:capacity], overflow


def _compact_batch(ids: jnp.ndarray, dists: jnp.ndarray, valid: jnp.ndarray,
                   capacity: int):
    """Row-wise stable masked compaction: (m, K) candidates -> (m,
    capacity) frontier.  Each query compacts independently (per-row
    cumsum + one 2D scatter); overflow is counted per query."""
    m = ids.shape[0]
    total = valid.sum(axis=1, dtype=jnp.int32)            # (m,)
    pos = jnp.cumsum(valid, axis=1) - 1                   # (m, K)
    slot = jnp.where(valid & (pos < capacity), pos, capacity)
    row = jnp.arange(m, dtype=jnp.int32)[:, None]
    out_ids = jnp.zeros((m, capacity + 1), jnp.int32).at[row, slot].set(
        ids, mode="drop")
    out_dists = jnp.full((m, capacity + 1), BIG, jnp.int32).at[row, slot].set(
        dists, mode="drop")
    kept = jnp.minimum(total, capacity)
    out_valid = jnp.arange(capacity + 1, dtype=jnp.int32)[None, :] < kept[:, None]
    overflow = jnp.maximum(total - capacity, 0)
    return (out_ids[:, :capacity], out_dists[:, :capacity],
            out_valid[:, :capacity], overflow)


def _leaf_live(index: SketchIndex, id_live: jnp.ndarray) -> jnp.ndarray:
    """(n,) bool id liveness -> (t_L,) bool leaf liveness: a leaf is live
    iff at least one live id maps to it (duplicates share a leaf).  Used
    by the dynamic segmented index (DESIGN.md §4) to feed the tombstone
    mask into the verify stage."""
    t_L = index.t[index.L]
    return jnp.zeros((t_L,), bool).at[index.id_leaf].max(id_live, mode="drop")


def _search_trace(index: SketchIndex, q: jnp.ndarray, *, tau: int,
                  caps: Tuple[int, ...],
                  id_live: jnp.ndarray | None = None) -> SearchResult:
    """Traced search body.  ``q``: (L,) uint8/int32 query sketch;
    ``id_live``: optional (n,) bool tombstone mask — dead ids never
    survive and fully-dead leaves are pruned at the verify stage."""
    q = q.astype(jnp.int32)
    live = _leaf_live(index, id_live) if id_live is not None else None
    ids = jnp.zeros((1,), jnp.int32)
    dists = jnp.zeros((1,), jnp.int32)
    valid = jnp.ones((1,), bool)
    overflow = jnp.int32(0)
    traversed = jnp.int32(1)

    depth = len(index.levels)
    for lev in range(1, depth + 1):
        enc = index.levels[lev - 1]
        c_ids, c_labels, c_exists = enc.children(ids)            # (F, A)
        c_dists = dists[:, None] + (c_labels != q[lev - 1]).astype(jnp.int32)
        c_valid = valid[:, None] & c_exists & (c_dists <= tau)
        ids, dists, valid, ov = _compact(
            c_ids.reshape(-1), c_dists.reshape(-1), c_valid.reshape(-1),
            caps[lev])
        overflow = overflow + ov
        traversed = traversed + valid.sum(dtype=jnp.int32)

    if index.tail is not None:
        tail = index.tail
        # scatter frontier distances onto ℓ_s roots (+∞ = pruned subtrie)
        base_root = jnp.full((tail.t_root,), BIG, jnp.int32)
        safe_ids = jnp.where(valid, ids, 0)
        base_root = base_root.at[safe_ids].min(
            jnp.where(valid, dists, BIG), mode="drop")
        base_leaf = base_root[tail.leaf_root]                     # (t_L,)
        if tail.suffix_len > 0:
            q_sfx = pack_vertical_jax(q[index.ls:][None], index.b)[0]  # (b, W)
            hit, leaf_dist = ops.sparse_verify(tail.paths_vert, q_sfx,
                                               base_leaf, tau=tau, live=live)
            survive = hit > 0
        else:
            if live is not None:
                base_leaf = jnp.where(live, base_leaf, BIG)
            survive = base_leaf <= tau
            leaf_dist = base_leaf
    else:
        # no collapsed tail (LOUDS/FST baselines): frontier is at level L;
        # scatter-min the frontier distances straight onto the leaves
        t_L = index.t[index.L]
        safe_ids = jnp.where(valid, ids, 0)
        leaf_dist = jnp.full((t_L,), BIG, jnp.int32).at[safe_ids].min(
            jnp.where(valid, dists, BIG), mode="drop")
        if live is not None:
            leaf_dist = jnp.where(live, leaf_dist, BIG)
        survive = leaf_dist <= tau

    mask = survive[index.id_leaf]
    if id_live is not None:
        mask = mask & id_live
    dist = jnp.where(mask, leaf_dist[index.id_leaf], BIG)
    return SearchResult(mask=mask, dist=dist, overflow=overflow,
                        traversed=traversed)


def _traverse_frontier_batch(index: SketchIndex, qs: jnp.ndarray, *,
                             tau: int, caps: Tuple[int, ...],
                             level_widths: Optional[list] = None):
    """The shared 2D-frontier descent (levels 1..depth) of the natively
    batched searcher: ``qs`` is (m, L) int32 and the level-ℓ frontier a
    (m, cap_ℓ) array compacted per query — one ``children()`` gather per
    level for the whole batch.  Returns the final frontier
    ``(ids, dists, valid)`` (each (m, cap_depth)) plus per-query
    ``overflow``/``traversed`` (m,) int32.  Reused by the fused
    segment-arena program (DESIGN.md §6), which stops here and scatters
    every segment's frontier onto one concatenated root plane.

    ``level_widths``: optional list the per-level live frontier widths
    ((m,) int32 each) are appended to during tracing — the explain
    path's frontier-width sampler (DESIGN.md §11) stacks them into its
    per-trie-level report; default callers trace the identical graph
    (the sum already feeds ``traversed``)."""
    m = qs.shape[0]
    ids = jnp.zeros((m, 1), jnp.int32)
    dists = jnp.zeros((m, 1), jnp.int32)
    valid = jnp.ones((m, 1), bool)
    overflow = jnp.zeros((m,), jnp.int32)
    traversed = jnp.ones((m,), jnp.int32)

    depth = len(index.levels)
    for lev in range(1, depth + 1):
        enc = index.levels[lev - 1]
        cap = ids.shape[1]
        c_ids, c_labels, c_exists = enc.children(ids.reshape(-1))  # (m·cap, A)
        A = c_ids.shape[-1]
        c_ids = c_ids.reshape(m, cap, A)
        c_labels = c_labels.reshape(m, cap, A)
        c_exists = c_exists.reshape(m, cap, A)
        q_char = qs[:, lev - 1][:, None, None]
        c_dists = dists[:, :, None] + (c_labels != q_char).astype(jnp.int32)
        c_valid = valid[:, :, None] & c_exists & (c_dists <= tau)
        ids, dists, valid, ov = _compact_batch(
            c_ids.reshape(m, -1), c_dists.reshape(m, -1),
            c_valid.reshape(m, -1), caps[lev])
        overflow = overflow + ov
        width = valid.sum(axis=1, dtype=jnp.int32)
        if level_widths is not None:
            level_widths.append(width)
        traversed = traversed + width
    return ids, dists, valid, overflow, traversed


def scatter_root_plane(ids: jnp.ndarray, vals: jnp.ndarray,
                       valid: jnp.ndarray, m: int,
                       t_root: int) -> jnp.ndarray:
    """Scatter one segment's final frontier onto its (m, t_root) slice of
    the concatenated ℓ_s-root base plane (the fused programs' traversal →
    verify hand-off, DESIGN.md §6/§7): per-root minimum of ``vals`` over
    the valid frontier entries, BIG where the traversal pruned the root.
    The full-length arena passes ``vals = 0`` (reached/pruned only — its
    columns recompute the prefix inside the XOR); the suffix store passes
    ``vals = dists``, the traversal's exact prefix distances, which the
    suffix verify adds to complete the full-length Hamming distance bit
    for bit.  The scratch slot ``t_root`` absorbs ``mode="drop"`` pads
    and is sliced off."""
    row = jnp.arange(m, dtype=jnp.int32)[:, None]
    safe = jnp.where(valid, ids, 0)
    reach = jnp.full((m, t_root + 1), BIG, jnp.int32).at[
        row, safe].min(jnp.where(valid, vals, BIG), mode="drop")
    return reach[:, :t_root]


def select_topk_columns(dist: jnp.ndarray, col_ids: jnp.ndarray, k: int):
    """Traced k-smallest selection over labeled column planes: the
    on-device counterpart of ``distributed_search.topk_from_dists``.

    dist: (m, R) int32 — one distance per (query, column), BIG on
    non-results; col_ids: (R,) int32 global labels per column; returns
    ((m, k) int32 ids, (m, k) int32 dists), each row ascending by
    (distance, label) — an exact lexicographic two-key sort
    (``lax.sort`` with ``num_keys=2``), so tie order matches the host
    selection bit for bit; BIG lanes come back as (-1, BIG) pads.
    Requires k <= R (the caller clamps k to the column count)."""
    m, R = dist.shape
    labels = jnp.broadcast_to(col_ids.astype(jnp.int32)[None, :], (m, R))
    d_sorted, l_sorted = jax.lax.sort((dist, labels), dimension=-1,
                                      num_keys=2)
    d_k, l_k = d_sorted[:, :k], l_sorted[:, :k]
    return jnp.where(d_k < BIG, l_k, -1), jnp.minimum(d_k, BIG)


# crossover between the unrolled reduction selection and the full sort:
# each reduction pick costs ~6 plane traversals, the 4-operand sort
# costs ~90 picks' worth on CPU — stay iterative through every
# serving-sized k
_ITER_SELECT_MAX_K = 32


def select_topk_scores(scores: jnp.ndarray, dist: jnp.ndarray,
                       col_ids: jnp.ndarray, k: int):
    """Traced k-*largest* selection over re-ranked column planes.

    scores: (m, R) float32 exact re-rank scores, -1.0 sentinel on
    non-survivor lanes; dist: (m, R) int32 Hamming distances (carried
    along, BIG off-survivor); col_ids: (R,) int32 global labels; returns
    ((m, k) ids, (m, k) dists, (m, k) f32 scores), each row descending
    by (score, -label) — ties at equal score break toward the smaller
    id, matching the host brute-force ordering bit for bit.

    The sort key is the *bit pattern* of the score: IEEE-754 floats in
    [0, 1] are monotone under an int32 bitcast and the -1.0 sentinel's
    sign bit makes its bitcast negative, so ordering on the bitcast
    needs no float comparator and keeps exact tie semantics.

    Two lowerings, identical bits: small k runs ``k`` unrolled
    max/argmin reduction passes (memory-bound — roughly 5x cheaper than
    a full-plane sort on CPU), large k falls back to one lexicographic
    ``lax.sort``.  Requires k <= R."""
    m, R = scores.shape
    key = jax.lax.bitcast_convert_type(scores.astype(jnp.float32),
                                       jnp.int32)
    labels = jnp.broadcast_to(col_ids.astype(jnp.int32)[None, :], (m, R))
    sc = scores.astype(jnp.float32)
    if k <= _ITER_SELECT_MAX_K:
        picks = []
        for _ in range(k):
            mx = key.max(-1, keepdims=True)
            tie = key == mx
            lab = jnp.where(tie, labels,
                            jnp.int32(2 ** 31 - 1)).min(-1, keepdims=True)
            pick = tie & (labels == lab)
            picks.append((lab[:, 0],
                          jnp.where(pick, dist, -1).max(-1),
                          jnp.where(pick, sc, -jnp.inf).max(-1)))
            key = jnp.where(pick, jnp.int32(-2 ** 31), key)
        l_k = jnp.stack([p[0] for p in picks], -1)
        d_k = jnp.stack([p[1] for p in picks], -1)
        s_k = jnp.stack([p[2] for p in picks], -1)
    else:
        _, l_sorted, d_sorted, s_sorted = jax.lax.sort(
            (-key, labels, dist, sc), dimension=-1, num_keys=2)
        s_k, l_k, d_k = s_sorted[:, :k], l_sorted[:, :k], d_sorted[:, :k]
    hit = s_k >= 0
    return (jnp.where(hit, l_k, -1), jnp.where(hit, d_k, BIG),
            jnp.where(hit, s_k, jnp.float32(-1.0)))


def _search_trace_batch(index: SketchIndex, qs: jnp.ndarray, *, tau: int,
                        caps: Tuple[int, ...],
                        block_m: int = DEFAULT_BLOCK_M,
                        id_live: jnp.ndarray | None = None) -> SearchResult:
    """Natively batched search body: ``qs`` is (m, L) and the frontier is
    a (m, cap) 2D array compacted per query.  Each level issues ONE
    shared ``children()`` gather over the flattened (m·cap,) frontier
    instead of m separate traces (``_traverse_frontier_batch``), the
    tail scatter-min lands on a (m, t_root) base-distance plane, and the
    sparse layer runs through the query-tiled batch verify kernel — the
    collapsed-path array is streamed ⌈m/block_m⌉ times instead of m.
    Per-query masks, exact distances, and overflow counts are
    bit-identical to ``_search_trace`` (compaction is row-independent).
    ``id_live``: optional (n,) bool tombstone mask shared by every query
    (DESIGN.md §4)."""
    qs = qs.astype(jnp.int32)
    live = _leaf_live(index, id_live) if id_live is not None else None
    m = qs.shape[0]
    ids, dists, valid, overflow, traversed = _traverse_frontier_batch(
        index, qs, tau=tau, caps=caps)

    row = jnp.arange(m, dtype=jnp.int32)[:, None]
    safe_ids = jnp.where(valid, ids, 0)
    if index.tail is not None:
        tail = index.tail
        # batched scatter of frontier distances onto per-query ℓ_s root
        # planes (+∞ = pruned subtrie)
        base_root = jnp.full((m, tail.t_root), BIG, jnp.int32).at[
            row, safe_ids].min(jnp.where(valid, dists, BIG), mode="drop")
        base_leaf = base_root[:, tail.leaf_root]                  # (m, t_L)
        if tail.suffix_len > 0:
            q_sfx = pack_vertical_jax(qs[:, index.ls:], index.b)  # (m, b, W)
            q_sfx = jnp.transpose(q_sfx, (1, 2, 0))               # (b, W, m)
            hit, leaf_dist = ops.sparse_verify_batch(
                tail.paths_vert, q_sfx, base_leaf, tau=tau, live=live,
                block_m=block_m)
            survive = hit > 0
        else:
            if live is not None:
                base_leaf = jnp.where(live[None, :], base_leaf, BIG)
            survive = base_leaf <= tau
            leaf_dist = base_leaf
    else:
        # no collapsed tail (LOUDS/FST baselines): frontier is at level L
        t_L = index.t[index.L]
        leaf_dist = jnp.full((m, t_L), BIG, jnp.int32).at[row, safe_ids].min(
            jnp.where(valid, dists, BIG), mode="drop")
        if live is not None:
            leaf_dist = jnp.where(live[None, :], leaf_dist, BIG)
        survive = leaf_dist <= tau

    mask = survive[:, index.id_leaf]
    if id_live is not None:
        mask = mask & id_live[None, :]
    dist = jnp.where(mask, leaf_dist[:, index.id_leaf], BIG)
    return SearchResult(mask=mask, dist=dist, overflow=overflow,
                        traversed=traversed)


# ---------------------------------------------------------------------------
# compiled-searcher cache
# ---------------------------------------------------------------------------

# key: (id(index), tau, caps, block_m-or-None) -> (index, jitted fn).  The
# last slot is None for the single-query searcher and the verify kernel's
# query-tile size for the natively batched one.  The index is
# held strongly in the value so its id can never be recycled while the
# entry lives; serving processes hold few indexes, so this pins O(1) of
# extra memory per cached rung.  FIFO-bounded so sweeps over many
# (index, τ, cap) combinations (benchmarks) cannot grow without limit.
_SEARCHER_CACHE: Dict[tuple, tuple] = {}
_SEARCHER_CACHE_CAP = 128
_CACHE_STATS = {"hits": 0, "misses": 0, "traces": 0}


def _note_trace() -> None:
    """Call from inside a jitted body: runs only while jit traces, so it
    counts real traces (including per-shape re-specialization of one
    cached fn).  Shared by the searchers here and the fused arena
    programs (``core.segments``), so ``searcher_cache_info()['traces']``
    stays the one number that must freeze once every shape bucket is
    warm."""
    _CACHE_STATS["traces"] += 1


def _pin_cache_get(cache: dict, cap: int, key: tuple, obj, build):
    """id-keyed bounded cache shared by the single- and multi-index
    searchers: the value pins ``obj`` so its id can never be recycled
    while the entry lives; FIFO-evicts beyond ``cap``.  Returns
    (cached_value, hit)."""
    entry = cache.get(key)
    if entry is not None and entry[0] is obj:
        return entry[1], True
    value = build()
    while len(cache) >= cap:
        cache.pop(next(iter(cache)))  # FIFO evict
    cache[key] = (obj, value)
    return value, False


def searcher_cache_info() -> Dict[str, int]:
    """Process-level cache counters.  ``misses`` counts Python-cache
    misses (one per new (index, τ, caps, block_m, with_live) key);
    ``traces`` counts actual jit traces, including jit's own per-shape
    re-specialization — with the power-of-two m-bucketing this stops
    growing after one warmup per bucket, even under a varying-m query
    stream."""
    return {"hits": _CACHE_STATS["hits"], "misses": _CACHE_STATS["misses"],
            "traces": _CACHE_STATS["traces"], "size": len(_SEARCHER_CACHE)}


def clear_searcher_cache() -> None:
    _SEARCHER_CACHE.clear()
    _CACHE_STATS["hits"] = 0
    _CACHE_STATS["misses"] = 0
    _CACHE_STATS["traces"] = 0


def get_searcher(index: SketchIndex, tau: int,
                 cap_max: int = CAP_MAX_DEFAULT, *, batch: bool = False,
                 block_m: int = DEFAULT_BLOCK_M, with_live: bool = False):
    """Cached compiled searcher for this (index, τ, caps).  ``batch=False``
    returns ``fn(q: (L,)) -> SearchResult``; ``batch=True`` the natively
    batched ``fn(qs: (m, L)) -> SearchResult`` with a leading query axis
    (2D-frontier traversal + the query-tiled verify kernel at tile size
    ``block_m``).  ``with_live=True`` compiles the tombstone-aware variant
    ``fn(q_or_qs, id_live: (n,) bool) -> SearchResult`` (dead ids never
    survive; the liveness bitmap is a *traced* argument, so flipping
    tombstones never re-jits — the dynamic segmented index's fast path,
    DESIGN.md §4).

    Batched searchers bucket the query axis: ``qs`` is padded up to
    ``bucket_m(m)`` rows (repeating the last query) before the jitted
    trace and the results are sliced back to m, so any client batch size
    ``m <= bucket`` reuses one compiled trace per power-of-two bucket —
    variable-size serving traffic stops re-jitting after one warmup per
    bucket (DESIGN.md §5)."""
    caps = frontier_capacities(index.t, index.b, tau, cap_max)
    key = (id(index), tau, caps, block_m if batch else None, with_live)

    traced = _note_trace

    def build():
        if batch and with_live:
            @jax.jit
            def run(qs, id_live):
                traced()
                return _search_trace_batch(index, qs, tau=tau, caps=caps,
                                           block_m=block_m, id_live=id_live)
        elif batch:
            @jax.jit
            def run(qs):
                traced()
                return _search_trace_batch(index, qs, tau=tau, caps=caps,
                                           block_m=block_m)
        elif with_live:
            @jax.jit
            def run(q, id_live):
                traced()
                return _search_trace(index, q, tau=tau, caps=caps,
                                     id_live=id_live)
        else:
            @jax.jit
            def run(q):
                traced()
                return _search_trace(index, q, tau=tau, caps=caps)
        return run

    fn, hit = _pin_cache_get(_SEARCHER_CACHE, _SEARCHER_CACHE_CAP, key,
                             index, build)
    _CACHE_STATS["hits" if hit else "misses"] += 1
    if not batch:
        return fn

    def bucketed(qs, *rest):
        qs = jnp.asarray(qs)
        m = qs.shape[0]
        mb = bucket_m(m)
        if mb == m:
            return fn(qs, *rest)
        res = fn(_pad_rows(qs, mb), *rest)
        return SearchResult(*(a[:m] for a in res))

    return bucketed


def make_searcher(index: SketchIndex, tau: int,
                  cap_max: int = CAP_MAX_DEFAULT):
    """Compile (or fetch from the process cache) a single-query searcher
    for this (index, τ).  Returns ``fn(q) -> SearchResult``."""
    return get_searcher(index, tau, cap_max, batch=False)


def make_batch_searcher(index: SketchIndex, tau: int,
                        cap_max: int = CAP_MAX_DEFAULT,
                        block_m: int = DEFAULT_BLOCK_M):
    """Natively batched searcher: (m, L) queries -> SearchResult with a
    leading query axis.  Unlike a vmap of the single-query trace, the
    whole batch shares one traversal (one children() gather per level)
    and one query-tiled verify scan of the collapsed-path array.  The
    query axis is padded to the power-of-two ``bucket_m(m)`` internally
    (results sliced back), so varying client batch sizes reuse one
    compiled trace per bucket."""
    return get_searcher(index, tau, cap_max, batch=True, block_m=block_m)


# ---------------------------------------------------------------------------
# host wrappers: overflow ladder + top-k engine
# ---------------------------------------------------------------------------

def search(index: SketchIndex, q: np.ndarray, tau: int,
           cap_max: int = CAP_MAX_DEFAULT,
           max_cap: int = LADDER_CAP_MAX) -> SearchResult:
    """Host convenience wrapper with the overflow ladder: retries with a
    doubled capacity until the traversal is exact (or ``max_cap`` is hit).
    ``q``: (L,) uint8 -> ``SearchResult`` over the index's n ids.  Every
    rung comes from the process-level searcher cache, so a repeated
    (index, τ) call never re-jits."""
    q = jnp.asarray(q)
    while True:
        res = get_searcher(index, tau, cap_max)(q)
        if int(res.overflow) == 0 or cap_max >= max_cap:
            return res
        cap_max *= 2


def _tau_for_k(index: SketchIndex, k: int) -> int:
    """Ladder seed: the cost model's shared uniform-DB estimator
    (``cost_model.tau_for_k``) over this index's (b, L, n)."""
    return tau_for_k(index.b, index.L, index.n, k)


@functools.lru_cache(maxsize=_SEARCHER_CACHE_CAP)
def _topk_select(k: int):
    """Jitted batched (dist (m, n) -> (dists, ids) (m, k)) k-smallest
    selection.  ``lax.top_k`` breaks ties toward the lower index, so equal
    distances order by id.  Keyed on ``k`` alone (n only shapes the traced
    input, and jit re-specializes per shape anyway) and bounded like
    ``_SEARCHER_CACHE`` so k-sweeps cannot grow it without limit."""
    def sel(dist):
        neg, idx = jax.lax.top_k(-dist, k)
        return -neg, idx.astype(jnp.int32)

    return jax.jit(jax.vmap(sel))


def _pad_topk(dists: np.ndarray, ids: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
    kk = ids.shape[-1]
    if kk == k:
        return dists, ids
    pad = [(0, 0)] * (ids.ndim - 1) + [(0, k - kk)]
    return (np.pad(dists, pad, constant_values=int(BIG)),
            np.pad(ids, pad, constant_values=-1))


def topk(index: SketchIndex, q: np.ndarray, k: int,
         tau0: int | None = None, cap_max: int = CAP_MAX_DEFAULT,
         max_cap: int = LADDER_CAP_MAX,
         block_m: int = DEFAULT_BLOCK_M) -> TopKResult:
    """Exact k-nearest-neighbor search: run the compiled range searcher on
    a τ-escalation ladder until ≥ k ids survive, then select the k smallest
    exact distances (ties broken by id).  ``q``: (L,) uint8 ->
    ``TopKResult`` with (k,) int32 ids/dists.

    Correctness: once ``mask.sum() >= k`` at threshold τ with zero frontier
    overflow, every excluded id has distance > τ ≥ the k-th smallest — so
    the selection over ``dist`` (exact inside the ball, BIG outside) is
    globally exact.  A nonzero ``TopKResult.overflow`` (only possible once
    the capacity ladder saturates ``max_cap``) marks a potentially partial
    result.  If ``k > n`` the result is padded with (-1, BIG).
    """
    res = topk_batch(index, jnp.asarray(q)[None], k, tau0=tau0,
                     cap_max=cap_max, max_cap=max_cap, block_m=block_m)
    return TopKResult(ids=res.ids[0], dists=res.dists[0], tau=res.tau,
                      overflow=res.overflow)


def topk_batch(index: SketchIndex, qs: np.ndarray, k: int,
               tau0: int | None = None, cap_max: int = CAP_MAX_DEFAULT,
               max_cap: int = LADDER_CAP_MAX,
               block_m: int = DEFAULT_BLOCK_M) -> TopKResult:
    """Batched ``topk``: (m, L) queries -> (m, k) ids/dists.  One ladder
    for the whole batch — τ escalates until every query has ≥ k survivors,
    so all queries share the same compiled searcher (the natively batched
    2D-frontier trace + query-tiled verify kernel)."""
    qs = jnp.asarray(qs)
    kk = min(k, index.n)
    tau = tau0 if tau0 is not None else _tau_for_k(index, kk)
    tau = min(max(tau, 0), index.L)
    # the escalated capacity carries across tau rungs: a larger tau-ball
    # can only need at least as much frontier as the one that overflowed
    cap = cap_max
    while True:
        while True:
            res = get_searcher(index, tau, cap, batch=True,
                               block_m=block_m)(qs)
            overflow = int(res.overflow.sum())
            if overflow == 0 or cap >= max_cap:
                break
            cap *= 2
        if int(res.mask.sum(axis=1).min()) >= kk or tau >= index.L:
            break
        tau = min(index.L, max(tau + 1, 2 * tau))
    # bucket the selection's query axis too: BIG pad rows select (-1, BIG)
    # lanes that the final slice drops, so selection never re-traces per m
    m, mb = res.dist.shape[0], bucket_m(res.dist.shape[0])
    dist_in = res.dist if mb == m else jnp.concatenate(
        [res.dist, jnp.full((mb - m, res.dist.shape[1]), BIG, jnp.int32)])
    dists, ids = _topk_select(kk)(dist_in)
    dists, ids = _pad_topk(np.asarray(dists)[:m], np.asarray(ids)[:m], k)
    # BIG lanes are non-results (possible when the capacity ladder
    # saturated with overflow): mask their arbitrary ids to the pad value
    ids = np.where(dists >= int(BIG), -1, ids)
    return TopKResult(ids=jnp.asarray(ids), dists=jnp.asarray(dists),
                      tau=tau, overflow=overflow)
