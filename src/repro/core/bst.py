"""b-Bit Sketch Trie (bST) and baseline succinct tries, as JAX pytrees.

Every index is a stack of per-level *encodings* with one uniform traced
operation

    children(parent_ids: int32[F]) -> (ids, labels, exists): int32[F, 2^b]

— i.e. the paper's ``children(u)`` but over a whole frontier at once
(see DESIGN.md §2: DFS -> level-synchronous traversal).  Encodings:

  * ``DenseLevel``  — complete 2^b-ary level: children are arithmetic,
                      storage is *zero bits* (paper §V-A).
  * ``TableLevel``  — bitmap H_ℓ of length 2^b·t_{ℓ-1}; existence is
                      ``H.get``, the child id is ``H.rank`` (paper §V-B).
  * ``ListLevel``   — labels C_ℓ + first-sibling bitvector B_ℓ; the child
                      range is two ``select`` calls (paper §V-B).
  * ``LoudsLevel``  — labels C_ℓ + unary degree sequence U_ℓ with
                      ``select0`` child ranges — the LOUDS-trie baseline.
  * ``SparseTail``  — collapsed root-to-leaf suffix paths P (stored
                      directly in the *vertical bit-plane format* the
                      Pallas kernel streams) + leftmost-leaf bitvector D
                      (paper §V-C).

``build_bst`` assembles dense/table-or-list/sparse per the paper's density
rules; ``build_louds`` / ``build_fst_style`` assemble the comparison
structures of Table III from the same TrieLevels scan.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .bitvector import BitVector
from .hamming import pack_vertical
from .trie_builder import TrieLevels, build_trie_levels, pick_layers, table_or_list

BIG = jnp.int32(1 << 20)


# ---------------------------------------------------------------------------
# level encodings
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DenseLevel:
    b: int
    t_prev: int

    def tree_flatten(self):
        return (), (self.b, self.t_prev)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*aux)

    def children(self, u: jnp.ndarray):
        A = 1 << self.b
        c = jnp.arange(A, dtype=jnp.int32)[None, :]
        ids = u[:, None] * A + c
        labels = jnp.broadcast_to(c, ids.shape)
        exists = jnp.ones(ids.shape, dtype=bool)
        return ids, labels, exists

    def model_bits(self) -> int:
        return 64  # just the level number (paper: O(log ℓ_m))

    def array_bytes(self) -> int:
        return 8


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class TableLevel:
    H: BitVector
    b: int
    t_prev: int

    def tree_flatten(self):
        return (self.H,), (self.b, self.t_prev)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    def children(self, u: jnp.ndarray):
        A = 1 << self.b
        c = jnp.arange(A, dtype=jnp.int32)[None, :]
        u_safe = jnp.clip(u, 0, self.t_prev - 1)
        pos = u_safe[:, None] * A + c                    # (F, A)
        exists = self.H.get(pos) == 1
        ids = self.H.rank(pos)                           # ones before pos = child index
        labels = jnp.broadcast_to(c, ids.shape)
        return ids, labels, exists

    def model_bits(self) -> int:
        n = (1 << self.b) * self.t_prev
        return n + int(self.H.cum.shape[0]) * 32  # payload + rank dir (o(n) modeled as actual)

    def array_bytes(self) -> int:
        return int(self.H.words.nbytes + self.H.cum.nbytes)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ListLevel:
    C: jnp.ndarray        # (t,) uint8 edge labels
    B: BitVector          # (t,) first-sibling flags
    b: int
    t_prev: int

    def tree_flatten(self):
        return (self.C, self.B), (self.b, self.t_prev)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    def children(self, u: jnp.ndarray):
        A = 1 << self.b
        t = self.C.shape[0]
        u_safe = jnp.clip(u, 0, self.t_prev - 1)
        start = self.B.select(u_safe + 1)                # (F,)
        end = self.B.select(u_safe + 2)                  # t for the last parent
        j = jnp.arange(A, dtype=jnp.int32)[None, :]
        ids = start[:, None] + j
        exists = ids < end[:, None]
        labels = self.C[jnp.clip(ids, 0, t - 1)].astype(jnp.int32)
        return ids, labels, exists

    def model_bits(self) -> int:
        t = int(self.C.shape[0])
        return (self.b + 1) * t + int(self.B.cum.shape[0]) * 32

    def array_bytes(self) -> int:
        return int(self.C.nbytes + self.B.words.nbytes + self.B.cum.nbytes)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class LoudsLevel:
    C: jnp.ndarray        # (t,) uint8 edge labels
    U: BitVector          # (t_prev + t,) unary degrees: 1^deg 0 per parent
    b: int
    t_prev: int

    def tree_flatten(self):
        return (self.C, self.U), (self.b, self.t_prev)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    def children(self, u: jnp.ndarray):
        A = 1 << self.b
        t = self.C.shape[0]
        u_safe = jnp.clip(u, 0, self.t_prev - 1)
        # ones before the u-th zero = cumulative degree of parents < u
        s0 = self.U.select0(jnp.maximum(u_safe, 1))
        start = jnp.where(u_safe == 0, 0, s0 - u_safe + 1)
        end = self.U.select0(u_safe + 1) - u_safe
        j = jnp.arange(A, dtype=jnp.int32)[None, :]
        ids = start[:, None] + j
        exists = ids < end[:, None]
        labels = self.C[jnp.clip(ids, 0, t - 1)].astype(jnp.int32)
        return ids, labels, exists

    def model_bits(self) -> int:
        t = int(self.C.shape[0])
        # labels b bits + 2 topology bits per node (unary seq has t ones, ~t zeros)
        return self.b * t + (self.t_prev + t) + int(self.U.cum.shape[0]) * 32

    def array_bytes(self) -> int:
        return int(self.C.nbytes + self.U.words.nbytes + self.U.cum.nbytes)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SparseTail:
    paths_vert: jnp.ndarray   # (b, W_sfx, t_L) uint32 — kernel-ready layout
    D: BitVector              # (t_L,) leftmost-leaf flags per ℓ_s subtrie
    leaf_root: jnp.ndarray    # (t_L,) int32 — leaf -> its ℓ_s ancestor id
    b: int
    suffix_len: int
    t_root: int               # t[ℓ_s]

    def tree_flatten(self):
        return (self.paths_vert, self.D, self.leaf_root), (self.b, self.suffix_len, self.t_root)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], children[2], *aux)

    def model_bits(self) -> int:
        t_L = int(self.leaf_root.shape[0])
        return self.b * self.suffix_len * t_L + t_L + int(self.D.cum.shape[0]) * 32

    def array_bytes(self) -> int:
        return int(self.paths_vert.nbytes + self.D.words.nbytes
                   + self.D.cum.nbytes + self.leaf_root.nbytes)


# ---------------------------------------------------------------------------
# index container
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SketchIndex:
    """A trie index over one database (shard) of b-bit sketches."""

    levels: Tuple        # encodings for ℓ = 1 .. depth (ℓ_s for bST, L otherwise)
    tail: Optional[SparseTail]
    id_leaf: jnp.ndarray  # (n,) original id -> leaf index
    # static metadata
    L: int
    b: int
    n: int
    t: Tuple[int, ...]   # node counts per level 0..L
    lm: int
    ls: int
    kinds: Tuple[str, ...]

    def tree_flatten(self):
        return (self.levels, self.tail, self.id_leaf), (
            self.L, self.b, self.n, self.t, self.lm, self.ls, self.kinds)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], children[2], *aux)

    # -- space accounting (drives Table III / Table IV benchmarks) -------
    def model_bits(self) -> int:
        bits = sum(lv.model_bits() for lv in self.levels)
        if self.tail is not None:
            bits += self.tail.model_bits()
        return bits

    def array_bytes(self, include_ids: bool = True) -> int:
        by = sum(lv.array_bytes() for lv in self.levels)
        if self.tail is not None:
            by += self.tail.array_bytes()
        if include_ids:
            by += int(self.id_leaf.nbytes)
        return by


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def _build_table_level(trie: TrieLevels, lev: int) -> TableLevel:
    A = 1 << trie.b
    t_prev = trie.t[lev - 1]
    bits = np.zeros(A * t_prev, dtype=np.uint8)
    pos = trie.parents[lev] * A + trie.labels[lev].astype(np.int64)
    bits[pos] = 1
    return TableLevel(H=BitVector.from_bits(bits), b=trie.b, t_prev=t_prev)


def _build_list_level(trie: TrieLevels, lev: int) -> ListLevel:
    par = trie.parents[lev]
    first = np.concatenate([[True], par[1:] != par[:-1]]) if len(par) > 1 else np.ones(len(par), bool)
    return ListLevel(C=jnp.asarray(trie.labels[lev]),
                     B=BitVector.from_bits(first.astype(np.uint8)),
                     b=trie.b, t_prev=trie.t[lev - 1])


def _build_louds_level(trie: TrieLevels, lev: int) -> LoudsLevel:
    par = trie.parents[lev]
    t_prev = trie.t[lev - 1]
    deg = np.bincount(par, minlength=t_prev)
    u_bits = np.zeros(t_prev + len(par), dtype=np.uint8)
    # 1^deg 0 per parent: ones everywhere except at terminator positions
    term = np.cumsum(deg + 1) - 1
    u_bits[:] = 1
    u_bits[term] = 0
    return LoudsLevel(C=jnp.asarray(trie.labels[lev]),
                      U=BitVector.from_bits(u_bits), b=trie.b, t_prev=t_prev)


def _build_sparse_tail(trie: TrieLevels, ls: int) -> SparseTail:
    t_L = trie.t[trie.L]
    sfx = trie.L - ls
    leaf_root = trie.node_of_leaf[ls]
    if sfx > 0:
        suffixes = trie.uniq[:, ls:]
        planes = pack_vertical(suffixes, trie.b)            # (t_L, b, W)
        paths_vert = np.transpose(planes, (1, 2, 0)).copy() # (b, W, t_L)
    else:
        paths_vert = np.zeros((trie.b, 1, t_L), dtype=np.uint32)
    d_bits = np.concatenate([[1], (leaf_root[1:] != leaf_root[:-1]).astype(np.uint8)]) \
        if t_L > 1 else np.ones(1, np.uint8)
    return SparseTail(paths_vert=jnp.asarray(paths_vert),
                      D=BitVector.from_bits(d_bits),
                      leaf_root=jnp.asarray(leaf_root, dtype=jnp.int32),
                      b=trie.b, suffix_len=sfx, t_root=trie.t[ls])


def build_bst(sketches: np.ndarray, b: int, lam: float = 0.5,
              trie: Optional[TrieLevels] = None) -> SketchIndex:
    """The paper's bST: dense prefix + adaptive TABLE/LIST middle + collapsed
    sparse tail.

    sketches: (n, L) uint8 over Σ=[0, 2^b); returns a queryable
    ``SketchIndex`` pytree (ids are row positions in ``sketches``)."""
    trie = trie or build_trie_levels(sketches, b)
    lm, ls = pick_layers(trie, lam)
    levels: List = []
    kinds: List[str] = []
    for lev in range(1, ls + 1):
        if lev <= lm:
            levels.append(DenseLevel(b=b, t_prev=trie.t[lev - 1]))
            kinds.append("dense")
        elif table_or_list(trie, lev) == "table":
            levels.append(_build_table_level(trie, lev))
            kinds.append("table")
        else:
            levels.append(_build_list_level(trie, lev))
            kinds.append("list")
    tail = _build_sparse_tail(trie, ls)
    return SketchIndex(levels=tuple(levels), tail=tail,
                       id_leaf=jnp.asarray(trie.id_leaf, dtype=jnp.int32),
                       L=trie.L, b=b, n=trie.n, t=tuple(trie.t),
                       lm=lm, ls=ls, kinds=tuple(kinds))


def build_louds(sketches: np.ndarray, b: int,
                trie: Optional[TrieLevels] = None) -> SketchIndex:
    """LOUDS-trie baseline: every level as (labels, unary-degree bitvector),
    no dense shortcut, no path collapse (Table III comparison).
    sketches: (n, L) uint8 -> ``SketchIndex``."""
    trie = trie or build_trie_levels(sketches, b)
    levels = tuple(_build_louds_level(trie, lev) for lev in range(1, trie.L + 1))
    return SketchIndex(levels=levels, tail=None,
                       id_leaf=jnp.asarray(trie.id_leaf, dtype=jnp.int32),
                       L=trie.L, b=b, n=trie.n, t=tuple(trie.t),
                       lm=0, ls=trie.L, kinds=tuple(["louds"] * trie.L))


def build_fst_style(sketches: np.ndarray, b: int,
                    trie: Optional[TrieLevels] = None) -> SketchIndex:
    """FST-style two-layer baseline: bitmap-encoded (LOUDS-DENSE-like) top
    levels while the density rule favours TABLE, list-encoded
    (LOUDS-SPARSE-like) below; no path collapse (Table III comparison).
    sketches: (n, L) uint8 -> ``SketchIndex``."""
    trie = trie or build_trie_levels(sketches, b)
    levels: List = []
    kinds: List[str] = []
    in_top = True
    for lev in range(1, trie.L + 1):
        if in_top and table_or_list(trie, lev) == "table":
            levels.append(_build_table_level(trie, lev))
            kinds.append("table")
        else:
            in_top = False
            levels.append(_build_list_level(trie, lev))
            kinds.append("list")
    return SketchIndex(levels=tuple(levels), tail=None,
                       id_leaf=jnp.asarray(trie.id_leaf, dtype=jnp.int32),
                       L=trie.L, b=b, n=trie.n, t=tuple(trie.t),
                       lm=0, ls=trie.L, kinds=tuple(kinds))
