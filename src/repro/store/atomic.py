"""Crash-safe filesystem primitives shared by the durable store and the
distributed checkpointer.

Everything durable in the repo is written with one protocol, factored out
of ``distributed/checkpoint.py`` (which now imports these helpers instead
of duplicating them):

1. write the payload to a sibling ``<final>.tmp-<pid>`` path,
2. flush + ``fsync`` the payload,
3. ``rename`` over the final path (atomic on POSIX),
4. ``fsync`` the parent directory so the rename itself is durable.

A crash at any point leaves either the old state or the new state visible
— never a torn file — plus, at worst, a stale ``.tmp-<pid>`` sibling that
:func:`sweep_stale_tmp` removes on the next startup.

Every fsync/rename boundary reports a labelled crash point to an optional
:class:`repro.store.faults.FaultInjector`, so the recovery test suite can
enumerate and kill at every one of them.
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import Callable, List, Optional

# stale siblings left by crashed writers: in-flight tmp payloads,
# half-deleted ``.rm`` garbage, displaced ``.old-<pid>`` predecessors
_STALE_RE = re.compile(r"\.(tmp-\d+|old-\d+|rm)$")


def _hit(faults, label: str) -> None:
    if faults is not None:
        faults.hit(label)


def fsync_dir(path: str) -> None:
    """fsync a directory so a rename inside it survives power loss."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_bytes(path: str, data: bytes, *, faults=None,
                       label: str = "file") -> None:
    """Atomically replace ``path`` with ``data`` (tmp → fsync → rename).

    Crash points: ``<label>:pre-fsync``, ``<label>:pre-rename``,
    ``<label>:post-rename``.
    """
    tmp = f"{path}.tmp-{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        _hit(faults, f"{label}:pre-fsync")
        os.fsync(f.fileno())
    _hit(faults, f"{label}:pre-rename")
    os.replace(tmp, path)
    _hit(faults, f"{label}:post-rename")
    fsync_dir(os.path.dirname(os.path.abspath(path)))


def atomic_write_json(path: str, obj, *, faults=None,
                      label: str = "json") -> None:
    atomic_write_bytes(path, json.dumps(obj, indent=1, sort_keys=True)
                       .encode("utf-8"), faults=faults, label=label)


def atomic_write_dir(final: str, populate: Callable[[str], None], *,
                     faults=None, label: str = "dir") -> None:
    """Materialize a directory atomically: ``populate(tmp)`` fills a
    ``<final>.tmp-<pid>`` staging dir, every file in it is fsynced, then
    the whole dir renames into place.  Readers never observe a partially
    written directory."""
    tmp = f"{final}.tmp-{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    populate(tmp)
    _hit(faults, f"{label}:pre-fsync")
    for name in os.listdir(tmp):
        p = os.path.join(tmp, name)
        if os.path.isfile(p):
            fd = os.open(p, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
    _hit(faults, f"{label}:pre-rename")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _hit(faults, f"{label}:post-rename")
    fsync_dir(os.path.dirname(os.path.abspath(final)))


def sweep_stale_tmp(root: str, *, skip_live_pid: bool = True) -> List[str]:
    """Remove crash leftovers (``*.tmp-<pid>``, ``*.old-<pid>``, ``*.rm``
    files and directories) anywhere under ``root``.  Returns the removed
    paths.  ``skip_live_pid`` keeps this process's own in-flight tmp
    writes (a concurrent :class:`AsyncCheckpointer` thread) untouched."""
    removed: List[str] = []
    if not os.path.isdir(root):
        return removed
    me = f"-{os.getpid()}"
    for dirpath, dirnames, filenames in os.walk(root, topdown=True):
        doomed = []
        for name in list(dirnames) + filenames:
            m = _STALE_RE.search(name)
            if not m:
                continue
            if skip_live_pid and m.group(1).startswith("tmp") \
                    and name.endswith(me):
                continue
            doomed.append(name)
        for name in doomed:
            p = os.path.join(dirpath, name)
            if os.path.isdir(p):
                shutil.rmtree(p, ignore_errors=True)
                if name in dirnames:
                    dirnames.remove(name)  # don't descend into it
            else:
                try:
                    os.unlink(p)
                except OSError:
                    continue
            removed.append(p)
    return removed


def read_json(path: str) -> Optional[dict]:
    """Load a JSON file written by :func:`atomic_write_json`; ``None`` if
    absent (a crash before the first atomic publish)."""
    if not os.path.exists(path):
        return None
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f)
