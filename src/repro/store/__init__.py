"""Durable index store (DESIGN.md §8): atomic segment snapshots, a
CRC-framed delta-buffer WAL, recover-on-start, and the fault-injection
harness that proves every fsync/rename boundary by enumeration."""

from .atomic import (atomic_write_bytes, atomic_write_dir, atomic_write_json,
                     fsync_dir, read_json, sweep_stale_tmp)
from .faults import CrashPoint, FaultInjector
from .store import CollectionStore, StackBinding
from .wal import (OP_DELETE, OP_INSERT, WriteAheadLog, decode_delete,
                  decode_insert, encode_delete, encode_insert, read_wal)

__all__ = [
    "CollectionStore", "StackBinding", "WriteAheadLog", "read_wal",
    "OP_INSERT", "OP_DELETE", "encode_insert", "decode_insert",
    "encode_delete", "decode_delete", "CrashPoint", "FaultInjector",
    "atomic_write_bytes", "atomic_write_json", "atomic_write_dir",
    "fsync_dir", "read_json", "sweep_stale_tmp",
]
