"""Append-only, CRC-framed, fsync-batched write-ahead log for the delta
buffer.

The mutable delta buffer is the only part of a segmented index that is
not an immutable on-disk snapshot, and it is exactly WAL-shaped: a short
ordered run of ``insert``/``delete`` records since the last flush.  The
log is truncated (whole-file, atomically) only at checkpoints where every
delta buffer in the collection is empty, so recovery never needs a
sequence watermark: manifest segments + full WAL replay reconstructs the
exact pre-crash state (replay filters inserts whose ids already landed in
a sealed segment, and deletes are idempotent).

On-disk framing (little-endian)::

    header:  magic  "bSTW" | version u8 | base_seq u64
    record:  magic u32 | seq u64 | op u8 | payload_len u32 | crc32 u32
             | payload

``crc32`` covers ``seq || op || payload``.  A torn or corrupt tail —
short header, bad record magic, truncated payload, CRC mismatch, or a
sequence break — ends replay at the last good record: dropped, never
crashed on.  That is the correct durability contract: a record the OS
never fully persisted was never acknowledged as synced.

Writes are buffered *in Python memory* and only reach the OS at sync
points (every ``fsync_every`` records, or an explicit :meth:`sync`).
This makes the fault-injection harness honest: a simulated crash between
syncs genuinely loses the unsynced tail, exactly like power loss.
"""

from __future__ import annotations

import os
import struct
import zlib
from typing import List, Optional, Tuple

import numpy as np

from .atomic import atomic_write_bytes, fsync_dir

_FILE_MAGIC = b"bSTW"
_VERSION = 1
_HEADER = struct.Struct("<4sBQ")           # magic, version, base_seq
_FRAME = struct.Struct("<IQBII")           # magic, seq, op, len, crc
_REC_MAGIC = 0x57A17EC5

OP_INSERT = 1
OP_DELETE = 2
OP_INSERT_PAYLOAD = 3      # insert carrying re-rank payload bitmaps


def _crc(seq: int, op: int, payload: bytes) -> int:
    return zlib.crc32(payload, zlib.crc32(struct.pack("<QB", seq, op)))


def encode_insert(ids: np.ndarray, sk: np.ndarray) -> bytes:
    """``insert`` payload: n u32 | L u16 | ids int64[n] | sketches u8[n,L]."""
    ids = np.ascontiguousarray(ids, np.int64)
    sk = np.ascontiguousarray(sk, np.uint8)
    n, L = sk.shape
    return struct.pack("<IH", n, L) + ids.tobytes() + sk.tobytes()


def decode_insert(payload: bytes) -> Tuple[np.ndarray, np.ndarray]:
    n, L = struct.unpack_from("<IH", payload)
    off = 6
    ids = np.frombuffer(payload, np.int64, n, off)
    sk = np.frombuffer(payload, np.uint8, n * L, off + 8 * n).reshape(n, L)
    return ids.copy(), sk.copy()


def encode_insert_payload(ids: np.ndarray, sk: np.ndarray,
                          pay: np.ndarray) -> bytes:
    """``insert`` payload with re-rank bitmaps: n u32 | L u16 | Wp u16 |
    ids int64[n] | sketches u8[n,L] | bitmaps u32[n,Wp]."""
    ids = np.ascontiguousarray(ids, np.int64)
    sk = np.ascontiguousarray(sk, np.uint8)
    pay = np.ascontiguousarray(pay, np.uint32)
    n, L = sk.shape
    Wp = pay.shape[1]
    return (struct.pack("<IHH", n, L, Wp) + ids.tobytes() + sk.tobytes()
            + pay.tobytes())


def decode_insert_payload(
        payload: bytes) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    n, L, Wp = struct.unpack_from("<IHH", payload)
    off = 8
    ids = np.frombuffer(payload, np.int64, n, off)
    off += 8 * n
    sk = np.frombuffer(payload, np.uint8, n * L, off).reshape(n, L)
    off += n * L
    pay = np.frombuffer(payload, np.uint32, n * Wp, off).reshape(n, Wp)
    return ids.copy(), sk.copy(), pay.copy()


def encode_delete(ids: np.ndarray) -> bytes:
    ids = np.ascontiguousarray(ids, np.int64)
    return struct.pack("<I", len(ids)) + ids.tobytes()


def decode_delete(payload: bytes) -> np.ndarray:
    (n,) = struct.unpack_from("<I", payload)
    return np.frombuffer(payload, np.int64, n, 4).copy()


def read_wal(path: str) -> Tuple[int, List[Tuple[int, int, bytes]], int]:
    """Scan a WAL file.  Returns ``(base_seq, records, dropped_bytes)``
    where ``records`` is ``[(seq, op, payload), ...]`` in order and
    ``dropped_bytes`` counts the torn/corrupt tail that was discarded."""
    if not os.path.exists(path):
        return 0, [], 0
    with open(path, "rb") as f:
        blob = f.read()
    if len(blob) < _HEADER.size:
        return 0, [], len(blob)
    magic, version, base_seq = _HEADER.unpack_from(blob)
    if magic != _FILE_MAGIC or version != _VERSION:
        return 0, [], len(blob)
    records: List[Tuple[int, int, bytes]] = []
    off = _HEADER.size
    expect = base_seq
    while off + _FRAME.size <= len(blob):
        magic, seq, op, length, crc = _FRAME.unpack_from(blob, off)
        end = off + _FRAME.size + length
        if (magic != _REC_MAGIC or seq != expect or end > len(blob)):
            break
        payload = blob[off + _FRAME.size:end]
        if _crc(seq, op, payload) != crc:
            break
        records.append((seq, op, payload))
        expect = seq + 1
        off = end
    return base_seq, records, len(blob) - off


class WriteAheadLog:
    """Durable insert/delete journal for one collection's delta buffers.

    ``fsync_every=1`` gives per-record durability (the fault harness uses
    this so every acknowledged op is recoverable); the serving default
    batches fsyncs, trading a bounded acknowledged-but-lost window for
    ingest throughput (measured as ``wal_on`` vs ``wal_off`` in
    BENCH_ingest.json).
    """

    def __init__(self, path: str, *, fsync_every: int = 64, faults=None):
        self.path = path
        self.fsync_every = max(1, int(fsync_every))
        self.faults = faults
        self._buf = bytearray()
        self._pending = 0
        self._fh = None
        base, records, dropped = read_wal(path)
        self.base_seq = base
        self.next_seq = records[-1][0] + 1 if records else base
        self.dropped_bytes = dropped
        if not os.path.exists(path):
            self._rewrite_header(0)
        elif dropped:
            # cut the torn/corrupt tail so new appends extend the good
            # prefix (a crash mid-truncate just leaves a shorter tail
            # that the next replay drops again)
            good = os.path.getsize(path) - dropped
            if good < _HEADER.size:
                self._rewrite_header(0)
            else:
                with open(path, "r+b") as f:
                    f.truncate(good)
                    f.flush()
                    os.fsync(f.fileno())

    # -- write path ----------------------------------------------------

    def append(self, op: int, payload: bytes) -> int:
        """Frame and buffer one record; syncs every ``fsync_every``
        records.  Returns the record's sequence number."""
        seq = self.next_seq
        self.next_seq += 1
        self._buf += _FRAME.pack(_REC_MAGIC, seq, op, len(payload),
                                 _crc(seq, op, payload))
        self._buf += payload
        self._pending += 1
        if self._pending >= self.fsync_every:
            self.sync()
        return seq

    def sync(self) -> None:
        """Write buffered records and fsync.  Crash points:
        ``wal:pre-write``, ``wal:pre-fsync``, ``wal:post-fsync``."""
        if not self._buf:
            return
        if self.faults is not None:
            self.faults.hit("wal:pre-write")
        if self._fh is None:
            self._fh = open(self.path, "ab")
        self._fh.write(bytes(self._buf))
        self._fh.flush()
        if self.faults is not None:
            self.faults.hit("wal:pre-fsync")
        os.fsync(self._fh.fileno())
        if self.faults is not None:
            self.faults.hit("wal:post-fsync")
        self._buf.clear()
        self._pending = 0

    def reset(self) -> None:
        """Truncate: atomically replace the log with a fresh header whose
        ``base_seq`` continues the sequence (so seqs never repeat across
        truncations).  Called only when every delta buffer is empty and
        persisted — buffered-but-unsynced records are dropped with it."""
        self._buf.clear()
        self._pending = 0
        self._rewrite_header(self.next_seq)

    def _rewrite_header(self, base_seq: int) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
        self.base_seq = base_seq
        self.next_seq = base_seq
        atomic_write_bytes(self.path,
                           _HEADER.pack(_FILE_MAGIC, _VERSION, base_seq),
                           faults=self.faults, label="wal-reset")

    # -- observability ---------------------------------------------------

    def size_bytes(self) -> int:
        try:
            return os.path.getsize(self.path)
        except OSError:
            return 0

    def close(self) -> None:
        self.sync()
        if self._fh is not None:
            os.fsync(self._fh.fileno())
            self._fh.close()
            self._fh = None
        d = os.path.dirname(os.path.abspath(self.path))
        if os.path.isdir(d):
            fsync_dir(d)
