"""Durable collection store: segment snapshots + delta-buffer WAL +
recover-on-start (DESIGN.md §8).

The LSM split in ``core/segments.py`` makes durability almost free:
sealed ``Segment``s are immutable, so each one is snapshotted exactly
once as an atomic directory; the only mutable state is (a) the delta
buffer — journaled by the :mod:`repro.store.wal` — and (b) the tombstone
bitmaps, whose dirty lanes are rewritten at the next checkpoint (their
delete records stay in the WAL until then, so a crash loses nothing).

On-disk layout (one root per collection)::

    <root>/collection.json              # CollectionConfig (registry)
    <root>/wal.log                      # insert/delete journal
    <root>/MANIFEST.json                # single-stack collections
    <root>/seg_<serial>/                #   arrays.npz  (packed, ids)
                                        #   live.npy    (tombstone bitmap)
                                        #   meta.json   (serial, n, L, b)
    <root>/stack_<s>/...                # sharded: one subtree per stack

``MANIFEST.json`` is the commit point: it names the live segment set
(with merge lineage), the stack's id allocator, a ``serial_floor`` that
keeps post-recovery serials collision-free with every serial ever
persisted, and ``sealed_seq`` — the last WAL sequence number whose
insert rows this stack has sealed into segments.  Every manifest/segment
write uses the atomic tmp-pid → fsync → rename protocol from
:mod:`repro.store.atomic`, so a crash mid-flush/merge/compact recovers
to either the pre- or post-operation segment set, never a mix.

Recovery replays the WAL in order: an insert record applies to a stack
iff its seq is beyond that stack's ``sealed_seq`` (so rows that were
sealed — even ones later compacted away — are never resurrected), and
delete records are idempotent re-tombstones.  The WAL is truncated only
at checkpoints where *every* stack's delta buffer is empty and persisted,
which is what makes the sealed-seq filter sufficient: the journal always
covers everything the snapshots don't.
"""

from __future__ import annotations

import io
import json
import os
import re
import shutil
from typing import Dict, List, Optional

import numpy as np

from ..core.hamming import unpack_vertical
from ..core.segments import Segment, ensure_serial_floor
from .atomic import (atomic_write_bytes, atomic_write_dir, atomic_write_json,
                     read_json, sweep_stale_tmp)
from .wal import (OP_DELETE, OP_INSERT, OP_INSERT_PAYLOAD, WriteAheadLog,
                  decode_delete, decode_insert, decode_insert_payload,
                  encode_delete, encode_insert, encode_insert_payload,
                  read_wal)

_SEG_RE = re.compile(r"^seg_(\d+)$")
_MANIFEST_VERSION = 1
_LINEAGE_KEEP = 32


class StackBinding:
    """What a ``SegmentedIndex`` sees as ``self.store``: log-before-apply
    write hooks and a checkpoint hook fired after flush/merge/compact.
    Shard-level stacks of a ``ShardedSegmentedIndex`` bind with
    ``log_writes=False`` — the top-level index journals global-id records
    once, while each stack still snapshots its own segments."""

    __slots__ = ("store", "stack_id", "log_writes")

    def __init__(self, store: "CollectionStore", stack_id: Optional[int],
                 log_writes: bool):
        self.store = store
        self.stack_id = stack_id
        self.log_writes = log_writes

    def log_insert(self, ids: np.ndarray, sk: np.ndarray,
                   payloads: Optional[np.ndarray] = None) -> None:
        if self.log_writes:
            self.store.log_insert(ids, sk, payloads=payloads)

    def log_delete(self, ids: np.ndarray) -> None:
        if self.log_writes:
            self.store.log_delete(ids)

    def begin_write(self) -> None:
        self.store.begin_write()

    def end_write(self) -> None:
        self.store.end_write()

    def checkpoint(self, idx) -> None:
        if self.stack_id is not None:
            self.store.checkpoint(self.stack_id)


class CollectionStore:
    """Durability engine for one collection (any backend, sharded or
    not).  ``attach`` binds a *fresh* index for durable writes;
    ``recover`` rebuilds a previously persisted index into a fresh one.
    """

    def __init__(self, root: str, *, fsync_every: int = 64, faults=None):
        self.root = root
        self.faults = faults
        os.makedirs(root, exist_ok=True)
        swept = sweep_stale_tmp(root)
        self.wal = WriteAheadLog(os.path.join(root, "wal.log"),
                                 fsync_every=fsync_every, faults=faults)
        self.index = None
        self._stacks: List[object] = []
        self._sharded = False
        self._replaying = False
        self._write_depth = 0
        # per stack: serial -> n_dead as persisted on disk, and the
        # manifest metadata (n_ids / sealed_seq / serial_floor / lineage)
        self._persisted: List[Dict[int, int]] = []
        self._meta: List[Dict[str, object]] = []
        self.counters: Dict[str, int] = {
            "checkpoints": 0, "segments_written": 0, "live_rewrites": 0,
            "wal_truncations": 0, "replayed_records": 0,
            "recovered_segments": 0, "wal_dropped_bytes":
            self.wal.dropped_bytes, "swept_tmp": len(swept)}

    # -- binding ---------------------------------------------------------

    def attach(self, index) -> object:
        """Bind a fresh (empty) index for durable writes.  Must happen
        before the first insert — rows already in memory are not
        journaled retroactively."""
        self.index = index
        self._sharded = hasattr(index, "shards")
        self._stacks = list(index.shards) if self._sharded else [index]
        last = self.wal.next_seq - 1
        self._persisted = [dict() for _ in self._stacks]
        self._meta = [{"n_ids": None, "sealed_seq": last,
                       "serial_floor": 0, "lineage": []}
                      for _ in self._stacks]
        for i, st in enumerate(self._stacks):
            st.store = StackBinding(self, i, log_writes=not self._sharded)
        if self._sharded:
            index.store = StackBinding(self, None, log_writes=True)
        return index

    def _stack_dir(self, i: int) -> str:
        if not self._sharded:
            return self.root
        return os.path.join(self.root, f"stack_{i:04d}")

    # -- write path ------------------------------------------------------

    def log_insert(self, ids: np.ndarray, sk: np.ndarray,
                   payloads: Optional[np.ndarray] = None) -> None:
        if not self._replaying and len(ids):
            if payloads is not None:
                self.wal.append(OP_INSERT_PAYLOAD,
                                encode_insert_payload(ids, sk, payloads))
            else:
                self.wal.append(OP_INSERT, encode_insert(ids, sk))

    def log_delete(self, ids: np.ndarray) -> None:
        if not self._replaying and len(ids):
            self.wal.append(OP_DELETE, encode_delete(ids))

    def begin_write(self) -> None:
        """Mark a multi-stack write in flight: a sharded index journals
        one global record, then routes rows to its stacks one by one.  A
        checkpoint fired mid-routing (a shard's auto-flush) must neither
        advance a *sibling* stack's ``sealed_seq`` over the in-flight
        record nor truncate the journal — the siblings have not applied
        their rows yet, and a crash would lose them."""
        self._write_depth += 1

    def end_write(self) -> None:
        self._write_depth -= 1

    def checkpoint(self, stack_id: int) -> None:
        """Persist one stack's segment set after a flush/merge/compact.
        Syncs the WAL first (so a delete whose lane rewrite lands in
        another stack's *next* checkpoint is never lost), then truncates
        the journal once every stack is empty and persisted.  The
        triggering stack's ``sealed_seq`` may advance even mid-write (it
        has applied its share of the in-flight record — routing is
        sequential), but sibling persistence and truncation wait until
        no write is in flight."""
        self.wal.sync()
        self._persist_stack(stack_id)
        if self._write_depth == 0:
            self._maybe_truncate()
        self.counters["checkpoints"] += 1

    def _persist_stack(self, i: int) -> None:
        idx = self._stacks[i]
        sdir = self._stack_dir(i)
        os.makedirs(sdir, exist_ok=True)
        pers = self._persisted[i]
        meta = self._meta[i]
        cur = {seg.serial: seg for seg in idx.segments}
        new, retired = [], [s for s in pers if s not in cur]
        for serial, seg in cur.items():
            if serial not in pers:
                self._write_segment(sdir, seg)
                new.append(serial)
            elif pers[serial] != seg.n - seg.n_live:
                buf = io.BytesIO()
                np.save(buf, seg.live)
                atomic_write_bytes(
                    os.path.join(sdir, f"seg_{serial:012d}", "live.npy"),
                    buf.getvalue(), faults=self.faults, label="live")
                self.counters["live_rewrites"] += 1
        sealed = (self.wal.next_seq - 1 if len(idx._delta_ids) == 0
                  else meta["sealed_seq"])
        floor = max([meta["serial_floor"]] + [s + 1 for s in cur])
        changed = (new or retired or meta["n_ids"] != idx.n_ids
                   or meta["sealed_seq"] != sealed
                   or meta["serial_floor"] != floor
                   or any(pers[s] != cur[s].n - cur[s].n_live
                          for s in cur if s in pers))
        if not changed:
            return
        lineage = list(meta["lineage"])
        if new or retired:
            lineage = (lineage + [{"new": sorted(new),
                                   "dropped": sorted(retired)}]
                       )[-_LINEAGE_KEEP:]
        manifest = {
            "version": _MANIFEST_VERSION,
            "n_ids": int(idx.n_ids),
            "sealed_seq": int(sealed),
            "serial_floor": int(floor),
            "segments": [{"serial": int(seg.serial), "n": seg.n,
                          "n_dead": seg.n - seg.n_live}
                         for seg in idx.segments],
            "lineage": lineage,
        }
        atomic_write_json(os.path.join(sdir, "MANIFEST.json"), manifest,
                          faults=self.faults, label="manifest")
        # the manifest is the commit point: only now is it safe to drop
        # retired segment directories (crash earlier -> old manifest
        # still references them; crash during the rmtree -> orphans the
        # next recovery sweeps)
        for serial in retired:
            shutil.rmtree(os.path.join(sdir, f"seg_{serial:012d}"),
                          ignore_errors=True)
        self._persisted[i] = {s: seg.n - seg.n_live
                              for s, seg in cur.items()}
        meta.update(n_ids=int(idx.n_ids), sealed_seq=int(sealed),
                    serial_floor=int(floor), lineage=lineage)

    def _write_segment(self, sdir: str, seg: Segment) -> None:
        def populate(tmp: str) -> None:
            arrays = {"packed": seg.packed, "ids": seg.ids}
            if seg.payloads is not None:
                arrays["payloads"] = seg.payloads
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            np.save(os.path.join(tmp, "live.npy"), seg.live)
            with open(os.path.join(tmp, "meta.json"), "w",
                      encoding="utf-8") as f:
                json.dump({"serial": int(seg.serial), "n": seg.n,
                           "L": seg.L, "b": seg.b}, f)
        atomic_write_dir(os.path.join(sdir, f"seg_{seg.serial:012d}"),
                         populate, faults=self.faults, label="seg")
        self.counters["segments_written"] += 1

    def _maybe_truncate(self) -> None:
        if any(len(st._delta_ids) for st in self._stacks):
            return
        for i in range(len(self._stacks)):
            self._persist_stack(i)          # no-op when already clean
        if self.wal.next_seq > self.wal.base_seq:
            self.wal.reset()
            self.counters["wal_truncations"] += 1

    # -- recovery --------------------------------------------------------

    def recover(self, index) -> object:
        """Rebuild ``index`` (fresh, empty, same config) from disk: load
        manifest segments, replay the WAL into the delta buffers, restore
        the id allocators and advance the global serial counter, then run
        the same maintenance fixpoint a live index would have run
        (flush-at-cap + size-tiered merge) so the recovered partition
        matches a never-crashed one."""
        self.attach(index)
        self._replaying = True
        try:
            floor = 0
            for i, st in enumerate(self._stacks):
                floor = max(floor, self._load_stack(i, st))
            if self._sharded:
                S = len(self._stacks)
                index.n_ids = max(
                    [0] + [(m["n_ids"] - 1) * S + s + 1
                           for s, m in enumerate(self._meta)
                           if m["n_ids"]])
            ensure_serial_floor(floor)
            _base, records, _dropped = read_wal(self.wal.path)
            for seq, op, payload in records:
                if op == OP_INSERT:
                    self._replay_insert(seq, *decode_insert(payload))
                elif op == OP_INSERT_PAYLOAD:
                    self._replay_insert(seq,
                                        *decode_insert_payload(payload))
                elif op == OP_DELETE:
                    index.delete(decode_delete(payload))
            self.counters["replayed_records"] += len(records)
        finally:
            self._replaying = False
        for st in self._stacks:
            if len(st._delta_ids) >= st.delta_cap:
                st.flush()
            if st.auto_merge:
                # restore the size-tier invariant: a crash between an
                # in-memory merge and its durable checkpoint recovers to
                # the pre-merge set; re-running the (idempotent) policy
                # converges it to what a never-crashed index holds
                st.maybe_merge()
        return index

    def _load_stack(self, i: int, st) -> int:
        sdir = self._stack_dir(i)
        man = read_json(os.path.join(sdir, "MANIFEST.json")) or {
            "n_ids": 0, "sealed_seq": -1, "serial_floor": 0,
            "segments": [], "lineage": []}
        segs: List[Segment] = []
        for ent in man["segments"]:
            d = os.path.join(sdir, f"seg_{ent['serial']:012d}")
            with np.load(os.path.join(d, "arrays.npz")) as arr:
                packed, ids = arr["packed"], arr["ids"]
                pay = arr["payloads"] if "payloads" in arr.files else None
            live = np.load(os.path.join(d, "live.npy"))
            sk = unpack_vertical(packed, st.b, st.L)
            segs.append(Segment(index=st._build(sk), packed=packed,
                                ids=ids, live=live, L=st.L, b=st.b,
                                serial=int(ent["serial"]), payloads=pay))
        st.segments = segs
        st.n_ids = int(man["n_ids"])
        self._persisted[i] = {seg.serial: seg.n - seg.n_live
                              for seg in segs}
        self._meta[i] = {"n_ids": int(man["n_ids"]),
                         "sealed_seq": int(man["sealed_seq"]),
                         "serial_floor": int(man["serial_floor"]),
                         "lineage": list(man.get("lineage", []))}
        self.counters["recovered_segments"] += len(segs)
        keep = {f"seg_{seg.serial:012d}" for seg in segs}
        if os.path.isdir(sdir):
            for name in os.listdir(sdir):      # orphans of a crashed write
                if _SEG_RE.match(name) and name not in keep:
                    shutil.rmtree(os.path.join(sdir, name),
                                  ignore_errors=True)
        return max([int(man["serial_floor"])]
                   + [seg.serial + 1 for seg in segs])

    def _replay_insert(self, seq: int, ids: np.ndarray, sk: np.ndarray,
                       pay: Optional[np.ndarray] = None) -> None:
        if self._sharded:
            S = len(self._stacks)
            for s, st in enumerate(self._stacks):
                if seq <= self._meta[s]["sealed_seq"]:
                    continue                    # already sealed pre-crash
                rows = np.flatnonzero(ids % S == s)
                if rows.size:
                    st._replay_insert(
                        ids[rows] // S, sk[rows],
                        payloads=pay[rows] if pay is not None else None)
            self.index.n_ids = max(self.index.n_ids, int(ids.max()) + 1)
        elif seq > self._meta[0]["sealed_seq"]:
            self._stacks[0]._replay_insert(ids, sk, payloads=pay)

    # -- config / observability -----------------------------------------

    def save_config(self, config: Dict[str, object]) -> None:
        atomic_write_json(os.path.join(self.root, "collection.json"),
                          config, faults=self.faults, label="config")

    @staticmethod
    def load_config(root: str) -> Optional[Dict[str, object]]:
        return read_json(os.path.join(root, "collection.json"))

    def stats(self) -> Dict[str, int]:
        snap = 0
        for dirpath, _dirnames, filenames in os.walk(self.root):
            for name in filenames:
                if name == "wal.log":
                    continue
                try:
                    snap += os.path.getsize(os.path.join(dirpath, name))
                except OSError:
                    pass
        return {"wal_bytes": self.wal.size_bytes(),
                "snapshot_bytes": snap, **self.counters}

    def close(self) -> None:
        self.wal.close()
