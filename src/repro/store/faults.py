"""Fault injection for the durability layer.

``distributed/fault_tolerance.py`` proves the training restart path with
``SimulatedFailure`` raised at a planned *step*; the store generalizes the
same idea to planned *I/O boundaries*: every fsync/rename in the snapshot,
WAL-append, flush, merge, and compact paths calls ``faults.hit(label)``,
and a :class:`FaultInjector` armed with ``crash_at=i`` raises
:class:`CrashPoint` at the *i*-th boundary it sees.  A process that dies
there has exactly the on-disk state a real crash at that instant would
leave (the WAL buffers unsynced records in memory, so they are genuinely
lost).  The recovery property test first runs in *counting* mode
(``crash_at=None``) to enumerate the boundaries, then replays the same
workload once per boundary — robustness by enumeration.

``SimulatedFailure`` subclasses :class:`CrashPoint`, so one ``except``
clause covers both planned-step and planned-I/O kills.
"""

from __future__ import annotations

from typing import List, Optional


class CrashPoint(RuntimeError):
    """Raised by :class:`FaultInjector` to simulate dying at an I/O
    boundary.  Carries the boundary's label and ordinal."""

    def __init__(self, label: str, ordinal: int):
        super().__init__(f"simulated crash at point {ordinal} ({label})")
        self.label = label
        self.ordinal = ordinal


class FaultInjector:
    """Counts labelled crash points; optionally kills at one of them.

    >>> fi = FaultInjector()                 # counting mode
    >>> fi.hit("wal:pre-fsync"); fi.hit("manifest:pre-rename")
    >>> fi.points
    ['wal:pre-fsync', 'manifest:pre-rename']
    >>> fi = FaultInjector(crash_at=1)
    >>> fi.hit("wal:pre-fsync")              # point 0: survives
    >>> fi.hit("manifest:pre-rename")        # point 1: dies
    Traceback (most recent call last):
        ...
    repro.store.faults.CrashPoint: simulated crash at point 1 (manifest:pre-rename)
    """

    def __init__(self, crash_at: Optional[int] = None):
        self.crash_at = crash_at
        self.points: List[str] = []

    @property
    def count(self) -> int:
        return len(self.points)

    def hit(self, label: str) -> None:
        ordinal = len(self.points)
        self.points.append(label)
        if self.crash_at is not None and ordinal == self.crash_at:
            raise CrashPoint(label, ordinal)
