"""Prometheus exposition-format primitives (DESIGN.md §11).

``Histogram`` is the fixed-bucket latency histogram ``ServingMetrics``
renders under ``/stats``; ``format_value`` is the one canonical number
formatter (floats render via ``repr`` — exact ``float()`` round-trip,
no ``0.30000000000000004`` drift from ad-hoc ``str()`` calls);
``parse_exposition`` is a strict scraper-side parser used by the
round-trip test — if it accepts the output, a real Prometheus scraper
will too.
"""

from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["DEFAULT_LATENCY_BUCKETS_S", "Histogram", "format_value",
           "render_family", "parse_exposition"]

# Fixed latency buckets (seconds): 0.5 ms .. 10 s, roughly 1-2.5-5 per
# decade — wide enough that the observed 4.9 s serving p99 lands inside
# the ladder, not in +Inf.
DEFAULT_LATENCY_BUCKETS_S: Tuple[float, ...] = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def format_value(v) -> str:
    """Canonical sample-value rendering: bools as 1/0, integers plain,
    floats via ``repr`` (shortest string that round-trips through
    ``float`` — what the Go exposition writer does), NaN/±Inf in the
    exposition spellings."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    f = float(v)
    if math.isnan(f):
        return "NaN"
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))          # 3.0 -> "3": scrapers parse either
    return repr(f)


class Histogram:
    """Fixed-bucket cumulative histogram (the Prometheus model: bucket
    counts are cumulative, ``le`` upper bounds, an implicit +Inf).
    ``observe`` is O(buckets) with no allocation — cheap enough for the
    per-request latency path; callers serialize access (ServingMetrics
    holds its own lock)."""

    def __init__(self, buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S):
        self.bounds: Tuple[float, ...] = tuple(float(b) for b in buckets)
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError("histogram buckets must be sorted ascending")
        self.counts: List[int] = [0] * (len(self.bounds) + 1)  # +Inf last
        self.total = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        for i, bound in enumerate(self.bounds):
            if v <= bound:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def cumulative(self) -> List[Tuple[str, int]]:
        """[(le_label, cumulative_count), ...] ending with +Inf."""
        out: List[Tuple[str, int]] = []
        running = 0
        for bound, c in zip(self.bounds, self.counts):
            running += c
            out.append((format_value(bound), running))
        out.append(("+Inf", self.count))
        return out

    def sample_lines(self, family: str, labels: str = "") -> List[str]:
        """The ``<family>_bucket``/``_sum``/``_count`` sample lines for
        one label set (``labels`` like ``op="topk"`` — no braces)."""
        sep = "," if labels else ""
        lines = [
            f'{family}_bucket{{{labels}{sep}le="{le}"}} {c}'
            for le, c in self.cumulative()]
        lab = f"{{{labels}}}" if labels else ""
        lines.append(f"{family}_sum{lab} {format_value(self.total)}")
        lines.append(f"{family}_count{lab} {self.count}")
        return lines


def render_family(family: str, ftype: str, help_text: str,
                  sample_lines: List[str]) -> List[str]:
    """One exposition block: ``# HELP`` + ``# TYPE`` + samples."""
    return [f"# HELP {family} {help_text}",
            f"# TYPE {family} {ftype}"] + sample_lines


# -- strict scraper-side parser ------------------------------------------

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^{}]*)\})?"
    r"\s+(?P<value>\S+)$")
_LABEL_RE = re.compile(r'^([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"$')

_TYPES = ("counter", "gauge", "histogram", "summary", "untyped")


def _family_of(name: str) -> str:
    for suffix in ("_bucket", "_sum", "_count"):
        if name.endswith(suffix):
            return name[: -len(suffix)]
    return name


def parse_exposition(text: str) -> Dict[str, object]:
    """Parse (and validate) Prometheus text exposition format.

    Returns ``{"samples": [(name, labels_dict, value_float)],
    "types": {family: type}, "helps": {family: text}}``.  Raises
    ``ValueError`` on anything a real scraper would reject: malformed
    sample lines, bad label syntax, unparseable values, unknown TYPE
    keywords, or a duplicate TYPE line for one family.  Additionally
    enforces (as our own output contract) that every sample's family
    carries a TYPE line, and that histogram ``_bucket`` series are
    cumulative-monotone and consistent with ``_count``.
    """
    samples: List[Tuple[str, Dict[str, str], float]] = []
    types: Dict[str, str] = {}
    helps: Dict[str, str] = {}
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.rstrip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                continue                       # plain comment
            kind, family = parts[1], parts[2]
            if not _NAME_RE.match(family):
                raise ValueError(f"line {lineno}: bad metric name in "
                                 f"{kind}: {family!r}")
            if kind == "HELP":
                helps[family] = parts[3] if len(parts) > 3 else ""
            else:
                ftype = parts[3].strip() if len(parts) > 3 else ""
                if ftype not in _TYPES:
                    raise ValueError(
                        f"line {lineno}: unknown TYPE {ftype!r}")
                if family in types:
                    raise ValueError(
                        f"line {lineno}: duplicate TYPE for {family}")
                types[family] = ftype
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: malformed sample: {raw!r}")
        labels: Dict[str, str] = {}
        body = m.group("labels")
        if body:
            for part in _split_labels(body, lineno):
                lm = _LABEL_RE.match(part)
                if lm is None:
                    raise ValueError(
                        f"line {lineno}: malformed label {part!r}")
                labels[lm.group(1)] = lm.group(2)
        try:
            value = float(m.group("value"))
        except ValueError:
            raise ValueError(f"line {lineno}: unparseable value "
                             f"{m.group('value')!r}") from None
        samples.append((m.group("name"), labels, value))
    for name, _, _ in samples:
        if _family_of(name) not in types and name not in types:
            raise ValueError(f"sample {name!r} has no # TYPE line")
    _check_histograms(samples, types)
    return {"samples": samples, "types": types, "helps": helps}


def _split_labels(body: str, lineno: int) -> List[str]:
    """Split ``a="x",b="y"`` on commas outside quotes."""
    parts: List[str] = []
    cur: List[str] = []
    in_str = False
    escape = False
    for ch in body:
        if escape:
            cur.append(ch)
            escape = False
        elif ch == "\\":
            cur.append(ch)
            escape = True
        elif ch == '"':
            cur.append(ch)
            in_str = not in_str
        elif ch == "," and not in_str:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if in_str:
        raise ValueError(f"line {lineno}: unterminated label string")
    if cur:
        parts.append("".join(cur))
    return parts


def _check_histograms(samples, types) -> None:
    """Bucket series must be cumulative-monotone in ``le`` and agree
    with their ``_count`` sample (per label set)."""
    series: Dict[Tuple[str, tuple], List[Tuple[float, float]]] = {}
    counts: Dict[Tuple[str, tuple], float] = {}
    for name, labels, value in samples:
        family = _family_of(name)
        if types.get(family) != "histogram":
            continue
        key_labels = tuple(sorted(
            (k, v) for k, v in labels.items() if k != "le"))
        if name.endswith("_bucket"):
            series.setdefault((family, key_labels), []).append(
                (float(labels.get("le", "inf")), value))
        elif name.endswith("_count"):
            counts[(family, key_labels)] = value
    for key, buckets in series.items():
        buckets.sort(key=lambda t: t[0])
        last = 0.0
        for le, c in buckets:
            if c < last:
                raise ValueError(
                    f"histogram {key[0]} buckets not cumulative")
            last = c
        if key in counts and buckets and buckets[-1][1] != counts[key]:
            raise ValueError(
                f"histogram {key[0]} +Inf bucket != _count")
