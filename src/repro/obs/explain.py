"""Structured query-explain records (DESIGN.md §11).

``QueryExplain`` is what ``SegmentedIndex.topk/topk_batch/search(...,
explain=True)`` returns alongside the (bit-identical) result: the
paper's pruning behavior made measurable per request — which τ-ladder
rungs ran, how wide the trie frontier was per level, how many leaves
each rung pruned vs verified, what the re-rank pass kept, and which
process-level caches the request hit.

This module is pure data + formatting: the recording happens inside
``core.segments`` (which owns the counters being deltaed); nothing here
imports the core machinery.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

__all__ = ["RungExplain", "QueryExplain"]


@dataclasses.dataclass
class RungExplain:
    """One τ-ladder rung.

    Attributes:
      tau:         the rung's Hamming threshold.
      candidates:  physical columns the verify kernel swept (R — every
                   sealed row + the delta buffer; the denominator of
                   the pruning ratio).
      survivors:   per-query count of columns with an exact distance
                   (live, within τ) — the verified candidate set.
      pruned:      per-query ``candidates - survivors`` — leaves the
                   traversal + tombstone masking killed at this rung.
      overflow:    dropped frontier entries (0 = the rung was exact).
      dispatches:  device-launch delta of this rung, by kind
                   (``fused`` / ``fanout`` / ``rerank`` / ``total``).
      duration_ms: host wall-clock of the rung (dispatch + readback).
      frontier:    per-query list of per-trie-level live frontier
                   widths (bst backend only — None elsewhere; the
                   sampling launch is explain-only and never runs on
                   the serving path).
    """

    tau: int
    candidates: int
    survivors: List[int]
    pruned: List[int]
    overflow: int
    dispatches: Dict[str, int]
    duration_ms: float
    frontier: Optional[List[List[int]]] = None


@dataclasses.dataclass
class QueryExplain:
    """The per-request explain record (``explain=True``).

    Attributes:
      op:           "topk" | "search".
      backend:      "bst" | "multi" | "sharded" (ShardedSegmentedIndex
                    reports "sharded-stacks").
      n_queries:    batch rows explained (1 for ``topk``/``search``).
      n_live:       live ids at request time.
      k / tau0:     the request parameters (k None for range search).
      tau_final:    the ladder rung the request settled on.
      rungs:        one ``RungExplain`` per attempted rung, in order.
      rerank:       the stage-2 metric, or None.
      rerank_survivors: per-query stage-1 survivor counts entering the
                    exact re-rank plane (None without ``rerank=``).
      cache:        searcher/fused compiled-program cache delta for the
                    request: hits / misses / traces.
      dispatch:     total device-launch delta by kind.
      tier:         column-store staging delta (prefetches,
                    staged_bytes, ...).
      duration_ms:  end-to-end host wall-clock of the explained call.
      degraded:     overload-degradation stage that produced this
                    answer ("rerank_off" | "shrink_k" | "cheap_tau",
                    DESIGN.md §12), or None for a full answer.  Set by
                    the serving layer — the core never degrades.
    """

    op: str
    backend: str
    n_queries: int
    n_live: int
    k: Optional[int]
    tau0: Optional[int]
    tau_final: int
    rungs: List[RungExplain]
    rerank: Optional[str] = None
    rerank_survivors: Optional[List[int]] = None
    cache: Dict[str, int] = dataclasses.field(default_factory=dict)
    dispatch: Dict[str, int] = dataclasses.field(default_factory=dict)
    tier: Dict[str, int] = dataclasses.field(default_factory=dict)
    duration_ms: float = 0.0
    degraded: Optional[str] = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def candidates_verified(self) -> int:
        """Total (query, column) distance evaluations that survived
        pruning across every rung — the work the trie couldn't avoid."""
        return sum(sum(r.survivors) for r in self.rungs)

    def summary(self) -> str:
        """Human-readable multi-line digest.

        >>> ex = QueryExplain(op="topk", backend="bst", n_queries=1,
        ...                   n_live=8, k=2, tau0=None, tau_final=3,
        ...                   rungs=[RungExplain(tau=3, candidates=8,
        ...                       survivors=[4], pruned=[4], overflow=0,
        ...                       dispatches={"fused": 1},
        ...                       duration_ms=0.5)])
        >>> print(ex.summary())
        topk backend=bst queries=1 n_live=8 k=2 tau_final=3
          rung tau=3: candidates=8 survivors=4 pruned=4 overflow=0
        """
        head = (f"{self.op} backend={self.backend} "
                f"queries={self.n_queries} n_live={self.n_live}")
        if self.k is not None:
            head += f" k={self.k}"
        head += f" tau_final={self.tau_final}"
        lines = [head]
        for r in self.rungs:
            lines.append(
                f"  rung tau={r.tau}: candidates={r.candidates} "
                f"survivors={sum(r.survivors)} pruned={sum(r.pruned)} "
                f"overflow={r.overflow}")
            if r.frontier is not None:
                widths = [sum(col) for col in zip(*r.frontier)] \
                    if r.frontier else []
                lines.append("    frontier widths/level: "
                             + ",".join(str(w) for w in widths))
        if self.rerank is not None:
            lines.append(f"  rerank={self.rerank} "
                         f"survivors={self.rerank_survivors}")
        if self.degraded is not None:
            lines.append(f"  DEGRADED stage={self.degraded}")
        return "\n".join(lines)
