"""Observability: request tracing, query explain, Prometheus export,
and the slow-query log (DESIGN.md §11).

Pure host-side instrumentation — nothing in this package imports the
core index machinery or issues device work, so the serving and core
layers can depend on it without cycles, and tracing can never change
what a query computes (the bit-identity + zero-dispatch invariants are
held by ``tests/test_obs.py``).
"""

from .explain import QueryExplain, RungExplain
from .prom import (DEFAULT_LATENCY_BUCKETS_S, Histogram, format_value,
                   parse_exposition)
from .slowlog import SlowQueryLog
from .trace import (Span, Tracer, attach, chrome_trace, current, span,
                    span_to_dict, write_chrome)

__all__ = [
    "Span", "Tracer", "attach", "chrome_trace", "current", "span",
    "span_to_dict", "write_chrome",
    "QueryExplain", "RungExplain",
    "Histogram", "DEFAULT_LATENCY_BUCKETS_S", "format_value",
    "parse_exposition",
    "SlowQueryLog",
]
