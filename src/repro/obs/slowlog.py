"""Structured slow-query log (DESIGN.md §11).

Any request slower than the scheduler's ``slow_ms`` threshold dumps its
completed span tree here: a bounded in-memory ring (inspection from
tests / a REPL) plus an optional JSONL file (one self-contained record
per line — the on-disk artifact ``tools/trace_report.py`` reads next to
the Chrome trace).
"""

from __future__ import annotations

import collections
import json
import threading
import time
from typing import Dict, List, Optional

from .trace import Span, span_to_dict

__all__ = ["SlowQueryLog"]


class SlowQueryLog:
    """Ring of slow-request records; thread-safe (the scheduler records
    from worker threads)."""

    def __init__(self, capacity: int = 256, path: Optional[str] = None):
        self.capacity = int(capacity)
        self.path = path
        self._lock = threading.Lock()
        self._entries = collections.deque(maxlen=self.capacity)
        self.dropped = 0          # records pushed out of the ring

    def record(self, root: Span, **meta) -> Dict[str, object]:
        """Log one finished request: the span tree (inlined, children
        and all) plus caller metadata (op, collection, threshold)."""
        entry: Dict[str, object] = {
            "time_unix": time.time(),
            "e2e_ms": round(root.dur * 1e3, 3),
            **meta,
            "spans": span_to_dict(root),
        }
        with self._lock:
            if len(self._entries) == self.capacity:
                self.dropped += 1
            self._entries.append(entry)
        if self.path is not None:
            line = json.dumps(entry)
            with self._lock:
                with open(self.path, "a") as f:
                    f.write(line + "\n")
        return entry

    def entries(self) -> List[Dict[str, object]]:
        with self._lock:
            return list(self._entries)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)
