"""Per-request span tracing (DESIGN.md §11).

A ``Span`` is one timed interval with nested children; a ``Tracer``
holds completed *root* spans in a bounded ring buffer (old requests
fall off — a long-lived server's trace memory is O(capacity), never
O(requests served)).

The instrumentation contract is built for the scheduler's threading
model:

  * the scheduler opens one root span per request at submit time and
    one shared *batch* span when a coalesced group executes — every
    request root of the group links the same batch node (the work was
    genuinely shared; the export de-duplicates it);
  * the executing thread *attaches* the batch span to a thread-local
    slot (``attach``), and every instrumentation point deeper in the
    stack (``core.segments``' rung dispatches, ``core.column_store``'s
    tier staging, the re-rank pass) calls the module-level ``span()``
    helper, which nests under whatever is attached — no signature
    threading through the query path;
  * with nothing attached, ``span()`` returns a shared no-op context
    manager after ONE thread-local read — the disabled cost is a dict
    build and a ``getattr``, and no device work ever happens either way
    (spans are host-side wall-clock timers only; the zero-dispatch
    invariant is spy-tested in ``tests/test_obs.py``).

Export is Chrome trace-event JSON (``chrome_trace`` /
``Tracer.write_chrome``): "X" complete events in microseconds, one
``tid`` per track, loadable in Perfetto / chrome://tracing.
``tools/trace_report.py`` validates and summarizes these files.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Dict, List, Optional

__all__ = ["Span", "Tracer", "attach", "chrome_trace", "current", "span",
           "span_to_dict", "write_chrome"]

_TLS = threading.local()


class Span:
    """One timed interval: ``ts``/``dur`` are ``time.perf_counter``
    seconds, ``args`` free-form labels, ``children`` nested spans.
    ``track`` names the export lane ("worker-..." for executor threads);
    None inherits the parent's lane (roots get a fresh request lane)."""

    __slots__ = ("name", "cat", "ts", "dur", "args", "children", "track")

    def __init__(self, name: str, cat: str = "span",
                 ts: Optional[float] = None, dur: float = 0.0,
                 track: Optional[str] = None,
                 args: Optional[dict] = None):
        self.name = name
        self.cat = cat
        self.ts = time.perf_counter() if ts is None else ts
        self.dur = dur
        self.track = track
        self.args = {} if args is None else args
        self.children: List["Span"] = []

    def child(self, name: str, cat: str = "span", **args) -> "Span":
        sp = Span(name, cat=cat, args=args)
        self.children.append(sp)
        return sp

    def find(self, name: str) -> Optional["Span"]:
        """First descendant (pre-order) with this name, else None."""
        for ch in self.children:
            if ch.name == name:
                return ch
            hit = ch.find(name)
            if hit is not None:
                return hit
        return None

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, dur={self.dur * 1e3:.3f}ms, "
                f"children={len(self.children)})")


def span_to_dict(sp: Span) -> dict:
    """Recursive JSON-ready form (the slow-query log's record body).
    Times are milliseconds relative to the process clock."""
    return {"name": sp.name, "cat": sp.cat,
            "ts_ms": round(sp.ts * 1e3, 3),
            "dur_ms": round(sp.dur * 1e3, 3),
            "args": dict(sp.args),
            "children": [span_to_dict(c) for c in sp.children]}


# -- thread-local context ------------------------------------------------

def current() -> Optional[Span]:
    """The span new ``span()`` calls nest under on this thread."""
    return getattr(_TLS, "cur", None)


class _NullCtx:
    """Shared do-nothing context manager — the disabled fast path."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL = _NullCtx()


class _SpanCtx:
    __slots__ = ("parent", "sp")

    def __init__(self, parent: Span, name: str, cat: str, args: dict):
        self.parent = parent
        self.sp = Span(name, cat=cat, args=args)

    def __enter__(self) -> Span:
        self.parent.children.append(self.sp)
        _TLS.cur = self.sp
        return self.sp

    def __exit__(self, *exc):
        self.sp.dur = time.perf_counter() - self.sp.ts
        _TLS.cur = self.parent
        return False


def span(name: str, cat: str = "span", **args):
    """Open a child span under the thread's attached context.  With no
    context attached this is a shared no-op — instrumentation points in
    the query path call it unconditionally."""
    parent = getattr(_TLS, "cur", None)
    if parent is None:
        return _NULL
    return _SpanCtx(parent, name, cat, args)


class _AttachCtx:
    __slots__ = ("root", "prev")

    def __init__(self, root: Optional[Span]):
        self.root = root
        self.prev = None

    def __enter__(self):
        self.prev = getattr(_TLS, "cur", None)
        _TLS.cur = self.root
        return self.root

    def __exit__(self, *exc):
        _TLS.cur = self.prev
        return False


def attach(root: Optional[Span]) -> _AttachCtx:
    """Make ``root`` the thread's current span for the duration (the
    scheduler attaches the batch span around execution; ``None``
    detaches — a no-op region)."""
    return _AttachCtx(root)


# -- ring buffer ---------------------------------------------------------

class Tracer:
    """Bounded ring of completed request trees.  ``add()`` is called by
    the scheduler once per finished request with its root span; when
    more than ``capacity`` roots accumulate the oldest fall off."""

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._roots: List[Span] = []

    def add(self, root: Span) -> None:
        with self._lock:
            self._roots.append(root)
            if len(self._roots) > self.capacity:
                del self._roots[: len(self._roots) - self.capacity]

    def roots(self) -> List[Span]:
        with self._lock:
            return list(self._roots)

    def clear(self) -> None:
        with self._lock:
            self._roots.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._roots)

    def chrome_events(self) -> List[dict]:
        return chrome_trace(self.roots())

    def write_chrome(self, path: str) -> str:
        """Dump the ring as one Chrome trace-event JSON file (a plain
        event array — Perfetto and chrome://tracing load it directly)."""
        return write_chrome(self.roots(), path)


# -- Chrome trace-event export -------------------------------------------

def chrome_trace(roots: List[Span]) -> List[dict]:
    """Flatten span trees into Chrome trace events ("X" complete events,
    microsecond ts/dur).  Tracks map to tids; spans without a track
    inherit the enclosing lane, and each root without one gets a fresh
    request lane (overlapping requests must not share a tid — a tid is a
    stack in the trace model).  Shared nodes (one batch span linked from
    several request roots) emit once, on their own track."""
    events: List[dict] = []
    tids: Dict[str, int] = {}
    seen: set = set()

    def tid_of(track: str) -> int:
        tid = tids.get(track)
        if tid is None:
            tid = tids[track] = len(tids) + 1
            events.append({"name": "thread_name", "ph": "M", "pid": 0,
                           "tid": tid, "args": {"name": track}})
        return tid

    def emit(sp: Span, lane: str) -> None:
        if id(sp) in seen:
            return
        seen.add(id(sp))
        lane = sp.track if sp.track is not None else lane
        events.append({
            "name": sp.name, "cat": sp.cat, "ph": "X",
            "ts": round(sp.ts * 1e6, 3),
            "dur": round(sp.dur * 1e6, 3),
            "pid": 0, "tid": tid_of(lane),
            "args": dict(sp.args),
        })
        for ch in sp.children:
            emit(ch, lane)

    for i, root in enumerate(roots):
        emit(root, root.track if root.track is not None else f"request-{i}")
    return events


def write_chrome(roots: List[Span], path: str) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(roots), f)
    return path
