"""Data pipeline with bST near-duplicate filtering — the paper's flagship
application (web-scale near-dup detection) wired into training.

Determinism contract: ``batch_for_step(step)`` is a pure function of
(config, step).  That is the straggler/elasticity story — any worker (or
a replacement for a failed one) regenerates any step's shard with no
coordination, and a restarted run replays bit-identically.

Dedup flow per step (when enabled):
  1. generate ``oversample x batch`` candidate documents; a configurable
     fraction are *near-duplicates* (token-perturbed copies) — synthetic
     stand-ins for the web-crawl duplicates of the paper's Review set;
  2. b-bit-minhash each document (``core.sketch.sketch_tokens``);
  3. reject candidates within Hamming ``tau`` of (a) an already-accepted
     candidate in this batch (pairwise vertical-format kernel) or (b) the
     persistent history index — a bST over every sketch accepted so far,
     rebuilt on a doubling schedule (LSM-style amortization);
  4. take the first ``batch`` survivors (padding deterministically with
     rejected docs if over-aggressive).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bst import build_bst
from ..core.hamming import hamming_pairwise_naive
from ..core.search import make_batch_searcher
from ..core.sketch import sketch_tokens


@dataclasses.dataclass
class DataConfig:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    dedup: bool = False
    oversample: int = 2
    dup_frac: float = 0.25       # injected near-duplicate rate
    dedup_L: int = 16
    dedup_b: int = 2
    dedup_tau: int = 2
    embeds_dim: int = 0          # >0: frontend-stub pipeline (hubert)
    rebuild_factor: float = 2.0  # rebuild history bST when 2x larger


class SketchDedupPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._sketch_key = jax.random.PRNGKey(cfg.seed ^ 0x5E7C)
        self._history: Optional[np.ndarray] = None     # accepted sketches
        self._index = None
        self._index_size = 0
        self.stats = {"candidates": 0, "rejected_in_batch": 0,
                      "rejected_history": 0}

    # -- candidate generation (pure in (cfg, step)) -----------------------
    def _candidates(self, step: int) -> np.ndarray:
        cfg = self.cfg
        n = cfg.batch * (cfg.oversample if cfg.dedup else 1)
        rng = np.random.default_rng((cfg.seed, step))
        toks = rng.integers(0, cfg.vocab, size=(n, cfg.seq + 1), dtype=np.int64)
        if cfg.dedup and cfg.dup_frac > 0:
            n_dup = int(n * cfg.dup_frac)
            src = rng.integers(0, n - n_dup, size=n_dup)
            for i, s in enumerate(src):
                row = toks[s].copy()
                # perturb ~2% of positions — a near (not exact) duplicate
                flip = rng.random(cfg.seq + 1) < 0.02
                row[flip] = rng.integers(0, cfg.vocab, size=flip.sum())
                toks[n - n_dup + i] = row
            perm = rng.permutation(n)
            toks = toks[perm]
        return toks

    # -- dedup -------------------------------------------------------------
    def _dedup_mask(self, sketches: np.ndarray) -> np.ndarray:
        """Greedy accept mask: True = keep."""
        cfg = self.cfg
        n = sketches.shape[0]
        keep = np.ones(n, bool)

        # (a) vs history bST
        if self._index is not None:
            searcher = make_batch_searcher(self._index, cfg.dedup_tau)
            res = searcher(jnp.asarray(sketches))
            dup_hist = np.asarray(res.mask).any(axis=1)
            self.stats["rejected_history"] += int(dup_hist.sum())
            keep &= ~dup_hist

        # (b) in-batch greedy: reject anything within tau of an earlier kept
        dists = np.asarray(hamming_pairwise_naive(
            jnp.asarray(sketches), jnp.asarray(sketches)))
        close = dists <= cfg.dedup_tau
        for i in range(n):
            if not keep[i]:
                continue
            later = close[i, i + 1:]
            dropped = later & keep[i + 1:]
            self.stats["rejected_in_batch"] += int(dropped.sum())
            keep[i + 1:] &= ~later
        return keep

    def _update_history(self, accepted: np.ndarray) -> None:
        if self._history is None:
            self._history = accepted.copy()
        else:
            self._history = np.concatenate([self._history, accepted])
        if (self._index is None
                or len(self._history) >= self.cfg.rebuild_factor
                * max(self._index_size, 1)):
            self._index = build_bst(self._history, self.cfg.dedup_b)
            self._index_size = len(self._history)

    # -- public ------------------------------------------------------------
    def batch_for_step(self, step: int) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        if cfg.embeds_dim:
            rng = np.random.default_rng((cfg.seed, step))
            return {
                "embeds": jnp.asarray(rng.standard_normal(
                    (cfg.batch, cfg.seq, cfg.embeds_dim), dtype=np.float32)),
                "targets": jnp.asarray(rng.integers(
                    0, cfg.vocab, (cfg.batch, cfg.seq)), jnp.int32),
            }
        toks = self._candidates(step)
        if cfg.dedup:
            sk = np.asarray(sketch_tokens(
                self._sketch_key, jnp.asarray(toks[:, :-1], jnp.int32),
                L=cfg.dedup_L, b=cfg.dedup_b))
            self.stats["candidates"] += len(toks)
            keep = self._dedup_mask(sk)
            order = np.concatenate([np.flatnonzero(keep),
                                    np.flatnonzero(~keep)])
            chosen = order[:cfg.batch]
            self._update_history(sk[chosen[keep[chosen]]]
                                 if keep[chosen].any() else sk[chosen[:1]])
            toks = toks[chosen]
        else:
            toks = toks[:cfg.batch]
        return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                "targets": jnp.asarray(toks[:, 1:], jnp.int32)}
