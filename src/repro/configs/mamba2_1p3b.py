"""mamba2-1.3b [arXiv:2405.21060; unverified]: 48 attention-free SSD
blocks, d_model 2048 (d_inner 4096, 64 ssm-heads of dim 64),
ssm_state 128, vocab 50280."""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    vocab=50280,
    d_ff=0,
    ssm=True,
    d_state=128,
    ssm_head_dim=64,
    expand=2,
    chunk=256,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, vocab=256, d_state=16,
    ssm_head_dim=16, chunk=8)
