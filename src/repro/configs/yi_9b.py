"""yi-9b [arXiv:2403.04652; hf]: 48L, d_model 4096, 32 heads (GQA kv=4,
head_dim 128), d_ff 11008, vocab 64000 — llama-arch GQA, untied."""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    vocab=64000,
    n_heads=32,
    n_kv=4,
    head_dim=128,
    d_ff=11008,
    rope_theta=5_000_000.0,
    tie_embeddings=False,
    decode_kv_shard="seq",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, vocab=256, n_heads=4, n_kv=1,
    head_dim=16, d_ff=128)
