"""Architecture registry: ``--arch <id>`` resolution, smoke variants,
per-arch valid shape cells, and the paper's own sketch-dataset configs."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from ..models.config import SHAPES, ModelConfig, ShapeConfig
from . import (chameleon_34b, command_r_35b, deepseek_moe_16b, gemma2_27b,
               granite_moe_3b, hubert_xlarge, mamba2_1p3b, smollm_135m,
               yi_9b, zamba2_2p7b)

_MODULES = {
    "gemma2-27b": gemma2_27b,
    "command-r-35b": command_r_35b,
    "smollm-135m": smollm_135m,
    "yi-9b": yi_9b,
    "granite-moe-3b-a800m": granite_moe_3b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "hubert-xlarge": hubert_xlarge,
    "chameleon-34b": chameleon_34b,
    "zamba2-2.7b": zamba2_2p7b,
    "mamba2-1.3b": mamba2_1p3b,
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


def get_config(arch: str, *, smoke: bool = False,
               pad_for_mesh: bool = False, model_axis: int = 16) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    cfg = _MODULES[arch].SMOKE if smoke else _MODULES[arch].CONFIG
    if pad_for_mesh:
        cfg = cfg.padded(model_axis)
    return cfg


def valid_shapes(arch: str) -> List[str]:
    """The assigned shape grid minus principled skips (DESIGN.md §4):
    encoder-only archs have no decode step; ``long_500k`` requires
    sub-quadratic context (SSM/hybrid only)."""
    cfg = get_config(arch)
    shapes = ["train_4k", "prefill_32k"]
    if cfg.causal and not cfg.inputs_embeds:
        shapes.append("decode_32k")
    if cfg.ssm:
        shapes.append("long_500k")
    return shapes


def all_cells() -> List[Tuple[str, str]]:
    """Every runnable (arch, shape) dry-run cell."""
    return [(a, s) for a in ARCH_IDS for s in valid_shapes(a)]


def skipped_cells() -> List[Tuple[str, str, str]]:
    """(arch, shape, reason) for each principled skip — reported, not lost."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        valid = set(valid_shapes(a))
        for s in SHAPES:
            if s in valid:
                continue
            if s in ("decode_32k", "long_500k") and (not cfg.causal
                                                     or cfg.inputs_embeds):
                out.append((a, s, "encoder-only: no autoregressive decode"))
            elif s == "long_500k":
                out.append((a, s, "full quadratic attention at 524k context"))
    return out


# ---------------------------------------------------------------------------
# the paper's own experimental configs (Table I)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SketchDatasetConfig:
    name: str
    n: int              # database size in the paper
    hashing: str        # "bbit_minhash" | "zbit_cws"
    L: int
    b: int
    lm: int             # paper's dense-layer top level (ℓ_m)
    ls: int             # paper's sparse-layer start (ℓ_s)


PAPER_DATASETS: Dict[str, SketchDatasetConfig] = {
    "review": SketchDatasetConfig("review", 12_886_488, "bbit_minhash", 16, 2, 8, 11),
    "cp": SketchDatasetConfig("cp", 216_121_626, "bbit_minhash", 32, 2, 9, 14),
    "sift": SketchDatasetConfig("sift", 1_000_000_000, "zbit_cws", 32, 4, 0, 21),
    "gist": SketchDatasetConfig("gist", 79_302_017, "zbit_cws", 64, 8, 0, 49),
}
