"""chameleon-34b [arXiv:2405.09818; unverified]: 48L, d_model 8192,
64 heads (GQA kv=8, head_dim 128), d_ff 22016, vocab 65536 — early
fusion: text tokens and VQ image codes share one vocabulary, so the
backbone input is a plain int32 token stream.

Frontend stub (per assignment): the VQ-VAE image tokenizer is NOT
implemented — ``input_specs`` supplies token ids directly (interleaved
text + image codes).  Note the pleasing inverse connection to the paper:
VQ codes ARE integer sketches, so bST dedup applies to raw image-token
streams with no extra hashing (DESIGN.md §4).  The released model's
qk-norm is replaced by the framework's standard pre-norm block.
"""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    vocab=65536,
    n_heads=64,
    n_kv=8,
    head_dim=128,
    d_ff=22016,
    rope_theta=10000.0,
    tie_embeddings=False,
    decode_kv_shard="seq",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, vocab=256, n_heads=4, n_kv=2,
    head_dim=16, d_ff=128)
