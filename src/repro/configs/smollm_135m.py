"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M; hf]: 30L, d_model 576,
9 heads (GQA kv=3, head_dim 64), d_ff 1536, vocab 49152 — llama-style
small model.  This is also the ~100M end-to-end training example."""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    vocab=49152,
    n_heads=9,
    n_kv=3,
    head_dim=64,
    d_ff=1536,
    rope_theta=10000.0,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=48, vocab=256, n_heads=3, n_kv=1,
    head_dim=16, d_ff=96)
