"""hubert-xlarge [arXiv:2106.07447; unverified]: 48L encoder-only,
d_model 1280, 16 heads (kv=16, head_dim 80), d_ff 5120, vocab 504
(masked-prediction cluster targets).

Frontend stub (per assignment): the conv waveform feature extractor is
NOT implemented — ``input_specs`` supplies precomputed (B, S, d_model)
frame embeddings.  Encoder-only => bidirectional attention, no decode
shapes.  RoPE stands in for the conv positional embedding (DESIGN.md).
"""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    vocab=504,
    n_heads=16,
    n_kv=16,
    head_dim=80,
    d_ff=5120,
    causal=False,
    inputs_embeds=True,
    tie_embeddings=False,
    act="gelu",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, vocab=64, n_heads=4, n_kv=4,
    head_dim=16, d_ff=128)
