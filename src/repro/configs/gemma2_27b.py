"""gemma2-27b [arXiv:2408.00118; hf]: 46L, d_model 4608, 32 heads
(GQA kv=16, head_dim 128), d_ff 36864, vocab 256000 — local(4096)/global
alternating attention, attn logit softcap 50, final softcap 30, extra
post-sublayer norms, sqrt(d)-scaled embeddings."""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    vocab=256000,
    n_heads=32,
    n_kv=16,
    head_dim=128,
    d_ff=36864,
    period=2,
    attn_kinds=("local", "global"),
    window=4096,
    softcap_attn=50.0,
    softcap_final=30.0,
    rope_theta=10000.0,
    post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    act="gelu",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=4, d_model=64, vocab=256, n_heads=4, n_kv=2,
    head_dim=16, d_ff=128, window=8)
