"""command-r-35b [hf:CohereForAI/c4ai-command-r-v01; unverified]: 40L,
d_model 8192, 64 heads (GQA kv=8, head_dim 128), d_ff 22528,
vocab 256000 — no biases, tied embeddings, rope theta 8e6.

(The real model uses parallel attention+MLP blocks and layernorm; we use
the framework's standard pre-norm sequential block — noted in DESIGN.md.)
"""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    vocab=256000,
    n_heads=64,
    n_kv=8,
    head_dim=128,
    d_ff=22528,
    rope_theta=8_000_000.0,
    tie_embeddings=True,
    decode_kv_shard="seq",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, vocab=256, n_heads=4, n_kv=2,
    head_dim=16, d_ff=128)
