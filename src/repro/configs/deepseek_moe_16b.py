"""deepseek-moe-16b [arXiv:2401.06066; hf]: 28L, d_model 2048, 16 heads
(kv=16 — MHA, head_dim 128), vocab 102400, fine-grained MoE: 2 shared +
64 routed experts, top-6, expert d_ff 1408.

Simplification (DESIGN.md): the released model's layer 0 is a dense MLP
(d_ff 10944); we use a uniform MoE stack so the layer scan stays
homogeneous — parameter count differs by <1%.
"""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    vocab=102400,
    n_heads=16,
    n_kv=16,
    head_dim=128,
    d_ff=0,
    n_experts=64,
    top_k=6,
    n_shared=2,
    moe_d_ff=1408,
    rope_theta=10000.0,
    tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, vocab=256, n_heads=4, n_kv=4,
    head_dim=16, n_experts=8, top_k=2, n_shared=1, moe_d_ff=32,
    capacity_factor=4.0)
