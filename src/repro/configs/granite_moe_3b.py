"""granite-moe-3b-a800m [hf:ibm-granite; hf]: 32L, d_model 1536,
24 heads (GQA kv=8, head_dim 64), vocab 49155, fine-grained MoE:
40 experts, top-8, expert d_ff 512 (per assignment)."""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    vocab=49155,
    n_heads=24,
    n_kv=8,
    head_dim=64,
    d_ff=0,
    n_experts=40,
    top_k=8,
    n_shared=0,
    moe_d_ff=512,
    rope_theta=10000.0,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, vocab=256, n_heads=4, n_kv=2,
    head_dim=16, n_experts=8, top_k=2, moe_d_ff=32, capacity_factor=4.0)
