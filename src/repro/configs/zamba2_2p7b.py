"""zamba2-2.7b [arXiv:2411.15242; hf]: 54 Mamba2 blocks, d_model 2560,
ssm_state 64, plus a SHARED attention block (32 heads, kv=32, head_dim
80, d_ff 10240) invoked every 6 mamba layers — same parameters each
invocation (9 invocations total).

Simplification (DESIGN.md): the released model concatenates the shared
block's input with the original embedding and applies per-invocation
LoRA deltas; we use a standard residual shared block — the
memory/communication shape (shared params, 9 KV caches) is preserved.
"""

import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    vocab=32000,
    n_heads=32,
    n_kv=32,
    head_dim=80,
    d_ff=10240,
    ssm=True,
    d_state=64,
    ssm_head_dim=64,
    expand=2,
    chunk=256,
    period=6,
    shared_attn_every=6,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=4, d_model=64, vocab=256, n_heads=4, n_kv=4,
    head_dim=16, d_ff=128, d_state=16, ssm_head_dim=16, chunk=8,
    period=2, shared_attn_every=2)
