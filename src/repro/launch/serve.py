"""Serving driver: batched autoregressive generation, plus the paper's
sketch-retrieval plane.

``python -m repro.launch.serve --arch smollm-135m --smoke`` — prefill a
batch of prompts and decode N tokens (greedy), reporting tokens/s.

``--retrieval`` additionally demonstrates the paper's technique as a
serving feature: the final hidden states of completed requests are
0-bit-CWS-sketched and submitted as *individual* top-k requests to the
serving scheduler (``repro.serving``), which coalesces them into one
shape-bucketed dispatch — the RAG lookup step running through the real
runtime rather than a raw searcher call.

``--ingest`` serves the *dynamic* retrieval plane (DESIGN.md §4 + §5):
a scheduler-fronted collection absorbs streaming document inserts and
deletes while answering top-k queries mid-stream — bounded queues,
micro-batched reads, writes interleaved re-jit-free — and ends with the
``/stats``-style metrics dump.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import ARCH_IDS, get_config
from ..core.hamming import pack_sets
from ..core.sketch import zbit_cws
from ..kernels.hamming_kernel import DEFAULT_BLOCK_M
from ..distributed.sharding import use_mesh
from ..launch.mesh import make_host_mesh
from ..models import model as M
from ..obs import SlowQueryLog, Tracer
from ..serving import (AdmissionConfig, BreakerConfig, CollectionConfig,
                       CollectionRegistry, DegradePolicy, Scheduler,
                       SchedulerConfig)
from ..train.steps import make_decode_step, make_prefill_step


# ---------------------------------------------------------------------------
# serving-runtime helpers (shared by --ingest and --retrieval)
# ---------------------------------------------------------------------------

def make_scheduler(args, L: int, b: int, name: str = "docs") -> Scheduler:
    """One scheduler fronting one collection with the CLI's knobs.

    ``--data-dir`` makes the collection durable (segment snapshots + WAL,
    DESIGN.md §8); ``--recover`` additionally rebuilds whatever that
    directory already holds before serving."""
    registry = None
    data_dir = getattr(args, "data_dir", None)
    if data_dir:
        if getattr(args, "recover", False):
            registry = CollectionRegistry.open(data_dir)
        else:
            registry = CollectionRegistry(data_dir=data_dir)
    tracer = slowlog = None
    trace_dir = getattr(args, "trace_dir", None)
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
        tracer = Tracer()
        slowlog = SlowQueryLog(
            path=os.path.join(trace_dir, "slow_queries.jsonl"))
    # overload control plane (DESIGN.md §12): --degrade-policy standard
    # turns on cost-budget admission + the degradation ladder;
    # --breaker adds the per-collection circuit breaker
    degrade_policy = getattr(args, "degrade_policy", "off")
    admission = degrade = None
    if degrade_policy and degrade_policy != "off":
        admission = AdmissionConfig()
        degrade = DegradePolicy()
    breaker = BreakerConfig() if getattr(args, "breaker", False) else None
    sched = Scheduler(registry=registry, config=SchedulerConfig(
        max_batch=args.max_batch, max_queue=args.max_queue,
        max_wait_ms=args.max_wait_ms,
        slow_ms=getattr(args, "slow_ms", None),
        admission=admission, degrade=degrade, breaker=breaker,
        default_deadline_ms=getattr(args, "deadline_ms", None)),
        tracer=tracer, slowlog=slowlog)
    if registry is None or name not in registry.names():
        # --rerank provisions the exact re-rank plane (DESIGN.md §10):
        # the collection stores per-row token-set bitmaps alongside the
        # sketch columns
        payload_words = ((args.vocab + 31) // 32
                         if getattr(args, "rerank", None) else None)
        sched.create_collection(name, CollectionConfig(
            L=L, b=b, delta_cap=args.delta_cap,
            block_m=args.block_m or DEFAULT_BLOCK_M,
            payload_words=payload_words))
    return sched


def dump_trace(sched: Scheduler, args) -> None:
    """--trace-dir epilogue: write the Chrome trace-event JSON
    (``tools/trace_report.py`` / Perfetto consume it) and note the
    slow-query log."""
    trace_dir = getattr(args, "trace_dir", None)
    if not trace_dir or sched.tracer is None:
        return
    path = sched.tracer.write_chrome(os.path.join(trace_dir, "trace.json"))
    print(f"wrote {len(sched.tracer)} request traces to {path}")
    if sched.slowlog is not None and len(sched.slowlog):
        print(f"  {len(sched.slowlog)} slow requests "
              f"(>= {args.slow_ms} ms) in {sched.slowlog.path}")


def run_ingest(args) -> int:
    """--ingest mode: stream synthetic document sketches through the
    scheduler's insert/delete surface and serve top-k queries mid-stream,
    ending with the /stats metrics dump."""
    L, b = 32, 4
    rng = np.random.default_rng(args.seed)
    n = args.index_size
    docs = rng.integers(0, 1 << b, size=(n, L), dtype=np.uint8)
    pays = None
    if args.rerank:
        # synthetic token sets behind the sketches — the exact stage's
        # source of truth
        sets = [rng.choice(args.vocab, size=int(rng.integers(4, 24)),
                           replace=False) for _ in range(n)]
        pays = pack_sets(sets, args.vocab)
    sched = make_scheduler(args, L, b).start()
    coll = sched.registry.get("docs")
    index = coll.index

    if getattr(args, "recover", False) and coll.store is not None \
            and index.n_live:
        # recovered a previous --data-dir run (possibly killed mid-
        # stream): report what came back and serve queries against it
        st = coll.stats()                # index stats + the "store" block
        sst = st["store"]
        print(f"recovered 'docs' from {args.data_dir}: {st['n_live']} "
              f"live docs, {st['n_segments']} segments + "
              f"{st['delta_rows']} delta rows "
              f"({sst['recovered_segments']} segment snapshots, "
              f"{sst['replayed_records']} WAL records replayed)")
        qs = docs[rng.integers(0, max(index.n_ids, 1), args.batch)]
        futs = [sched.submit_topk("docs", q, args.topk) for q in qs]
        nn = [f.result() for f in futs]
        for r in range(min(args.batch, 4)):
            print(f"  request {r}: top-{args.topk} docs {nn[r].ids} "
                  f"at distances {nn[r].dists} (tau*={nn[r].tau})")
        sched.stop()
        sched.registry.close()
        dump_trace(sched, args)
        print("--- /stats ---")
        print(sched.render_stats())
        return 0

    chunk = max(64, n // 16)
    t0 = time.time()
    id_futs = []
    for lo in range(0, n, chunk):
        id_futs.append(sched.submit_insert(
            "docs", docs[lo:lo + chunk],
            payloads=pays[lo:lo + chunk] if pays is not None else None))
        if lo == chunk * 4:   # mid-stream query traffic, coalesced by the
            # scheduler into shape-bucketed dispatches between inserts
            futs = [sched.submit_topk("docs", q, args.topk)
                    for q in docs[rng.integers(0, lo, args.batch)]]
            nn = [f.result() for f in futs]
            st = index.stats()
            print(f"mid-stream topk over {st['n_live']} live docs "
                  f"({st['n_segments']} segments + {st['delta_rows']} "
                  f"delta rows): tau*={nn[0].tau}")
    ids = np.concatenate([f.result() for f in id_futs])
    dt = time.time() - t0
    print(f"ingested {n} docs in {dt:.2f}s ({n / dt:.0f} inserts/s, "
          f"{index.counters['merges']} background merges)")

    removed = sched.submit_delete(
        "docs", ids[rng.choice(n, n // 8, replace=False)]).result()
    index.flush()
    index.maybe_merge()
    index.compact(min_dead_frac=0.25)
    st = index.stats()
    print(f"deleted {removed}; stack now {st['segments']} "
          f"(space {st['space_bits'] / 8 / 1024:.1f} KiB incl. tombstones, "
          f"{st['tombstones']} tombstones held)")

    if getattr(args, "warmup", False):
        w = sched.warmup(ks=(args.topk,), taus=(args.tau,))
        print(f"warmup: {w['calls']} calls over {w['buckets']} shape "
              f"buckets absorbed {w['traces']} fresh compiles")

    rows = rng.integers(0, n, args.batch)
    qs = docs[rows]
    t0 = time.time()
    if args.rerank:
        futs = [sched.submit_topk("docs", q, args.topk, rerank=args.rerank,
                                  q_payload=pays[row])
                for q, row in zip(qs, rows)]
    else:
        futs = [sched.submit_topk("docs", q, args.topk) for q in qs]
    nn = [f.result() for f in futs]
    dt = time.time() - t0
    for r in range(min(args.batch, 4)):
        extra = (f", {args.rerank} scores "
                 f"{np.round(np.asarray(nn[r].scores), 3)}"
                 if nn[r].scores is not None else "")
        print(f"  request {r}: top-{args.topk} docs {nn[r].ids} "
              f"at distances {nn[r].dists} (tau*={nn[r].tau}{extra})")
    print(f"post-merge scheduled topk: {dt / args.batch * 1e3:.1f} "
          f"ms/query (batch-fill "
          f"{sched.metrics.batch_fill_ratio():.2f})")
    sched.stop()
    sched.registry.close()              # sync durable stores (--data-dir)
    dump_trace(sched, args)
    print("--- /stats ---")
    print(sched.render_stats())
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--retrieval", action="store_true")
    ap.add_argument("--ingest", action="store_true",
                    help="streaming-ingest retrieval plane: scheduler-"
                         "fronted dynamic segmented index (model-free; "
                         "see DESIGN.md §4-§5)")
    ap.add_argument("--delta-cap", type=int, default=2048,
                    help="delta-buffer rows before a segment seals "
                         "(--ingest)")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="most queries the scheduler coalesces into one "
                         "read dispatch")
    ap.add_argument("--max-queue", type=int, default=1024,
                    help="per-collection queue bound (overload rejects)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="partial-batch flush deadline")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="default end-to-end latency budget per request; "
                         "requests expiring in queue fail with "
                         "DeadlineExceeded before any dispatch "
                         "(DESIGN.md §12)")
    ap.add_argument("--degrade-policy", default="off",
                    choices=["off", "standard"],
                    help="overload control plane: 'standard' enables "
                         "cost-budget admission + the graceful-"
                         "degradation ladder (rerank_off -> shrink_k -> "
                         "cheap_tau -> reject)")
    ap.add_argument("--breaker", action="store_true",
                    help="per-collection circuit breaker over deadline "
                         "outcomes (open/half-open probing)")
    ap.add_argument("--warmup", action="store_true",
                    help="pre-jit every power-of-two shape bucket after "
                         "ingest so first-request compiles never pollute "
                         "serving p99")
    ap.add_argument("--index-size", type=int, default=4096)
    ap.add_argument("--tau", type=int, default=3)
    ap.add_argument("--topk", type=int, default=3,
                    help="k nearest documents returned per request")
    ap.add_argument("--rerank", default=None,
                    choices=["jaccard", "cosine", "containment"],
                    help="--ingest: store token-set payload bitmaps and "
                         "serve the final query round through the exact "
                         "two-stage rerank= contract (DESIGN.md §10)")
    ap.add_argument("--vocab", type=int, default=256,
                    help="token vocabulary of the synthetic payload sets "
                         "(--ingest --rerank)")
    ap.add_argument("--block-m", type=int, default=None,
                    help="query-tile size of the batched verify kernel "
                         "(default: kernel DEFAULT_BLOCK_M)")
    ap.add_argument("--data-dir", default=None,
                    help="durable collection root: segment snapshots + "
                         "delta-buffer WAL (DESIGN.md §8)")
    ap.add_argument("--recover", action="store_true",
                    help="with --data-dir: rebuild collections persisted "
                         "there (manifest segments + WAL replay) before "
                         "serving")
    ap.add_argument("--trace-dir", default=None,
                    help="record per-request span traces and write them "
                         "here: trace.json (Chrome trace-event JSON — "
                         "Perfetto / chrome://tracing / tools/"
                         "trace_report.py) plus slow_queries.jsonl")
    ap.add_argument("--slow-ms", type=float, default=None,
                    help="slow-query threshold (end-to-end ms): requests "
                         "at or above it dump their span tree to the "
                         "slow-query log")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.ingest:
        return run_ingest(args)

    cfg = get_config(args.arch, smoke=args.smoke)
    if not cfg.causal or cfg.inputs_embeds:
        print(f"{args.arch} is encoder-only: no autoregressive serving "
              "(see DESIGN.md §Arch-applicability)")
        return 0
    mesh = make_host_mesh()
    dtype = jnp.float32 if jax.default_backend() == "cpu" else jnp.bfloat16

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    s_max = args.prompt_len + args.gen_len

    with use_mesh(mesh):
        params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
        prefill = jax.jit(make_prefill_step(cfg, s_max=s_max,
                                            compute_dtype=dtype))
        decode = jax.jit(make_decode_step(cfg, compute_dtype=dtype))

        t0 = time.time()
        logits, cache, cache_len = prefill(params, {"tokens": prompts})
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        generated = [tok]
        for i in range(args.gen_len - 1):
            logits, cache = decode(params, tok, cache, cache_len + i)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            generated.append(tok)
        out = jnp.concatenate(generated, axis=1)
        out.block_until_ready()
        dt = time.time() - t0
        total_tokens = args.batch * args.gen_len
        print(f"served {args.batch} requests x {args.gen_len} tokens "
              f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s incl. compile)")
        print("sample continuation ids:", np.asarray(out[0][:12]))

        if args.retrieval:
            # the paper's technique as the retrieval plane: hidden-state
            # sketches -> scheduler-fronted bST Hamming search.  Each
            # completed request submits its own top-k lookup; the
            # scheduler coalesces them into one shape-bucketed dispatch.
            L, b = 32, 4
            key = jax.random.PRNGKey(7)
            docs = rng.random((args.index_size, 64)).astype(np.float32)
            doc_sk = np.asarray(zbit_cws(key, jnp.asarray(docs), L=L, b=b))
            sched = make_scheduler(args, L, b)
            sched.submit_insert("docs", doc_sk)
            # query: final hidden state of each request, hashed the same way
            h = jax.nn.softmax(logits, axis=-1) @ params[
                "embed" if "embed" in params else "lm_head"].astype(jnp.float32)
            q = jnp.abs(h[:, :64]) if h.shape[-1] >= 64 else jnp.pad(
                jnp.abs(h), ((0, 0), (0, 64 - h.shape[-1])))
            q_sk = np.asarray(zbit_cws(key, q, L=L, b=b))
            range_futs = [sched.submit_search("docs", qr, args.tau)
                          for qr in q_sk]
            topk_futs = [sched.submit_topk("docs", qr, args.topk)
                         for qr in q_sk]
            sched.pump()     # synchronous drive on the serving thread
            hits = np.array([f.result().mask.sum() for f in range_futs])
            print(f"retrieval: tau={args.tau} hits per request: {hits} "
                  f"(scheduler batch-fill "
                  f"{sched.metrics.batch_fill_ratio():.2f})")
            for r, f in enumerate(topk_futs):
                nn = f.result()
                print(f"  request {r}: top-{args.topk} docs {nn.ids} "
                      f"at distances {nn.dists} (tau*={nn.tau})")
            dump_trace(sched, args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
