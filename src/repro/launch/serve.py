"""Serving driver: batched autoregressive generation, plus the paper's
sketch-retrieval plane.

``python -m repro.launch.serve --arch smollm-135m --smoke`` — prefill a
batch of prompts and decode N tokens (greedy), reporting tokens/s.

``--retrieval`` additionally demonstrates the paper's technique as a
serving feature: the final hidden states of completed requests are
0-bit-CWS-sketched and queried against a bST index of (synthetic)
document sketches — batched Hamming-threshold retrieval as the RAG
lookup step.

``--ingest`` serves the *dynamic* retrieval plane (DESIGN.md §4): a
segmented index absorbs streaming document inserts and deletes through
the ``ingest_insert`` / ``ingest_delete`` endpoints while answering
top-k queries mid-stream — no model required, no rebuild, no blocked
search.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import ARCH_IDS, get_config
from ..core.bst import build_bst
from ..core.search import make_batch_searcher, topk_batch
from ..core.segments import SegmentedIndex
from ..core.sketch import zbit_cws
from ..kernels.hamming_kernel import DEFAULT_BLOCK_M
from ..distributed.sharding import use_mesh
from ..launch.mesh import make_host_mesh
from ..models import model as M
from ..train.steps import make_decode_step, make_prefill_step


# ---------------------------------------------------------------------------
# ingest endpoints (the mutation surface a serving frontend would expose;
# the --ingest mode below drives them as a demo traffic generator)
# ---------------------------------------------------------------------------

def ingest_insert(index: SegmentedIndex, sketches: np.ndarray) -> np.ndarray:
    """Insert endpoint: (k, L) uint8 document sketches -> (k,) int64
    stable doc ids.  Sealing/merging happens inside the index without
    blocking concurrent searches."""
    return index.insert(sketches)


def ingest_delete(index: SegmentedIndex, doc_ids: np.ndarray) -> int:
    """Delete endpoint: tombstones doc ids, returns how many were newly
    removed.  O(k log n); compiled searchers stay warm (liveness is a
    traced argument, never a recompile)."""
    return index.delete(doc_ids)


def run_ingest(args) -> int:
    """--ingest mode: stream synthetic document sketches through the
    insert/delete endpoints and serve top-k queries mid-stream."""
    L, b = 32, 4
    rng = np.random.default_rng(args.seed)
    n = args.index_size
    docs = rng.integers(0, 1 << b, size=(n, L), dtype=np.uint8)
    index = SegmentedIndex(L, b, delta_cap=args.delta_cap,
                           block_m=args.block_m or DEFAULT_BLOCK_M)

    chunk = max(64, n // 16)
    t0 = time.time()
    ids = np.zeros((0,), np.int64)
    for lo in range(0, n, chunk):
        ids = np.concatenate(
            [ids, ingest_insert(index, docs[lo:lo + chunk])])
        if lo == chunk * 4:   # mid-stream query traffic
            qs = docs[rng.integers(0, lo, args.batch)]
            nn = index.topk_batch(qs, args.topk)
            st = index.stats()
            print(f"mid-stream topk over {st['n_live']} live docs "
                  f"({len(st['segments'])} segments + {st['delta_rows']} "
                  f"delta rows): tau*={nn.tau}")
    dt = time.time() - t0
    print(f"ingested {n} docs in {dt:.2f}s ({n / dt:.0f} inserts/s, "
          f"{index.counters['merges']} background merges)")

    removed = ingest_delete(index, ids[rng.choice(n, n // 8, replace=False)])
    index.flush()
    index.maybe_merge()
    index.compact(min_dead_frac=0.25)
    st = index.stats()
    print(f"deleted {removed}; stack now {st['segments']} "
          f"(space {st['space_bits'] / 8 / 1024:.1f} KiB incl. tombstones)")

    qs = docs[rng.integers(0, n, args.batch)]
    t0 = time.time()
    nn = index.topk_batch(qs, args.topk)
    dt = time.time() - t0
    for r in range(min(args.batch, 4)):
        print(f"  request {r}: top-{args.topk} docs {np.asarray(nn.ids[r])} "
              f"at distances {np.asarray(nn.dists[r])} (tau*={nn.tau})")
    print(f"post-merge batched topk: {dt / args.batch * 1e3:.1f} ms/query")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--retrieval", action="store_true")
    ap.add_argument("--ingest", action="store_true",
                    help="streaming-ingest retrieval plane: dynamic "
                         "segmented index + insert/delete endpoints "
                         "(model-free; see DESIGN.md §4)")
    ap.add_argument("--delta-cap", type=int, default=2048,
                    help="delta-buffer rows before a segment seals "
                         "(--ingest)")
    ap.add_argument("--index-size", type=int, default=4096)
    ap.add_argument("--tau", type=int, default=3)
    ap.add_argument("--topk", type=int, default=3,
                    help="k nearest documents returned per request")
    ap.add_argument("--block-m", type=int, default=None,
                    help="query-tile size of the batched verify kernel "
                         "(default: kernel DEFAULT_BLOCK_M)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.ingest:
        return run_ingest(args)

    cfg = get_config(args.arch, smoke=args.smoke)
    if not cfg.causal or cfg.inputs_embeds:
        print(f"{args.arch} is encoder-only: no autoregressive serving "
              "(see DESIGN.md §Arch-applicability)")
        return 0
    mesh = make_host_mesh()
    dtype = jnp.float32 if jax.default_backend() == "cpu" else jnp.bfloat16

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    s_max = args.prompt_len + args.gen_len

    with use_mesh(mesh):
        params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
        prefill = jax.jit(make_prefill_step(cfg, s_max=s_max,
                                            compute_dtype=dtype))
        decode = jax.jit(make_decode_step(cfg, compute_dtype=dtype))

        t0 = time.time()
        logits, cache, cache_len = prefill(params, {"tokens": prompts})
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        generated = [tok]
        for i in range(args.gen_len - 1):
            logits, cache = decode(params, tok, cache, cache_len + i)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            generated.append(tok)
        out = jnp.concatenate(generated, axis=1)
        out.block_until_ready()
        dt = time.time() - t0
        total_tokens = args.batch * args.gen_len
        print(f"served {args.batch} requests x {args.gen_len} tokens "
              f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s incl. compile)")
        print("sample continuation ids:", np.asarray(out[0][:12]))

        if args.retrieval:
            # the paper's technique as the retrieval plane: hidden-state
            # sketches -> bST Hamming search
            L, b = 32, 4
            key = jax.random.PRNGKey(7)
            docs = rng.random((args.index_size, 64)).astype(np.float32)
            doc_sk = np.asarray(zbit_cws(key, jnp.asarray(docs), L=L, b=b))
            index = build_bst(doc_sk, b)
            # query: final hidden state of each request, hashed the same way
            h = jax.nn.softmax(logits, axis=-1) @ params[
                "embed" if "embed" in params else "lm_head"].astype(jnp.float32)
            q = jnp.abs(h[:, :64]) if h.shape[-1] >= 64 else jnp.pad(
                jnp.abs(h), ((0, 0), (0, 64 - h.shape[-1])))
            q_sk = zbit_cws(key, q, L=L, b=b)
            # natively batched searcher: the whole request batch shares
            # one 2D-frontier traversal + one query-tiled verify scan
            block_m = args.block_m or DEFAULT_BLOCK_M
            res = make_batch_searcher(index, args.tau, block_m=block_m)(q_sk)
            hits = np.asarray(res.mask).sum(axis=1)
            print(f"retrieval: tau={args.tau} hits per request: {hits} "
                  f"(batched verify tile block_m={block_m})")
            # top-k nearest documents (τ-escalation ladder + exact
            # distances out of the same compiled searcher cache)
            nn = topk_batch(index, q_sk, args.topk, block_m=block_m)
            for r in range(args.batch):
                print(f"  request {r}: top-{args.topk} docs "
                      f"{np.asarray(nn.ids[r])} at distances "
                      f"{np.asarray(nn.dists[r])} (tau*={nn.tau})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
