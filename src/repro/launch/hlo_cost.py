"""While-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 23 units reports 1/23rd of the real FLOPs (verified
empirically; see EXPERIMENTS.md §Dry-run).  The same undercount applies
to bytes and — critically — to collectives living inside the scanned
layer body.  This module parses the post-SPMD HLO, recovers while-loop
trip counts from their condition computations, and walks the call graph
multiplying by trips:

  flops      — dot ops: 2 * prod(result) * prod(contracted dims); other
               ops approx 1 flop/output element (elementwise dominates
               nothing here, but keeps Tc honest for VPU-ish cells);
  bytes      — per top-level instruction: operand results + own result
               (fusions count at the fusion boundary — the post-fusion
               HBM-traffic view, same convention as XLA's analysis);
  collectives— operand bytes by kind, times enclosing trip counts.

All numbers are per-device (post-partitioning shapes).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[\w\[\],{}]+))\s+"
    r"([\w\-]+)\((.*?)\)(,.*|\s*)$")
_TRIP_CFG_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_ATTR_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_ATTR_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_ATTR_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_ATTR_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    op: str            # base op (suffix digits and -start stripped)
    operands: List[str]
    args: str          # raw operand-list text (constants carry values here)
    tail: str          # attribute text after the operand list


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]

    def instr_shapes(self) -> Dict[str, str]:
        return {i.name: i.shape_str for i in self.instrs}


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    current: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if current is None:
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                current = Computation(name=m.group(2), instrs=[])
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.strip() == "}":
            comps[current.name] = current
            current = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_str, op, args, tail = m.groups()
        base = op.rstrip("0123456789.")
        if base.endswith("-start"):
            base = base[:-len("-start")]
        # operand refs only from the argument list (not attrs)
        operands = _OPERAND_RE.findall(args)
        current.instrs.append(Instr(name=name, shape_str=shape_str,
                                    op=base, operands=operands, args=args,
                                    tail=tail))
    return comps, entry


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    coll_count: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})

    def add(self, other: "Cost", times: float = 1.0) -> None:
        self.flops += times * other.flops
        self.bytes += times * other.bytes
        for k in COLLECTIVE_KINDS:
            self.coll_bytes[k] += times * other.coll_bytes[k]
            self.coll_count[k] += times * other.coll_count[k]

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "copy-done", "all-gather-done",
                   "all-reduce-done", "collective-permute-done", "after-all",
                   "partition-id", "replica-id", "copy-start"}


def _dot_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    result_elems = _shape_elems(instr.shape_str)
    m = _LHS_CDIMS_RE.search(instr.tail)
    contracted = 1
    if m and instr.operands:
        lhs_shape = shapes.get(instr.operands[0], "")
        dims_list = _shape_dims(lhs_shape)
        if dims_list:
            _, dims = dims_list[0]
            for idx in (int(d) for d in m.group(1).split(",") if d):
                if idx < len(dims):
                    contracted *= dims[idx]
    return 2.0 * result_elems * contracted


def _trip_count(cond: Computation,
                comps: Dict[str, "Computation"]) -> float:
    """Max integer constant in the loop condition — canonical jax scans
    compare the induction variable against the trip count (the constant
    may live one call level down, inside a wrapped-compare fusion)."""
    def scan(comp: Computation) -> int:
        best = 0
        for instr in comp.instrs:
            if instr.op == "constant":
                try:
                    best = max(best, int(instr.args))
                except ValueError:
                    pass
            for attr_re in (_ATTR_CALLS_RE, _ATTR_APPLY_RE):
                m = attr_re.search(instr.tail)
                if m and m.group(1) in comps:
                    best = max(best, scan(comps[m.group(1)]))
        return best
    return float(max(scan(cond), 1))


class ModuleCost:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_module(hlo_text)
        # constants defined as `%c = s32[] constant(8)` parse with
        # op=constant and the value inside the "args" — recover from raw
        # text once, for trip counts:
        self._memo: Dict[str, Cost] = {}
        self._fusion_flops_memo: Dict[str, float] = {}
        self._fusion_util_memo: Dict[str, Dict[int, float]] = {}

    # ------------------------------------------------------------------
    # operand utilization: a fusion parameter consumed only by
    # dynamic-slice/gather reads just the sliced rows, not the whole
    # array (the stacked-layer weights inside a scanned body are the
    # canonical case: without this, every loop iteration would "read"
    # all 30 layers).  A parameter that is the in-place target of a
    # dynamic-update-slice costs ~2x the update (read+write of the
    # region), not the whole buffer.
    # ------------------------------------------------------------------
    def _fusion_param_util(self, comp_name: str) -> Dict[int, float]:
        """parameter index -> bytes actually touched (absent = full)."""
        if comp_name in self._fusion_util_memo:
            return self._fusion_util_memo[comp_name]
        out: Dict[int, float] = {}
        comp = self.comps.get(comp_name)
        if comp is not None:
            param_idx: Dict[str, int] = {}
            for instr in comp.instrs:
                if instr.op == "parameter":
                    m = re.match(r"(\d+)", instr.args)
                    if m:
                        param_idx[instr.name] = int(m.group(1))
            uses: Dict[str, List[Instr]] = {p: [] for p in param_idx}
            for instr in comp.instrs:
                for o in instr.operands:
                    if o in uses:
                        uses[o].append(instr)
            for pname, users in uses.items():
                if not users:
                    continue
                if all(u.op in ("dynamic-slice", "gather") for u in users):
                    out[param_idx[pname]] = sum(
                        _shape_bytes(u.shape_str) for u in users)
                elif all(u.op == "dynamic-update-slice"
                         and u.operands and u.operands[0] == pname
                         for u in users):
                    upd = 0.0
                    shapes = comp.instr_shapes()
                    for u in users:
                        if len(u.operands) > 1:
                            upd += 2 * _shape_bytes(
                                shapes.get(u.operands[1], ""))
                    out[param_idx[pname]] = upd
        self._fusion_util_memo[comp_name] = out
        return out

    # fused computations: only dots inside contribute flops; bytes are
    # accounted at the fusion boundary by the caller.
    def _fused_flops(self, comp_name: str) -> float:
        if comp_name in self._fusion_flops_memo:
            return self._fusion_flops_memo[comp_name]
        comp = self.comps.get(comp_name)
        total = 0.0
        if comp is not None:
            shapes = comp.instr_shapes()
            for instr in comp.instrs:
                if instr.op == "dot":
                    total += _dot_flops(instr, shapes)
                elif instr.op == "fusion":
                    m = _ATTR_CALLS_RE.search(instr.tail)
                    if m:
                        total += self._fused_flops(m.group(1))
                else:
                    total += _shape_elems(instr.shape_str)  # ~1 flop/elem
        self._fusion_flops_memo[comp_name] = total
        return total

    def comp_cost(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        self._memo[comp_name] = Cost()  # break cycles defensively
        comp = self.comps.get(comp_name)
        cost = Cost()
        if comp is None:
            return cost
        shapes = comp.instr_shapes()

        def operand_bytes(instr: Instr) -> float:
            return sum(_shape_bytes(shapes.get(o, "")) for o in instr.operands)

        for instr in comp.instrs:
            if instr.op == "while":
                body = _ATTR_BODY_RE.search(instr.tail)
                cond = _ATTR_COND_RE.search(instr.tail)
                cfg_m = _TRIP_CFG_RE.search(instr.tail)
                if cfg_m:  # XLA annotates known trip counts — trust it
                    trips = float(cfg_m.group(1))
                elif cond and cond.group(1) in self.comps:
                    trips = _trip_count(self.comps[cond.group(1)], self.comps)
                else:
                    trips = 1.0
                if body:
                    cost.add(self.comp_cost(body.group(1)), times=trips)
                continue
            if instr.op in ("call", "conditional", "async-start"):
                m = _ATTR_APPLY_RE.search(instr.tail) or \
                    _ATTR_CALLS_RE.search(instr.tail)
                if m:
                    cost.add(self.comp_cost(m.group(1)))
                continue
            if instr.op == "fusion":
                m = _ATTR_CALLS_RE.search(instr.tail)
                util: Dict[int, float] = {}
                if m:
                    cost.flops += self._fused_flops(m.group(1))
                    util = self._fusion_param_util(m.group(1))
                if "dynamic-update-slice" in instr.name:
                    # in-place update: result aliases the big buffer; the
                    # traffic is ~2x the update (read+write of the region)
                    op_bytes = [_shape_bytes(shapes.get(o, ""))
                                for o in instr.operands]
                    if op_bytes:
                        update = sum(op_bytes) - max(op_bytes)
                        cost.bytes += 2 * update
                    continue
                if "dynamic-slice" in instr.name and "dot" not in instr.name:
                    cost.bytes += 2 * _shape_bytes(instr.shape_str)
                    continue
                ob = 0.0
                for i_op, o in enumerate(instr.operands):
                    ob += util.get(i_op, _shape_bytes(shapes.get(o, "")))
                cost.bytes += ob + _shape_bytes(instr.shape_str)
                continue
            if instr.op in ("dynamic-slice", "gather"):
                cost.bytes += 2 * _shape_bytes(instr.shape_str)
                continue
            if instr.op == "dynamic-update-slice":
                upd = (_shape_bytes(shapes.get(instr.operands[1], ""))
                       if len(instr.operands) > 1 else 0)
                cost.bytes += 2 * upd
                continue
            if instr.op in COLLECTIVE_KINDS:
                ob = operand_bytes(instr) or _shape_bytes(instr.shape_str)
                cost.coll_bytes[instr.op] += ob
                cost.coll_count[instr.op] += 1
                cost.bytes += ob + _shape_bytes(instr.shape_str)
                continue
            if instr.op == "dot":
                cost.flops += _dot_flops(instr, shapes)
                cost.bytes += operand_bytes(instr) + _shape_bytes(instr.shape_str)
                continue
            if instr.op in _SKIP_BYTES_OPS:
                continue
            # generic op: ~1 flop per output element + boundary bytes
            cost.flops += _shape_elems(instr.shape_str)
            cost.bytes += operand_bytes(instr) + _shape_bytes(instr.shape_str)

        self._memo[comp_name] = cost
        return cost

    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze_hlo(hlo_text: str) -> Cost:
    return ModuleCost(hlo_text).entry_cost()
