"""Training driver: ``python -m repro.launch.train --arch smollm-135m``.

Runs a real training loop on whatever devices this host has (the
production meshes are exercised by the dry-run).  Wires together the
full substrate: sketch-dedup'd data pipeline, sharded params/optimizer,
microbatched train step, async checkpointing, failure-injection drills,
and the straggler monitor.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import ARCH_IDS, get_config
from ..data.pipeline import DataConfig, SketchDedupPipeline
from ..distributed.checkpoint import AsyncCheckpointer
from ..distributed.fault_tolerance import (FailurePlan, SimulatedFailure,
                                           StragglerMonitor, resume_or_init)
from ..distributed.sharding import use_mesh
from ..launch.mesh import make_host_mesh
from ..models import model as M
from ..optim.adamw import Hyper, abstract_opt_state, adamw_init
from ..train.steps import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--dedup", action="store_true",
                    help="near-duplicate-filter batches through bST")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a failure at this step (restart drill)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh()
    hyper = Hyper(base_lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                  total_steps=args.steps)
    data = SketchDedupPipeline(
        DataConfig(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                   seed=args.seed, dedup=args.dedup,
                   embeds_dim=cfg.d_model if cfg.inputs_embeds else 0))
    step_fn = jax.jit(make_train_step(
        cfg, hyper, num_microbatches=args.microbatches,
        compute_dtype=jnp.float32 if jax.default_backend() == "cpu"
        else jnp.bfloat16))

    abstract = M.abstract_params(cfg)
    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    plan = FailurePlan(args.fail_at) if args.fail_at >= 0 else None
    monitor = StragglerMonitor(n_workers=1)

    def init():
        return M.init_params(jax.random.PRNGKey(args.seed), cfg)

    with use_mesh(mesh):
        if args.ckpt_dir:
            state_abs = {"params": abstract,
                         "opt": abstract_opt_state(abstract)}
            state, start = resume_or_init(
                args.ckpt_dir, state_abs,
                lambda: {"params": init(), "opt": None}, mesh=None)
            params = state["params"]
            opt = state["opt"] if start else adamw_init(params)
            if start:
                print(f"[resume] from step {start}")
        else:
            params, opt, start = init(), None, 0
            opt = adamw_init(params)

        t_last = time.time()
        for step in range(start, args.steps):
            if plan is not None:
                try:
                    plan.maybe_fail(step)
                except SimulatedFailure as e:
                    print(f"[drill] {e}; exiting non-zero for the restart "
                          "wrapper")
                    if ckpt:
                        ckpt.wait()
                    return 13
            batch = data.batch_for_step(step)
            params, opt, metrics = step_fn(params, opt, batch)
            if (step + 1) % args.log_every == 0 or step == start:
                dt = time.time() - t_last
                t_last = time.time()
                monitor.observe([dt])
                print(f"step {step + 1:5d}  loss {float(metrics['loss']):.4f}"
                      f"  gnorm {float(metrics['grad_norm']):.3f}"
                      f"  lr {float(metrics['lr']):.2e}  ({dt:.2f}s)",
                      flush=True)
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt})
        if ckpt:
            ckpt.wait()
    print("train: done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
