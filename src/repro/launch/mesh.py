"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (jax locks the device count on first backend
init — the dry-run must set XLA_FLAGS before that happens)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 = 256 chips per pod; the multi-pod
    variant prepends a pure-DP "pod" axis (2 pods = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (smoke tests, examples): all local
    devices on a ("data",) axis."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def dp_shards(mesh) -> int:
    """Number of data-parallel shards (pod x data axes)."""
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
