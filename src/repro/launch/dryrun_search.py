import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run for the PAPER-TECHNIQUE cell: the sharded bST similarity
search lowered + compiled on the production mesh, one trie shard per
chip (512 shards on the multi-pod mesh).

The index arrays are passed as sharded *arguments* (shard axis split
over every mesh axis), so under GSPMD each device traverses exactly its
own trie; the only collective is the final result all-gather.  Records
the same JSON schema as the LM cells into the dry-run results dir.

    python -m repro.launch.dryrun_search [--mesh both] [--n 131072]
"""

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import distributed_search as ds
from ..launch import hlo_cost
from ..launch.mesh import make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--n", type=int, default=1 << 17)
    ap.add_argument("--L", type=int, default=32)
    ap.add_argument("--b", type=int, default=4)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--verify", default="scan", choices=["gather", "scan"])
    ap.add_argument("--caps", default="worst", choices=["worst", "expected"])
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args(argv)

    assert len(jax.devices()) == 512
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    rng = np.random.default_rng(0)
    db = rng.integers(0, 1 << args.b, size=(args.n, args.L), dtype=np.uint8)

    os.makedirs(args.out, exist_ok=True)
    for mesh_name in meshes:
        multi = mesh_name == "multi"
        mesh = make_production_mesh(multi_pod=multi)
        n_shards = mesh.devices.size
        print(f"[search-cell] building {n_shards} trie shards ...", flush=True)
        t0 = time.time()
        index = ds.build_sharded_bst(db, args.b, n_shards)
        t_build = time.time() - t0

        from jax.sharding import NamedSharding, PartitionSpec as P
        axes = tuple(mesh.axis_names)
        shard0 = NamedSharding(mesh, P(axes))     # dim0 over ALL mesh axes
        repl = NamedSharding(mesh, P())

        t_max = tuple(int(x) for x in np.asarray(index.t).max(axis=0))
        caps = (ds.expected_caps(t_max, index.b, args.tau)
                if args.caps == "expected"
                else ds.frontier_capacities(t_max, index.b, args.tau, 1 << 14))

        arrays = {
            "levels": tuple(
                (lv.words, lv.cum, lv.labels) if lv.kind == "list"
                else (lv.words, lv.cum) if lv.kind == "table" else ()
                for lv in index.levels),
            "t": index.t, "pv": index.paths_vert,
            "dw": index.d_words, "dc": index.d_cum,
            "lr": index.leaf_root, "il": index.id_leaf, "nl": index.n_local,
        }
        arr_specs = jax.tree_util.tree_map(
            lambda a: NamedSharding(
                mesh, P(axes) if a.shape[0] == n_shards else P()), arrays)

        def search(arr, queries):
            def per_query(q):
                masks, dists, ov = jax.vmap(
                    lambda levels, t_row, pv, dw, dc, lr, il, nl:
                    ds._shard_search(index, levels, t_row, pv, dw, dc, lr,
                                     il, nl, q, args.tau, caps,
                                     verify=args.verify)
                )(arr["levels"], arr["t"], arr["pv"], arr["dw"], arr["dc"],
                  arr["lr"], arr["il"], arr["nl"])
                return masks, dists, ov.sum()
            masks, dists, ovs = jax.vmap(per_query)(queries)
            return masks, dists, ovs.sum()

        q_abs = jax.ShapeDtypeStruct((args.queries, args.L), jnp.uint8)
        arr_abs = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), arrays)

        t0 = time.time()
        with mesh:
            jitted = jax.jit(search, in_shardings=(arr_specs, repl))
            lowered = jitted.lower(arr_abs, q_abs)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        cost = hlo_cost.analyze_hlo(compiled.as_text())
        try:
            ma = compiled.memory_analysis()
            mem = {"argument_bytes": int(ma.argument_size_in_bytes),
                   "temp_bytes": int(ma.temp_size_in_bytes)}
            mem["total_bytes"] = mem["argument_bytes"] + mem["temp_bytes"]
        except Exception as e:
            mem = {"error": repr(e)}
        roof = {
            "t_compute_s": cost.flops / 197e12,
            "t_memory_s": cost.bytes / 819e9,
            "t_collective_s": cost.total_coll_bytes / (4 * 50e9),
        }
        terms = {"compute": roof["t_compute_s"],
                 "memory": roof["t_memory_s"],
                 "collective": roof["t_collective_s"]}
        roof["bottleneck"] = max(terms, key=terms.get)
        record = {
            "arch": "bst-sharded-search", "shape": f"n{args.n}_q{args.queries}_tau{args.tau}_{args.verify}_{args.caps}",
            "mesh": "2x16x16" if multi else "16x16", "chips": n_shards,
            "kind": "search", "status": "ok",
            "build_s": round(t_build, 1), "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "hlo_cost": {"flops": cost.flops, "bytes": cost.bytes},
            "collectives": {
                "bytes_by_kind": {k: int(v) for k, v in cost.coll_bytes.items()},
                "count_by_kind": {k: int(v) for k, v in cost.coll_count.items()},
                "total_bytes": int(cost.total_coll_bytes)},
            "memory": mem,
            "roofline": roof,
        }
        tag = f"{record['mesh']}__bst-sharded-search__{record['shape']}"
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(record, f, indent=1)
        print(f"  ok: build {t_build:.1f}s lower {t_lower:.1f}s compile "
              f"{t_compile:.1f}s | Tm {roof['t_memory_s']:.5f} "
              f"Tcoll {roof['t_collective_s']:.5f} | mem "
              f"{mem.get('total_bytes', 0) / 1e6:.1f} MB", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
